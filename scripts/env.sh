# Environment hygiene for JAX serving runs. SOURCE this (don't execute):
#
#   source scripts/env.sh                       # hygiene only
#   REPRO_HOST_DEVICES=4 source scripts/env.sh  # + N forced host devices
#
# Factored out of scripts/ci.sh so accelerator hosts, cron benchmarks and
# one-off shells all get the same discipline the exemplar JAX serving
# setups use (SNIPPETS.md snippets 2-3, the HomebrewNLP/olmax run.sh):
#
#  * TF_CPP_MIN_LOG_LEVEL=4  — silence the TF/XLA C++ log spew that
#    drowns a gate's own output.
#  * tcmalloc via LD_PRELOAD  — glibc malloc fragments long-lived
#    benchmark processes; preloaded only when the library actually
#    exists (an unconditional preload breaks every subprocess on hosts
#    without it), and never clobbers a caller's own LD_PRELOAD.
#  * TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD — stops tcmalloc from
#    narrating every multi-GB arena growth during big stacked launches.
#  * XLA_FLAGS --xla_force_host_platform_device_count=$REPRO_HOST_DEVICES
#    — splits one CPU host into N real jax devices. This is what makes
#    `GPUPool(device_backend="jax")` / `scripts/ci.sh --sharded` exercise
#    true multi-device placement on a CPU-only box. MUST be exported
#    before the first jax backend touch: XLA reads the flags exactly
#    once, so set it here (or via launch.host_mesh.ensure_host_devices
#    at the very top of a python entry point), not mid-process.
#
# Everything respects values the caller already exported.

export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

if [ -z "${LD_PRELOAD:-}" ]; then
    for _tcm in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
                /usr/lib/libtcmalloc.so.4; do
        if [ -f "$_tcm" ]; then
            export LD_PRELOAD="$_tcm"
            break
        fi
    done
    unset _tcm
fi
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"

# Optional: force an N-device host platform for sharded serving work.
# Appends to (rather than replaces) any XLA_FLAGS already set, dropping a
# stale device-count flag first so the surviving value is unambiguous.
if [ -n "${REPRO_HOST_DEVICES:-}" ]; then
    _flags="$(printf '%s' "${XLA_FLAGS:-}" \
        | sed 's/--xla_force_host_platform_device_count=[0-9]*//g')"
    export XLA_FLAGS="${_flags:+$_flags }--xla_force_host_platform_device_count=${REPRO_HOST_DEVICES}"
    unset _flags
fi
