"""Dev shakeout for the AMS simulation world (short video, all schemes)."""
import time

import numpy as np

from repro.core.server import AMSConfig
from repro.data.video import VideoConfig
from repro.sim.runner import SCHEMES, SimConfig, run_scheme
from repro.sim.seg_world import SegWorld, pretrain_student

t0 = time.time()
vcfg = VideoConfig(height=48, width=48, fps=4.0, duration=120.0, seed=7,
                   drift_period=90.0)
world = SegWorld.make(vcfg)
pre = pretrain_student(world.seg_cfg, n_videos=3, steps=60,
                       video_kw=dict(height=48, width=48, fps=4.0, duration=60.0))
print(f"pretrain done {time.time()-t0:.1f}s")

ams_cfg = AMSConfig(t_update=10.0, t_horizon=60.0, k_iters=8, batch_size=4,
                    gamma=0.05, phi_target=0.04)
sim = SimConfig(eval_stride=4)

for scheme in SCHEMES:
    t1 = time.time()
    r = run_scheme(scheme, world, pre, ams_cfg, sim)
    up, down = r.bandwidth_kbps(vcfg.duration)
    print(f"{scheme:16s} mIoU={r.mean_miou:.3f} up={up:7.1f}Kbps down={down:7.1f}Kbps "
          f"updates={r.updates} ({time.time()-t1:.1f}s)")
