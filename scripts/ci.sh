#!/usr/bin/env bash
# CI entry point: tier-1 tests + a fast serving-runtime smoke.
# Run from the repo root:  bash scripts/ci.sh
#
# The gate must be green on a clean tree, so the one module that is
# known-red in accelerator-less containers (tests/test_dryrun_small.py —
# 7 env failures, present since the seed; see ROADMAP) is excluded from
# the gating run. tests/test_kernels.py rejoined the gate in PR 7 (its
# failures were a pltpu.CompilerParams rename, fixed with a compat
# shim). Run the full tier-1 command
# (`PYTHONPATH=src python -m pytest -x -q`) on accelerator hosts.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Environment hygiene lives in scripts/env.sh (sourceable on its own for
# accelerator hosts / one-off shells): TF log silencing, guarded tcmalloc
# preload, and — when REPRO_HOST_DEVICES is set — the XLA_FLAGS forced
# host-platform device count the sharded gate runs under.
. "$(dirname "$0")/env.sh"

# `bash scripts/ci.sh --kernels` runs ONLY the Pallas kernel gate (fast
# local loop for kernel work); the full run includes it as its last gate.
if [ "${1:-}" = "--kernels" ]; then
    echo "== kernel gate: benchmarks.kernels_bench --kernels =="
    python -m benchmarks.kernels_bench --kernels
    exit $?
fi

# `bash scripts/ci.sh --chaos` runs ONLY the chaos gate (fast local loop
# for fault-injection work); the full run includes it below.
if [ "${1:-}" = "--chaos" ]; then
    echo "== chaos gate: benchmarks.serving_scale --smoke --chaos =="
    python -m benchmarks.serving_scale --smoke --chaos
    exit $?
fi

# `bash scripts/ci.sh --fleet` runs ONLY the fleet control-plane gate (fast
# local loop for FleetState / cohort-event work); the full run includes it.
if [ "${1:-}" = "--fleet" ]; then
    echo "== fleet gate: benchmarks.serving_scale --smoke --fleet =="
    python -m benchmarks.serving_scale --smoke --fleet
    exit $?
fi

# `bash scripts/ci.sh --sharded` runs ONLY the sharded-execution gate in a
# child process with 4 forced host devices (the flag must be set before
# jax initializes, so it cannot ride inside an already-warm process); the
# full run includes it below.
if [ "${1:-}" = "--sharded" ]; then
    echo "== sharded gate: benchmarks.serving_scale --smoke --sharded (4 host devices) =="
    XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=4" \
        python -m benchmarks.serving_scale --smoke --sharded
    exit $?
fi

echo "== tier-1 gate: pytest (minus known env-red modules) =="
python -m pytest -q \
    --ignore=tests/test_dryrun_small.py
tier1=$?

echo "== serving smoke: benchmarks.serving_scale --smoke =="
python -m benchmarks.serving_scale --smoke
smoke=$?

echo "== multi-GPU serving smoke: benchmarks.serving_scale --smoke --gpus 4 =="
# asserts >=3x sustained-session scaling 1 -> 4 GPUs (fair policy) and that
# affinity-aware placement beats blind assignment; refreshes BENCH_serving.json
python -m benchmarks.serving_scale --smoke --gpus 4
pool_smoke=$?

echo "== fused-training smoke: benchmarks.serving_scale --smoke --fused =="
# asserts coalesced stacked train launches sustain MORE sessions on 1 GPU
# than the sequential engine, and that the real-math fused wall-clock for
# 8 seg sessions x one phase is <= 0.6x sequential; updates the
# fused_training section of BENCH_serving.json
python -m benchmarks.serving_scale --smoke --fused
fused_smoke=$?

echo "== update-pipeline smoke: benchmarks.serving_scale --smoke --update-pipeline =="
# asserts the fused post-train update pipeline (stacked selection + batched
# delta encode, amortized update_batch_s pricing) sustains at least as many
# sessions on one fused GPU as per-session pricing, that the real-math
# batched select+encode for 8 seg sessions is <= 0.6x sequential wall-clock
# with byte-identical wire deltas; updates the update_pipeline section of
# BENCH_serving.json
python -m benchmarks.serving_scale --smoke --update-pipeline
update_smoke=$?

echo "== dual-stream smoke: benchmarks.serving_scale --smoke --overlap =="
# asserts the dual-stream device model (label/train stream overlap with
# preemptible labeling launches) sustains STRICTLY more sessions on one
# fused GPU than the serialized single-clock baseline at the same mIoU
# target; records preemption + per-stream utilization telemetry in the
# dual_stream section of BENCH_serving.json
python -m benchmarks.serving_scale --smoke --overlap
overlap_smoke=$?

echo "== flight-recorder smoke: benchmarks.serving_scale --smoke --trace =="
# asserts a traced fused dual-stream run emits byte-identical, schema-valid
# Chrome trace JSON (required counter tracks, non-negative durations,
# per-stream serial execution, cross-stream concurrency bounds, grant
# nesting) without perturbing the schedule, then runs the modeled-vs-
# measured cost-model drift audit on the real fused math; writes the trace
# artifact and the observability section of BENCH_serving.json
trace_out="$(mktemp -t serving_trace.XXXXXX.json)"
python -m benchmarks.serving_scale --smoke --trace "$trace_out"
trace_smoke=$?
rm -f "$trace_out"

echo "== chaos smoke: benchmarks.serving_scale --smoke --chaos =="
# asserts the engine under the seeded reference FaultPlan (lossy links,
# uplink + downlink outages, a device crash, a thermal slowdown) conserves
# requests (enqueued == granted + dropped + queued), recovers every crashed
# grant via the gpu_done watchdog, retries lost uploads with backoff,
# supersedes stale deltas instead of blindly retransmitting, and holds the
# mean-mIoU gap vs the fault-free fleet within bound — while
# FaultPlan.none() stays bit-identical to running with no plan; writes the
# chaos section of BENCH_serving.json
python -m benchmarks.serving_scale --smoke --chaos
chaos_smoke=$?

echo "== fleet smoke: benchmarks.serving_scale --smoke --fleet =="
# asserts the struct-of-arrays FleetState control plane reproduces the
# per-object engine bit-for-bit at small n (fair/edf/gain x pool sizes x
# admission cap x reference FaultPlan, byte-identical FaultPlan.none()
# traces) and sustains >= 10x the per-object events/sec at 10^4 clients,
# then sweeps 10^3 -> 10^5 clients (the top point on O(1)-memory moments
# telemetry) into the fleet section of BENCH_serving.json
python -m benchmarks.serving_scale --smoke --fleet
fleet_smoke=$?

echo "== sharded smoke: benchmarks.serving_scale --smoke --sharded (4 host devices) =="
# asserts, with 4 forced host-platform devices (scripts/env.sh), that the
# sharded fused path (train_phases_sharded over GPUPool device_backend=jax)
# reproduces the single-device modeled path — selection/wire masks
# byte-identical, fp16 wire deltas within 1 ULP, per-device dispatch
# byte-identical — and measures sharded-vs-serial wall-clock (the speedup
# assertion engages only on multi-core hosts; a 1-core container cannot
# physically run 4 devices in parallel); writes the sharded section of
# BENCH_serving.json with the per-device modeled-vs-measured drift
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=4" \
    python -m benchmarks.serving_scale --smoke --sharded
sharded_smoke=$?

echo "== kernel gate: benchmarks.kernels_bench --kernels =="
# asserts the Pallas serving kernels against their XLA references on the
# real fused path: byte-identical selection/wire masks, fp16 wire-delta
# values within 1 ULP, byte-identical top-k masks, a recorded auto-mode
# dispatch race, and finite roofline-fraction fields written to the
# observability.kernels section of BENCH_serving.json
python -m benchmarks.kernels_bench --kernels
kernel_gate=$?

echo "tier-1 gate exit=$tier1, serving smoke exit=$smoke, pool smoke exit=$pool_smoke, fused smoke exit=$fused_smoke, update smoke exit=$update_smoke, overlap smoke exit=$overlap_smoke, trace smoke exit=$trace_smoke, chaos smoke exit=$chaos_smoke, fleet smoke exit=$fleet_smoke, sharded smoke exit=$sharded_smoke, kernel gate exit=$kernel_gate"
[ "$tier1" -eq 0 ] && [ "$smoke" -eq 0 ] && [ "$pool_smoke" -eq 0 ] && [ "$fused_smoke" -eq 0 ] && [ "$update_smoke" -eq 0 ] && [ "$overlap_smoke" -eq 0 ] && [ "$trace_smoke" -eq 0 ] && [ "$chaos_smoke" -eq 0 ] && [ "$fleet_smoke" -eq 0 ] && [ "$sharded_smoke" -eq 0 ] && [ "$kernel_gate" -eq 0 ] && echo "CI OK"
exit $((tier1 | smoke | pool_smoke | fused_smoke | update_smoke | overlap_smoke | trace_smoke | chaos_smoke | fleet_smoke | sharded_smoke | kernel_gate))
