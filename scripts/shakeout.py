"""Dev shakeout: forward + loss + prefill + decode for every smoke config."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke
from repro.models.registry import build

rng = jax.random.PRNGKey(0)
S, B = 32, 2

for arch in ARCH_IDS:
    cfg = get_smoke(arch)
    model = build(cfg)
    params = model.init(rng)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    memory = None
    if cfg.num_xattn_tokens:
        memory = jax.random.normal(rng, (B, cfg.num_xattn_tokens, cfg.d_model))
    logits, aux = model.forward(params, tokens, memory)
    assert logits.shape == (B, S, cfg.vocab_size), (arch, logits.shape)
    assert jnp.isfinite(logits).all(), arch
    loss, metrics = model.loss(params, {"tokens": tokens, "labels": tokens, "memory": memory})
    assert jnp.isfinite(loss), (arch, loss)
    # prefill + decode
    cache_len = S + 8
    lg, caches = model.prefill(params, tokens, cache_len, memory)
    assert lg.shape == (B, 1, cfg.vocab_size), (arch, lg.shape)
    lg2, caches2 = model.decode_step(params, caches, tokens[:, :1], jnp.int32(S))
    assert lg2.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(lg2).all(), arch
    # cache structure round-trips
    flat1 = jax.tree.leaves(caches)
    flat2 = jax.tree.leaves(caches2)
    assert len(flat1) == len(flat2)
    print(f"OK {arch:28s} params={model.num_params():,} loss={float(loss):.3f}")

print("ALL OK")
