"""Paper Fig. 8 (App. C): training-horizon vs accuracy — train the student on
[t - T_horizon, t), evaluate on [t, t + T_update)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import SEG_CFG, Timer, emit, pretrained, video_cfg
from repro.core.masked_adam import adam_update, init_state
from repro.metrics.miou import miou
from repro.sim.seg_world import SegWorld


def _probe(world, pre, t_sec: float, horizon: float, t_update: float,
           iters: int = 30, rng=None):
    fps = world.video.cfg.fps
    t_idx = int(t_sec * fps)
    h_idx = max(0, int((t_sec - horizon) * fps))
    train_idx = np.linspace(h_idx, t_idx - 1, min(24, t_idx - h_idx)).astype(int)
    frames = np.stack([world.video.frame(int(i))[0] for i in train_idx])
    labels = np.stack([world.teacher.label(int(i)) for i in train_idx])
    params = jax.tree.map(lambda x: x, pre)
    opt = init_state(params)
    for _ in range(iters):
        pick = rng.integers(0, len(train_idx), size=6)
        _, g = world.loss_and_grad(params, frames[pick], labels[pick])
        params, opt, _ = adam_update(params, g, opt, lr=1e-3)
    # evaluate on the future window
    scores = []
    for i in range(t_idx, int(t_idx + t_update * fps), 2):
        img, _ = world.video.frame(i)
        pred = np.asarray(world.predict(params, img[None])[0])
        scores.append(miou(pred, world.teacher.label(i), world.video.cfg.n_classes))
    return float(np.mean(scores))


def run(quick: bool = True, duration: float = 240.0):
    pre = pretrained()
    world = SegWorld.make(video_cfg(41, duration))
    rng = np.random.default_rng(0)
    horizons = (8.0, 32.0, 120.0)
    t_updates = (10.0, 30.0)
    probes = (80.0, 140.0, 200.0) if not quick else (120.0, 200.0)
    out = {}
    for h in horizons:
        for tu in t_updates:
            with Timer() as t:
                scores = [_probe(world, pre, ts, h, tu, rng=rng) for ts in probes]
            m = float(np.mean(scores))
            out[(h, tu)] = m
            emit(f"fig8.h{int(h)}.tu{int(tu)}", t.us, f"miou={m:.4f}")
    return out


if __name__ == "__main__":
    run()
