"""Formats the dry-run jsonl outputs into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS, emit


def load(path):
    with open(path) as f:
        return [json.loads(l) for l in f]


def table(rows) -> str:
    hdr = ("| arch | shape | variant | bottleneck | t_compute | t_memory | "
           "t_collective | useful FLOPs | args/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} | **{r['bottleneck']}** | "
            f"{r['t_compute_s']*1e3:.2f} ms | {r['t_memory_s']*1e3:.2f} ms | "
            f"{r['t_collective_s']*1e3:.2f} ms | {r['useful_flops_ratio']:.2f} | "
            f"{r['device_arg_bytes']/2**30:.2f} GiB |\n")
    return "".join(out)


def run(quick: bool = True):
    single = os.path.join(RESULTS, "roofline_single_pod.jsonl")
    if not os.path.exists(single):
        emit("roofline.report", 0.0, "missing=run dryrun --all first")
        return
    rows = load(single)
    md = ["# Roofline table (single-pod 16x16, TPU v5e constants)\n\n", table(rows)]
    optp = os.path.join(RESULTS, "roofline_optimized.jsonl")
    if os.path.exists(optp):
        orows = load(optp)
        md.append("\n# Optimized (§Perf levers: ring caches, m_bf16, moe_shard, decode_ep)\n\n")
        md.append(table(orows))
        base = {(r["arch"], r["shape"]): r for r in rows}
        md.append("\n## Dominant-term speedups vs baseline\n\n")
        md.append("| pair | baseline | optimized | speedup |\n|---|---|---|---|\n")
        def dom(r):
            return max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        for r in orows:
            b = base.get((r["arch"], r["shape"]))
            if b and dom(r) < 0.98 * dom(b):
                md.append(f"| {r['arch']} x {r['shape']} | {dom(b)*1e3:.2f} ms | "
                          f"{dom(r)*1e3:.2f} ms | {dom(b)/dom(r):.2f}x |\n")
    mp = os.path.join(RESULTS, "roofline_multi_pod.jsonl")
    if os.path.exists(mp):
        mrows = load(mp)
        md.append("\n# Multi-pod (2x16x16) lowering proof\n\n")
        md.append(table(mrows))
    out_path = os.path.join(RESULTS, "roofline.md")
    with open(out_path, "w") as f:
        f.write("".join(md))
    bottle = {}
    for r in rows:
        bottle[r["bottleneck"]] = bottle.get(r["bottleneck"], 0) + 1
    emit("roofline.report", 0.0,
         f"pairs={len(rows)};bottlenecks={bottle};out={os.path.relpath(out_path)}")


if __name__ == "__main__":
    run()
