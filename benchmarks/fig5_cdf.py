"""Paper Fig. 5: per-frame robustness — fraction of frames on which each
scheme beats No Customization."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit


def run(per_frame: dict | None = None, quick: bool = True):
    if per_frame is None:
        from benchmarks.table1_schemes import run as t1

        _, per_frame = t1(quick=quick)
    base = np.concatenate([np.asarray(v) for v in per_frame["no_custom"]])
    out = {}
    for scheme, frames in per_frame.items():
        if scheme == "no_custom":
            continue
        cur = np.concatenate([np.asarray(v) for v in frames])
        n = min(len(cur), len(base))
        frac = float((cur[:n] > base[:n]).mean())
        gain_p50 = float(np.median(cur[:n] - base[:n]))
        out[scheme] = (frac, gain_p50)
        emit(f"fig5.{scheme}", 0.0, f"frac_frames_improved={frac:.3f};"
             f"median_gain={gain_p50:+.4f}")
    return out


if __name__ == "__main__":
    run()
