"""Paper Fig. 3: adaptive sampling rate tracks scene change (stop-and-go)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, default_ams, emit, pretrained, video_cfg
from repro.data.video import stop_and_go
from repro.sim.runner import SimConfig, run_scheme
from repro.sim.seg_world import SegWorld


def run(quick: bool = True, duration: float = 180.0):
    pre = pretrained()
    vc = video_cfg(17, duration, motion_schedule=stop_and_go(duration * 0.33,
                                                             duration * 0.66))
    world = SegWorld.make(vc)
    with Timer() as t:
        # asr_eta=2: the compressed timescale needs a faster integral gain
        # for the controller to settle within the 60 s stop window
        r = run_scheme("ams", world, pre, default_ams(asr_eta=2.0),
                       SimConfig(eval_stride=6))
    hist = r.extras["history"]
    rates = [(h["t"], h["rate"]) for h in hist]
    mid = [r_ for tt, r_ in rates if duration * 0.4 < tt < duration * 0.66]
    moving = [r_ for tt, r_ in rates if tt < duration * 0.3 or tt > duration * 0.75]
    r_stop = float(np.mean(mid)) if mid else float("nan")
    r_stop_min = float(np.min(mid)) if mid else float("nan")
    r_move = float(np.mean(moving)) if moving else float("nan")
    emit("fig3.asr", t.us, f"rate_moving={r_move:.3f};rate_stopped={r_stop:.3f};"
         f"rate_stopped_min={r_stop_min:.3f};drops={r_stop < r_move}")
    return rates


if __name__ == "__main__":
    run()
