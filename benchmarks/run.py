"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

  PYTHONPATH=src python -m benchmarks.run            # quick suite
  PYTHONPATH=src python -m benchmarks.run --full     # longer sweeps
  PYTHONPATH=src python -m benchmarks.run --only table1,fig3
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

ALL = ("table1", "fig5", "table3", "fig3", "fig4", "fig6", "fig8",
       "serving_scale", "ablation_teacher", "kernels", "roofline")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated subset")
    args = ap.parse_args(argv)
    quick = not args.full
    only = set(args.only.split(",")) if args.only else set(ALL)

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []

    per_frame = None
    if "table1" in only:
        try:
            from benchmarks.table1_schemes import run as t1

            _, per_frame = t1(quick=quick)
        except Exception:
            failures.append(("table1", traceback.format_exc()))
    if "fig5" in only:
        try:
            from benchmarks.fig5_cdf import run as f5

            f5(per_frame=per_frame, quick=quick)
        except Exception:
            failures.append(("fig5", traceback.format_exc()))
    for name, mod in (("table3", "table3_selection"), ("fig3", "fig3_asr"),
                      ("fig4", "fig4_bw_sweep"), ("fig6", "fig6_multiclient"),
                      ("fig8", "fig8_horizon"),
                      ("serving_scale", "serving_scale"),
                      ("ablation_teacher", "ablation_teacher"),
                      ("kernels", "kernels_bench"),
                      ("roofline", "roofline_report")):
        if name not in only:
            continue
        try:
            module = __import__(f"benchmarks.{mod}", fromlist=["run"])
            module.run(quick=quick)
        except Exception:
            failures.append((name, traceback.format_exc()))

    print(f"# total {time.time()-t0:.1f}s, {len(failures)} failures", file=sys.stderr)
    for name, tb in failures:
        print(f"# FAILED {name}\n{tb}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
