"""Paper Fig. 4: accuracy vs downlink bandwidth — AMS sweeps T_update,
Just-In-Time sweeps its accuracy threshold."""
from __future__ import annotations

from benchmarks.common import Timer, default_ams, emit, pretrained, video_cfg
from repro.sim.runner import SimConfig, run_scheme
from repro.sim.seg_world import SegWorld


def run(quick: bool = True, duration: float = 120.0, seed: int = 11):
    pre = pretrained()
    pts = []
    t_updates = (10.0, 20.0, 40.0)
    # 0.60 is the matched-accuracy point vs AMS (paper methodology §4.1);
    # higher thresholds trace JIT's accuracy-vs-bandwidth curve upward.
    thresholds = (0.60, 0.75, 0.90) if quick else (0.55, 0.60, 0.70, 0.80, 0.90)
    for tu in t_updates:
        world = SegWorld.make(video_cfg(seed, duration))
        with Timer() as t:
            r = run_scheme("ams", world, pre, default_ams(t_update=tu),
                           SimConfig(eval_stride=4), seed=seed)
        _, down = r.bandwidth_kbps(duration)
        pts.append(("ams", tu, r.mean_miou, down))
        emit(f"fig4.ams.tu{int(tu)}", t.us, f"miou={r.mean_miou:.4f};down_kbps={down:.1f}")
    for th in thresholds:
        world = SegWorld.make(video_cfg(seed, duration))
        sim = SimConfig(eval_stride=4, jit_threshold=th)
        with Timer() as t:
            r = run_scheme("jit", world, pre, default_ams(), sim, seed=seed)
        _, down = r.bandwidth_kbps(duration)
        pts.append(("jit", th, r.mean_miou, down))
        emit(f"fig4.jit.th{int(th*100)}", t.us,
             f"miou={r.mean_miou:.4f};down_kbps={down:.1f}")
    return pts


if __name__ == "__main__":
    run()
