"""Paper Table 1/2: scheme comparison (mIoU, uplink/downlink Kbps)."""
from __future__ import annotations

from benchmarks.common import Timer, default_ams, emit, pretrained, video_cfg
from repro.sim.runner import SCHEMES, SimConfig, run_scheme
from repro.sim.seg_world import SegWorld


def run(quick: bool = True, duration: float = 120.0, seeds=(11, 23)):
    if quick:
        seeds = seeds[:2]
    pre = pretrained()
    sim = SimConfig(eval_stride=4)
    rows = {}
    per_frame = {}
    for scheme in SCHEMES:
        mious, ups, downs, updates = [], [], [], []
        frames_all = []
        for seed in seeds:
            world = SegWorld.make(video_cfg(seed, duration))
            with Timer() as t:
                r = run_scheme(scheme, world, pre, default_ams(), sim, seed=seed)
            up, down = r.bandwidth_kbps(duration)
            mious.append(r.mean_miou)
            ups.append(up)
            downs.append(down)
            updates.append(r.updates)
            frames_all.append(r.miou_per_frame)
        m = sum(mious) / len(mious)
        u = sum(ups) / len(ups)
        d = sum(downs) / len(downs)
        rows[scheme] = (m, u, d, sum(updates))
        per_frame[scheme] = frames_all
        emit(f"table1.{scheme}", t.us, f"miou={m:.4f};up_kbps={u:.1f};down_kbps={d:.1f};"
             f"updates={sum(updates)}")
    return rows, per_frame


if __name__ == "__main__":
    run()
