"""Paper Fig. 6/10: accuracy degradation as clients share the server GPUs.

Three sweeps on the event-driven serving runtime (`repro.serving`):
  1. client count x ATR on/off under the fair policy (the seed's sweep);
  2. scheduler comparison (fair / EDF / gain-aware) at the saturating client
     count — the gain-aware policy reclaims cycles from near-static feeds,
     so it should match or beat fair round-robin on mean mIoU while the
     network columns show real (nonzero-latency) delta delivery;
  3. GPU-count sweep — the saturating fleet doubled onto a 4-GPU pool,
     affinity-blind (gain) vs residency-aware (affinity) placement: the
     affinity policy avoids most weight-migration stalls, so it should beat
     blind assignment on mean mIoU (or phases served) at n_gpus=4.
"""
from __future__ import annotations

from benchmarks.common import SEG_CFG, Timer, default_ams, emit, pretrained


def _row(r: dict) -> str:
    up, down = r["mean_up_kbps"], r["mean_down_kbps"]
    return (f"miou={r['mean_miou']:.4f};gpu_util={r['gpu_utilization']:.2f};"
            f"deferred={r['phases_deferred']};drop={r['dropped_requests']};"
            f"up_kbps={up:.1f};down_kbps={down:.1f};"
            f"delta_lat_s={r['delta_latency_mean_s']:.3f}")


def run(quick: bool = True, duration: float = 100.0):
    from repro.sim.multiclient import run_multiclient

    pre = pretrained()
    counts = (1, 4, 8) if quick else (1, 2, 4, 6, 8, 10)
    video_kw = dict(height=48, width=48, fps=4.0)
    out = {}
    base = None
    us = {}

    # -- sweep 1: saturation with/without ATR (fair policy) ---------------
    for atr in (False, True):
        for n in counts:
            # asr_eta=2: stationary feeds must reach the slowdown band
            # (r < 0.25 fps) within the compressed run for ATR to act
            cfg = default_ams(atr_enabled=atr, asr_eta=2.0)
            with Timer() as t:
                r = run_multiclient(n, pre, SEG_CFG, cfg, duration=duration,
                                    video_kw=video_kw)
            if base is None:
                base = r["mean_miou"]
            key = f"fig6.{'atr' if atr else 'noatr'}.n{n}"
            out[(atr, n)] = r
            us[(atr, n)] = t.us
            emit(key, t.us, f"{_row(r)};degradation={base - r['mean_miou']:+.4f}")

    # -- sweep 2: scheduling policies at the saturating count -------------
    n_sat = max(counts)
    for policy in ("fair", "edf", "gain"):
        if policy == "fair":
            # identical config to the noatr/n_sat run above and the engine
            # is deterministic — reuse instead of re-simulating
            r, t_us = out[(False, n_sat)], us[(False, n_sat)]
        else:
            cfg = default_ams(asr_eta=2.0)
            with Timer() as t:
                r = run_multiclient(n_sat, pre, SEG_CFG, cfg, duration=duration,
                                    video_kw=video_kw, policy=policy)
            t_us = t.us
        out[(policy, n_sat)] = r
        emit(f"fig6.sched.{policy}.n{n_sat}", t_us, _row(r))

    # -- sweep 3: GPU pool, affinity-blind vs residency-aware -------------
    n_pool = 2 * n_sat  # the 1-GPU saturating fleet, doubled onto 4 GPUs
    for n_gpus, affinity in ((1, False), (4, False), (4, True)):
        cfg = default_ams(asr_eta=2.0)
        with Timer() as t:
            r = run_multiclient(n_pool, pre, SEG_CFG, cfg, duration=duration,
                                video_kw=video_kw, policy="gain",
                                n_gpus=n_gpus, affinity=affinity)
        out[("pool", n_gpus, affinity)] = r
        tag = "affinity" if affinity else "blind"
        emit(f"fig6.pool.g{n_gpus}.{tag}.n{n_pool}", t.us,
             f"{_row(r)};served={r['phases_served']};"
             f"migrations={r['migrations']};"
             f"migration_s={r['migration_s_total']:.1f}")
    return out


if __name__ == "__main__":
    run()
