"""Paper Fig. 6/10: accuracy degradation as clients share one server GPU,
with and without ATR."""
from __future__ import annotations

from benchmarks.common import SEG_CFG, Timer, default_ams, emit, pretrained


def run(quick: bool = True, duration: float = 100.0):
    from repro.sim.multiclient import run_multiclient

    pre = pretrained()
    counts = (1, 4, 8) if quick else (1, 2, 4, 6, 8, 10)
    out = {}
    base = None
    for atr in (False, True):
        for n in counts:
            # asr_eta=2: stationary feeds must reach the slowdown band
            # (r < 0.25 fps) within the compressed run for ATR to act
            cfg = default_ams(atr_enabled=atr, asr_eta=2.0)
            with Timer() as t:
                r = run_multiclient(n, pre, SEG_CFG, cfg, duration=duration,
                                    video_kw=dict(height=48, width=48, fps=4.0))
            if base is None:
                base = r["mean_miou"]
            key = f"fig6.{'atr' if atr else 'noatr'}.n{n}"
            out[(atr, n)] = r
            emit(key, t.us, f"miou={r['mean_miou']:.4f};"
                 f"degradation={base - r['mean_miou']:+.4f};"
                 f"gpu_util={r['gpu_utilization']:.2f};deferred={r['phases_deferred']}")
    return out


if __name__ == "__main__":
    run()
