"""Serving-engine scale-out: sessions sustained per GPU as the pool grows.

Uses compute-free `StubSession`s (modeled GPU/network timing, no JAX math)
so the numbers are pure engine/scheduler behaviour. Two questions:

  1. capacity — for each pool size, the largest fleet whose mean mIoU stays
     at/above ``TARGET_MIOU`` (sessions sustained; the Appendix E scaling
     argument made measurable);
  2. placement — at the saturating fleet on 4 GPUs, does residency-aware
     `AffinityAware` assignment beat the affinity-blind `GainAware` ranking
     it shares a score with (migration time avoided -> phases + freshness)?

Emits ``BENCH_serving.json`` (sessions sustained, sessions-per-GPU, the
affinity comparison, the fused-training and dual-stream sections) next to
the repo root so future PRs can track the trajectory. ``--smoke`` is the CI
entry point: ``--smoke`` alone is the PR-1 single-GPU engine smoke;
``--smoke --gpus 4`` additionally asserts >=3x sustained-session scaling
from 1 -> 4 GPUs under the fair policy and that affinity beats blind
assignment; ``--smoke --fused`` asserts that coalesced stacked train
launches (fuse_train, priced by the sublinear `GPUCostModel.train_batch_s`)
sustain MORE sessions on one GPU than the sequential engine, and that the
real-math fused wall-clock for 8 seg sessions x one phase is <= 0.6x
sequential; ``--smoke --overlap`` asserts the dual-stream device model
(label/train stream overlap + preemptible labeling, `serving.StreamModel`)
sustains STRICTLY more sessions on one fused GPU than the serialized
single-clock baseline at the same mIoU target, and records preemption +
per-stream utilization telemetry; ``--smoke --trace out.json`` is the
flight-recorder gate — it asserts a traced fused dual-stream run emits
byte-identical, schema-valid Chrome trace JSON (grant/train/select/encode
spans, counter tracks, nesting + concurrency invariants) without
perturbing the schedule, then runs the modeled-vs-measured cost-model
drift audit on the real fused math (``observability`` section of
BENCH_serving.json); ``--smoke --chaos`` is the chaos gate — under the
seeded reference `FaultPlan` (lossy links, an uplink and a downlink
outage, one device crash, a thermal slowdown) the fleet must conserve
requests (enqueued == granted + dropped + queued), recover every crashed
grant through the gpu_done watchdog, retry lost uploads with backoff,
supersede stale deltas rather than blindly retransmit, and keep the mean
mIoU within a bounded gap of the fault-free fleet — while
``FaultPlan.none()`` stays bit-identical to running with no plan at all
(``chaos`` section of BENCH_serving.json).

``--smoke --sharded`` is the sharded-execution gate (run under >= 2 jax
devices — ``scripts/ci.sh --sharded`` forces 4 host-platform devices): D
co-resident fused groups dispatched on D real pool devices
(`core.batched.train_phases_sharded` over `GPUPool(device_backend="jax")`)
must reproduce the modeled single-device path — wire masks byte-identical,
fp16 wire deltas within 1 ULP, the serial all-None path byte-identical —
while the per-device modeled-vs-measured drift audit (``sharded_device``)
and the sharded-vs-serial wall-clock land in the ``sharded`` section of
BENCH_serving.json (the speedup assertion engages only on multi-core
hosts).

``--smoke --fleet`` is the fleet-control-plane gate — the struct-of-arrays
`FleetState` path (cohort events, vectorized policies/admission) must
reproduce the per-object engine bit-for-bit at small n across policies and
under chaos (`FaultPlan.none()` trace bytes included), sustain 10⁴ stub
sessions at >= 10x the per-object events/sec, and record the 10³ -> 10⁵
sweep (events/sec + peak RSS, O(1)-memory telemetry at 10⁵) in the
``fleet`` section of BENCH_serving.json.

Run: PYTHONPATH=src python -m benchmarks.serving_scale [--smoke]
     [--gpus 4] [--fused] [--overlap] [--trace out.json] [--chaos]
     [--fleet]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import Timer, emit
from repro.core.scheduler import GPUCostModel
from repro.serving import (
    ClientNetwork,
    FleetState,
    LinkSpec,
    ServingConfig,
    ServingEngine,
    StreamModel,
    StubSession,
)

TARGET_MIOU = 0.84
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")


def make_stub_fleet(n: int, *, stationary_frac: float = 0.3,
                    link: LinkSpec | None = None) -> list[StubSession]:
    """A mixed fleet: the head of the list is near-static (low sampling rate,
    slow decay), the rest dynamic — the same shape as the seg sweeps."""
    link = link or LinkSpec(up_kbps=500.0, down_kbps=2000.0)
    fleet = []
    for i in range(n):
        static = i < int(stationary_frac * n)
        fleet.append(StubSession(
            i,
            rate=0.15 if static else 1.0,
            dynamics=0.0005 if static else 0.004,
            net=ClientNetwork(link),
        ))
    return fleet


def make_fleet_state(n: int, *, stationary_frac: float = 0.3,
                     telemetry: str = "full") -> FleetState:
    """Struct-of-arrays twin of `make_stub_fleet`: same mixed fleet, same
    per-client parameters and link provisioning, array storage."""
    static = np.arange(n) < int(stationary_frac * n)
    return FleetState(
        n,
        rate=np.where(static, 0.15, 1.0),
        dynamics=np.where(static, 0.0005, 0.004),
        up_kbps=500.0, down_kbps=2000.0,
        telemetry=telemetry)


def run_fleet(n: int, *, n_gpus: int = 1, policy: str = "fair",
              duration: float = 240.0, max_queue: int = 32,
              fuse_train: int = 1, streams: StreamModel | None = None,
              cost: GPUCostModel | None = None,
              fuse_updates: bool = True, tracer=None,
              faults=None) -> dict:
    cfg_kw = {} if faults is None else {"faults": faults}
    engine = ServingEngine(
        make_stub_fleet(n), policy=policy, cost=cost or GPUCostModel(),
        cfg=ServingConfig(duration=duration, max_queue=max_queue,
                          n_gpus=n_gpus, fuse_train=fuse_train,
                          fuse_updates=fuse_updates,
                          streams=streams or StreamModel(), **cfg_kw),
        tracer=tracer)
    return engine.run()


def sessions_sustained(n_gpus: int, *, policy: str = "fair",
                       counts=(4, 8, 12, 16, 20, 24, 28, 32),
                       duration: float = 240.0,
                       target: float = TARGET_MIOU,
                       fuse_train: int = 1,
                       streams: StreamModel | None = None,
                       cost: GPUCostModel | None = None,
                       fuse_updates: bool = True) -> tuple[int, dict]:
    """Largest fleet in ``counts`` whose mean mIoU holds ``target`` on an
    ``n_gpus`` pool (0 if even the smallest fleet degrades past it)."""
    best, per_count = 0, {}
    for n in counts:
        r = run_fleet(n, n_gpus=n_gpus, policy=policy, duration=duration,
                      fuse_train=fuse_train, streams=streams, cost=cost,
                      fuse_updates=fuse_updates)
        per_count[n] = r
        if r["mean_miou"] >= target:
            best = max(best, n)
    return best, per_count


def _read_bench() -> dict:
    """Current BENCH_serving.json contents ({} if absent or unparsable)."""
    if os.path.exists(BENCH_PATH):
        try:
            with open(BENCH_PATH) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            pass
    return {}


def _write_bench(update: dict) -> None:
    """Merge ``update`` into BENCH_serving.json (the pool sweep and the
    fused-training sweep each own different keys; neither clobbers the
    other's section)."""
    bench = _read_bench()
    bench.update(update)
    with open(BENCH_PATH, "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.normpath(BENCH_PATH)}")


def run(counts=None, duration: float | None = None, policy: str = "gain",
        max_queue: int = 32, quick: bool = False) -> dict:
    """The PR-1 single-GPU engine sweep: events/sec + saturation telemetry."""
    if counts is None:
        counts = (4, 16) if quick else (4, 8, 16, 32, 64)
    if duration is None:
        duration = 60.0 if quick else 300.0
    out = {}
    for n in counts:
        fleet = make_stub_fleet(n)
        engine = ServingEngine(
            fleet, policy=policy, cost=GPUCostModel(),
            cfg=ServingConfig(duration=duration, max_queue=max_queue))
        with Timer() as t:
            r = engine.run()
        out[n] = r
        emit(f"serving_scale.{policy}.n{n}", t.us,
             f"evps={r['events_per_sec']:.0f};events={r['events_processed']};"
             f"gpu_util={r['gpu_utilization']:.2f};"
             f"deferral_rate={r['deferral_rate']:.2f};"
             f"drop={r['dropped_requests']};backlog={r['max_backlog']};"
             f"up_kbps={r['mean_up_kbps']:.1f};"
             f"down_kbps={r['mean_down_kbps']:.1f};"
             f"miou={r['mean_miou']:.3f}")
    return out


def run_pool_sweep(max_gpus: int = 4, *, counts=None, duration: float = 240.0,
                   affinity_n: int = 24, mode: str = "full") -> dict:
    """GPU-count sweep (sessions sustained vs pool size, fair policy) plus
    the affinity-on/off comparison at ``affinity_n`` clients on the full
    pool. Writes BENCH_serving.json."""
    if counts is None:
        counts = ((4, 8, 12, 24, 26) if mode == "smoke"
                  else (4, 8, 12, 16, 20, 24, 26, 28, 32))
    gpu_counts = sorted({1, max_gpus} | ({2} if max_gpus >= 4 else set()))
    if mode == "smoke":
        gpu_counts = [1, max_gpus]
    sustained = {}
    for ng in gpu_counts:
        with Timer() as t:
            best, per_count = sessions_sustained(ng, counts=counts,
                                                 duration=duration)
        sustained[ng] = best
        peak = per_count[max(c for c in counts if c <= max(best, counts[0]))]
        emit(f"serving_scale.pool.fair.g{ng}", t.us,
             f"sustained={best};per_gpu={best / ng:.1f};"
             f"target_miou={TARGET_MIOU};"
             f"util_at_peak={peak['gpu_utilization']:.2f};"
             f"migrations_at_peak={peak['migrations']}")

    affinity_cmp = {}
    for pol in ("gain", "affinity"):
        with Timer() as t:
            r = run_fleet(affinity_n, n_gpus=max_gpus, policy=pol,
                          duration=duration)
        affinity_cmp[pol] = {"mean_miou": r["mean_miou"],
                             "phases_served": r["phases_served"],
                             "migrations": r["migrations"],
                             "migration_s_total": r["migration_s_total"]}
        emit(f"serving_scale.affinity.{pol}.g{max_gpus}.n{affinity_n}", t.us,
             f"miou={r['mean_miou']:.4f};served={r['phases_served']};"
             f"migrations={r['migrations']};"
             f"migration_s={r['migration_s_total']:.1f}")

    bench = {
        "mode": mode,
        "target_miou": TARGET_MIOU,
        "duration_s": duration,
        "policy": "fair",
        "sessions_sustained": {str(g): sustained[g] for g in sustained},
        "sessions_per_gpu": {str(g): sustained[g] / g for g in sustained},
        "affinity_at_max_gpus": {"n_clients": affinity_n,
                                 "n_gpus": max_gpus, **affinity_cmp},
    }
    _write_bench(bench)
    return bench


def run_fused_sweep(fuse: int = 4, *, counts=(8, 10, 12, 14, 16, 20),
                    duration: float = 240.0) -> dict:
    """Fused cross-session training on ONE GPU: sessions sustained at the
    target mIoU with coalesced stacked launches (`fuse_train`) vs the
    sequential engine, under the batched-launch cost model
    (`GPUCostModel.train_batch_s`) — plus the real-math wall-clock compare
    from `kernels_bench`. Updates the ``fused_training`` section of
    BENCH_serving.json."""
    from benchmarks.kernels_bench import fused_phase_compare

    with Timer() as t:
        seq_best, _ = sessions_sustained(1, counts=counts, duration=duration)
        fused_best, per_count = sessions_sustained(
            1, counts=counts, duration=duration, fuse_train=fuse)
    peak = per_count[max(fused_best, counts[0])]
    emit(f"serving_scale.fused.g1.f{fuse}", t.us,
         f"sustained_seq={seq_best};sustained_fused={fused_best};"
         f"target_miou={TARGET_MIOU};"
         f"fused_launches_at_peak={peak['fused_launches']};"
         f"riders_at_peak={peak['rider_grants']}")
    wall = fused_phase_compare()
    bench = {
        "fused_training": {
            "fuse_train": fuse,
            "duration_s": duration,
            "target_miou": TARGET_MIOU,
            "sessions_sustained_1gpu": {"sequential": seq_best,
                                        "fused": fused_best},
            "fused_launches_at_peak": peak["fused_launches"],
            "rider_grants_at_peak": peak["rider_grants"],
            "wallclock_8_sessions_1_phase": wall,
        }
    }
    _write_bench(bench)
    return bench["fused_training"]


def run_update_sweep(fuse: int = 4, *, counts=(8, 10, 12, 14, 16, 18, 20),
                     duration: float = 240.0) -> dict:
    """Fused post-train update pipeline on ONE fused GPU: sessions sustained
    at the target mIoU when a fused grant's B selections + delta encodes are
    priced as one amortized `GPUCostModel.update_batch_s` launch
    (``fuse_updates``) vs B serial `update_solo_s` charges — under a cost
    model where the update path is actually priced (select_s +
    delta_comp_s_per_mb nonzero; the default model prices it at zero, where
    the two engines are bit-identical). Also records the real-math
    wall-clock compare from `kernels_bench.update_pipeline_compare` (8 seg
    sessions, stacked select + batched encode vs per-session, byte-identical
    wire). Updates the ``update_pipeline`` section of BENCH_serving.json."""
    from benchmarks.kernels_bench import update_pipeline_compare

    # 20 KB stub delta -> 0.1 s compress; selection launch 0.15 s: the
    # update stage is ~1/4 of a K=20 phase, the regime ShadowTutor/EdgeSync
    # report for partial-update production on edge-serving GPUs
    cost = GPUCostModel(select_s=0.15, delta_comp_s_per_mb=5.0)
    with Timer() as t:
        seq_best, _ = sessions_sustained(1, counts=counts, duration=duration,
                                         fuse_train=fuse, cost=cost,
                                         fuse_updates=False)
        bat_best, per_count = sessions_sustained(
            1, counts=counts, duration=duration, fuse_train=fuse, cost=cost,
            fuse_updates=True)
    peak = per_count[max(bat_best, counts[0])]
    up = peak["update_pipeline"]
    emit(f"serving_scale.update.g1.f{fuse}", t.us,
         f"sustained_per_session={seq_best};sustained_batched={bat_best};"
         f"target_miou={TARGET_MIOU};"
         f"batched_launches_at_peak={up['batched_launches']};"
         f"update_s_saved_at_peak={up['update_s_saved']:.1f}")
    wall = update_pipeline_compare()
    bench = {
        "update_pipeline": {
            "fuse_train": fuse,
            "duration_s": duration,
            "target_miou": TARGET_MIOU,
            "cost": {"select_s": cost.select_s,
                     "delta_comp_s_per_mb": cost.delta_comp_s_per_mb,
                     "update_setup_s": cost.update_setup_s,
                     "update_discount": cost.update_discount},
            "sessions_sustained_1gpu": {"per_session": seq_best,
                                        "batched": bat_best},
            "batched_launches_at_peak": up["batched_launches"],
            "batched_sessions_at_peak": up["batched_sessions"],
            "update_s_saved_at_peak": up["update_s_saved"],
            "wallclock_8_sessions_select_encode": wall,
        }
    }
    _write_bench(bench)
    return bench["update_pipeline"]


def run_overlap_sweep(fuse: int = 4, *, counts=(10, 12, 14, 16, 18, 20),
                      duration: float = 240.0, slowdown: float = 1.1,
                      preempt_cost: float = 0.02) -> dict:
    """Dual-stream device model on ONE fused GPU: sessions sustained at the
    target mIoU when teacher labeling overlaps training (label vs train
    streams, bounded ``slowdown`` while both are busy) with labeling
    launches preemptible at frame-batch boundaries — vs the serialized
    single-clock baseline (the PR-3 behavior) on the same fleet. Updates
    the ``dual_stream`` section of BENCH_serving.json with the capacity
    pair plus preemption and per-stream utilization telemetry at the
    overlapped peak."""
    streams = StreamModel(mode="overlap", slowdown=slowdown, preempt=True,
                          preempt_cost_s=preempt_cost)
    with Timer() as t:
        ser_best, _ = sessions_sustained(1, counts=counts, duration=duration,
                                         fuse_train=fuse)
        ovl_best, per_count = sessions_sustained(
            1, counts=counts, duration=duration, fuse_train=fuse,
            streams=streams)
    peak = per_count[max(ovl_best, counts[0])]
    su = peak["per_gpu_stream_utilization"]
    emit(f"serving_scale.overlap.g1.f{fuse}", t.us,
         f"sustained_serialized={ser_best};sustained_overlap={ovl_best};"
         f"target_miou={TARGET_MIOU};slowdown={slowdown};"
         f"preemptions_at_peak={peak['preemptions']};"
         f"label_util={su['label'][0]:.2f};train_util={su['train'][0]:.2f};"
         f"overlap_s={peak['overlap_s']:.0f}")
    bench = {
        "dual_stream": {
            "fuse_train": fuse,
            "duration_s": duration,
            "target_miou": TARGET_MIOU,
            "stream_model": {"mode": "overlap", "slowdown": slowdown,
                             "preempt": True,
                             "preempt_cost_s": preempt_cost},
            "sessions_sustained_1gpu": {"serialized": ser_best,
                                        "overlap": ovl_best},
            "preemptions_at_peak": peak["preemptions"],
            "preempted_frames_at_peak": peak["preempted_frames"],
            "overlap_s_at_peak": peak["overlap_s"],
            "stream_utilization_at_peak": {
                "label": su["label"][0], "train": su["train"][0]},
        }
    }
    _write_bench(bench)
    return bench["dual_stream"]


def run_trace_probe(trace_path: str, *, n: int = 8,
                    duration: float = 120.0) -> dict:
    """Flight-recorder gate: trace a fused dual-stream fleet twice and
    assert the Chrome trace JSON is byte-identical, schema-valid
    (`serving.validate_trace`: required counter tracks, non-negative
    durations, per-stream serial execution, concurrency bounds, grant
    nesting) and carries the full grant/train/select/encode span
    vocabulary; a serialized run must validate too, and tracing must not
    perturb the schedule (traced == untraced results). Writes the overlap
    trace to ``trace_path``."""
    from repro.serving import Tracer, validate_trace

    cost = GPUCostModel(select_s=0.15, delta_comp_s_per_mb=5.0)
    overlap = StreamModel(mode="overlap", slowdown=1.1, preempt=True,
                          preempt_cost_s=0.02)

    def traced(streams):
        tracer = Tracer()
        r = run_fleet(n, n_gpus=2, duration=duration, fuse_train=4,
                      streams=streams, cost=cost, tracer=tracer)
        return r, tracer.to_json()

    r1, j1 = traced(overlap)
    _, j2 = traced(overlap)
    assert j1 == j2, "trace not byte-identical across identical runs"
    trace = json.loads(j1)
    problems = validate_trace(trace)
    assert not problems, f"trace schema violations: {problems[:5]}"
    names = {e.get("name") for e in trace["traceEvents"]}
    for want in ("grant", "train", "select", "encode", "label_batch",
                 "delta", "frames"):
        assert want in names, f"trace missing {want!r} spans"
    _, js = traced(StreamModel())  # serialized: concurrency limit 1
    problems = validate_trace(json.loads(js))
    assert not problems, f"serialized trace violations: {problems[:5]}"
    # the recorder must be an observer: same schedule with tracing off
    r0 = run_fleet(n, n_gpus=2, duration=duration, fuse_train=4,
                   streams=overlap, cost=cost)
    drop = ("wall_s", "events_per_sec", "events_per_sec_steady",
            "observability")
    assert ({k: v for k, v in r0.items() if k not in drop}
            == {k: v for k, v in r1.items() if k not in drop}), (
        "tracing changed the simulated schedule")
    with open(trace_path, "w") as f:
        f.write(j1)
    print(f"wrote {trace_path} ({len(trace['traceEvents'])} events) — "
          f"open at https://ui.perfetto.dev")
    return trace


def run_chaos_probe(*, n: int = 12, n_gpus: int = 2,
                    duration: float = 240.0,
                    miou_gap_bound: float = 0.10) -> dict:
    """Chaos gate (`--chaos`): the engine under the reference `FaultPlan`
    (lossy links, an uplink and a downlink outage, one device crash, a
    thermal slowdown) must (1) keep `FaultPlan.none()` bit-identical to a
    fault-free run, (2) be deterministic under faults (same plan, same
    results), (3) balance its books — every request enqueued is granted,
    dropped, or still queued; every crashed grant is recovered; every lost
    delta resolves to retransmit/supersede/abandon — and (4) degrade
    gracefully: zero lost sessions and a bounded mean-mIoU gap vs the
    fault-free fleet. Also traces a chaos run (byte-identical, schema-valid,
    retry/outage/crash/supersede vocabulary). Writes the ``chaos`` section
    of BENCH_serving.json."""
    from repro.serving import FaultPlan, Tracer, validate_trace

    drop = ("wall_s", "events_per_sec", "events_per_sec_steady",
            "observability")

    def core(r):
        return {k: v for k, v in r.items() if k not in drop}

    kw = dict(n_gpus=n_gpus, duration=duration, fuse_train=4)
    with Timer() as t:
        # 1. faults-off golden: FaultPlan.none() == no plan, bit-for-bit
        base = run_fleet(n, **kw)
        none = run_fleet(n, faults=FaultPlan.none(), **kw)
        assert core(base) == core(none), (
            "FaultPlan.none() perturbed the fault-free engine")
        # 2. determinism under the reference plan
        plan = FaultPlan.reference(duration, n_gpus=n_gpus)
        r = run_fleet(n, faults=plan, **kw)
        r2 = run_fleet(n, faults=plan, **kw)
        assert core(r) == core(r2), (
            "chaos run not reproducible with the same seeded plan")
    ch = r["chaos"]
    # 3a. request conservation: nothing vanishes
    assert r["requests_enqueued"] == (r["requests_granted"]
                                      + r["dropped_requests"]
                                      + r["unserved_backlog"]), (
        f"request books don't balance: {r['requests_enqueued']} enqueued vs "
        f"{r['requests_granted']} granted + {r['dropped_requests']} dropped "
        f"+ {r['unserved_backlog']} queued")
    # 3b. every crashed grant recovered, every fault path exercised
    assert ch["device_crashes"] >= 1, "the crash window never fired"
    assert ch["grants_killed"] >= 1, (
        "the crash killed no grant (plan should hit a loaded device)")
    assert ch["grants_recovered"] == ch["grants_killed"], (
        f"{ch['grants_killed']} grants killed but only "
        f"{ch['grants_recovered']} recovered by the watchdog")
    assert ch["watchdog_fires"] == ch["grants_recovered"]
    assert ch["uploads_lost"] > 0 and ch["upload_retries"] > 0
    assert ch["deltas_lost"] > 0
    assert ch["deltas_superseded"] > 0, (
        "the downlink outage should supersede at least one stale delta")
    # 3c. every lost delta resolves (retransmitted, superseded or abandoned)
    assert (ch["deltas_retransmitted"] + ch["deltas_superseded"]
            + ch["deltas_abandoned"]) >= ch["deltas_lost"]
    assert ch["slowed_grants"] >= 1, "the slowdown window never fired"
    # 3d. zero lost sessions: every client still evaluates and the served
    # phase counts stay consistent
    assert len(r["miou_per_client"]) == n
    assert all(m == m for m in r["miou_per_client"]), "a session went dark"
    assert sum(r["phases_per_client"]) <= r["phases_served"]
    # 4. graceful degradation, not collapse
    gap = base["mean_miou"] - r["mean_miou"]
    assert 0.0 <= gap <= miou_gap_bound, (
        f"mIoU gap under faults is {gap:.3f} "
        f"(fault-free {base['mean_miou']:.3f} -> {r['mean_miou']:.3f}); "
        f"bound is {miou_gap_bound}")
    # 5. the flight recorder under chaos: deterministic, valid, and carries
    # the fault vocabulary without perturbing the schedule
    def traced():
        tracer = Tracer()
        rr = run_fleet(n, faults=plan, tracer=tracer, **kw)
        return rr, tracer.to_json()

    rt, j1 = traced()
    _, j2 = traced()
    assert j1 == j2, "chaos trace not byte-identical across identical runs"
    trace = json.loads(j1)
    problems = validate_trace(trace)
    assert not problems, f"chaos trace schema violations: {problems[:5]}"
    names = {e.get("name") for e in trace["traceEvents"]}
    for want in ("outage", "crash", "retry", "supersede"):
        assert want in names, f"chaos trace missing {want!r} events"
    assert core(rt) == core(r), "tracing perturbed the chaos schedule"
    emit(f"serving_scale.chaos.g{n_gpus}.n{n}", t.us,
         f"miou_gap={gap:.3f};crashes={ch['device_crashes']};"
         f"grants_recovered={ch['grants_recovered']};"
         f"upload_retries={ch['upload_retries']};"
         f"deltas_superseded={ch['deltas_superseded']};"
         f"shed={ch['requests_shed']}")
    bench = {
        "chaos": {
            "n_clients": n,
            "n_gpus": n_gpus,
            "duration_s": duration,
            "plan": {"seed": plan.seed, "up_loss": plan.up_loss,
                     "down_loss": plan.down_loss,
                     "outages": len(plan.outages),
                     "crashes": len(plan.crashes),
                     "slowdowns": len(plan.slowdowns)},
            "mean_miou_fault_free": base["mean_miou"],
            "mean_miou_under_faults": r["mean_miou"],
            "miou_gap": gap,
            "miou_gap_bound": miou_gap_bound,
            "final_staleness_max_s": r["chaos"]["final_staleness_max_s"],
            "link_outage_s": ch["link_outage_s"],
            "crash_s": ch["crash_s"],
            "grants_killed": ch["grants_killed"],
            "grants_recovered": ch["grants_recovered"],
            "sessions_recovered": ch["sessions_recovered"],
            "requests_shed": ch["requests_shed"],
            "upload_retries": ch["upload_retries"],
            "uploads_lost": ch["uploads_lost"],
            "upload_bytes_wasted": ch["upload_bytes_wasted"],
            "deltas_lost": ch["deltas_lost"],
            "deltas_retransmitted": ch["deltas_retransmitted"],
            "deltas_superseded": ch["deltas_superseded"],
            "retransmitted_bytes": ch["retransmitted_bytes"],
            "superseded_bytes": ch["superseded_bytes"],
            "dropped_frame_bytes": r["dropped_frame_bytes"],
        }
    }
    _write_bench(bench)
    return bench["chaos"]


def _rss_mb() -> float:
    """Current resident set in MB (VmRSS; falls back to the process peak)."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1]) / 1024.0
    except OSError:
        pass
    import resource
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _peak_rss_mb() -> float:
    import resource
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_fleet_probe(*, eq_n: int = 32, floor_n: int = 10_000,
                    floor_ratio: float = 10.0,
                    sweep=(1_000, 10_000, 100_000),
                    duration: float = 240.0,
                    eq_duration: float = 60.0) -> dict:
    """Fleet-control-plane gate (`--fleet`). Three parts:

    1. **equivalence** — at ``eq_n`` clients the `FleetState` engine must
       reproduce the per-object `StubSession` engine bit-for-bit (results
       minus wall-clock fields) across fair/edf/gain, pool sizes, an
       admission cap, and the seeded reference `FaultPlan` — and a traced
       `FaultPlan.none()` run must emit byte-identical trace JSON;
    2. **throughput floor** — at ``floor_n`` stubs (same fleet, same
       duration, full telemetry on both sides) the fleet path must sustain
       >= ``floor_ratio`` x the per-object events/sec, with identical
       results;
    3. **sweep** — 10³ -> 10⁵ clients, recording events/sec and resident
       memory per point (the 10⁵ point runs O(1)-memory ``moments``
       telemetry) into the ``fleet`` section of BENCH_serving.json.
    """
    from repro.serving import FaultPlan, Tracer

    drop = ("wall_s", "events_per_sec", "events_per_sec_steady",
            "observability")

    def core(r):
        return {k: v for k, v in r.items() if k not in drop}

    checks = []
    with Timer() as t:
        # 1. equivalence sweep: policies x pool sizes x admission cap
        for pol in ("fair", "edf", "gain"):
            for n_gpus in (1, 4):
                cfg = ServingConfig(duration=eq_duration, max_queue=32,
                                    n_gpus=n_gpus,
                                    admission_util_cap=(0.8 if n_gpus == 4
                                                        else None))
                r1 = ServingEngine(make_stub_fleet(eq_n), policy=pol,
                                   cfg=cfg).run()
                r2 = ServingEngine(make_fleet_state(eq_n), policy=pol,
                                   cfg=cfg).run()
                assert core(r1) == core(r2), (
                    f"fleet path diverged from per-object: policy={pol} "
                    f"n_gpus={n_gpus}")
                checks.append(f"{pol}/g{n_gpus}")
        # chaos: the reference plan must drive both paths identically
        plan = FaultPlan.reference(eq_duration, n_gpus=2)
        cfg = ServingConfig(duration=eq_duration, max_queue=32, n_gpus=2,
                            faults=plan)
        r1 = ServingEngine(make_stub_fleet(eq_n), policy="gain",
                           cfg=cfg).run()
        r2 = ServingEngine(make_fleet_state(eq_n), policy="gain",
                           cfg=cfg).run()
        assert core(r1) == core(r2), "fleet path diverged under chaos"
        checks.append("chaos")
        # FaultPlan.none() trace bytes: the recorder sees the same schedule
        tcfg = ServingConfig(duration=eq_duration, max_queue=32, n_gpus=2,
                             faults=FaultPlan.none())
        tr1, tr2 = Tracer(), Tracer()
        r1 = ServingEngine(make_stub_fleet(8), policy="gain", cfg=tcfg,
                           tracer=tr1).run()
        r2 = ServingEngine(make_fleet_state(8), policy="gain", cfg=tcfg,
                           tracer=tr2).run()
        assert core(r1) == core(r2), "traced fleet results diverged"
        assert tr1.to_json() == tr2.to_json(), (
            "fleet trace bytes differ from per-object under FaultPlan.none()")
        checks.append("trace-bytes")
    emit(f"serving_scale.fleet.eq.n{eq_n}", t.us,
         f"checks={len(checks)};duration={eq_duration}")

    # 2. throughput floor at floor_n, same duration both paths
    floor_cfg = ServingConfig(duration=duration, max_queue=32, n_gpus=4)
    with Timer() as t:
        r_fl = ServingEngine(make_fleet_state(floor_n), cfg=floor_cfg).run()
    fleet_evps = r_fl["events_per_sec"]
    with Timer() as t2:
        r_obj = ServingEngine(make_stub_fleet(floor_n), cfg=floor_cfg).run()
    obj_evps = r_obj["events_per_sec"]
    assert core(r_obj) == core(r_fl), (
        f"fleet path diverged from per-object at n={floor_n}")
    ratio = fleet_evps / max(obj_evps, 1e-9)
    assert ratio >= floor_ratio, (
        f"fleet events/sec is only {ratio:.1f}x the per-object path at "
        f"n={floor_n} ({fleet_evps:.0f} vs {obj_evps:.0f}); floor is "
        f"{floor_ratio}x")
    emit(f"serving_scale.fleet.floor.n{floor_n}", t.us,
         f"fleet_evps={fleet_evps:.0f};object_evps={obj_evps:.0f};"
         f"ratio={ratio:.1f};events={r_fl['events_processed']}")

    # 3. the 10^3 -> 10^5 sweep (largest point folds telemetry to moments)
    sweep_out = {}
    for n in sweep:
        telemetry = "moments" if n >= 100_000 else "full"
        with Timer() as t:
            r = ServingEngine(make_fleet_state(n, telemetry=telemetry),
                              cfg=floor_cfg).run()
        sweep_out[str(n)] = {
            "events_per_sec": r["events_per_sec"],
            "events_processed": r["events_processed"],
            "wall_s": r["wall_s"],
            "mean_miou": r["mean_miou"],
            "telemetry": telemetry,
            "rss_mb": round(_rss_mb(), 1),
        }
        emit(f"serving_scale.fleet.sweep.n{n}", t.us,
             f"evps={r['events_per_sec']:.0f};"
             f"events={r['events_processed']};telemetry={telemetry};"
             f"rss_mb={sweep_out[str(n)]['rss_mb']}")

    bench = {
        "fleet": {
            "duration_s": duration,
            "equivalence": {"n_clients": eq_n, "duration_s": eq_duration,
                            "checks": checks},
            "floor": {"n_clients": floor_n,
                      "events_per_sec_fleet": fleet_evps,
                      "events_per_sec_object": obj_evps,
                      "ratio": ratio, "floor_ratio": floor_ratio},
            "sweep": sweep_out,
            "peak_rss_mb": round(_peak_rss_mb(), 1),
        }
    }
    _write_bench(bench)
    return bench["fleet"]


def run_drift_probe(n_sessions: int = 4, k_iters: int = 4,
                    size: int = 16) -> dict:
    """Modeled-vs-measured cost audit on the REAL fused math: run a small
    seg fleet through `train_phases_fused` (force_stack) twice — first
    launch compiles, second is steady state — and fold the `core.timing`
    stage stats against `GPUCostModel`'s pricing (`serving.drift_report`,
    summarized by `roofline.analysis.serving_stage_report`). Updates the
    ``observability`` section of BENCH_serving.json."""
    from benchmarks.kernels_bench import _update_fleet
    from repro.core import timing
    from repro.core.batched import train_phases_fused
    from repro.roofline.analysis import serving_stage_report
    from repro.serving import drift_report

    # the priced update pipeline from run_update_sweep, so select/encode
    # have a nonzero model to audit against
    cost = GPUCostModel(select_s=0.15, delta_comp_s_per_mb=5.0)
    timing.set_enabled(True)
    sessions = _update_fleet(n_sessions, k_iters, size)
    snap = timing.snapshot()
    with Timer() as t:
        train_phases_fused(sessions, 16.0, force_stack=True)  # first launch
        train_phases_fused(sessions, 26.0, force_stack=True)  # steady state
    stats = timing.delta(snap)
    drift = drift_report(cost, stats)
    report = serving_stage_report(drift)
    assert report["measured_total_s"] > 0.0, "no stage timings recorded"
    for stage in ("train_fused", "select_stacked", "encode_stacked"):
        assert stage in report["stages"], f"stage {stage!r} not measured"
    emit(f"serving_scale.drift.b{n_sessions}.k{k_iters}", t.us,
         f"bottleneck={report['bottleneck']};"
         f"measured_total_s={report['measured_total_s']:.4f};"
         f"compile_s={timing.compile_s(stats):.2f}")
    bench = {
        "observability": {
            "n_sessions": n_sessions,
            "k_iters": k_iters,
            "cost": {"select_s": cost.select_s,
                     "delta_comp_s_per_mb": cost.delta_comp_s_per_mb,
                     "train_iter_s": cost.train_iter_s,
                     "train_batch_setup_s": cost.train_batch_setup_s,
                     "train_batch_discount": cost.train_batch_discount},
            "compile_s": timing.compile_s(stats),
            "drift": {stage: dict(e) for stage, e in drift.items()},
            "stage_report": report,
        }
    }
    # the kernel gate (`kernels_bench --kernels`) owns observability.kernels;
    # top-level merge would clobber it, so carry it forward
    kernels = (_read_bench().get("observability") or {}).get("kernels")
    if kernels is not None:
        bench["observability"]["kernels"] = kernels
    _write_bench(bench)
    return bench["observability"]


def run_sharded_probe(n_groups: int = 4, group_b: int = 2, k_iters: int = 3,
                      size: int = 16) -> dict:
    """Real sharded execution on an actual device mesh: D co-resident fused
    groups run their full grant lifecycles (train -> select -> encode) on D
    concrete ``jax.Device``s at once (`core.batched.train_phases_sharded`
    over `GPUPool(device_backend="jax")` slot bindings; CPU-only hosts get
    the devices from `launch.host_mesh` / ``REPRO_HOST_DEVICES`` in
    scripts/env.sh).

    Four identical seg fleets each run one warm round (t=16, per-device
    executables compile) and one steady round (t=26):

      * modeled reference — per-group `train_phases_fused`, the engine's
        default path;
      * serial sharded — `train_phases_sharded` with all-None devices: the
        same refactored launch/commit code on the default device, asserted
        BYTE-identical to the reference (and the wall-clock baseline);
      * per-device dispatch — one async launch per group on its own
        device; identical jitted programs on same-kind devices, so wire
        masks must stay byte-identical and fp16 values within 1 ULP
        (byte-identity is recorded — and expected — but the asserted
        contract is the PR-7 tolerance);
      * SPMD one-launch — the groups concatenated along the session axis
        under a cached `NamedSharding` (same tolerance contract; GSPMD may
        re-fuse the math).

    The steady sharded rounds run under `core.timing`; `drift_report` must
    yield the per-device ``sharded_device`` modeled-vs-measured audit, and
    sessions-sustained comes from the measured steady round wall-clock vs
    the fleet's T_update. The sharded-beats-serial wall-clock assertion
    engages only on hosts with >= 2 CPU cores: forced host devices on a
    1-core container interleave on one core (~0.93x measured there — same
    reasoning as the interpret-mode kernel gates: correctness is the
    portable story, wall-clock needs real parallel hardware). Writes the
    ``sharded`` section of BENCH_serving.json."""
    import jax

    from benchmarks.kernels_bench import _f16_ulp_diff, _update_fleet
    from repro.core import batched, timing
    from repro.core.batched import train_phases_fused, train_phases_sharded
    from repro.launch.host_mesh import host_device_count_flag
    from repro.serving import drift_report
    from repro.serving.resources import GPUPool

    n_dev = len(jax.devices())
    assert n_dev >= 2, (
        f"sharded gate needs >= 2 jax devices, found {n_dev}. Force host "
        f"devices BEFORE jax initializes: `REPRO_HOST_DEVICES=4 source "
        f"scripts/env.sh` (XLA_FLAGS {host_device_count_flag(4)!r}), or "
        f"run `bash scripts/ci.sh --sharded`.")
    n_sessions = n_groups * group_b
    cost = GPUCostModel(select_s=0.15, delta_comp_s_per_mb=5.0)
    pool = GPUPool(n_gpus=n_groups, cost=cost, device_backend="jax")
    slot_devs = pool.jax_devices()
    assert pool.distinct_jax_devices == min(n_groups, n_dev), (
        f"pool bound {pool.distinct_jax_devices} distinct devices; "
        f"expected {min(n_groups, n_dev)}")

    def fleet_groups():
        fleet = _update_fleet(n_sessions, k_iters, size)
        return [fleet[g * group_b:(g + 1) * group_b]
                for g in range(n_groups)]

    # four identical fleets (deterministic seeds), split into D groups of b
    g_ref, g_ser, g_dsp, g_spmd = (fleet_groups() for _ in range(4))
    batched.sharded_reset()

    # two warm rounds, then steady: round 0 pays the exec/kernel races and
    # per-device compiles; round 1 recompiles once more (the first round's
    # committed launch outputs change the input avals — opt-state scalars
    # come back strongly typed); round 2 is genuine steady state
    phases = (16.0, 26.0, 36.0)
    ref, ser, dsp, spm = [], [], [], []
    wall = {"serial": 0.0, "sharded": 0.0}
    snap = None
    for t_phase in phases:
        ref.append([train_phases_fused(g, t_phase, force_stack=True)
                    for g in g_ref])
        with Timer() as tm:
            ser.append(train_phases_sharded(
                g_ser, t_phase, devices=[None] * n_groups))
        wall["serial"] = tm.us / 1e6  # last (steady) round wins
        if t_phase == phases[-1]:  # clock + drift-audit the steady round
            timing.set_enabled(True)
            snap = timing.snapshot()
        with Timer() as tm:
            dsp.append(train_phases_sharded(g_dsp, t_phase,
                                            devices=slot_devs))
        wall["sharded"] = tm.us / 1e6
        spm.append(train_phases_sharded(g_spmd, t_phase, devices=slot_devs,
                                        spmd=True))
    stats = timing.delta(snap)

    def flat(rounds):
        return [d for r in rounds for grp in r for d in grp]

    d_ref, d_ser, d_dsp, d_spm = flat(ref), flat(ser), flat(dsp), flat(spm)
    assert len(d_ref) == len(phases) * n_sessions
    assert all(d is not None for d in d_ref)
    # serial sharded IS the refactored fused path on the default device
    assert all(a.packed_mask == b.packed_mask
               for a, b in zip(d_ref, d_ser)), (
        "all-None train_phases_sharded changed a streamed wire mask")
    assert all(np.array_equal(np.asarray(a.values), np.asarray(b.values))
               for a, b in zip(d_ref, d_ser)), (
        "all-None train_phases_sharded changed wire-delta bytes")
    equivalence = {"n_deltas": len(d_ref), "serial_byte_identical": True}
    for name, dd in (("dispatch", d_dsp), ("spmd", d_spm)):
        assert all(a.packed_mask == b.packed_mask
                   for a, b in zip(d_ref, dd)), (
            f"{name} sharded path changed a streamed wire mask")
        ulp = max(_f16_ulp_diff(a.values, b.values)
                  for a, b in zip(d_ref, dd))
        assert ulp <= 1, (
            f"{name} sharded wire-delta values drifted {ulp} f16 ULP (>1) "
            f"from the modeled path")
        equivalence[name] = {
            "values_max_f16_ulp": ulp,
            "values_byte_identical": int(sum(
                np.array_equal(np.asarray(a.values), np.asarray(b.values))
                for a, b in zip(d_ref, dd))),
        }

    info = batched.sharded_info()
    assert info["spmd_launches"] == len(phases), info
    # serial + dispatch paths, D launches each, every round
    assert info["dispatch_launches"] == 2 * len(phases) * n_groups, info
    assert info["distinct_devices"] == min(n_groups, n_dev), info

    drift = drift_report(cost, stats)
    sd = drift.get("sharded_device")
    assert sd is not None, "no per-device sharded timings recorded"
    per_dev = sd.get("per_device", {})
    assert sorted(per_dev) == list(range(n_groups)), (
        f"per-device drift covers slots {sorted(per_dev)}; "
        f"expected 0..{n_groups - 1}")
    for slot, e in per_dev.items():
        assert e["steady_calls"] >= 1 and e["measured_steady_s"] > 0.0, (
            f"device {slot} recorded no steady sharded time")
        assert e["modeled_steady_s"] > 0.0 and e["drift_ratio"] is not None
    ts = drift.get("train_sharded")
    assert ts is not None and ts["steady_calls"] >= 2, (
        "steady train_sharded batches (dispatch + spmd) not recorded")

    # sessions sustained from the MEASURED steady lifecycle (core.timing):
    # one sharded round serves n_sessions phases; the pool keeps up with
    # however many such cohorts fit in one T_update period
    round_s = ts["measured_per_call_s"]
    t_update = float(g_ref[0][0].cfg.t_update)
    assert 0.0 < round_s < t_update, (
        f"one sharded round took {round_s:.2f}s against a {t_update}s "
        f"update period — the pool cannot sustain even one cohort")
    sustained = int(n_sessions * t_update / round_s)

    ratio = wall["serial"] / max(wall["sharded"], 1e-9)
    multi_core = (os.cpu_count() or 1) >= 2
    if multi_core:
        assert ratio > 1.0, (
            f"sharded steady round ({wall['sharded']:.3f}s on "
            f"{info['distinct_devices']} devices) did not beat serial "
            f"dispatch ({wall['serial']:.3f}s) on a {os.cpu_count()}-core "
            f"host")
    emit(f"serving_scale.sharded.d{n_groups}.b{group_b}",
         wall["sharded"] * 1e6,
         f"devices={info['distinct_devices']};ratio={ratio:.2f};"
         f"speedup_asserted={multi_core};sustained={sustained};"
         f"dispatch_ulp={equivalence['dispatch']['values_max_f16_ulp']};"
         f"spmd_ulp={equivalence['spmd']['values_max_f16_ulp']}")
    bench = {
        "sharded": {
            "n_jax_devices": n_dev,
            "n_groups": n_groups,
            "group_b": group_b,
            "k_iters": k_iters,
            "cpu_count": os.cpu_count(),
            "equivalence": equivalence,
            "wallclock_steady_round": {
                "serial_s": wall["serial"], "sharded_s": wall["sharded"],
                "ratio_serial_over_sharded": ratio,
                "speedup_asserted": multi_core},
            "sessions_sustained": sustained,
            "round_s_measured": round_s,
            "t_update_s": t_update,
            "counters": info,
            "drift": {stage: dict(e) for stage, e in drift.items()
                      if stage in ("sharded_device", "train_sharded")},
        }
    }
    _write_bench(bench)
    return bench["sharded"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: 2 counts, short horizon")
    ap.add_argument("--policy", default="gain",
                    choices=("fair", "edf", "gain", "affinity"))
    ap.add_argument("--gpus", type=int, default=1,
                    help="pool size; >1 runs the GPU-count sweep")
    ap.add_argument("--fused", action="store_true",
                    help="fused cross-session training sweep: sessions "
                         "sustained on 1 GPU with coalesced stacked "
                         "launches + real-math wall-clock compare")
    ap.add_argument("--overlap", action="store_true",
                    help="dual-stream sweep: sessions sustained on 1 fused "
                         "GPU with label/train stream overlap + preemptible "
                         "labeling vs the serialized single-clock baseline")
    ap.add_argument("--update-pipeline", action="store_true",
                    help="fused update-pipeline sweep: sessions sustained "
                         "on 1 fused GPU with amortized batched "
                         "select+encode pricing vs per-session charges, "
                         "plus the real-math byte-identical wall-clock "
                         "compare")
    ap.add_argument("--chaos", action="store_true",
                    help="chaos gate: deterministic fault injection "
                         "(lossy links, outages, a device crash, a "
                         "slowdown) must conserve requests, recover every "
                         "crashed grant via the watchdog, supersede stale "
                         "deltas, and hold a bounded mIoU gap vs the "
                         "fault-free fleet; FaultPlan.none() must be "
                         "bit-identical to no plan")
    ap.add_argument("--fleet", action="store_true",
                    help="fleet control-plane gate: the struct-of-arrays "
                         "FleetState path must reproduce the per-object "
                         "engine bit-for-bit at small n (policies x pool "
                         "sizes x admission x chaos, byte-identical "
                         "traces) and sustain >= 10x its events/sec at "
                         "10^4 clients, then sweep 10^3 -> 10^5 recording "
                         "events/sec + resident memory")
    ap.add_argument("--sharded", action="store_true",
                    help="sharded-execution gate (needs >= 2 jax devices; "
                         "ci.sh forces 4 host devices): co-resident fused "
                         "groups dispatched on real pool devices must "
                         "match the modeled path (masks byte-identical, "
                         "fp16 deltas within 1 ULP), with the per-device "
                         "modeled-vs-measured drift audit and the "
                         "sharded-vs-serial wall-clock (speedup asserted "
                         "on multi-core hosts only)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="flight-recorder gate: trace a fused dual-stream "
                         "fleet, assert byte-identical + schema-valid "
                         "Chrome trace JSON (written to PATH), and run the "
                         "modeled-vs-measured drift audit on the real "
                         "fused math")
    ap.add_argument("--duration", type=float, default=None)
    args = ap.parse_args()
    if args.smoke and args.sharded:
        sb = run_sharded_probe()
        wc = sb["wallclock_steady_round"]
        print(f"serving_scale sharded smoke OK "
              f"({sb['counters']['distinct_devices']} devices; "
              f"serial {wc['serial_s']:.3f}s vs sharded "
              f"{wc['sharded_s']:.3f}s, ratio "
              f"{wc['ratio_serial_over_sharded']:.2f}x"
              f"{'' if wc['speedup_asserted'] else ' (1-core host: speedup not asserted)'}; "
              f"dispatch ulp {sb['equivalence']['dispatch']['values_max_f16_ulp']}, "
              f"spmd ulp {sb['equivalence']['spmd']['values_max_f16_ulp']}; "
              f"sustained {sb['sessions_sustained']} sessions)")
        print("serving_scale smoke OK")
        return
    if args.smoke and args.fleet:
        fb = run_fleet_probe(duration=args.duration or 120.0)
        top = fb["sweep"][str(max(int(k) for k in fb["sweep"]))]
        print(f"serving_scale fleet smoke OK "
              f"({fb['floor']['ratio']:.0f}x per-object events/sec at "
              f"n={fb['floor']['n_clients']}; top of sweep "
              f"{top['events_per_sec']:.2e} ev/s, {top['rss_mb']:.0f} MB "
              f"RSS, telemetry={top['telemetry']})")
        print("serving_scale smoke OK")
        return
    if args.smoke and args.chaos:
        cb = run_chaos_probe()
        print(f"serving_scale chaos smoke OK "
              f"(mIoU {cb['mean_miou_fault_free']:.3f} -> "
              f"{cb['mean_miou_under_faults']:.3f}, gap "
              f"{cb['miou_gap']:.3f} <= {cb['miou_gap_bound']}; "
              f"{cb['grants_killed']} crashed grants all recovered, "
              f"{cb['upload_retries']} upload retries, "
              f"{cb['deltas_superseded']} deltas superseded)")
        print("serving_scale smoke OK")
        return
    if args.smoke and args.trace:
        trace = run_trace_probe(args.trace)
        ob = run_drift_probe()
        print(f"serving_scale trace smoke OK "
              f"({len(trace['traceEvents'])} trace events; drift bottleneck "
              f"{ob['stage_report']['bottleneck']}, "
              f"compile {ob['compile_s']:.1f}s)")
        print("serving_scale smoke OK")
        return
    if args.smoke and args.update_pipeline:
        ub = run_update_sweep()
        seq = ub["sessions_sustained_1gpu"]["per_session"]
        bat = ub["sessions_sustained_1gpu"]["batched"]
        assert seq > 0, "per-session update pricing sustains nothing"
        assert bat >= seq, (
            f"batched update pipeline should never sustain fewer sessions "
            f"(got {bat} vs per-session {seq})")
        assert ub["update_s_saved_at_peak"] > 0.0
        wall = ub["wallclock_8_sessions_select_encode"]
        assert wall["byte_identical"], "batched encode changed wire bytes"
        assert wall["ratio"] <= 0.6, (
            f"batched select+encode for 8 sessions is {wall['ratio']:.2f}x "
            f"sequential; expected <= 0.6x")
        print(f"serving_scale update-pipeline smoke OK (sustained {seq} -> "
              f"{bat} sessions on 1 GPU, select+encode {wall['ratio']:.2f}x, "
              f"{ub['update_s_saved_at_peak']:.1f}s device time saved at "
              f"peak)")
        print("serving_scale smoke OK")
        return
    if args.smoke and args.overlap:
        ob = run_overlap_sweep()
        ser = ob["sessions_sustained_1gpu"]["serialized"]
        ovl = ob["sessions_sustained_1gpu"]["overlap"]
        assert ser > 0, "serialized fused 1-GPU engine sustains nothing"
        assert ovl > ser, (
            f"dual-stream overlap should sustain strictly more sessions on "
            f"one GPU than the serialized clock (got {ovl} vs {ser})")
        su = ob["stream_utilization_at_peak"]
        assert su["label"] > 0.0 and su["train"] > 0.0
        assert ob["overlap_s_at_peak"] > 0.0
        print(f"serving_scale overlap smoke OK (sustained {ser} -> {ovl} "
              f"sessions on 1 GPU, {ob['preemptions_at_peak']} preemptions "
              f"at peak)")
        print("serving_scale smoke OK")
        return
    if args.smoke and args.fused:
        fb = run_fused_sweep()
        seq = fb["sessions_sustained_1gpu"]["sequential"]
        fus = fb["sessions_sustained_1gpu"]["fused"]
        assert seq > 0, "sequential 1-GPU engine sustains nothing"
        assert fus > seq, (
            f"fused training should sustain more sessions on one GPU "
            f"(got {fus} vs sequential {seq})")
        ratio = fb["wallclock_8_sessions_1_phase"]["ratio"]
        assert ratio <= 0.6, (
            f"fused wall-clock for 8 sessions x 1 phase is {ratio:.2f}x "
            f"sequential; expected <= 0.6x")
        print(f"serving_scale fused smoke OK (sustained {seq} -> {fus} "
              f"sessions on 1 GPU, wall-clock {ratio:.2f}x)")
        print("serving_scale smoke OK")
        return
    if args.smoke:
        if args.gpus <= 1:  # the pool smoke below is its own gate; don't
            # repeat the single-GPU sweep ci.sh already ran separately
            out = run(duration=args.duration, policy=args.policy, quick=True)
            assert all(r["events_processed"] > 0 for r in out.values())
            assert all(r["mean_up_kbps"] > 0 for r in out.values())
        else:
            bench = run_pool_sweep(args.gpus, mode="smoke")
            s1 = bench["sessions_sustained"]["1"]
            sg = bench["sessions_sustained"][str(args.gpus)]
            assert s1 > 0, "1-GPU pool sustains nothing at the target mIoU"
            assert sg >= 3 * s1, (
                f"sustained sessions scaled {sg}/{s1} = {sg / max(s1, 1):.1f}x "
                f"from 1 -> {args.gpus} GPUs; expected >= 3x")
            aff = bench["affinity_at_max_gpus"]
            assert (aff["affinity"]["mean_miou"] > aff["gain"]["mean_miou"]
                    or aff["affinity"]["phases_served"]
                    > aff["gain"]["phases_served"]), (
                "affinity-aware placement should beat blind assignment")
            print(f"serving_scale pool smoke OK "
                  f"(sustained {s1} -> {sg} sessions, affinity beats blind)")
        print("serving_scale smoke OK")
    else:
        run(duration=args.duration, policy=args.policy)
        if args.gpus > 1:
            run_pool_sweep(args.gpus, duration=args.duration or 240.0)
        if args.fused:
            run_fused_sweep(duration=args.duration or 240.0)
        if args.overlap:
            run_overlap_sweep(duration=args.duration or 240.0)
        if args.update_pipeline:
            run_update_sweep(duration=args.duration or 240.0)
        if args.chaos:
            run_chaos_probe(duration=args.duration or 240.0)
        if args.fleet:
            run_fleet_probe(duration=args.duration or 240.0)
        if args.sharded:
            run_sharded_probe()


if __name__ == "__main__":
    main()
