"""Serving-engine scale: push client count and measure the runtime itself.

Uses compute-free `StubSession`s (modeled GPU/network timing, no JAX math)
so the numbers are pure engine throughput: events/sec, GPU utilization,
deferral rate, and per-client Kbps as one GPU saturates under 4 -> 64
clients. ``--smoke`` is the CI entry point (small counts, short horizon).

Run: PYTHONPATH=src python -m benchmarks.serving_scale [--smoke] [--policy gain]
"""
from __future__ import annotations

import argparse

from benchmarks.common import Timer, emit
from repro.core.scheduler import GPUCostModel
from repro.serving import (
    ClientNetwork,
    LinkSpec,
    ServingConfig,
    ServingEngine,
    StubSession,
)


def make_stub_fleet(n: int, *, stationary_frac: float = 0.3,
                    link: LinkSpec | None = None) -> list[StubSession]:
    """A mixed fleet: the head of the list is near-static (low sampling rate,
    slow decay), the rest dynamic — the same shape as the seg sweeps."""
    link = link or LinkSpec(up_kbps=500.0, down_kbps=2000.0)
    fleet = []
    for i in range(n):
        static = i < int(stationary_frac * n)
        fleet.append(StubSession(
            i,
            rate=0.15 if static else 1.0,
            dynamics=0.0005 if static else 0.004,
            net=ClientNetwork(link),
        ))
    return fleet


def run(counts=None, duration: float | None = None, policy: str = "gain",
        max_queue: int = 32, quick: bool = False) -> dict:
    if counts is None:
        counts = (4, 16) if quick else (4, 8, 16, 32, 64)
    if duration is None:
        duration = 60.0 if quick else 300.0
    out = {}
    for n in counts:
        fleet = make_stub_fleet(n)
        engine = ServingEngine(
            fleet, policy=policy, cost=GPUCostModel(),
            cfg=ServingConfig(duration=duration, max_queue=max_queue))
        with Timer() as t:
            r = engine.run()
        out[n] = r
        emit(f"serving_scale.{policy}.n{n}", t.us,
             f"evps={r['events_per_sec']:.0f};events={r['events_processed']};"
             f"gpu_util={r['gpu_utilization']:.2f};"
             f"deferral_rate={r['deferral_rate']:.2f};"
             f"drop={r['dropped_requests']};backlog={r['max_backlog']};"
             f"up_kbps={r['mean_up_kbps']:.1f};"
             f"down_kbps={r['mean_down_kbps']:.1f};"
             f"miou={r['mean_miou']:.3f}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: 2 counts, short horizon")
    ap.add_argument("--policy", default="gain",
                    choices=("fair", "edf", "gain"))
    ap.add_argument("--duration", type=float, default=None)
    args = ap.parse_args()
    if args.smoke:
        out = run(duration=args.duration, policy=args.policy, quick=True)
        assert all(r["events_processed"] > 0 for r in out.values())
        assert all(r["mean_up_kbps"] > 0 for r in out.values())
        print("serving_scale smoke OK")
    else:
        run(duration=args.duration, policy=args.policy)


if __name__ == "__main__":
    main()
