"""Paper Table 3: coordinate-selection strategy ablation at gamma=5%,
reported as mIoU delta vs full-model updates (and downlink Kbps)."""
from __future__ import annotations

from benchmarks.common import Timer, default_ams, emit, pretrained, video_cfg
from repro.sim.runner import SimConfig, run_scheme
from repro.sim.seg_world import SegWorld

STRATEGIES = ("full", "gradient_guided", "random", "first", "last", "first_last")


def run(quick: bool = True, duration: float = 120.0, gamma: float = 0.05, seed: int = 31):
    pre = pretrained()
    sim = SimConfig(eval_stride=4)
    results = {}
    for strat in STRATEGIES:
        world = SegWorld.make(video_cfg(seed, duration))
        cfg = default_ams(strategy=strat, gamma=1.0 if strat == "full" else gamma)
        with Timer() as t:
            r = run_scheme("ams", world, pre, cfg, sim, seed=seed)
        _, down = r.bandwidth_kbps(duration)
        results[strat] = (r.mean_miou, down)
    base = results["full"][0]
    for strat, (m, down) in results.items():
        emit(f"table3.{strat}", t.us,
             f"miou={m:.4f};delta_vs_full={m - base:+.4f};down_kbps={down:.1f}")
    return results


if __name__ == "__main__":
    run()
