"""Shared benchmark plumbing: cached pretrained checkpoint, default configs,
CSV emission (contract: ``name,us_per_call,derived``)."""
from __future__ import annotations

import os
import time

import jax

from repro import checkpoint
from repro.core.server import AMSConfig
from repro.data.video import VideoConfig
from repro.models.seg.student import SegConfig, make_student
from repro.sim.seg_world import SegWorld, pretrain_student

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
SIZE, FPS = 48, 4.0
SEG_CFG = SegConfig(n_classes=5)


def video_cfg(seed: int, duration: float = 150.0, **kw) -> VideoConfig:
    return VideoConfig(height=SIZE, width=SIZE, fps=FPS, duration=duration,
                       seed=seed, drift_period=kw.pop("drift_period", 240.0), **kw)


def default_ams(**kw) -> AMSConfig:
    # calibrated to the compressed timescale (EXPERIMENTS.md §Repro): the
    # paper's T_update=10 s and gamma=5% are kept; K/horizon/lr scale to the
    # 150 s streams with a 240 s drift period.
    # ATR slowdown band shifted up from the paper's 0.25/0.35 fps: our ASR
    # equilibrates at ~0.35 fps on stationary feeds (the oracle teacher's
    # corruption refresh sets a phi noise floor), so the band must sit above
    # that equilibrium to separate stationary from dynamic feeds.
    base = dict(t_update=10.0, t_horizon=40.0, k_iters=25, batch_size=8,
                gamma=0.05, lr=2e-3, phi_target=0.15, asr_eta=1.0,
                atr_gamma0=0.45, atr_gamma1=0.60)
    base.update(kw)
    return AMSConfig(**base)


def pretrained(steps: int = 600):
    """Generic 'No Customization' checkpoint, cached across benchmarks."""
    path = os.path.join(RESULTS, "pretrained_student_v2.npz")
    like = make_student(SEG_CFG, jax.random.PRNGKey(42))
    if checkpoint.exists(path):
        return checkpoint.load(path, like)
    params = pretrain_student(SEG_CFG, n_videos=5, steps=steps, lr=2e-3,
                              video_kw=dict(height=SIZE, width=SIZE, fps=FPS,
                                            duration=60.0))
    checkpoint.save(path, params)
    return params


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0

    @property
    def us(self):
        return self.s * 1e6
