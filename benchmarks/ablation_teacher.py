"""Teacher-fidelity ablation (beyond paper): the §Repro conclusions must not
depend on the oracle-teacher substitution (DESIGN.md §5) — re-run
AMS/No-Customization under a *learned* wide-convnet teacher and check the
same ordering and bandwidth."""
from __future__ import annotations

from benchmarks.common import Timer, default_ams, emit, pretrained, video_cfg
from repro.data.video import SyntheticVideo
from repro.models.seg.teacher import train_teacher
from repro.sim.runner import SimConfig, run_scheme
from repro.sim.seg_world import SegWorld


def run(quick: bool = True, duration: float = 120.0, seed: int = 11):
    pre = pretrained()
    vc = video_cfg(seed, duration)
    for kind in ("oracle", "learned"):
        world = SegWorld.make(vc)
        if kind == "learned":
            with Timer() as tt:
                world.teacher = train_teacher(world.video, vc.n_classes,
                                              steps=150 if quick else 400)
            emit("ablation_teacher.fit", tt.us, "wide-convnet teacher fit on GT")
        results = {}
        for scheme in ("no_custom", "ams"):
            with Timer() as t:
                r = run_scheme(scheme, world, pre, default_ams(),
                               SimConfig(eval_stride=5), seed=seed)
            _, down = r.bandwidth_kbps(duration)
            results[scheme] = r.mean_miou
            emit(f"ablation_teacher.{kind}.{scheme}", t.us,
                 f"miou={r.mean_miou:.4f};down_kbps={down:.1f}")
        emit(f"ablation_teacher.{kind}.gain", 0.0,
             f"ams_minus_nocustom={results['ams'] - results['no_custom']:+.4f}")


if __name__ == "__main__":
    run()
