"""Kernel microbenchmarks: fused masked-Adam Pallas kernel vs the unfused
tree_map implementation, and the flash kernel vs the naive oracle.

On this CPU container the Pallas kernels run in interpret mode, so wall time
is NOT the TPU story — the derived column reports the structural win instead:
HBM bytes per parameter per iteration (fused = one pass) and attention HBM
working set (flash = O(block^2) vs naive O(S^2))."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit


def run(quick: bool = True):
    n = 1 << 18
    rng = np.random.default_rng(0)
    p, g, m = (jnp.asarray(rng.normal(size=n), jnp.float32) for _ in range(3))
    v = jnp.asarray(rng.uniform(0.01, 1, n), jnp.float32)
    b = jnp.asarray(rng.integers(0, 2, n), jnp.float32)

    from repro.core.masked_adam import init_state, masked_adam_update
    from repro.kernels.masked_adam.ops import masked_adam_leaf

    tree = {"w": p}
    st = init_state(tree)
    mask = {"w": b}

    @jax.jit
    def unfused(tree, st, mask, grads):
        return masked_adam_update(tree, grads, st, mask)

    unfused(tree, st, mask, {"w": g})  # warm
    with Timer() as t1:
        for _ in range(5):
            out = unfused(tree, st, mask, {"w": g})
        jax.block_until_ready(out[0]["w"])
    # fused kernel (interpret mode)
    bc = jnp.float32(1e-3)
    masked_adam_leaf(p, g, m, v, b, bc)  # warm
    with Timer() as t2:
        for _ in range(5):
            o = masked_adam_leaf(p, g, m, v, b, bc)
        jax.block_until_ready(o[0])
    # structural: unfused XLA emits ~10 elementwise HLO ops -> >= 2 extra
    # round-trips without fusion; fused kernel = 6 reads + 4 writes exactly.
    emit("kernels.masked_adam.unfused", t1.us / 5, "hbm_passes=variable(XLA fusion)")
    emit("kernels.masked_adam.fused_pallas_interp", t2.us / 5,
         "hbm_bytes_per_param=40(6r+4w fixed)")

    from repro.kernels.flash_attention.ops import flash_attention_pallas
    from repro.kernels.flash_attention.ref import flash_attention_ref

    B, S, KV, G, hd = 1, 512, 2, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, KV, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    q4 = q.transpose(0, 2, 3, 1, 4).reshape(B, KV * G, S, hd)
    ref = jax.jit(lambda a, b_, c: flash_attention_ref(a, b_, c))
    ref(q4, k.transpose(0, 2, 1, 3), vv.transpose(0, 2, 1, 3))
    with Timer() as t3:
        o = ref(q4, k.transpose(0, 2, 1, 3), vv.transpose(0, 2, 1, 3))
        jax.block_until_ready(o)
    with Timer() as t4:
        o = flash_attention_pallas(q, k, vv, block_q=128, block_k=128)
        jax.block_until_ready(o)
    naive_ws = S * S * KV * G * 4
    flash_ws = 128 * 128 * 4 * 2
    emit("kernels.flash.naive", t3.us, f"score_bytes={naive_ws}")
    emit("kernels.flash.pallas_interp", t4.us,
         f"vmem_tile_bytes={flash_ws};skip_blocks=causal/window")


if __name__ == "__main__":
    run()
