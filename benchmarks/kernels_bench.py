"""Kernel microbenchmarks: fused masked-Adam Pallas kernel vs the unfused
tree_map implementation, the flash kernel vs the naive oracle, and fused
cross-session training (`core.batched`) vs the sequential phase loop.

On this CPU container the Pallas kernels run in interpret mode, so wall time
is NOT the TPU story — the derived column reports the structural win instead:
HBM bytes per parameter per iteration (fused = one pass) and attention HBM
working set (flash = O(block^2) vs naive O(S^2)). The fused-training compare
IS a real wall-clock story here: collapsing B sessions x K iterations of
dispatch into stacked launches pays off on any backend."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit


def fused_phase_compare(n_sessions: int = 8, k_iters: int = 20,
                        size: int = 24) -> dict:
    """Wall-clock for ``n_sessions`` seg sessions x one training phase:
    the sequential per-session ``train_phase`` loop vs one fused stacked
    launch (`core.batched.train_phases_fused`). Both paths are warmed
    (compile excluded); identical twin fleets keep the math comparable."""
    from repro.core import batched
    from repro.core.server import AMSConfig, AMSSession, Task
    from repro.data.video import VideoConfig
    from repro.models.seg.student import SegConfig, make_student
    from repro.sim.seg_world import SegWorld, phi_pixel_loss

    seg = SegConfig(n_classes=5)
    ams = AMSConfig(t_update=10.0, t_horizon=60.0, k_iters=k_iters,
                    batch_size=4, gamma=0.05, lr=2e-3, phi_target=0.15)
    pre = make_student(seg, jax.random.PRNGKey(0))

    def fleet(offset: int):
        out = []
        for i in range(n_sessions):
            world = SegWorld.make(
                VideoConfig(seed=offset + i, height=size, width=size,
                            fps=2.0, duration=30.0), seg)
            task = Task(loss_and_grad=world.loss_and_grad, teacher=None,
                        phi_loss=phi_pixel_loss)
            s = AMSSession(task, ams, jax.tree.map(lambda x: x, pre), seed=i)
            frames = np.stack([world.video.frame(j)[0] for j in range(8)])
            labels = np.stack([world.teacher.label(j) for j in range(8)])
            s.receive_labeled(frames, labels, 5.0)
            out.append(s)
        return out

    for s in fleet(500):  # warm the sequential path
        s.train_phase(6.0)
    batched.train_phases_fused(fleet(600), 6.0)  # warm the fused executable

    seq = fleet(700)
    with Timer() as t_seq:
        for s in seq:
            s.train_phase(6.0)
    fused = fleet(800)
    with Timer() as t_fused:
        batched.train_phases_fused(fused, 6.0)
    ratio = t_fused.s / max(t_seq.s, 1e-9)
    emit(f"kernels.fused_train.sequential.n{n_sessions}", t_seq.us,
         f"k={k_iters};launches={n_sessions * k_iters}")
    emit(f"kernels.fused_train.stacked.n{n_sessions}", t_fused.us,
         f"k={k_iters};ratio_vs_sequential={ratio:.3f};"
         f"cache={batched.cache_info()['size']}")
    return {"n_sessions": n_sessions, "k_iters": k_iters,
            "sequential_s": t_seq.s, "fused_s": t_fused.s, "ratio": ratio}


def update_pipeline_compare(n_sessions: int = 8, k_iters: int = 20,
                            size: int = 24) -> dict:
    """Wall-clock for ``n_sessions`` seg sessions' post-train update
    production (gradient-guided selection + wire-delta encode): the
    per-session loop — B bisection/sort launches and B leaf-by-leaf
    device->host encodes — vs the fused pipeline: ONE stacked selection
    launch + ONE batched stacked encode (`core.selection` + `core.delta`).
    Parameters enter the batched path already stacked (that is the shape a
    fused train launch leaves them in); the u_prev stack is built inside the
    timed region. Both paths are warmed (compile excluded) and the batched
    deltas are asserted byte-identical to the per-session ones."""
    from repro.core import selection
    from repro.core.batched import stack_trees
    from repro.core.delta import encode_delta, encode_delta_stack

    sessions = _update_fleet(n_sessions, k_iters, size)
    gamma = sessions[0].cfg.gamma
    u_prevs = [s.u_prev for s in sessions]
    params = [s.params for s in sessions]
    params_stacked = stack_trees(params)  # a fused grant holds them stacked

    def sequential():
        out = []
        for u, p in zip(u_prevs, params):
            mask = selection.gradient_guided_mask(u, gamma)
            out.append(encode_delta(p, mask))
        return out

    def fused():
        masks = selection.stacked_gradient_guided_masks(
            stack_trees(u_prevs), gamma)
        return encode_delta_stack(params_stacked, masks, n_sessions)

    seq_d = sequential()  # warm both paths (jit compiles excluded)
    fus_d = fused()
    identical = all(
        np.array_equal(a.values, b.values) and a.packed_mask == b.packed_mask
        and a.total_bytes == b.total_bytes for a, b in zip(seq_d, fus_d))
    assert identical, "batched update pipeline changed wire bytes"
    reps = 5
    with Timer() as t_seq:
        for _ in range(reps):
            sequential()
    with Timer() as t_fused:
        for _ in range(reps):
            fused()
    ratio = t_fused.s / max(t_seq.s, 1e-9)
    emit(f"kernels.update_pipeline.sequential.n{n_sessions}", t_seq.us / reps,
         f"launches={2 * n_sessions};bytes={sum(d.total_bytes for d in seq_d)}")
    emit(f"kernels.update_pipeline.stacked.n{n_sessions}", t_fused.us / reps,
         f"launches=2;ratio_vs_sequential={ratio:.3f};byte_identical={identical}")
    return {"n_sessions": n_sessions, "sequential_s": t_seq.s / reps,
            "fused_s": t_fused.s / reps, "ratio": ratio,
            "byte_identical": bool(identical)}


def _update_fleet(n_sessions: int, k_iters: int, size: int):
    """Seg sessions one phase in (u_prev populated) — the state the update
    pipeline runs from."""
    from repro.core.server import AMSConfig, AMSSession, Task
    from repro.data.video import VideoConfig
    from repro.models.seg.student import SegConfig, make_student
    from repro.sim.seg_world import SegWorld, phi_pixel_loss

    seg = SegConfig(n_classes=5)
    ams = AMSConfig(t_update=10.0, t_horizon=60.0, k_iters=k_iters,
                    batch_size=4, gamma=0.05, lr=2e-3, phi_target=0.15)
    pre = make_student(seg, jax.random.PRNGKey(0))
    out = []
    for i in range(n_sessions):
        world = SegWorld.make(
            VideoConfig(seed=900 + i, height=size, width=size, fps=2.0,
                        duration=30.0), seg)
        task = Task(loss_and_grad=world.loss_and_grad, teacher=None,
                    phi_loss=phi_pixel_loss)
        s = AMSSession(task, ams, jax.tree.map(lambda x: x, pre), seed=i)
        frames = np.stack([world.video.frame(j)[0] for j in range(8)])
        labels = np.stack([world.teacher.label(j) for j in range(8)])
        s.receive_labeled(frames, labels, 5.0)
        s.train_phase(6.0)
        out.append(s)
    return out


def run(quick: bool = True):
    n = 1 << 18
    rng = np.random.default_rng(0)
    p, g, m = (jnp.asarray(rng.normal(size=n), jnp.float32) for _ in range(3))
    v = jnp.asarray(rng.uniform(0.01, 1, n), jnp.float32)
    b = jnp.asarray(rng.integers(0, 2, n), jnp.float32)

    from repro.core.masked_adam import init_state, masked_adam_update
    from repro.kernels.masked_adam.ops import masked_adam_leaf

    tree = {"w": p}
    st = init_state(tree)
    mask = {"w": b}

    @jax.jit
    def unfused(tree, st, mask, grads):
        return masked_adam_update(tree, grads, st, mask)

    unfused(tree, st, mask, {"w": g})  # warm
    with Timer() as t1:
        for _ in range(5):
            out = unfused(tree, st, mask, {"w": g})
        jax.block_until_ready(out[0]["w"])
    # fused kernel (interpret mode)
    bc = jnp.float32(1e-3)
    masked_adam_leaf(p, g, m, v, b, bc)  # warm
    with Timer() as t2:
        for _ in range(5):
            o = masked_adam_leaf(p, g, m, v, b, bc)
        jax.block_until_ready(o[0])
    # structural: unfused XLA emits ~10 elementwise HLO ops -> >= 2 extra
    # round-trips without fusion; fused kernel = 6 reads + 4 writes exactly.
    emit("kernels.masked_adam.unfused", t1.us / 5, "hbm_passes=variable(XLA fusion)")
    emit("kernels.masked_adam.fused_pallas_interp", t2.us / 5,
         "hbm_bytes_per_param=40(6r+4w fixed)")

    from repro.kernels.flash_attention.ops import flash_attention_pallas
    from repro.kernels.flash_attention.ref import flash_attention_ref

    B, S, KV, G, hd = 1, 512, 2, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, KV, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    q4 = q.transpose(0, 2, 3, 1, 4).reshape(B, KV * G, S, hd)
    ref = jax.jit(lambda a, b_, c: flash_attention_ref(a, b_, c))
    ref(q4, k.transpose(0, 2, 1, 3), vv.transpose(0, 2, 1, 3))
    with Timer() as t3:
        o = ref(q4, k.transpose(0, 2, 1, 3), vv.transpose(0, 2, 1, 3))
        jax.block_until_ready(o)
    with Timer() as t4:
        o = flash_attention_pallas(q, k, vv, block_q=128, block_k=128)
        jax.block_until_ready(o)
    naive_ws = S * S * KV * G * 4
    flash_ws = 128 * 128 * 4 * 2
    emit("kernels.flash.naive", t3.us, f"score_bytes={naive_ws}")
    emit("kernels.flash.pallas_interp", t4.us,
         f"vmem_tile_bytes={flash_ws};skip_blocks=causal/window")

    fused_phase_compare(n_sessions=4 if quick else 8)
    update_pipeline_compare(n_sessions=4 if quick else 8)


if __name__ == "__main__":
    run()
