"""Kernel microbenchmarks: fused masked-Adam Pallas kernel vs the unfused
tree_map implementation, the flash kernel vs the naive oracle, and fused
cross-session training (`core.batched`) vs the sequential phase loop.

On this CPU container the Pallas kernels run in interpret mode, so wall time
is NOT the TPU story — the derived column reports the structural win instead:
HBM bytes per parameter per iteration (fused = one pass) and attention HBM
working set (flash = O(block^2) vs naive O(S^2)). The fused-training compare
IS a real wall-clock story here: collapsing B sessions x K iterations of
dispatch into stacked launches pays off on any backend."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit


def fused_phase_compare(n_sessions: int = 8, k_iters: int = 20,
                        size: int = 24) -> dict:
    """Wall-clock for ``n_sessions`` seg sessions x one training phase:
    the sequential per-session ``train_phase`` loop vs one fused stacked
    launch (`core.batched.train_phases_fused`). Both paths are warmed
    (compile excluded); identical twin fleets keep the math comparable."""
    from repro.core import batched
    from repro.core.server import AMSConfig, AMSSession, Task
    from repro.data.video import VideoConfig
    from repro.models.seg.student import SegConfig, make_student
    from repro.sim.seg_world import SegWorld, phi_pixel_loss

    seg = SegConfig(n_classes=5)
    ams = AMSConfig(t_update=10.0, t_horizon=60.0, k_iters=k_iters,
                    batch_size=4, gamma=0.05, lr=2e-3, phi_target=0.15)
    pre = make_student(seg, jax.random.PRNGKey(0))

    def fleet(offset: int):
        out = []
        for i in range(n_sessions):
            world = SegWorld.make(
                VideoConfig(seed=offset + i, height=size, width=size,
                            fps=2.0, duration=30.0), seg)
            task = Task(loss_and_grad=world.loss_and_grad, teacher=None,
                        phi_loss=phi_pixel_loss)
            s = AMSSession(task, ams, jax.tree.map(lambda x: x, pre), seed=i)
            frames = np.stack([world.video.frame(j)[0] for j in range(8)])
            labels = np.stack([world.teacher.label(j) for j in range(8)])
            s.receive_labeled(frames, labels, 5.0)
            out.append(s)
        return out

    for s in fleet(500):  # warm the sequential path
        s.train_phase(6.0)
    batched.train_phases_fused(fleet(600), 6.0)  # warm the fused executable

    seq = fleet(700)
    with Timer() as t_seq:
        for s in seq:
            s.train_phase(6.0)
    fused = fleet(800)
    with Timer() as t_fused:
        batched.train_phases_fused(fused, 6.0)
    ratio = t_fused.s / max(t_seq.s, 1e-9)
    emit(f"kernels.fused_train.sequential.n{n_sessions}", t_seq.us,
         f"k={k_iters};launches={n_sessions * k_iters}")
    emit(f"kernels.fused_train.stacked.n{n_sessions}", t_fused.us,
         f"k={k_iters};ratio_vs_sequential={ratio:.3f};"
         f"cache={batched.cache_info()['size']}")
    return {"n_sessions": n_sessions, "k_iters": k_iters,
            "sequential_s": t_seq.s, "fused_s": t_fused.s, "ratio": ratio}


def update_pipeline_compare(n_sessions: int = 8, k_iters: int = 20,
                            size: int = 24) -> dict:
    """Wall-clock for ``n_sessions`` seg sessions' post-train update
    production (gradient-guided selection + wire-delta encode): the
    per-session loop — B bisection/sort launches and B leaf-by-leaf
    device->host encodes — vs the fused pipeline: ONE stacked selection
    launch + ONE batched stacked encode (`core.selection` + `core.delta`).
    Parameters enter the batched path already stacked (that is the shape a
    fused train launch leaves them in); the u_prev stack is built inside the
    timed region. Both paths are warmed (compile excluded) and the batched
    deltas are asserted byte-identical to the per-session ones."""
    from repro.core import selection
    from repro.core.batched import stack_trees
    from repro.core.delta import encode_delta, encode_delta_stack

    sessions = _update_fleet(n_sessions, k_iters, size)
    gamma = sessions[0].cfg.gamma
    u_prevs = [s.u_prev for s in sessions]
    params = [s.params for s in sessions]
    params_stacked = stack_trees(params)  # a fused grant holds them stacked

    def sequential():
        out = []
        for u, p in zip(u_prevs, params):
            mask = selection.gradient_guided_mask(u, gamma)
            out.append(encode_delta(p, mask))
        return out

    def fused():
        masks = selection.stacked_gradient_guided_masks(
            stack_trees(u_prevs), gamma)
        return encode_delta_stack(params_stacked, masks, n_sessions)

    seq_d = sequential()  # warm both paths (jit compiles excluded)
    fus_d = fused()
    identical = all(
        np.array_equal(a.values, b.values) and a.packed_mask == b.packed_mask
        and a.total_bytes == b.total_bytes for a, b in zip(seq_d, fus_d))
    assert identical, "batched update pipeline changed wire bytes"
    reps = 5
    with Timer() as t_seq:
        for _ in range(reps):
            sequential()
    with Timer() as t_fused:
        for _ in range(reps):
            fused()
    ratio = t_fused.s / max(t_seq.s, 1e-9)
    emit(f"kernels.update_pipeline.sequential.n{n_sessions}", t_seq.us / reps,
         f"launches={2 * n_sessions};bytes={sum(d.total_bytes for d in seq_d)}")
    emit(f"kernels.update_pipeline.stacked.n{n_sessions}", t_fused.us / reps,
         f"launches=2;ratio_vs_sequential={ratio:.3f};byte_identical={identical}")
    return {"n_sessions": n_sessions, "sequential_s": t_seq.s / reps,
            "fused_s": t_fused.s / reps, "ratio": ratio,
            "byte_identical": bool(identical)}


def _update_fleet(n_sessions: int, k_iters: int, size: int):
    """Seg sessions one phase in (u_prev populated) — the state the update
    pipeline runs from."""
    from repro.core.server import AMSConfig, AMSSession, Task
    from repro.data.video import VideoConfig
    from repro.models.seg.student import SegConfig, make_student
    from repro.sim.seg_world import SegWorld, phi_pixel_loss

    seg = SegConfig(n_classes=5)
    ams = AMSConfig(t_update=10.0, t_horizon=60.0, k_iters=k_iters,
                    batch_size=4, gamma=0.05, lr=2e-3, phi_target=0.15)
    pre = make_student(seg, jax.random.PRNGKey(0))
    out = []
    for i in range(n_sessions):
        world = SegWorld.make(
            VideoConfig(seed=900 + i, height=size, width=size, fps=2.0,
                        duration=30.0), seg)
        task = Task(loss_and_grad=world.loss_and_grad, teacher=None,
                    phi_loss=phi_pixel_loss)
        s = AMSSession(task, ams, jax.tree.map(lambda x: x, pre), seed=i)
        frames = np.stack([world.video.frame(j)[0] for j in range(8)])
        labels = np.stack([world.teacher.label(j) for j in range(8)])
        s.receive_labeled(frames, labels, 5.0)
        s.train_phase(6.0)
        out.append(s)
    return out


def _f16_ulp_diff(a, b) -> int:
    """Max ULP distance between two float16 arrays (0 = byte-identical)."""
    def lex(x):
        u = np.asarray(x, np.float16).reshape(-1).view(np.uint16).astype(np.int32)
        return np.where(u >= 0x8000, 0x8000 - u, u)  # monotone in value
    la, lb = lex(a), lex(b)
    return int(np.max(np.abs(la - lb))) if la.size else 0


def kernel_equivalence_gate(n_sessions: int = 4, k_iters: int = 3,
                            size: int = 24) -> dict:
    """Serving-level XLA-vs-Pallas contract, asserted on the REAL fused
    path: identical twin seg fleets run two fused phases under
    ``kernel_mode("xla")`` and ``kernel_mode("pallas")``; the streamed
    wire deltas must carry byte-identical packed masks (selection is an
    exact integer search in both engines) and fp16 values within 1 ULP
    (the residue of XLA:CPU's context-dependent FMA contraction, which
    makes even the XLA path differ jit-vs-nojit — see
    `core.batched._build_phase_fn`)."""
    from repro.core import batched, kernel_dispatch, selection

    def run_mode(kern):
        batched.cache_clear()
        selection.stacked_cache_clear()
        kernel_dispatch.reset()
        batched.set_kernel_mode(kern)
        try:
            ss = _update_fleet(n_sessions, k_iters, size)
            r1 = batched.train_phases_fused(ss, 8.0, force_stack=True)
            r2 = batched.train_phases_fused(ss, 12.0, force_stack=True)
        finally:
            batched.set_kernel_mode("xla")
        return r1 + r2

    dx, dp = run_mode("xla"), run_mode("pallas")
    masks_ok = all(a.packed_mask == b.packed_mask for a, b in zip(dx, dp))
    assert masks_ok, "pallas kernel changed a streamed wire mask"
    max_ulp = max(_f16_ulp_diff(a.values, b.values) for a, b in zip(dx, dp))
    assert max_ulp <= 1, (
        f"pallas wire-delta values drifted {max_ulp} f16 ULP (>1) from XLA")
    n_identical = sum(np.array_equal(np.asarray(a.values),
                                     np.asarray(b.values))
                      for a, b in zip(dx, dp))
    emit(f"kernels.gate.equivalence.n{n_sessions}", 0.0,
         f"deltas={len(dx)};masks_byte_identical={masks_ok};"
         f"values_max_ulp={max_ulp};values_identical={n_identical}/{len(dx)}")
    return {"n_deltas": len(dx), "masks_byte_identical": bool(masks_ok),
            "values_max_f16_ulp": max_ulp,
            "values_byte_identical": int(n_identical)}


def kernel_roofline_compare(b: int = 4, n: int = 1 << 16) -> dict:
    """Standalone stacked-kernel timings vs their analytic HBM bounds.

    Times the fused Pallas masked-Adam and bit-pattern top-k against their
    XLA references on a synthetic B-stacked tree, reports each engine's
    achieved fraction of the memory roofline
    (`roofline.analysis.kernel_roofline_fraction` over
    `adam_step_hbm_bytes` / `topk_hbm_bytes`), and asserts the top-k masks
    are byte-identical. Interpret-mode wall-clock is not the TPU story —
    the fractions quantify the structural bytes story either way."""
    import functools
    import math

    from repro.core import selection
    from repro.core.batched import stack_trees
    from repro.core.masked_adam import init_state, masked_adam_update
    from repro.kernels.masked_adam.ops import masked_adam_stacked
    from repro.kernels.topk_mask import stacked_topk_masks
    from repro.roofline import analysis

    rng = np.random.default_rng(7)

    def one_tree():
        return {"w": jnp.asarray(rng.normal(size=(n - 300,)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(300,)), jnp.float32)}

    trees = [one_tree() for _ in range(b)]
    grads = [one_tree() for _ in range(b)]
    masks = [jax.tree.map(lambda l: jnp.asarray(
        rng.integers(0, 2, l.shape), bool), t) for t in trees]
    p = stack_trees(trees)
    g = stack_trees(grads)
    m = stack_trees(masks)
    st = stack_trees([init_state(t) for t in trees])

    xla_adam = jax.jit(jax.vmap(lambda p_, g_, s_, m_: masked_adam_update(
        p_, g_, s_, m_)))
    pal_adam = jax.jit(functools.partial(masked_adam_stacked,
                                         lr=1e-3, b1=0.9, b2=0.999, eps=1e-8))
    reps = 3
    out = {}
    adam_nbytes = b * analysis.adam_step_hbm_bytes(n)
    times = {}
    for name, fn in (("xla", xla_adam), ("pallas", pal_adam)):
        jax.block_until_ready(jax.tree.leaves(fn(p, g, st, m))[0])  # warm
        with Timer() as t:
            for _ in range(reps):
                o = fn(p, g, st, m)
            jax.block_until_ready(jax.tree.leaves(o)[0])
        times[name] = t.s / reps
    out["adam"] = {
        "b": b, "n_per_session": n, "nbytes": adam_nbytes,
        "xla_s": times["xla"], "pallas_s": times["pallas"],
        "ratio": times["pallas"] / max(times["xla"], 1e-12),
        "roofline_fraction_xla": analysis.kernel_roofline_fraction(
            adam_nbytes, times["xla"]),
        "roofline_fraction_pallas": analysis.kernel_roofline_fraction(
            adam_nbytes, times["pallas"]),
    }

    u = stack_trees([one_tree() for _ in range(b)])
    frac = 0.05
    xla_topk = jax.jit(jax.vmap(functools.partial(
        selection._bitwise_topk_body, frac=frac)))
    mx = xla_topk(u)
    mp = stacked_topk_masks(u, frac=frac)
    identical = all(np.array_equal(np.asarray(a), np.asarray(c))
                    for a, c in zip(jax.tree.leaves(mx), jax.tree.leaves(mp)))
    assert identical, "pallas top-k masks differ from the XLA counting search"
    times = {}
    for name, fn in (("xla", xla_topk),
                     ("pallas", lambda t_: stacked_topk_masks(t_, frac=frac))):
        jax.block_until_ready(jax.tree.leaves(fn(u))[0])  # warm
        with Timer() as t:
            for _ in range(reps):
                o = fn(u)
            jax.block_until_ready(jax.tree.leaves(o)[0])
        times[name] = t.s / reps
    out["topk"] = {
        "b": b, "n_per_session": n, "frac": frac,
        "masks_byte_identical": bool(identical),
        "nbytes_pallas": b * analysis.topk_hbm_bytes(n, passes=1),
        "nbytes_xla": b * analysis.topk_hbm_bytes(n, passes=32),
        "xla_s": times["xla"], "pallas_s": times["pallas"],
        "ratio": times["pallas"] / max(times["xla"], 1e-12),
        "roofline_fraction_xla": analysis.kernel_roofline_fraction(
            b * analysis.topk_hbm_bytes(n, passes=32), times["xla"]),
        "roofline_fraction_pallas": analysis.kernel_roofline_fraction(
            b * analysis.topk_hbm_bytes(n, passes=1), times["pallas"]),
    }
    for group in ("adam", "topk"):
        for field in ("roofline_fraction_xla", "roofline_fraction_pallas"):
            v = out[group][field]
            assert v is not None and math.isfinite(v) and v > 0, (
                f"{group}.{field} not a finite positive fraction: {v!r}")
        emit(f"kernels.gate.{group}.pallas", out[group]["pallas_s"] * 1e6,
             f"roofline_fraction={out[group]['roofline_fraction_pallas']:.3e};"
             f"ratio_vs_xla={out[group]['ratio']:.3f}")
    return out


def run_kernel_gate(quick: bool = True) -> dict:
    """The ``scripts/ci.sh --kernels`` gate: serving-level XLA-vs-Pallas
    equivalence + kernel roofline fractions + an auto-mode race, merged
    into the ``observability.kernels`` section of BENCH_serving.json (and
    re-read to assert the roofline-fraction fields landed finite)."""
    import math

    from benchmarks import serving_scale
    from repro.core import batched, kernel_dispatch, selection

    results = {"equivalence": kernel_equivalence_gate(
        n_sessions=2 if quick else 4, k_iters=2 if quick else 3,
        size=16 if quick else 24)}
    results.update(kernel_roofline_compare(b=2 if quick else 4,
                                           n=1 << (14 if quick else 16)))
    # demonstrate the dispatch race: auto mode settles select_stacked once
    kernel_dispatch.reset()
    selection.stacked_cache_clear()
    batched.set_kernel_mode("auto")
    try:
        rng = np.random.default_rng(11)
        u = {"w": jnp.asarray(rng.normal(size=(2, 4096)), jnp.float32)}
        selection.stacked_gradient_guided_masks(u, 0.05)
    finally:
        batched.set_kernel_mode("xla")
    results["dispatch"] = kernel_dispatch.kernel_dispatch_info()
    assert results["dispatch"]["auto_races"], "auto race recorded no decision"

    # merge under observability.kernels without clobbering the drift audit
    bench = serving_scale._read_bench()
    obs = bench.get("observability") or {}
    obs["kernels"] = results
    serving_scale._write_bench({"observability": obs})
    written = serving_scale._read_bench()["observability"]["kernels"]
    for group in ("adam", "topk"):
        for field in ("roofline_fraction_xla", "roofline_fraction_pallas"):
            v = written[group][field]
            assert isinstance(v, float) and math.isfinite(v), (
                f"BENCH_serving.json observability.kernels.{group}.{field} "
                f"is not finite: {v!r}")
    return results


def run(quick: bool = True):
    n = 1 << 18
    rng = np.random.default_rng(0)
    p, g, m = (jnp.asarray(rng.normal(size=n), jnp.float32) for _ in range(3))
    v = jnp.asarray(rng.uniform(0.01, 1, n), jnp.float32)
    b = jnp.asarray(rng.integers(0, 2, n), jnp.float32)

    from repro.core.masked_adam import init_state, masked_adam_update
    from repro.kernels.masked_adam.ops import masked_adam_leaf

    tree = {"w": p}
    st = init_state(tree)
    mask = {"w": b}

    @jax.jit
    def unfused(tree, st, mask, grads):
        return masked_adam_update(tree, grads, st, mask)

    unfused(tree, st, mask, {"w": g})  # warm
    with Timer() as t1:
        for _ in range(5):
            out = unfused(tree, st, mask, {"w": g})
        jax.block_until_ready(out[0]["w"])
    # fused kernel (interpret mode)
    bc = jnp.float32(1e-3)
    masked_adam_leaf(p, g, m, v, b, bc)  # warm
    with Timer() as t2:
        for _ in range(5):
            o = masked_adam_leaf(p, g, m, v, b, bc)
        jax.block_until_ready(o[0])
    # structural: unfused XLA emits ~10 elementwise HLO ops -> >= 2 extra
    # round-trips without fusion; fused kernel = 6 reads + 4 writes exactly.
    emit("kernels.masked_adam.unfused", t1.us / 5, "hbm_passes=variable(XLA fusion)")
    emit("kernels.masked_adam.fused_pallas_interp", t2.us / 5,
         "hbm_bytes_per_param=40(6r+4w fixed)")

    from repro.kernels.flash_attention.ops import flash_attention_pallas
    from repro.kernels.flash_attention.ref import flash_attention_ref

    B, S, KV, G, hd = 1, 512, 2, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, KV, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    q4 = q.transpose(0, 2, 3, 1, 4).reshape(B, KV * G, S, hd)
    ref = jax.jit(lambda a, b_, c: flash_attention_ref(a, b_, c))
    ref(q4, k.transpose(0, 2, 1, 3), vv.transpose(0, 2, 1, 3))
    with Timer() as t3:
        o = ref(q4, k.transpose(0, 2, 1, 3), vv.transpose(0, 2, 1, 3))
        jax.block_until_ready(o)
    with Timer() as t4:
        o = flash_attention_pallas(q, k, vv, block_q=128, block_k=128)
        jax.block_until_ready(o)
    naive_ws = S * S * KV * G * 4
    flash_ws = 128 * 128 * 4 * 2
    emit("kernels.flash.naive", t3.us, f"score_bytes={naive_ws}")
    emit("kernels.flash.pallas_interp", t4.us,
         f"vmem_tile_bytes={flash_ws};skip_blocks=causal/window")

    fused_phase_compare(n_sessions=4 if quick else 8)
    update_pipeline_compare(n_sessions=4 if quick else 8)


if __name__ == "__main__":
    import sys

    if "--kernels" in sys.argv[1:]:
        run_kernel_gate(quick="--full" not in sys.argv[1:])
    else:
        run()
