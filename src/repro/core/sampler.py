"""Adaptive frame sampling (ASR, §3.2) and the φ-score.

φ_k = task loss of the teacher's prediction on frame I_k measured against the
teacher's label for I_{k-1} — a label-space scene-change signal. The server
runs an integral controller (Eq. 1):

    r_{t+1} = clip(r_t + η_r (φ̄_t - φ_target), r_min, r_max)
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def phi_score(loss_fn, label_prev, label_now) -> float:
    """φ for one consecutive pair of teacher labels; `loss_fn` is the task's
    own loss with (prediction=label_now, target=label_prev)."""
    return float(loss_fn(label_now, label_prev))


@dataclass
class ASRController:
    phi_target: float
    eta: float = 0.5
    r_min: float = 0.1
    r_max: float = 1.0
    delta_t: float = 10.0  # seconds between rate updates
    rate: float = field(default=0.0)
    phi_ema: float = field(default=-1.0)  # recent-φ EMA; <0 until first observe
    _phis: list = field(default_factory=list)
    _last_update: float = 0.0

    def __post_init__(self):
        if not self.rate:
            self.rate = self.r_max

    def observe(self, phi: float) -> None:
        phi = float(phi)
        self._phis.append(phi)
        # fast scene-dynamics signal for schedulers: unlike `rate` (integral
        # controller, lags by design) this separates static from dynamic
        # feeds within a few observations
        self.phi_ema = phi if self.phi_ema < 0 else 0.8 * self.phi_ema + 0.2 * phi

    def maybe_update(self, t_now: float) -> float:
        """Apply Eq. 1 every delta_t seconds; returns the current rate."""
        if t_now - self._last_update >= self.delta_t and self._phis:
            phi_bar = float(np.mean(self._phis))
            self.rate = float(
                np.clip(self.rate + self.eta * (phi_bar - self.phi_target),
                        self.r_min, self.r_max)
            )
            self._phis.clear()
            self._last_update = t_now
        return self.rate
