"""Time-stamped training buffer B (Algorithm 1 lines 3, 8, 12).

Holds (sample, teacher_label, timestamp) tuples; minibatches are sampled
uniformly over the last T_horizon seconds. Host-side (numpy) — this is the
server's data-plane state, not device state.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ReplayBuffer:
    horizon: float  # T_horizon seconds
    slack: float = 60.0  # keep a little history beyond the horizon
    frames: list = field(default_factory=list)
    labels: list = field(default_factory=list)
    stamps: list = field(default_factory=list)

    def add(self, frame, label, t: float) -> None:
        self.frames.append(np.asarray(frame))
        self.labels.append(np.asarray(label))
        self.stamps.append(float(t))
        self._evict(t)

    def _evict(self, t_now: float) -> None:
        cutoff = t_now - self.horizon - self.slack
        k = 0
        while k < len(self.stamps) and self.stamps[k] < cutoff:
            k += 1
        if k:
            del self.frames[:k], self.labels[:k], self.stamps[:k]

    def window_indices(self, t_now: float) -> np.ndarray:
        stamps = np.asarray(self.stamps)
        return np.nonzero(stamps >= t_now - self.horizon)[0]

    def __len__(self) -> int:
        return len(self.stamps)

    def sample(self, rng: np.random.Generator, batch_size: int, t_now: float):
        """Uniform minibatch over the last T_horizon seconds (line 12).
        Returns (frames, labels) stacked, or None if the window is empty."""
        idx = self.window_indices(t_now)
        if idx.size == 0:
            return None
        pick = rng.choice(idx, size=batch_size, replace=idx.size < batch_size)
        frames = np.stack([self.frames[i] for i in pick])
        labels = np.stack([self.labels[i] for i in pick])
        return frames, labels
