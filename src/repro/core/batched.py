"""Fused cross-session training: stacked vmap/scan execution of train phases.

Every AMS session of a given task shares one pytree structure (the student),
so B co-resident sessions' training phases need not be B separate K-iteration
dispatch loops: stack their ``(params, opt_state, mask, replay_batches)``
along a leading session axis (struct-of-arrays), run ``jax.lax.scan`` over
the K inner iterations of a ``jax.vmap``-ed loss/masked-optimizer step, and
unstack the results back into per-session state. One compiled executable —
cached at module level by (loss fn, shape-dtype struct, K, optimizer,
hyperparameters) and therefore shared by every same-shaped session in the
process — replaces ``B x K`` separate launches.

Numerics: the stacked executable agrees with the sequential
``AMSSession.train_phase`` to float32 tolerance (vmap batches the
convolutions differently, and XLA fuses the optimizer math into the backward
pass). A *singleton* group therefore runs the sequential step code itself —
bitwise-identical to ``train_phase`` by construction — so fusing is a pure
opt-in: with coalescing disabled every phase is a singleton and nothing
changes, to the bit.

Execution mode: the K iterations either live inside the executable as a
``lax.scan`` (one launch per phase — the accelerator-friendly shape) or the
cached executable is the vmapped *step* with the K-loop in Python (XLA:CPU
runs while-loop bodies on a single thread, measured ~4x slower than the
same math dispatched step-by-step). ``mode="auto"`` (the default) settles
scan-vs-loop **empirically**: the first fused call for a compile key builds
both executables, times one real execution of each on the caller's own
stacked batch, keeps the winner, and caches the decision — a one-shot
microbenchmark per (backend, compile key) instead of a backend-name check,
so an accelerator whose scan lowering happens to be slow (or a CPU build
whose loop dispatch is) is measured, not assumed. ``set_exec_mode`` forces
either shape (benchmarks/tests); ``auto_mode_info`` exposes the measured
decisions.

Sharded execution (`train_phases_sharded`): everything above runs on jax's
*default* device, so co-resident groups granted to different `GPUPool`
slots still execute serially — the pool's per-device clocks are modeled,
not measured. With the pool's ``device_backend="jax"`` knob each slot
binds a concrete ``jax.Device`` (`launch.host_mesh` forces N of them on a
CPU host), and `train_phases_sharded` runs D groups' fused lifecycles
(train → stacked select → batched encode) on D devices at once: each
group's stacked inputs are ``jax.device_put`` onto its slot's device and
the SAME cached executables dispatch asynchronously — jit keeps one
compiled program per (device, compile key), so per-device results are
bit-identical to the single-device fused path. ``spmd=True`` instead
concatenates uniform groups along the session axis and makes ONE
GSPMD launch over a cached `launch.mesh.make_session_mesh` sharding
(`_SHARD_CACHE`, per (mesh devices, compile key) via jit's sharding-aware
executable cache); one launch, but numerics only to the PR-7 float32
tolerance contract (masks and wire bytes stay byte-identical). Per-device
and whole-batch wall-clock land in `core.timing` ("sharded_device" /
"train_sharded"), which `obs.drift_report` prices per device against the
`GPUCostModel` — the modeled-vs-measured audit the serving stack's
capacity numbers hang off.
"""
from __future__ import annotations

import time
from collections import defaultdict
from concurrent.futures import ThreadPoolExecutor
from typing import Hashable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernel_dispatch, selection, timing
from repro.core.delta import encode_delta_stack
from repro.core.kernel_dispatch import kernel_dispatch_info, set_kernel_mode
from repro.core.masked_adam import masked_adam_update, momentum_update

# ---------------------------------------------------------------------------
# struct-of-arrays stack / unstack
# ---------------------------------------------------------------------------


@jax.jit
def _stack_impl(trees: tuple):
    return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)


def stack_trees(trees: list):
    """Stack B same-structure pytrees along a new leading session axis.

    Jitted: the whole tree stacks in ONE launch (compile-cached by
    structure/shape) instead of one `jnp.stack` dispatch per leaf — at
    fleet scale the per-leaf dispatch overhead was most of the cost of
    assembling a stacked selection launch."""
    if not trees:
        raise ValueError("stack_trees needs at least one tree")
    return _stack_impl(tuple(trees))


def unstack_tree(tree, n: int) -> list:
    """Inverse of `stack_trees`: split the leading axis back into B trees."""
    return [jax.tree.map(lambda l: l[i], tree) for i in range(n)]


def _dtype_name(leaf) -> str:
    # leaves are jax/numpy arrays with a .dtype attribute; the asarray
    # fallback (python scalars) is kept off the hot path — going through
    # jnp.asarray for every leaf dominated the compile-key cost at scale
    dt = getattr(leaf, "dtype", None)
    return dt.name if dt is not None else np.asarray(leaf).dtype.name


def tree_struct(tree) -> Hashable:
    """Hashable shape/dtype/structure fingerprint of a pytree — the part of
    a compile key that decides whether two sessions can share an executable."""
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef,
            tuple((tuple(l.shape), _dtype_name(l)) for l in leaves))


# ---------------------------------------------------------------------------
# module-level fused-phase executable cache
# ---------------------------------------------------------------------------

_PHASE_CACHE: dict = {}
_HITS = 0
_MISSES = 0

_EXEC_MODE = "auto"  # "auto" | "scan" | "loop"
# measured scan-vs-loop winners: (backend, base compile key) -> mode.
# "auto" consults this instead of the backend name; each entry is settled
# by a one-shot timed race of both executables on the first real call.
_AUTO_MODES: dict = {}


def set_exec_mode(mode: str) -> None:
    """Force the phase-executable shape: ``scan`` (K iterations inside one
    ``lax.scan`` launch), ``loop`` (one vmapped-step launch per iteration),
    or ``auto`` (first fused call per compile key races both and keeps the
    measured winner). Cached executables for the other mode are kept; the
    key includes the resolved mode."""
    if mode not in ("auto", "scan", "loop"):
        raise ValueError(f"exec mode must be auto|scan|loop, got {mode!r}")
    global _EXEC_MODE
    _EXEC_MODE = mode


def auto_mode_info() -> dict:
    """The measured auto decisions: {(backend, compile key): "scan"|"loop"}.
    Empty until an ``auto``-mode fused call has raced the two shapes."""
    return dict(_AUTO_MODES)


def cache_info() -> dict:
    """Hook for tests/telemetry: how often did sessions share an executable?"""
    return {"size": len(_PHASE_CACHE), "hits": _HITS, "misses": _MISSES}


def cache_clear() -> None:
    global _HITS, _MISSES
    _PHASE_CACHE.clear()
    _AUTO_MODES.clear()
    _HITS = _MISSES = 0


def _build_phase_fn(loss_and_grad, optimizer: str, lr: float, b1: float,
                    b2: float, eps: float, momentum: float, mode: str,
                    kernel: str = "xla"):
    """The fused executable: K iterations of a vmapped step.

    Signature: ``(params, opt_state, mask, frames, labels)`` where every tree
    leaf carries a leading session axis B and frames/labels are shaped
    ``(K, B, batch, ...)``. Returns stacked ``(params, opt_state, u_last,
    loss_last)`` with ``loss_last`` of shape (B,). ``mode="scan"`` compiles
    the whole phase into one launch; ``mode="loop"`` compiles the step once
    and dispatches it K times (see module docstring for why CPU wants this).

    ``kernel="pallas"`` (adam only) swaps the per-leaf tree_map optimizer
    for the fused Pallas kernel: the loss/grad stays a plain ``jax.vmap``,
    but the masked-Adam step runs as one `pl.pallas_call` per param dtype
    over flattened-and-concatenated ``(B, rows, 128)`` buffers — the
    session axis is a kernel grid dimension, and p/g/m/v/mask stream
    through VMEM exactly once per iteration
    (`repro.kernels.masked_adam.ops.masked_adam_stacked`). The unstack is
    bit-exact; the arithmetic agrees with the XLA path to float32 rounding
    (XLA's context-dependent FMA contraction can move single ULPs — the
    same caveat as scan-vs-loop, and it makes even the XLA path differ
    jit-vs-nojit), so the downstream selection masks and packed wire masks
    are byte-identical and the fp16 delta values agree to 1 ULP —
    CI-asserted (`scripts/ci.sh --kernels`).
    """

    def step(p, st, m, f, l):
        loss, grads = loss_and_grad(p, f, l)
        if optimizer == "adam":
            p, st, u = masked_adam_update(p, grads, st, m,
                                          lr=lr, b1=b1, b2=b2, eps=eps)
        else:
            p, st, u = momentum_update(p, grads, st, m,
                                       lr=lr, momentum=momentum)
        return p, st, u, loss

    if kernel == "pallas" and optimizer == "adam":
        from repro.kernels.masked_adam.ops import masked_adam_stacked

        vgrad = jax.vmap(lambda p, f, l: loss_and_grad(p, f, l))

        def vstep(p, st, m, f, l):
            loss, grads = vgrad(p, f, l)
            p, st, u = masked_adam_stacked(p, grads, st, m,
                                           lr=lr, b1=b1, b2=b2, eps=eps)
            return p, st, u, loss
    else:
        vstep = jax.vmap(step)

    if mode == "loop":
        jstep = jax.jit(vstep)

        def phase(params, opt_state, mask, frames, labels):
            for k in range(frames.shape[0]):
                params, opt_state, u, loss = jstep(params, opt_state, mask,
                                                   frames[k], labels[k])
            return params, opt_state, u, loss

        return phase

    @jax.jit
    def phase(params, opt_state, mask, frames, labels):
        u0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)

        def body(carry, xs):
            p, st, _ = carry
            f, l = xs
            p, st, u, loss = vstep(p, st, mask, f, l)
            return (p, st, u), loss

        (params, opt_state, u), losses = jax.lax.scan(
            body, (params, opt_state, u0), (frames, labels))
        return params, opt_state, u, losses[-1]

    return phase


def _block(tree) -> None:
    for leaf in jax.tree.leaves(tree):
        getattr(leaf, "block_until_ready", lambda: None)()


def _resolved_kernel(optimizer: str, base_key) -> str | None:
    """The kernel implementation the cached executable should embed:
    ``xla`` | ``pallas``, or None when ``kernel_mode("auto")`` has not yet
    raced this (backend, compile key). Non-adam optimizers have no Pallas
    implementation and always resolve to ``xla``."""
    if optimizer != "adam":
        return "xla"
    km = kernel_dispatch.kernel_mode()
    if km != "auto":
        return km
    return kernel_dispatch.auto_winner("train_fused", jax.default_backend(),
                                       base_key)


def fused_phase_fn(loss_and_grad, *, struct: Hashable, k_iters: int,
                   optimizer: str, lr: float, b1: float, b2: float,
                   eps: float, momentum: float):
    """The cached stacked-phase executable for one compile key.

    Keyed by the loss callable itself (sessions built from the same task
    share it — see `sim.seg_world`'s per-config compile cache), the stacked
    shape-dtype struct, K, and the optimizer recipe: N same-shaped sessions
    cost one compile, not N.

    Two independent axes settle ``auto`` decisions by one-shot timed races
    on the first real stacked batch, each recorded per (backend, compile
    key):

    * exec mode (``set_exec_mode``): scan-vs-loop, as before — the racer
      builds both executables, times one warmed execution of each, records
      the winner in `_AUTO_MODES` and caches its executable.
    * kernel mode (``set_kernel_mode``): XLA tree_map vs the fused Pallas
      masked-Adam, raced only AFTER the exec shape is settled (the exec
      race runs with the XLA kernel, so a default ``kernel_mode("xla")``
      process is bit-identical to the pre-dispatch code). The winner lands
      in `core.kernel_dispatch` (see `kernel_dispatch_info`).

    Each race is one cache miss; losers are discarded uncounted."""
    global _HITS, _MISSES
    base_key = (loss_and_grad, struct, k_iters, optimizer, lr, b1, b2, eps,
                momentum)
    backend = jax.default_backend()
    if _EXEC_MODE != "auto":
        mode = _EXEC_MODE
    else:
        mode = _AUTO_MODES.get((backend, base_key))
    kern = _resolved_kernel(optimizer, base_key)
    if mode is not None and kern is not None:
        key = base_key + (mode, kern)
        fn = _PHASE_CACHE.get(key)
        if fn is None:
            _MISSES += 1
            fn = _build_phase_fn(loss_and_grad, optimizer, lr, b1, b2, eps,
                                 momentum, mode, kern)
            _PHASE_CACHE[key] = fn
        else:
            _HITS += 1
        return fn
    _MISSES += 1

    def _timed_best(fn, args):
        _block(fn(*args))  # compile + warm, excluded from the clock
        best, out = float("inf"), None
        for _ in range(2):  # best-of-2: damp scheduler/GC jitter
            t0 = time.perf_counter()
            out = fn(*args)
            _block(out)
            best = min(best, time.perf_counter() - t0)
        return best, out

    if mode is None:
        # exec-shape race (kernel pinned: resolved if decided, else the
        # XLA reference — so the exec decision never depends on an
        # unraced kernel axis)
        kern0 = kern if kern is not None else "xla"

        def race(params, opt_state, mask, frames, labels):
            args = (params, opt_state, mask, frames, labels)
            outs, times = {}, {}
            for m in ("loop", "scan"):
                fn = _build_phase_fn(loss_and_grad, optimizer, lr, b1, b2,
                                     eps, momentum, m, kern0)
                times[m], out = _timed_best(fn, args)
                outs[m] = (fn, out)
            # ties break lexically ("loop"); note the race is wall-clock —
            # a near-tie can resolve differently across processes, and the
            # two shapes agree only to float32 tolerance (forced modes, or
            # a pre-warmed cache, give bit-stable numerics when needed)
            winner = min(times, key=lambda m: (times[m], m))
            _AUTO_MODES[(backend, base_key)] = winner
            _PHASE_CACHE[base_key + (winner, kern0)] = outs[winner][0]
            return outs[winner][1]

        return race

    def krace(params, opt_state, mask, frames, labels):
        # XLA-vs-Pallas race at the settled exec shape. Both paths produce
        # byte-identical selection masks and wire masks (CI-asserted); the
        # fp16 delta values agree to 1 ULP — the residue of XLA:CPU's
        # context-dependent FMA contraction, which makes even the XLA
        # reference differ jit-vs-nojit (see `_build_phase_fn`).
        args = (params, opt_state, mask, frames, labels)
        outs, times = {}, {}
        for kn in ("xla", "pallas"):
            fn = _PHASE_CACHE.get(base_key + (mode, kn))
            if fn is None:
                fn = _build_phase_fn(loss_and_grad, optimizer, lr, b1, b2,
                                     eps, momentum, mode, kn)
            times[kn], out = _timed_best(fn, args)
            outs[kn] = (fn, out)
        winner = min(times, key=lambda kn: (times[kn], kn))
        kernel_dispatch.record_auto("train_fused", backend, base_key,
                                    winner, times)
        _PHASE_CACHE[base_key + (mode, winner)] = outs[winner][0]
        return outs[winner][1]

    return krace


# ---------------------------------------------------------------------------
# fused phase over live sessions
# ---------------------------------------------------------------------------

# update-pipeline telemetry: how much of the post-train select/encode work
# ran stacked (one launch / one transfer pair per fused group) instead of
# per-session. The serving engine snapshots this around a run.
_UPDATE_STATS = {"stacked_select_launches": 0, "stacked_select_sessions": 0,
                 "stacked_encode_launches": 0, "stacked_encode_sessions": 0}


def update_pipeline_info() -> dict:
    """Counters for the fused post-train update pipeline (stacked selection
    launches + batched delta encodes and the sessions they covered)."""
    return dict(_UPDATE_STATS)


def update_pipeline_reset() -> None:
    for k in _UPDATE_STATS:
        _UPDATE_STATS[k] = 0


def _mask_struct(s, mask) -> Hashable:
    """Shape fingerprint of the phase's mask tree. A deferred gradient-
    guided mask (None) has param-shaped bool leaves by construction, so its
    struct is derivable without materializing it."""
    if mask is not None:
        return tree_struct(mask)
    leaves, treedef = jax.tree.flatten(s.params)
    return (treedef, tuple((tuple(l.shape), "bool") for l in leaves))


def _group_key(s, mask, frames, labels) -> Hashable:
    cfg = s.cfg
    return (s.task.loss_and_grad,
            tree_struct((s.params, s.opt_state)), _mask_struct(s, mask),
            cfg.k_iters, cfg.optimizer, cfg.lr, cfg.b1, cfg.b2, cfg.eps,
            cfg.momentum,
            # the update pipeline batches selection (keyed by γ/strategy)
            # and delta encode (keyed by wire dtype) across the group, so
            # they must agree for sessions to share a fused launch
            cfg.strategy, cfg.gamma, cfg.value_dtype,
            tuple(frames.shape), str(frames.dtype),
            tuple(labels.shape), str(labels.dtype))


def _stacked_masks(members, force_stack: bool, device=None):
    """The group's stacked mask tree, batching deferred gradient-guided
    selections into one vmapped launch.

    ``members`` carry mask=None where selection was deferred
    (`AMSSession._select_mask_or_defer`); those sessions' ``u_prev`` trees
    stack into a single `selection.stacked_gradient_guided_masks` call —
    B thresholds + B mask trees from one executable instead of B solo
    bisections. Concrete masks (first-phase random, Table-3 ablations)
    stack as-is; a mixed group re-stacks device-side slices (no host
    round-trip).

    ``device`` (a ``jax.Device`` or `Sharding`, sharded path only) places
    the selection on the group's own pool device: an all-deferred group
    moves the stacked ``u_prev`` there so the bisection launch itself runs
    on-device; mixed groups select on the default device and only the
    final stacked mask moves. None (the default) touches placement not at
    all — bit-identical to the pre-sharding code."""
    deferred = [j for j, m in enumerate(members) if m[2] is None]
    gamma = members[0][1].cfg.gamma
    if len(deferred) >= 2 or (deferred and force_stack):
        u_stack = stack_trees([members[j][1].u_prev for j in deferred])
        pure = len(deferred) == len(members)
        if device is not None and pure:
            u_stack = jax.device_put(u_stack, device)
        stacked_d = selection.stacked_gradient_guided_masks(u_stack, gamma)
        _UPDATE_STATS["stacked_select_launches"] += 1
        _UPDATE_STATS["stacked_select_sessions"] += len(deferred)
        if pure:
            return stacked_d
        per = {j: jax.tree.map(lambda l, k=k: l[k], stacked_d)
               for k, j in enumerate(deferred)}
    else:
        per = {j: selection.gradient_guided_mask(members[j][1].u_prev, gamma)
               for j in deferred}
    masks = [per.get(j, m[2]) for j, m in enumerate(members)]
    out = stack_trees(masks)
    return jax.device_put(out, device) if device is not None else out


def train_phases_fused(sessions: list, t_now: float,
                       force_stack: bool = False, device=None) -> list:
    """Run one training phase for several sessions as fused launches.

    Per-session host-side work (replay sampling, ASR/ATR bookkeeping)
    happens in session order, consuming each session's RNG streams exactly
    as its own ``train_phase`` would. Sessions that share a compile key —
    same loss callable, shapes, K, optimizer, selection/wire recipe — are
    stacked and executed as ONE scan/vmap launch; a session with nothing to
    train yields None in its slot, exactly like ``train_phase``.

    The post-train update pipeline is fused too: the group's gradient-guided
    selections run as one stacked bisection launch (`core.selection`), and
    the B wire deltas come from one batched device->host encode
    (`delta.encode_delta_stack`, byte-identical to per-session encoding) —
    no per-session serial stage is left between the fused launch and the
    deltas.

    Singleton groups take the sequential step path (bitwise-identical to
    ``train_phase``); pass ``force_stack=True`` to push even B=1 through the
    stacked executable (benchmarks/tests only).

    ``device`` places each stacked group's lifecycle on a concrete
    ``jax.Device`` (the pool slot's binding under
    ``GPUPool(device_backend="jax")``). Identical jitted programs on
    same-kind devices produce bit-identical results, so this moves *where*
    the math runs, not what it computes; the sequential singleton path
    ignores it (its contract is bitwise equality with ``train_phase`` on
    the default device). None — the default — performs zero placements.
    """
    results: dict[int, object] = {}
    groups: dict[Hashable, list] = defaultdict(list)
    for i, s in enumerate(sessions):
        prep = s._prepare_phase_deferred(t_now)
        if prep is None:
            results[i] = None
            continue
        mask, frames, labels = prep  # mask None = deferred gradient-guided
        groups[_group_key(s, mask, frames, labels)].append(
            (i, s, mask, frames, labels))

    for members in groups.values():
        if len(members) == 1 and not force_stack:
            i, s, mask, frames, labels = members[0]
            if mask is None:
                mask = selection.gradient_guided_mask(s.u_prev, s.cfg.gamma)
            results[i] = s._run_phase_prepared(t_now, mask, frames, labels)
            continue
        out, _ = _launch_stacked(members, device=device)
        _commit_stacked(members, t_now, out, results)
    return [results[i] for i in range(len(sessions))]


def _batch_spec(device):
    """Placement for scan-major ``(K, B, ...)`` batches: a session
    `NamedSharding` names axis 0, but frames/labels carry the session axis
    at position 1 — shift the spec; a plain Device places the whole leaf."""
    if isinstance(device, jax.sharding.NamedSharding):
        return jax.sharding.NamedSharding(
            device.mesh, jax.sharding.PartitionSpec(None, *device.spec))
    return device


def _launch_stacked(members, device=None, record=True):
    """Stack one compile-key group and dispatch its fused train launch.

    Returns ``((params, opt, u, losses, mask), first_launch)`` with the
    arrays still on device (dispatch is async — nothing here blocks unless
    timing is on, which needs the completed wall-clock; ``record=False``
    skips the stage record so `train_phases_sharded` can dispatch D groups
    without a serializing block and clock them itself). ``device`` may be
    a ``jax.Device`` or a `Sharding`; None keeps the default placement."""
    ss = [m[1] for m in members]
    params = stack_trees([s.params for s in ss])
    opt = stack_trees([s.opt_state for s in ss])
    mask = _stacked_masks(members, True, device=device)
    # batches: per-session (K, batch, ...) -> scan-major (K, B, batch, ...)
    frames = jnp.stack([m[3] for m in members], axis=1)
    labels = jnp.stack([m[4] for m in members], axis=1)
    if device is not None:
        # one placement per tree; the mask already lives there, and every
        # launch below follows its committed inputs onto the same device
        params, opt = jax.device_put((params, opt), device)
        frames, labels = jax.device_put((frames, labels), _batch_spec(device))
    s0 = ss[0]
    miss0 = _MISSES
    phase = fused_phase_fn(
        s0.task.loss_and_grad,
        struct=tree_struct((params, opt, mask)),
        k_iters=s0.cfg.k_iters, optimizer=s0.cfg.optimizer,
        lr=s0.cfg.lr, b1=s0.cfg.b1, b2=s0.cfg.b2, eps=s0.cfg.eps,
        momentum=s0.cfg.momentum)
    if record and timing.enabled():
        # first launch (a cache miss — including the auto-mode race)
        # lands in the compile bucket, steady launches in steady-state
        t0 = time.perf_counter()
        params, opt, u, losses = phase(params, opt, mask, frames, labels)
        timing.block((params, opt, u, losses))
        # nbytes: analytic optimizer-update traffic only (the
        # masked-Adam roofline term — forward/backward excluded),
        # B x K x `roofline.analysis.adam_step_hbm_bytes`
        timing.record("train_fused", time.perf_counter() - t0,
                      first=_MISSES > miss0,
                      key=(len(members), s0.cfg.k_iters),
                      nbytes=(len(members) * s0.cfg.k_iters * 33
                              * selection.tree_size(s0.params)))
    else:
        params, opt, u, losses = phase(params, opt, mask, frames, labels)
    return (params, opt, u, losses, mask), _MISSES > miss0


# ---------------------------------------------------------------------------
# sharded execution: D co-resident groups on D real pool devices
# ---------------------------------------------------------------------------

# (mesh device ids) -> session NamedSharding for the one-launch SPMD path.
# The compiled sharded program itself is cached by jit, which keys
# executables by (sharding, compile key) — keeping the mesh object stable
# here is what lets that cache hit; rebuilding a Mesh per call would
# recompile every launch.
_SHARD_CACHE: dict = {}
_SHARD_STATS = {"batches": 0, "groups": 0, "sessions": 0,
                "dispatch_launches": 0, "spmd_launches": 0,
                "distinct_devices": 0}


def sharded_info() -> dict:
    """Counters for sharded batches: launches per path (per-device dispatch
    vs SPMD one-launch), groups/sessions covered, and the widest distinct-
    device fan-out actually achieved (1 on a one-device host — correctness
    holds but nothing ran in parallel)."""
    return dict(_SHARD_STATS)


def sharded_reset() -> None:
    for k in _SHARD_STATS:
        _SHARD_STATS[k] = 0


def _session_sharding(devices):
    """The cached 1-D session-axis NamedSharding over ``devices``."""
    key = tuple(id(d) for d in devices)
    hit = _SHARD_CACHE.get(key)
    if hit is None:
        mesh = jax.sharding.Mesh(np.array(devices), axis_names=("session",))
        hit = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("session"))
        _SHARD_CACHE[key] = hit
    return hit


def _launch_spmd(members, group_key, shard_b, sharding):
    """One `shard_map` launch covering D uniform co-resident groups.

    GSPMD cannot partition the vmapped phase along the session axis (vmap
    lowers the student's convolutions into feature-group form, and XLA
    refuses to split the group dimension), so the one-launch path maps
    instead: every mesh device runs the SAME per-group executable the
    dispatch path uses — shard width = the group's B — over its slice of
    the session-concatenated stacks. The per-group phase fn must be
    settled (its exec/kernel races decided) before it can be traced as a
    shard_map body; an unsettled key is raced once on shard 0's slice
    first, outputs discarded.

    Returns ``((params, opt, u, losses, mask), first_launch)`` like
    `_launch_stacked`, with every tree still sharded across the mesh."""
    from jax.experimental.shard_map import shard_map

    ss = [m[1] for m in members]
    params = stack_trees([s.params for s in ss])
    opt = stack_trees([s.opt_state for s in ss])
    mask = _stacked_masks(members, True, device=sharding)
    frames = jnp.stack([m[3] for m in members], axis=1)
    labels = jnp.stack([m[4] for m in members], axis=1)
    params, opt = jax.device_put((params, opt), sharding)
    frames, labels = jax.device_put((frames, labels), _batch_spec(sharding))
    s0 = ss[0]
    miss0 = _MISSES

    def shard_struct(t):
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((shard_b,) + l.shape[1:],
                                           l.dtype), t)

    struct = tree_struct((shard_struct(params), shard_struct(opt),
                          shard_struct(mask)))
    fkw = dict(struct=struct, k_iters=s0.cfg.k_iters,
               optimizer=s0.cfg.optimizer, lr=s0.cfg.lr, b1=s0.cfg.b1,
               b2=s0.cfg.b2, eps=s0.cfg.eps, momentum=s0.cfg.momentum)
    base = (s0.task.loss_and_grad, struct, s0.cfg.k_iters, s0.cfg.optimizer,
            s0.cfg.lr, s0.cfg.b1, s0.cfg.b2, s0.cfg.eps, s0.cfg.momentum)
    backend = jax.default_backend()
    settled = ((_EXEC_MODE != "auto" or (backend, base) in _AUTO_MODES)
               and _resolved_kernel(s0.cfg.optimizer, base) is not None)
    if not settled:
        p0, o0, m0 = jax.tree.map(lambda l: l[:shard_b],
                                  (params, opt, mask))
        fused_phase_fn(s0.task.loss_and_grad, **fkw)(
            p0, o0, m0, frames[:, :shard_b], labels[:, :shard_b])
    fn = fused_phase_fn(s0.task.loss_and_grad, **fkw)
    key = ("spmd", tuple(id(d) for d in sharding.mesh.devices.flat),
           group_key, shard_b, id(fn))
    wrapped = _SHARD_CACHE.get(key)
    first = wrapped is None or _MISSES > miss0
    if wrapped is None:
        spec = jax.sharding.PartitionSpec("session")
        batch_spec = jax.sharding.PartitionSpec(None, "session")
        wrapped = jax.jit(shard_map(
            fn, mesh=sharding.mesh,
            in_specs=(spec, spec, spec, batch_spec, batch_spec),
            out_specs=spec))
        _SHARD_CACHE[key] = wrapped
    params, opt, u, losses = wrapped(params, opt, mask, frames, labels)
    return (params, opt, u, losses, mask), first


def train_phases_sharded(session_groups: list, t_now: float, *,
                         devices: list, spmd: bool = False) -> list:
    """Run D co-resident groups' fused lifecycles on D pool devices at once.

    ``session_groups[g]`` is the member list of one granted pool slot (the
    sessions a fused grant would stack); ``devices[g]`` is that slot's
    ``jax.Device`` binding (`GPUPool.jax_devices()` under
    ``device_backend="jax"``). Host-side phase preparation runs in input
    order — the same RNG consumption as ``train_phases_fused`` over the
    concatenation — then every group's stacked train→select launch is
    placed on its own device and dispatched *asynchronously*: D devices
    compute concurrently, and one waiter thread per launch timestamps each
    device's own completion (``block_until_ready`` releases the GIL).
    Wire deltas and commits follow in group order, one batched
    device->host encode per group.

    A ``devices`` entry of None dispatches that group on the default
    device — passing all-None degrades to serial fused execution, which is
    exactly the baseline the `--sharded` benchmark clocks against. Each
    group must share ONE compile key (the engine only fuses same-key
    sessions onto a slot); mixed groups raise.

    ``spmd=True`` runs uniform groups (one compile key, equal B, concrete
    devices) as ONE `shard_map` launch instead: groups concatenate along
    the session axis, a cached `_session_sharding` mesh splits the stack
    across the devices, and every device runs the SAME per-group
    executable over its shard (`_launch_spmd`). One launch per lifecycle —
    the accelerator-friendly shape — but the collective-mapped program is
    a different executable from the solo one, so numerics carry the PR-7
    tolerance contract (masks/wire bytes byte-identical, fp16 within
    1 ULP) rather than the per-device dispatch path's bit-identity.

    Timing lands per device ("sharded_device", key=(slot, B, K)) and per
    batch ("train_sharded"); `obs.drift_report` prices both against the
    pool's `GPUCostModel` — the per-device modeled-vs-measured audit.

    Returns a list of per-group result lists (delta-or-None per session,
    ``train_phases_fused`` semantics)."""
    if len(devices) != len(session_groups):
        raise ValueError(
            f"{len(session_groups)} session groups need as many device "
            f"bindings, got {len(devices)}")
    results_per: list[dict] = [{} for _ in session_groups]
    prepped = []
    for gi, sessions in enumerate(session_groups):
        members, key0 = [], None
        for i, s in enumerate(sessions):
            prep = s._prepare_phase_deferred(t_now)
            if prep is None:
                results_per[gi][i] = None
                continue
            mask, frames, labels = prep
            k = _group_key(s, mask, frames, labels)
            if key0 is None:
                key0 = k
            elif k != key0:
                raise ValueError(
                    "a sharded group must share ONE compile key (the "
                    "engine fuses only same-key sessions onto a device); "
                    "split mixed sessions across slots")
            members.append((i, s, mask, frames, labels))
        if members:
            prepped.append((gi, members, key0))

    timing_on = timing.enabled()
    if prepped:
        _SHARD_STATS["batches"] += 1
        _SHARD_STATS["groups"] += len(prepped)
        _SHARD_STATS["sessions"] += sum(len(m) for _, m, _ in prepped)
        _SHARD_STATS["distinct_devices"] = max(
            _SHARD_STATS["distinct_devices"],
            len({id(devices[gi]) for gi, _, _ in prepped
                 if devices[gi] is not None}) or 1)
    t0 = time.perf_counter()

    if spmd and len(prepped) >= 2:
        if len({k for _, _, k in prepped}) != 1 \
                or len({len(m) for _, m, _ in prepped}) != 1:
            raise ValueError(
                "spmd one-launch needs uniform groups: one compile key and "
                "equal B on every device")
        devs = [devices[gi] for gi, _, _ in prepped]
        if any(d is None for d in devs):
            raise ValueError(
                "spmd needs a concrete jax.Device per group — build the "
                "pool with device_backend='jax'")
        # flatten to one big member list with synthetic flat indices, so
        # the shared commit tail can scatter results back per group
        flat, slots = [], []
        for gi, members, _ in prepped:
            for (i, s, m, f, l) in members:
                flat.append((len(flat), s, m, f, l))
                slots.append((gi, i))
        out, first = _launch_spmd(flat, prepped[0][2], len(prepped[0][1]),
                                  _session_sharding(devs))
        _block(out)
        _SHARD_STATS["spmd_launches"] += 1
        if timing_on:
            b = len(prepped[0][1])
            k0 = flat[0][1].cfg.k_iters
            timing.record(
                "train_sharded", time.perf_counter() - t0, first=first,
                key=(len(prepped), b, k0),
                nbytes=(len(flat) * k0 * 33
                        * selection.tree_size(flat[0][1].params)))
        flat_results: dict = {}
        _commit_stacked(flat, t_now, out, flat_results)
        for j, (gi, i) in enumerate(slots):
            results_per[gi][i] = flat_results[j]
        return [[results_per[gi].get(i) for i in range(len(sg))]
                for gi, sg in enumerate(session_groups)]

    launches = []
    for gi, members, _ in prepped:
        out, first = _launch_stacked(members, device=devices[gi],
                                     record=False)
        launches.append((gi, members, out, first))
        _SHARD_STATS["dispatch_launches"] += 1
    if launches:
        # per-device completion clocks: one waiter thread per launch, each
        # timestamping its own device's finish (threads, not a serial
        # block loop — blocking on slot 0 first would fold slot 1's real
        # finish time into slot 0's wait)
        def _wait(out):
            _block(out)
            return time.perf_counter()

        if len(launches) > 1:
            with ThreadPoolExecutor(max_workers=len(launches)) as ex:
                done = list(ex.map(_wait, [l[2] for l in launches]))
        else:
            done = [_wait(launches[0][2])]
        if timing_on:
            for (gi, members, out, first), t_done in zip(launches, done):
                s0 = members[0][1]
                timing.record(
                    "sharded_device", t_done - t0, first=first,
                    key=(gi, len(members), s0.cfg.k_iters),
                    nbytes=(len(members) * s0.cfg.k_iters * 33
                            * selection.tree_size(s0.params)))
            bks = {(len(m), m[0][1].cfg.k_iters) for _, m, _, _ in launches}
            uniform = bks.pop() if len(bks) == 1 else None
            timing.record(
                "train_sharded", max(done) - t0,
                first=any(l[3] for l in launches),
                key=(len(launches),) + (uniform or ()),
                nbytes=sum(len(m) * m[0][1].cfg.k_iters * 33
                           * selection.tree_size(m[0][1].params)
                           for _, m, _, _ in launches))
    for gi, members, out, _ in launches:
        _commit_stacked(members, t_now, out, results_per[gi])
    return [[results_per[gi].get(i) for i in range(len(sg))]
            for gi, sg in enumerate(session_groups)]


def _commit_stacked(members, t_now, out, results) -> None:
    """Encode the group's wire deltas (one batched device->host pull) and
    commit per-member state — the tail every stacked launch shares."""
    params, opt, u, losses, mask = out
    losses = np.asarray(losses)
    b = len(members)
    s0 = members[0][1]
    deltas = encode_delta_stack(params, mask, b, s0.cfg.value_dtype)
    _UPDATE_STATS["stacked_encode_launches"] += 1
    _UPDATE_STATS["stacked_encode_sessions"] += b
    for j, (i, s, _, _, _), p_j, o_j, u_j in zip(
            range(b), members, unstack_tree(params, b),
            unstack_tree(opt, b), unstack_tree(u, b)):
        # the delta is already encoded (batched), so no per-member mask
        # slice is ever consumed — don't dispatch B tree-slicings for it
        results[i] = s._commit_phase(t_now, p_j, o_j, u_j,
                                     float(losses[j]), None,
                                     delta=deltas[j])
