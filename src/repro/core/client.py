"""Edge client: local inference + double-buffered model swap (§3, "Edge
device"): updates are applied to an inactive copy and atomically swapped so
inference is never disrupted."""
from __future__ import annotations

from typing import Callable

from repro.core.delta import ModelDelta, apply_delta


class EdgeClient:
    def __init__(self, predict_fn: Callable, params0):
        self._predict = predict_fn
        self.active = params0
        self.inactive = params0
        self.updates_applied = 0

    def apply_update(self, delta: ModelDelta) -> None:
        """Build the updated tree off to the side, then swap it in with one
        atomic assignment — inference never sees a half-applied update.
        apply_delta is functional over immutable jax arrays, so the
        "inactive buffer" is simply the new tree under construction and both
        replicas converge by aliasing: one delta decode per update, no deep
        copies (real deployments pay the second buffer in device memory,
        which this functional sim doesn't model)."""
        self.active = self.inactive = apply_delta(self.active, delta)
        self.updates_applied += 1

    def infer(self, frame):
        return self._predict(self.active, frame)
