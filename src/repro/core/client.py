"""Edge client: local inference + double-buffered model swap (§3, "Edge
device"): updates are applied to an inactive copy and atomically swapped so
inference is never disrupted."""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from repro.core.delta import ModelDelta, apply_delta


class EdgeClient:
    def __init__(self, predict_fn: Callable, params0):
        self._predict = predict_fn
        self.active = params0
        self.inactive = jax.tree.map(lambda x: x, params0)
        self.updates_applied = 0

    def apply_update(self, delta: ModelDelta) -> None:
        """Apply to the inactive copy, then swap (never blocks inference)."""
        self.inactive = apply_delta(self.inactive, delta)
        self.active, self.inactive = self.inactive, self.active
        # fold the same update into the now-inactive copy so both replicas
        # converge (the paper keeps two full copies in memory)
        self.inactive = jax.tree.map(lambda a: a, self.active)
        self.updates_applied += 1

    def infer(self, frame):
        return self._predict(self.active, frame)
