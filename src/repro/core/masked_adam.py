"""Algorithm 2 — gradient-guided coordinate descent for the Adam optimizer.

The paper's key observation: Adam's moments must be tracked along the
*actually visited* parameter trajectory, so the coordinate subset I_n has to
be fixed BEFORE the K iterations of phase n (it is chosen from the largest
|Adam update| of phase n-1, Gauss-Southwell on the preconditioned update).

Within a phase, every iteration:
    m <- b1 m + (1-b1) g          (ALL coordinates)
    v <- b2 v + (1-b2) g^2        (ALL coordinates)
    u <- lr * sqrt(1-b2^i)/(1-b1^i) * m / sqrt(v + eps)   (paper line 12)
    w <- w - u * mask             (only I_n moves)

The returned `u` of the last iteration feeds the next phase's selection.

Everything is pytree-generic: the same code adapts a 0.5M-param segmentation
student and a 405B-param transformer (masks shard like their parameters).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


def tree_unzip(out, n: int) -> tuple:
    """Split a pytree of n-tuples (the shape a multi-output `jax.tree.map`
    produces) into n parallel pytrees. Shared by the update rules here and by
    `core.batched`'s stacked phase executor."""
    is_leaf = lambda t: isinstance(t, tuple)  # noqa: E731
    return tuple(
        jax.tree.map(lambda t, i=i: t[i], out, is_leaf=is_leaf) for i in range(n)
    )


class MaskedAdamState(NamedTuple):
    m: Any  # first-moment pytree (like params)
    v: Any  # second-moment pytree
    count: jax.Array  # global step i (scalar int32)


def init_state(params, m_dtype=None, v_dtype=jnp.float32) -> MaskedAdamState:
    m = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=m_dtype or p.dtype), params)
    v = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=v_dtype), params)
    return MaskedAdamState(m=m, v=v, count=jnp.zeros((), jnp.int32))


def masked_adam_update(
    params,
    grads,
    state: MaskedAdamState,
    mask,  # pytree of bool/0-1 arrays like params (b_n in the paper)
    *,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
):
    """One inner iteration (paper lines 7-13). Returns (params', state', u)."""
    i = state.count + 1
    bc = lr * jnp.sqrt(1.0 - b2**i.astype(jnp.float32)) / (1.0 - b1**i.astype(jnp.float32))

    def upd(p, g, m, v, b):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1.0 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1.0 - b2) * jnp.square(g32)
        u = bc * m_new / jnp.sqrt(v_new + eps)
        p_new = (p.astype(jnp.float32) - u * b.astype(jnp.float32)).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype), u

    out = jax.tree.map(upd, params, grads, state.m, state.v, mask)
    params_new, m_new, v_new, u = tree_unzip(out, 4)
    return params_new, MaskedAdamState(m_new, v_new, i), u


def adam_update(params, grads, state, **kw):
    """Unmasked Adam (mask of ones) — used by baselines and pretraining."""
    ones = jax.tree.map(lambda p: jnp.ones((), p.dtype), params)  # broadcast scalar ones
    return masked_adam_update(params, grads, state, ones, **kw)


class MomentumState(NamedTuple):
    velocity: Any


def init_momentum(params) -> MomentumState:
    return MomentumState(jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))


def momentum_update(params, grads, state: MomentumState, mask=None, *, lr=1e-3, momentum=0.9):
    """Momentum SGD (the Just-In-Time baseline's optimizer, §4.1), with
    optional coordinate mask (JIT also uses gradient-guided selection)."""
    if mask is None:
        mask = jax.tree.map(lambda p: jnp.ones((), p.dtype), params)

    def upd(p, g, vel, b):
        vel_new = momentum * vel + g.astype(jnp.float32)
        u = lr * vel_new
        p_new = (p.astype(jnp.float32) - u * b.astype(jnp.float32)).astype(p.dtype)
        return p_new, vel_new, u

    out = jax.tree.map(upd, params, grads, state.velocity, mask)
    params_new, vel, u = tree_unzip(out, 3)
    return params_new, MomentumState(vel), u
