"""Adaptive Training Rate (Appendix D, Eq. 2).

A *slowdown mode* stretches T_update by Δ per step while the ASR sampling
rate indicates a stationary scene (r_n < γ0) and snaps back to τ_min as soon
as variation picks up (r_n > γ1)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class ATRController:
    tau_min: float = 10.0
    delta: float = 2.0
    gamma0: float = 0.25  # enter slowdown below this sampling rate (fps)
    gamma1: float = 0.35  # exit slowdown above this sampling rate (fps)
    t_update: float = 10.0
    slowdown: bool = False

    def update(self, sampling_rate: float) -> float:
        if self.slowdown and sampling_rate > self.gamma1:
            self.slowdown = False
        elif not self.slowdown and sampling_rate < self.gamma0:
            self.slowdown = True
        if self.slowdown:
            self.t_update = self.t_update + self.delta
        else:
            self.t_update = self.tau_min
        return self.t_update
