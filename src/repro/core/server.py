"""AMS server (Algorithm 1) — one session per edge device.

The session owns the server-side copy of the student, the Adam moments, the
training buffer, and the ASR/ATR controllers. It is generic over a `Task`
adapter so the same server trains the paper's segmentation student and any
transformer from the model zoo (the AMS technique is pytree-generic).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selection
from repro.core.atr import ATRController
from repro.core.buffer import ReplayBuffer
from repro.core.delta import ModelDelta, encode_delta
from repro.core.masked_adam import (
    MaskedAdamState,
    MomentumState,
    init_momentum,
    init_state,
    masked_adam_update,
    momentum_update,
)
from repro.core.sampler import ASRController


@dataclass(frozen=True)
class AMSConfig:
    """Paper defaults (§4.1): T_horizon=240s, T_update=10s, K=20, γ=5%,
    Adam(1e-3, 0.9, 0.999); ASR r∈[0.1,1] fps, δt=10s."""

    t_update: float = 10.0
    t_horizon: float = 240.0
    k_iters: int = 20
    batch_size: int = 8
    gamma: float = 0.05
    strategy: str = "gradient_guided"
    optimizer: str = "adam"  # "momentum" = Just-In-Time's optimizer
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    momentum: float = 0.9
    value_dtype: str = "float16"
    # ASR
    phi_target: float = 0.25
    asr_eta: float = 0.5
    r_min: float = 0.1
    r_max: float = 1.0
    asr_delta_t: float = 10.0
    # ATR (Appendix D)
    atr_enabled: bool = False
    atr_delta: float = 2.0
    atr_gamma0: float = 0.25
    atr_gamma1: float = 0.35


@dataclass
class Task:
    """Adapter binding AMS to a concrete model/task.

    loss_and_grad(params, frames, labels) -> (loss, grads)       [jit-able]
    teacher(frames) -> labels                                    [host or jit]
    phi_loss(label_now, label_prev) -> float  (task loss for the φ-score)
    """

    loss_and_grad: Callable
    teacher: Callable
    phi_loss: Callable


class AMSSession:
    def __init__(self, task: Task, cfg: AMSConfig, params0, seed: int = 0):
        self.task = task
        self.cfg = cfg
        self.params = params0
        if cfg.optimizer == "adam":
            self.opt_state: Any = init_state(params0)
        else:
            self.opt_state = init_momentum(params0)
        self.buffer = ReplayBuffer(horizon=cfg.t_horizon)
        self.asr = ASRController(
            phi_target=cfg.phi_target, eta=cfg.asr_eta, r_min=cfg.r_min,
            r_max=cfg.r_max, delta_t=cfg.asr_delta_t,
        )
        self.atr = ATRController(
            tau_min=cfg.t_update, delta=cfg.atr_delta,
            gamma0=cfg.atr_gamma0, gamma1=cfg.atr_gamma1, t_update=cfg.t_update,
        )
        self.rng = np.random.default_rng(seed)
        self.jrng = jax.random.PRNGKey(seed)
        self.u_prev = None  # last full Adam update (phase n-1)
        self.phase = 0
        self.last_label = None
        self.next_train_time = 0.0
        self.t_update = cfg.t_update
        # telemetry
        self.history: list = []

    # ---------------- inference phase (Algorithm 1, lines 5-9) -----------
    def receive_frames(self, frames, t_now: float) -> None:
        """Label new sample frames with the teacher; feed buffer + φ-score.

        The teacher runs ONCE over the stacked batch (one launch instead of
        one per frame); the φ-score ingest stays sequential — it compares
        consecutive labels, so order matters."""
        frames = list(frames)
        if not frames:
            self.asr.maybe_update(t_now)
            return
        labels = np.asarray(self.task.teacher(np.stack(frames)))
        for frame, label in zip(frames, labels):
            self._ingest(frame, label, t_now)
        self.asr.maybe_update(t_now)

    def receive_labeled(self, frames, labels, t_now: float) -> None:
        """Same as receive_frames but labels were produced upstream (oracle
        teacher in the simulation world labels by frame index)."""
        for frame, label in zip(frames, labels):
            self._ingest(frame, np.asarray(label), t_now)
        self.asr.maybe_update(t_now)

    def _ingest(self, frame, label, t_now: float) -> None:
        if self.last_label is not None:
            self.asr.observe(self.task.phi_loss(label, self.last_label))
        self.last_label = label
        self.buffer.add(frame, label, t_now)

    # ---------------- training phase (Algorithm 1, lines 10-17) ----------
    def _select_mask(self):
        cfg = self.cfg
        if cfg.strategy == "gradient_guided" and self.u_prev is None:
            # first phase: uniform random (paper §3.1.2)
            self.jrng, k = jax.random.split(self.jrng)
            return selection.random_mask(k, self.params, cfg.gamma)
        self.jrng, k = jax.random.split(self.jrng)
        return selection.make_mask(
            cfg.strategy, params=self.params, u_prev=self.u_prev, frac=cfg.gamma, rng=k
        )

    def _select_mask_or_defer(self):
        """`_select_mask` with the gradient-guided launch left pending.

        A gradient-guided selection (the non-first-phase common case) is a
        pure function of ``u_prev`` — no RNG — so the fused pipeline can
        batch B of them into ONE vmapped bisection launch
        (`selection.stacked_gradient_guided_masks`). This consumes the
        session RNGs exactly as `_select_mask` does (the jrng split happens
        even when its key goes unused) and returns None for "deferred:
        stack me"; every other strategy returns its concrete mask."""
        cfg = self.cfg
        if cfg.strategy == "gradient_guided" and self.u_prev is not None:
            self.jrng, _ = jax.random.split(self.jrng)
            return None
        return self._select_mask()

    def _prepare_phase(self, t_now: float):
        """Host-side phase setup: select the coordinate mask and draw all K
        replay minibatches, consuming the session RNGs exactly as the
        sequential loop does. Returns ``(mask, frames, labels)`` with
        frames/labels stacked as (K, batch, ...), or None when there is
        nothing to train on."""
        prep = self._prepare_phase_deferred(t_now)
        if prep is None:
            return None
        mask, frames, labels = prep
        if mask is None:
            mask = selection.gradient_guided_mask(self.u_prev, self.cfg.gamma)
        return mask, frames, labels

    def _prepare_phase_deferred(self, t_now: float):
        """`_prepare_phase` for the fused pipeline: identical RNG
        consumption and batch shapes, but a gradient-guided mask slot is
        returned as None (deferred) so `core.batched` can run one stacked
        selection launch for the whole group instead of B solo ones."""
        cfg = self.cfg
        if len(self.buffer) == 0:
            return None
        mask = self._select_mask_or_defer()
        batches = []
        for _ in range(cfg.k_iters):
            batch = self.buffer.sample(self.rng, cfg.batch_size, t_now)
            if batch is None:  # empty horizon window: jrng consumed, no train
                return None
            batches.append(batch)
        frames = np.stack([b[0] for b in batches])
        labels = np.stack([b[1] for b in batches])
        return mask, frames, labels

    def _run_phase_prepared(self, t_now: float, mask, frames,
                            labels) -> ModelDelta:
        """The sequential K-iteration loop over prepared batches (the
        reference numerics; `core.batched` runs the same phase stacked)."""
        cfg = self.cfg
        params, opt_state, u = self.params, self.opt_state, None
        for k in range(cfg.k_iters):
            loss, grads = self.task.loss_and_grad(params, frames[k], labels[k])
            if cfg.optimizer == "adam":
                params, opt_state, u = masked_adam_update(
                    params, grads, opt_state, mask,
                    lr=cfg.lr, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
                )
            else:
                params, opt_state, u = momentum_update(
                    params, grads, opt_state, mask, lr=cfg.lr, momentum=cfg.momentum
                )
        return self._commit_phase(t_now, params, opt_state, u, float(loss), mask)

    def _commit_phase(self, t_now: float, params, opt_state, u, loss: float,
                      mask, delta: ModelDelta | None = None) -> ModelDelta:
        """Adopt a finished phase's state and produce the wire delta — shared
        tail of the sequential and fused paths. A fused group encodes the
        whole stack's deltas in one batched device round-trip
        (`delta.encode_delta_stack`) and passes each session's slice in as
        ``delta`` (byte-identical to encoding here)."""
        cfg = self.cfg
        self.params, self.opt_state, self.u_prev = params, opt_state, u
        self.phase += 1
        if delta is None:
            delta = encode_delta(params, mask, cfg.value_dtype)
        # ATR: stretch/reset T_update from the ASR rate (Appendix D)
        if cfg.atr_enabled:
            self.t_update = self.atr.update(self.asr.rate)
        self.next_train_time = t_now + self.t_update
        self.history.append(
            {"t": t_now, "loss": float(loss), "bytes": delta.total_bytes,
             "rate": self.asr.rate, "t_update": self.t_update}
        )
        return delta

    def train_phase(self, t_now: float) -> ModelDelta | None:
        prep = self._prepare_phase(t_now)
        if prep is None:
            return None
        return self._run_phase_prepared(t_now, *prep)

    @property
    def sampling_rate(self) -> float:
        return self.asr.rate
