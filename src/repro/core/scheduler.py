"""Multi-session server scheduling (Appendix E).

Round-robin over sessions, one inference+training step per turn, one session
on the GPU at a time (the paper's strategy — minimizes context switching).
The GPU is modeled by a busy-until clock with per-operation costs calibrated
to the paper's V100 numbers (teacher inference 200-300 ms/frame; K=20 student
iterations per phase)."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class GPUCostModel:
    teacher_infer_s: float = 0.25  # per frame (paper: 200-300 ms on V100)
    train_iter_s: float = 0.05  # per student minibatch iteration
    @property
    def phase_s(self) -> float:  # K=20 iterations
        return 20 * self.train_iter_s


@dataclass
class RoundRobinScheduler:
    cost: GPUCostModel = field(default_factory=GPUCostModel)
    gpu_free_at: float = 0.0
    turn: int = 0
    # telemetry
    busy_s: float = 0.0
    served: int = 0
    deferred: int = 0

    def try_acquire(self, t_now: float, n_frames: int, k_iters: int) -> bool:
        """One session's turn: label n_frames + run a training phase.
        Returns False (deferred) if the GPU is still busy."""
        if t_now < self.gpu_free_at:
            self.deferred += 1
            return False
        dur = n_frames * self.cost.teacher_infer_s + k_iters * self.cost.train_iter_s
        self.gpu_free_at = max(self.gpu_free_at, t_now) + dur
        self.busy_s += dur
        self.served += 1
        return True

    def utilization(self, t_now: float) -> float:
        return self.busy_s / max(t_now, 1e-9)
