"""Multi-session server scheduling (Appendix E).

Round-robin over sessions, one inference+training step per turn, one session
on the GPU at a time (the paper's strategy — minimizes context switching).
The GPU is modeled by a busy-until clock with per-operation costs calibrated
to the paper's V100 numbers (teacher inference 200-300 ms/frame; K=20 student
iterations per phase)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable


@dataclass(frozen=True)
class GPUCostModel:
    teacher_infer_s: float = 0.25  # per frame (paper: 200-300 ms on V100)
    train_iter_s: float = 0.05  # per student minibatch iteration
    # cross-client batched labeling (serving runtime): one launch labels the
    # whole backlog, amortizing per-frame cost to a fraction of the solo rate
    label_batch_overhead_s: float = 0.05
    label_batch_discount: float = 0.5
    # top-gamma% delta selection + entropy coding runs on the device after a
    # phase (paper §3.1.2); 0.0 keeps the seed/PR-1 behavior (free)
    delta_comp_s_per_mb: float = 0.0
    # gradient-guided coordinate selection (bisection/sort launch) per
    # session; 0.0 keeps the selection stage unmodeled (the PR-4 behavior)
    select_s: float = 0.0
    # fused post-train update pipeline (core.batched + core.delta): a fused
    # grant's B selections run as one stacked launch and its B deltas as one
    # batched device->host encode — a setup charge plus discounted marginal
    # riders, mirroring train_batch_s. Applies only when the update path is
    # priced at all (select_s or delta_comp_s_per_mb nonzero).
    update_setup_s: float = 0.02
    update_discount: float = 0.4
    # fused cross-session training (core.batched): B co-resident sessions'
    # phases run as one stacked scan/vmap launch — a setup charge plus a
    # sublinear per-session marginal cost (no B x K dispatch overhead, better
    # device occupancy). B=1 is exactly the solo cost, so an unfused engine
    # is bit-identical.
    train_batch_setup_s: float = 0.05
    train_batch_discount: float = 0.45

    @property
    def phase_s(self) -> float:  # K=20 iterations
        return 20 * self.train_iter_s

    def phase_cost_s(self, n_frames: int, k_iters: int) -> float:
        return n_frames * self.teacher_infer_s + k_iters * self.train_iter_s

    def label_batch_s(self, n_frames: int) -> float:
        if n_frames <= 0:
            return 0.0
        return (self.label_batch_overhead_s
                + n_frames * self.teacher_infer_s * self.label_batch_discount)

    def train_batch_s(self, n_sessions: int, k_iters: int) -> float:
        """One fused launch training ``n_sessions`` co-resident sessions for
        ``k_iters`` iterations each: a stacking setup charge, the first
        session at full price, and each additional rider at a discounted
        *marginal* cost (the stacked executable replaces B x K dispatches
        with one launch and fills the device better). Exactly the sequential
        cost at B=1, so an unfused engine stays bit-identical."""
        if n_sessions <= 0:
            return 0.0
        solo = k_iters * self.train_iter_s
        if n_sessions == 1:
            return solo
        return (self.train_batch_setup_s + solo
                + (n_sessions - 1) * solo * self.train_batch_discount)

    def delta_comp_s(self, nbytes: int) -> float:
        """GPU time to select/compress one ModelDelta of ``nbytes``."""
        if nbytes <= 0:
            return 0.0
        return self.delta_comp_s_per_mb * nbytes / 1e6

    def update_solo_s(self, nbytes: int) -> float:
        """One session's post-train update production: coordinate selection
        plus delta compression (0.0 when both stages are unmodeled)."""
        return self.select_s + self.delta_comp_s(nbytes)

    def update_batch_s(self, bytes_list) -> float:
        """One fused update launch producing ``len(bytes_list)`` deltas:
        the stacked selection + batched encode replace B serial
        select/gather/pack round-trips, so the primary pays full price and
        each rider a discounted marginal cost after a stacking setup charge.
        B=1 is exactly `update_solo_s`, and an unpriced pipeline (all solo
        costs zero) stays free — no setup charge appears out of nowhere, so
        default-cost engines are bit-identical."""
        costs = [self.update_solo_s(b) for b in bytes_list]
        if not costs or sum(costs) <= 0.0:
            return 0.0
        if len(costs) == 1:
            return costs[0]
        return (self.update_setup_s + costs[0]
                + self.update_discount * sum(costs[1:]))


def next_in_turn(waiting: Iterable[int], turn: int, n_clients: int) -> int | None:
    """The round-robin successor: among ``waiting`` client ids, the first one
    at or after the ``turn`` pointer (mod n). Shared by RoundRobinScheduler
    and the serving engine's fair policy so both implement the same order."""
    waiting = list(waiting)
    if not waiting:
        return None
    n = max(n_clients, max(waiting) + 1, 1)
    return min(waiting, key=lambda c: ((c - turn) % n, c))


@dataclass
class RoundRobinScheduler:
    """Busy-clock scheduler for polling callers (the legacy tick-loop
    style). The event-driven serving engine does not use this class — its
    fair policy is `serving.policies.FairRoundRobin` — but both derive
    their turn order from `next_in_turn` above, so the ring semantics
    cannot silently diverge."""

    cost: GPUCostModel = field(default_factory=GPUCostModel)
    gpu_free_at: float = 0.0
    turn: int = 0
    n_clients: int = 0
    waiting_timeout: float = 5.0  # s without re-polling before a waiter is dropped
    # telemetry
    busy_s: float = 0.0
    served: int = 0
    deferred: int = 0
    _waiting: dict = field(default_factory=dict)  # client id -> last poll time

    def try_acquire(self, t_now: float, n_frames: int, k_iters: int,
                    client: int | None = None) -> bool:
        """One session's turn: label n_frames + run a training phase.

        With a ``client`` id, grants are round-robin over the clients
        currently asking: the GPU goes to the waiting client closest after
        the ``turn`` pointer, and the pointer advances past each grant — so
        poll order cannot starve late-indexed clients. Clients that never ask
        are skipped rather than holding the ring, and a waiter that stops
        re-polling (crash, disconnect) is expired after ``waiting_timeout``
        so it cannot block everyone else's grants forever. Without an id
        (legacy single-queue callers), any request is granted when the GPU
        is free. Returns False (deferred) if the GPU is busy or it isn't
        our turn."""
        if client is not None:
            self.n_clients = max(self.n_clients, client + 1)
            self._waiting[client] = t_now  # refresh liveness on every poll
        if t_now < self.gpu_free_at:
            self.deferred += 1
            return False
        if client is not None:
            for c, last_poll in list(self._waiting.items()):
                if t_now - last_poll > self.waiting_timeout:
                    del self._waiting[c]
            nxt = next_in_turn(self._waiting, self.turn, self.n_clients)
            if nxt != client:
                self.deferred += 1
                return False
            del self._waiting[client]
            # unwrapped on purpose: next_in_turn reduces mod the *current*
            # client count, which may still be growing at this point
            self.turn = client + 1
        dur = self.cost.phase_cost_s(n_frames, k_iters)
        self.gpu_free_at = max(self.gpu_free_at, t_now) + dur
        self.busy_s += dur
        self.served += 1
        return True

    def utilization(self, t_now: float) -> float:
        return self.busy_s / max(t_now, 1e-9)
