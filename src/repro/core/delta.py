"""Sparse model-update codec (§3.1.2, downlink payload).

A ModelDelta carries, per leaf: the new values of masked coordinates as
fp16, plus one global gzip'd bit-vector marking their positions — exactly
the paper's wire format ("it sends a bit-vector identifying the location of
the parameters... compressed [with] gzip").
"""
from __future__ import annotations

import gzip
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ModelDelta:
    values: np.ndarray  # concatenated masked values (value_dtype)
    packed_mask: bytes  # gzip'd packed bit-vector over the flat param space
    n_total: int  # total parameter count (for unpacking)
    value_dtype: str = "float16"

    # --- wire accounting -------------------------------------------------
    @property
    def value_bytes(self) -> int:
        return self.values.nbytes

    @property
    def mask_bytes(self) -> int:
        return len(self.packed_mask)

    @property
    def total_bytes(self) -> int:
        return self.value_bytes + self.mask_bytes


def _flatten(tree) -> np.ndarray:
    leaves = [np.asarray(l).reshape(-1) for l in jax.tree.leaves(tree)]
    return np.concatenate(leaves) if leaves else np.zeros((0,))


# reusable flat-mask scratch, keyed by total parameter count: encode_delta
# runs once per train phase per session, and re-allocating an N-bool buffer
# (plus two full flatten/concat passes) per call showed up at fleet scale.
# Not thread-safe — the serving engine is single-threaded by construction.
_MASK_SCRATCH: dict[int, np.ndarray] = {}


def encode_delta(params_new, mask, value_dtype="float16") -> ModelDelta:
    """Single pass over paired (param, mask) leaves: masked values are
    gathered per leaf (never materializing the full flat parameter vector)
    and mask bits are written into a reused scratch buffer before packing.
    Byte-identical to the two-pass flatten/concat encoding."""
    p_leaves = jax.tree.leaves(params_new)
    m_leaves = jax.tree.leaves(mask)
    n_total = sum(l.size for l in p_leaves)
    flat_m = _MASK_SCRATCH.get(n_total)
    if flat_m is None or n_total == 0:
        flat_m = _MASK_SCRATCH.setdefault(n_total, np.empty(n_total, bool))
    picked, off = [], 0
    for p, m in zip(p_leaves, m_leaves):
        m_flat = np.asarray(m).reshape(-1).astype(bool)
        flat_m[off:off + m_flat.size] = m_flat
        picked.append(np.asarray(p).reshape(-1)[m_flat])
        off += m_flat.size
    values = (np.concatenate(picked) if picked
              else np.zeros((0,))).astype(value_dtype)
    # mtime=0 pins the 4-byte gzip MTIME header field: the wire encoding is
    # a pure function of the mask (same total_bytes, no wall-clock leakage)
    packed = gzip.compress(np.packbits(flat_m).tobytes(), compresslevel=6,
                           mtime=0)
    return ModelDelta(values=values, packed_mask=packed, n_total=n_total,
                      value_dtype=value_dtype)


def apply_delta(params_old, delta: ModelDelta):
    """Edge-side: overwrite masked coordinates with streamed values."""
    flat_m = np.unpackbits(
        np.frombuffer(gzip.decompress(delta.packed_mask), np.uint8)
    )[: delta.n_total].astype(bool)
    leaves, treedef = jax.tree.flatten(params_old)
    out, off_p, off_v = [], 0, 0
    vals = delta.values
    for leaf in leaves:
        n = int(np.prod(leaf.shape))
        m = flat_m[off_p : off_p + n]
        k = int(m.sum())
        flat = np.asarray(leaf).reshape(-1).copy()
        flat[m] = vals[off_v : off_v + k].astype(flat.dtype)
        out.append(jnp.asarray(flat.reshape(leaf.shape), dtype=leaf.dtype))
        off_p += n
        off_v += k
    assert off_p == delta.n_total and off_v == vals.size
    return jax.tree.unflatten(treedef, out)


def full_model_bytes(params, value_dtype="float16") -> int:
    """Wire cost of a naive full-model update (the paper's 3.2 Mbps case)."""
    return _flatten(params).astype(value_dtype).nbytes
