"""Sparse model-update codec (§3.1.2, downlink payload).

A ModelDelta carries, per leaf: the new values of masked coordinates as
fp16, plus one global gzip'd bit-vector marking their positions — exactly
the paper's wire format ("it sends a bit-vector identifying the location of
the parameters... compressed [with] gzip").
"""
from __future__ import annotations

import gzip
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ModelDelta:
    values: np.ndarray  # concatenated masked values (value_dtype)
    packed_mask: bytes  # gzip'd packed bit-vector over the flat param space
    n_total: int  # total parameter count (for unpacking)
    value_dtype: str = "float16"

    # --- wire accounting -------------------------------------------------
    @property
    def value_bytes(self) -> int:
        return self.values.nbytes

    @property
    def mask_bytes(self) -> int:
        return len(self.packed_mask)

    @property
    def total_bytes(self) -> int:
        return self.value_bytes + self.mask_bytes


def _flatten(tree) -> np.ndarray:
    leaves = [np.asarray(l).reshape(-1) for l in jax.tree.leaves(tree)]
    return np.concatenate(leaves) if leaves else np.zeros((0,))


def encode_delta(params_new, mask, value_dtype="float16") -> ModelDelta:
    flat_p = _flatten(params_new)
    flat_m = _flatten(mask).astype(bool)
    values = flat_p[flat_m].astype(value_dtype)
    packed = gzip.compress(np.packbits(flat_m).tobytes(), compresslevel=6)
    return ModelDelta(values=values, packed_mask=packed, n_total=flat_p.size,
                      value_dtype=value_dtype)


def apply_delta(params_old, delta: ModelDelta):
    """Edge-side: overwrite masked coordinates with streamed values."""
    flat_m = np.unpackbits(
        np.frombuffer(gzip.decompress(delta.packed_mask), np.uint8)
    )[: delta.n_total].astype(bool)
    leaves, treedef = jax.tree.flatten(params_old)
    out, off_p, off_v = [], 0, 0
    vals = delta.values
    for leaf in leaves:
        n = int(np.prod(leaf.shape))
        m = flat_m[off_p : off_p + n]
        k = int(m.sum())
        flat = np.asarray(leaf).reshape(-1).copy()
        flat[m] = vals[off_v : off_v + k].astype(flat.dtype)
        out.append(jnp.asarray(flat.reshape(leaf.shape), dtype=leaf.dtype))
        off_p += n
        off_v += k
    assert off_p == delta.n_total and off_v == vals.size
    return jax.tree.unflatten(treedef, out)


def full_model_bytes(params, value_dtype="float16") -> int:
    """Wire cost of a naive full-model update (the paper's 3.2 Mbps case)."""
    return _flatten(params).astype(value_dtype).nbytes
