"""Sparse model-update codec (§3.1.2, downlink payload).

A ModelDelta carries, per leaf: the new values of masked coordinates as
fp16, plus one global gzip'd bit-vector marking their positions — exactly
the paper's wire format ("it sends a bit-vector identifying the location of
the parameters... compressed [with] gzip").
"""
from __future__ import annotations

import gzip
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import timing


@dataclass
class ModelDelta:
    values: np.ndarray  # concatenated masked values (value_dtype)
    packed_mask: bytes  # gzip'd packed bit-vector over the flat param space
    n_total: int  # total parameter count (for unpacking)
    value_dtype: str = "float16"

    # --- wire accounting -------------------------------------------------
    @property
    def value_bytes(self) -> int:
        return self.values.nbytes

    @property
    def mask_bytes(self) -> int:
        return len(self.packed_mask)

    @property
    def total_bytes(self) -> int:
        return self.value_bytes + self.mask_bytes


def _flatten(tree) -> np.ndarray:
    leaves = [np.asarray(l).reshape(-1) for l in jax.tree.leaves(tree)]
    return np.concatenate(leaves) if leaves else np.zeros((0,))


# reusable flat-mask scratch: encode_delta runs once per train phase per
# session, and re-allocating an N-bool buffer (plus two full flatten/concat
# passes) per call showed up at fleet scale. Keyed by (n_total, value_dtype)
# so interleaved encodes of same-sized trees at different wire dtypes can
# never alias each other's in-flight buffer (a hazard once callers hold a
# delta across a later encode). Not thread-safe — the serving engine is
# single-threaded by construction.
_MASK_SCRATCH: dict[tuple[int, str], np.ndarray] = {}


def _pack_mask_bits(flat_m: np.ndarray) -> bytes:
    """gzip'd packed bit-vector over one flat bool mask — the wire format.

    mtime=0 pins the 4-byte gzip MTIME header field: the wire encoding is
    a pure function of the mask (same total_bytes, no wall-clock leakage)."""
    return gzip.compress(np.packbits(flat_m).tobytes(), compresslevel=6,
                         mtime=0)


def encode_delta(params_new, mask, value_dtype="float16") -> ModelDelta:
    """Single pass over paired (param, mask) leaves: masked values are
    gathered per leaf (never materializing the full flat parameter vector)
    and mask bits are written into a reused scratch buffer before packing.
    Byte-identical to the two-pass flatten/concat encoding."""
    if not timing.enabled():
        return _encode_delta_impl(params_new, mask, value_dtype)
    t0 = time.perf_counter()
    d = _encode_delta_impl(params_new, mask, value_dtype)
    # pure host work (asarray syncs the device): no compile split needed
    timing.record("encode_solo", time.perf_counter() - t0,
                  nbytes=d.total_bytes)
    return d


def _encode_delta_impl(params_new, mask, value_dtype) -> ModelDelta:
    p_leaves = jax.tree.leaves(params_new)
    m_leaves = jax.tree.leaves(mask)
    n_total = sum(l.size for l in p_leaves)
    key = (n_total, str(value_dtype))
    flat_m = _MASK_SCRATCH.get(key)
    if flat_m is None or n_total == 0:
        flat_m = _MASK_SCRATCH.setdefault(key, np.empty(n_total, bool))
    picked, off = [], 0
    for p, m in zip(p_leaves, m_leaves):
        m_flat = np.asarray(m).reshape(-1).astype(bool)
        flat_m[off:off + m_flat.size] = m_flat
        picked.append(np.asarray(p).reshape(-1)[m_flat])
        off += m_flat.size
    values = (np.concatenate(picked) if picked
              else np.zeros((0,))).astype(value_dtype)
    packed = _pack_mask_bits(flat_m)
    return ModelDelta(values=values, packed_mask=packed, n_total=n_total,
                      value_dtype=value_dtype)


# ---------------------------------------------------------------------------
# batched encode (fused post-train update pipeline)
# ---------------------------------------------------------------------------

# One cached flatten/cast executable per (stacked struct, value_dtype) —
# the `core.batched` compile-key cache pattern. The executable keeps the
# masked-value cast and the mask flattening ON DEVICE for the whole stack,
# so a fused grant's B deltas cost ONE stacked device->host transfer pair
# instead of B x n_leaves leaf-by-leaf `np.asarray` pulls.
_STACK_CACHE: dict = {}
_STACK_HITS = 0
_STACK_MISSES = 0


def stack_cache_info() -> dict:
    """Hook for tests/telemetry: how often did fused grants share a stacked
    encode executable?"""
    return {"size": len(_STACK_CACHE), "hits": _STACK_HITS,
            "misses": _STACK_MISSES}


def stack_cache_clear() -> None:
    global _STACK_HITS, _STACK_MISSES
    _STACK_CACHE.clear()
    _STACK_HITS = _STACK_MISSES = 0


def _stack_flatten_fn(value_dtype: str):
    @jax.jit
    def flatten(params_stacked, mask_stacked):
        vals = jnp.concatenate(
            [l.reshape(l.shape[0], -1).astype(value_dtype)
             for l in jax.tree.leaves(params_stacked)], axis=1)
        bits = jnp.concatenate(
            [l.reshape(l.shape[0], -1).astype(bool)
             for l in jax.tree.leaves(mask_stacked)], axis=1)
        return vals, bits

    return flatten


def encode_delta_stack(params_stacked, mask_stacked, n_sessions: int,
                       value_dtype="float16") -> list[ModelDelta]:
    """B sessions' deltas from stacked trees in one device round-trip.

    ``params_stacked``/``mask_stacked`` carry a leading session axis (the
    shape a fused train launch already holds them in). The fp16 cast and the
    per-leaf flattening run on device over the whole stack, then ONE stacked
    transfer pair lands ``(B, n_total)`` values + mask bits on the host; the
    per-session gather and the gzip'd bit-vector pack reuse `encode_delta`'s
    wire format. Each returned delta is byte-identical to
    ``encode_delta(params_b, mask_b, value_dtype)`` — the cast commutes with
    the gather elementwise, so casting device-side first changes no bytes."""
    global _STACK_HITS, _STACK_MISSES
    p_leaves, treedef = jax.tree.flatten(params_stacked)
    n_total = sum(int(np.prod(l.shape[1:])) for l in p_leaves)
    key = (treedef,
           tuple((tuple(l.shape), l.dtype.name) for l in p_leaves),
           str(value_dtype))
    fn = _STACK_CACHE.get(key)
    first = fn is None
    if first:
        _STACK_MISSES += 1
        fn = _stack_flatten_fn(str(value_dtype))
        _STACK_CACHE[key] = fn
    else:
        _STACK_HITS += 1
    t0 = time.perf_counter() if timing.enabled() else 0.0
    vals_dev, bits_dev = fn(params_stacked, mask_stacked)
    vals = np.asarray(vals_dev)  # ONE stacked pull each, not B x n_leaves
    bits = np.asarray(bits_dev)
    out = []
    for b in range(n_sessions):
        flat_m = bits[b]
        out.append(ModelDelta(values=vals[b][flat_m],
                              packed_mask=_pack_mask_bits(flat_m),
                              n_total=n_total, value_dtype=value_dtype))
    if timing.enabled():
        timing.record("encode_stacked", time.perf_counter() - t0,
                      first=first, key=(n_sessions,),
                      nbytes=sum(d.total_bytes for d in out))
    return out


def apply_delta(params_old, delta: ModelDelta):
    """Edge-side: overwrite masked coordinates with streamed values."""
    flat_m = np.unpackbits(
        np.frombuffer(gzip.decompress(delta.packed_mask), np.uint8)
    )[: delta.n_total].astype(bool)
    leaves, treedef = jax.tree.flatten(params_old)
    out, off_p, off_v = [], 0, 0
    vals = delta.values
    for leaf in leaves:
        n = int(np.prod(leaf.shape))
        m = flat_m[off_p : off_p + n]
        k = int(m.sum())
        flat = np.asarray(leaf).reshape(-1).copy()
        flat[m] = vals[off_v : off_v + k].astype(flat.dtype)
        out.append(jnp.asarray(flat.reshape(leaf.shape), dtype=leaf.dtype))
        off_p += n
        off_v += k
    assert off_p == delta.n_total and off_v == vals.size
    return jax.tree.unflatten(treedef, out)


def full_model_bytes(params, value_dtype="float16") -> int:
    """Wire cost of a naive full-model update (the paper's 3.2 Mbps case)."""
    return _flatten(params).astype(value_dtype).nbytes
