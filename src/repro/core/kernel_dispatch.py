"""Kernel dispatch for the serving hot path: XLA vs Pallas, raced or forced.

The fused grant lifecycle has two stages hot enough to justify hand-written
kernels — the masked-Adam inner update (pure HBM-bandwidth, ~36 bytes per
parameter per iteration) and the bit-pattern top-k threshold search behind
gradient-guided selection (32 counting passes that a kernel collapses into
ONE HBM read). Both now exist as Pallas implementations
(`repro.kernels.masked_adam.ops.masked_adam_stacked`,
`repro.kernels.topk_mask`), and this module is the switch that decides,
per call site, which implementation the cached executables embed:

* ``"xla"`` (the default) — the tree_map / counting-loop implementations
  every prior PR shipped. Bit-identical to PR 6, golden-tested.
* ``"pallas"`` — the Pallas kernels. Selection masks and packed wire
  masks stay byte-identical to the XLA path (the top-k threshold search
  is exact integer counting in both engines) and the fp16 wire-delta
  values agree to 1 ULP — the residue of XLA:CPU's context-dependent FMA
  contraction, which makes even the XLA reference differ jit-vs-nojit
  (both CI-asserted by ``scripts/ci.sh --kernels``). On a real
  accelerator they trade the multi-pass XLA lowering for single-HBM-pass
  kernels.
* ``"auto"`` — the same discipline as `core.batched.set_exec_mode`'s
  scan-vs-loop race: the first call for a (backend, compile key) builds
  both implementations, times one warmed execution of each on the caller's
  real batch, records the winner here, and every later call is a plain
  cache hit on measured evidence. Because the masks agree byte-for-byte,
  the race carries no adaptivity wobble — only ULP-level float residue
  and the wall-clock of the winning executable change.

State is process-global like the executable caches it steers; the serving
engine is single-threaded by construction. `kernel_dispatch_info` feeds
`serving.obs.debug_snapshot`.
"""
from __future__ import annotations

KERNEL_MODES = ("auto", "pallas", "xla")

_MODE = "xla"
# measured auto winners: (site, backend, compile key) -> {"winner", "times"}
# where site names the call site ("train_fused" | "topk") and the compile
# key is the same hashable struct key the site's executable cache uses.
_AUTO: dict = {}


def set_kernel_mode(mode: str) -> None:
    """Select the hot-path kernel implementation: ``xla`` (default,
    bit-identical to the pre-kernel path), ``pallas``, or ``auto`` (first
    call per (backend, compile key) races both and keeps the measured
    winner). Decided races survive a mode flip away and back."""
    if mode not in KERNEL_MODES:
        raise ValueError(f"kernel mode must be auto|pallas|xla, got {mode!r}")
    global _MODE
    _MODE = mode


def kernel_mode() -> str:
    return _MODE


def auto_winner(site: str, backend: str, key) -> str | None:
    """The recorded race winner for a call site's compile key, or None if
    this (backend, key) has not raced yet."""
    e = _AUTO.get((site, backend, key))
    return e["winner"] if e else None


def record_auto(site: str, backend: str, key, winner: str,
                times: dict) -> None:
    """Record a finished XLA-vs-Pallas race (measured best-of wall-clock
    per implementation, in seconds)."""
    _AUTO[(site, backend, key)] = {"winner": winner,
                                   "times": {k: float(v)
                                             for k, v in times.items()}}


def auto_info() -> dict:
    """The raw race table (hashable compile keys as-is) — tests."""
    return {k: dict(v) for k, v in _AUTO.items()}


def kernel_dispatch_info() -> dict:
    """JSON-friendly summary for `obs.debug_snapshot` / benchmarks: the
    forced mode plus every auto race decision, keyed by
    ``site:backend:<8-digit key hash>`` (compile keys are unhashable into
    JSON directly — same digest convention as ``auto_exec_modes``)."""
    return {
        "mode": _MODE,
        "auto_races": {
            f"{site}:{backend}:{abs(hash(key)) % 10**8:08d}": dict(e)
            for (site, backend, key), e in _AUTO.items()
        },
    }


def reset() -> None:
    """Back to defaults: mode ``xla``, race table cleared (tests)."""
    global _MODE
    _MODE = "xla"
    _AUTO.clear()
