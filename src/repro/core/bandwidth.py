"""Uplink/downlink byte ledger -> Kbps accounting (paper Tables 1-2)."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class BandwidthLedger:
    up_bytes: int = 0
    down_bytes: int = 0
    events: list = field(default_factory=list)

    def uplink(self, nbytes: int, t: float, what: str = "frames") -> None:
        self.up_bytes += int(nbytes)
        self.events.append((t, "up", what, int(nbytes)))

    def downlink(self, nbytes: int, t: float, what: str = "delta") -> None:
        self.down_bytes += int(nbytes)
        self.events.append((t, "down", what, int(nbytes)))

    def kbps(self, duration_s: float) -> tuple[float, float]:
        if duration_s <= 0:
            return 0.0, 0.0
        return (self.up_bytes * 8 / duration_s / 1e3,
                self.down_bytes * 8 / duration_s / 1e3)
