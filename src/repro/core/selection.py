"""Coordinate-subset selection strategies (paper §3.1.2 + Table 3).

`gradient_guided` implements the paper's method: pick the γ-fraction of
coordinates with the largest |u_{n-1}| (last Adam update of the previous
phase). The γ-quantile threshold is found by *bisection over per-leaf counts*
rather than a global sort — O(log(range)) passes of O(N) reductions, exactly
shardable under pjit, and scales to 4e11-parameter pytrees where a global
sort/concat is infeasible (DESIGN.md §5, hardware adaptation).

Also provides the Table-3 ablation strategies: random, first layers, last
layers, first&last.
"""
from __future__ import annotations

import functools
import math
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernel_dispatch, timing


def tree_size(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# gradient-guided (the paper's strategy)
# ---------------------------------------------------------------------------


def _count_above(tree, thr) -> jax.Array:
    return sum(jnp.sum(jnp.abs(l.astype(jnp.float32)) > thr) for l in jax.tree.leaves(tree))


def global_threshold(tree, frac: float, iters: int = 32) -> jax.Array:
    """Bisection for t with |{x : |x| > t}| ~= frac * N. jit-friendly."""
    n_target = jnp.asarray(frac * tree_size(tree), jnp.float32)
    hi = jnp.maximum(
        jnp.stack([jnp.max(jnp.abs(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]).max(),
        1e-20,
    )
    lo = jnp.zeros(())

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = _count_above(tree, mid).astype(jnp.float32)
        # too many above -> raise threshold
        return jnp.where(cnt > n_target, mid, lo), jnp.where(cnt > n_target, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


_SMALL = 20_000_000  # below this, exact concat-quantile beats bisection


def _mask_small_body(u_tree, frac: float):
    flat = jnp.concatenate([jnp.abs(l.astype(jnp.float32)).reshape(-1)
                            for l in jax.tree.leaves(u_tree)])
    k = max(int(frac * flat.size), 1)
    thr = jnp.sort(flat)[flat.size - k]
    return jax.tree.map(lambda u: (jnp.abs(u.astype(jnp.float32)) >= thr), u_tree)


def _mask_large_body(u_tree, frac: float):
    thr = global_threshold(u_tree, frac)
    return jax.tree.map(lambda u: (jnp.abs(u.astype(jnp.float32)) > thr), u_tree)


_mask_small = jax.jit(_mask_small_body, static_argnames=("frac",))
_mask_large = jax.jit(_mask_large_body, static_argnames=("frac",))


def gradient_guided_mask(u_tree, frac: float):
    """Mask of the γ-fraction largest-|u| coordinates (paper Alg. 2 line 1).

    Small pytrees: exact global top-k threshold via one sort. Large pytrees
    (sharded, up to 4e11 params): bisection over per-leaf counts — no concat,
    no sort, log2(range) all-reduce-sized passes."""
    body = _mask_small if tree_size(u_tree) <= _SMALL else _mask_large
    if not timing.enabled():
        return body(u_tree, frac)
    key = _stack_key(u_tree, frac)
    first = key not in _SOLO_SEEN
    _SOLO_SEEN.add(key)
    t0 = time.perf_counter()
    out = body(u_tree, frac)
    timing.block(out)
    timing.record("select_solo", time.perf_counter() - t0, first=first)
    return out


# shapes already selected on, so the first jit compile of a solo selection
# (per shape/γ) is attributed to the compile bucket, not steady-state
_SOLO_SEEN: set = set()


# ---------------------------------------------------------------------------
# stacked selection (fused post-train update pipeline)
# ---------------------------------------------------------------------------

# One cached executable per (shape/dtype struct, γ, path): B co-resident
# sessions' gradient-guided selections run as ONE vmapped launch over the
# leading session axis instead of B separate bisection/sort dispatches —
# same compile-key cache pattern as `core.batched`'s phase executables.
_STACK_CACHE: dict = {}
_STACK_HITS = 0
_STACK_MISSES = 0


def stacked_cache_info() -> dict:
    """Hook for tests/telemetry: how often did fused grants share a stacked
    selection executable?"""
    return {"size": len(_STACK_CACHE), "hits": _STACK_HITS,
            "misses": _STACK_MISSES}


def stacked_cache_clear() -> None:
    global _STACK_HITS, _STACK_MISSES
    _STACK_CACHE.clear()
    _STACK_HITS = _STACK_MISSES = 0


def _stack_key(tree, frac: float):
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef,
            tuple((tuple(l.shape), l.dtype.name) for l in leaves),
            float(frac))


def _bitwise_topk_body(u_tree, frac: float):
    """Exact sort-path threshold without the sort.

    Non-negative float32s order exactly as their unsigned bit patterns, so
    the k-th largest |u| is found by binary search over the 32-bit space:
    32 unrolled counting passes (compare + reduce, fully vectorized) replace
    the XLA sort that dominated a selection launch on CPU. The resulting
    threshold is the *exact* value ``sort(|u|)[N-k]``, so the `>= thr` masks
    are bit-identical to `_mask_small_body`'s — this is an implementation
    swap, not a numerics change."""
    leaves = jax.tree.leaves(u_tree)
    n = sum(int(np.prod(l.shape)) for l in leaves)
    k = max(int(frac * n), 1)
    bits = [jax.lax.bitcast_convert_type(
        jnp.abs(l.astype(jnp.float32)).reshape(-1), jnp.uint32)
        for l in leaves]
    thr_bits = jnp.uint32(0)
    for bit in range(31, -1, -1):
        cand = thr_bits | jnp.uint32(1 << bit)
        cnt = sum(jnp.sum(b >= cand) for b in bits)
        thr_bits = jnp.where(cnt >= k, cand, thr_bits)
    thr = jax.lax.bitcast_convert_type(thr_bits, jnp.float32)
    return jax.tree.map(
        lambda u: (jnp.abs(u.astype(jnp.float32)) >= thr), u_tree)


def _make_stack_fn(per_session: int, frac: float, kern: str):
    """One stacked-selection executable: ``kern`` picks the top-k engine.

    Large trees always take the vmapped bisection regardless of ``kern``
    (the bit-pattern search concatenates, which is exactly what the large
    path exists to avoid)."""
    if per_session > _SMALL:
        return jax.jit(jax.vmap(functools.partial(_mask_large_body,
                                                  frac=frac)))
    if kern == "pallas":
        from repro.kernels.topk_mask import stacked_topk_masks
        return functools.partial(stacked_topk_masks, frac=frac)
    return jax.jit(jax.vmap(functools.partial(_bitwise_topk_body,
                                              frac=frac)))


def _resolved_select_kernel(per_session: int, base_key) -> str | None:
    """``xla`` | ``pallas`` for the stacked selection, or None when
    ``kernel_mode("auto")`` still owes this (backend, struct key) a race.
    Sessions too large for the single-block kernel's VMEM budget (or on
    the bisection path entirely) are pinned to ``xla``."""
    if per_session > _SMALL:
        return "xla"
    from repro.kernels.topk_mask import pallas_topk_supported
    if not pallas_topk_supported(per_session):
        return "xla"
    km = kernel_dispatch.kernel_mode()
    if km != "auto":
        return km
    return kernel_dispatch.auto_winner("select_stacked",
                                       jax.default_backend(), base_key)


def _select_nbytes(b: int, per_session: int) -> int:
    """Analytic minimum HBM traffic for the stacked selection: one f32
    read of every |u| coordinate plus one bool mask write — what the
    fused kernel achieves; the 32-pass XLA lowering re-reads the buffer
    per pass (`roofline.analysis.topk_hbm_bytes` models both)."""
    return b * per_session * 5


def stacked_gradient_guided_masks(u_stacked, frac: float):
    """Per-session gradient-guided masks for a B-stacked update tree, in one
    launch.

    ``u_stacked`` is ``stack_trees([u_1, ..., u_B])``: every leaf carries a
    leading session axis. The per-session selection is vmapped over that
    axis, so the B thresholds and the B mask trees come out of ONE cached
    executable — session b's slice matches
    ``gradient_guided_mask(u_b, frac)``. Small trees take the bit-pattern
    top-k search: under ``kernel_mode("xla")`` the 32 unrolled counting
    passes of `_bitwise_topk_body`; under ``pallas`` the fused
    `repro.kernels.topk_mask` kernel that runs all 32 passes in VMEM off
    ONE HBM read; ``auto`` races the two once per (backend, struct key)
    and caches the measured winner (`core.kernel_dispatch`). All paths
    produce byte-identical masks — the kernel reproduces the exact
    counting search and the masks use the same float compare. Large trees
    vmap the same per-leaf bisection the solo path runs. Returns the
    stacked mask tree (leading axis preserved)."""
    global _STACK_HITS, _STACK_MISSES
    leaves = jax.tree.leaves(u_stacked)
    if not leaves:
        raise ValueError("stacked selection needs at least one leaf")
    per_session = sum(int(np.prod(l.shape[1:])) for l in leaves)
    b = int(leaves[0].shape[0])
    base = _stack_key(u_stacked, frac)
    kern = _resolved_select_kernel(per_session, base)
    if kern is not None:
        key = base + (kern,)
        fn = _STACK_CACHE.get(key)
        first = fn is None
        if first:
            _STACK_MISSES += 1
            fn = _make_stack_fn(per_session, frac, kern)
            _STACK_CACHE[key] = fn
        else:
            _STACK_HITS += 1
        if not timing.enabled():
            return fn(u_stacked)
        t0 = time.perf_counter()
        out = fn(u_stacked)
        timing.block(out)
        timing.record("select_stacked", time.perf_counter() - t0,
                      first=first, key=(b,),
                      nbytes=_select_nbytes(b, per_session))
        return out
    # kernel_mode("auto"), undecided: race XLA vs Pallas on this real
    # batch — byte-identical outputs make the race numerics-free; one
    # cache miss, loser discarded uncounted (mirrors `batched`'s races)
    _STACK_MISSES += 1
    outs, times = {}, {}
    for kn in ("xla", "pallas"):
        fn = _STACK_CACHE.get(base + (kn,))
        if fn is None:
            fn = _make_stack_fn(per_session, frac, kn)
        timing.block(fn(u_stacked))  # compile + warm, off the clock
        best = float("inf")
        out = None
        for _ in range(2):  # best-of-2: damp scheduler/GC jitter
            t0 = time.perf_counter()
            out = fn(u_stacked)
            timing.block(out)
            best = min(best, time.perf_counter() - t0)
        times[kn], outs[kn] = best, (fn, out)
    winner = min(times, key=lambda kn: (times[kn], kn))
    kernel_dispatch.record_auto("select_stacked", jax.default_backend(),
                                base, winner, times)
    _STACK_CACHE[base + (winner,)] = outs[winner][0]
    if timing.enabled():
        timing.record("select_stacked", times[winner], first=True, key=(b,),
                      nbytes=_select_nbytes(b, per_session))
    return outs[winner][1]


# ---------------------------------------------------------------------------
# ablation strategies (Table 3)
# ---------------------------------------------------------------------------


def random_mask(rng, params, frac: float):
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(rng, len(leaves))
    masks = [jax.random.bernoulli(k, frac, l.shape) for k, l in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, masks)


def _positional_mask(params, frac: float, *, reverse: bool):
    """Select whole leaves in flattened traversal order until γN params are
    covered (partial fill on the boundary leaf). Host-side, numpy."""
    leaves, treedef = jax.tree.flatten(params)
    order = list(range(len(leaves)))
    if reverse:
        order = order[::-1]
    budget = int(frac * sum(int(np.prod(l.shape)) for l in leaves))
    masks = [None] * len(leaves)
    for idx in order:
        n = int(np.prod(leaves[idx].shape))
        if budget >= n:
            masks[idx] = np.ones(leaves[idx].shape, bool)
            budget -= n
        elif budget > 0:
            flat = np.zeros(n, bool)
            flat[:budget] = True
            masks[idx] = flat.reshape(leaves[idx].shape)
            budget = 0
        else:
            masks[idx] = np.zeros(leaves[idx].shape, bool)
    return jax.tree.unflatten(treedef, [jnp.asarray(m) for m in masks])


def first_layers_mask(params, frac: float):
    return _positional_mask(params, frac, reverse=False)


def last_layers_mask(params, frac: float):
    return _positional_mask(params, frac, reverse=True)


def first_last_mask(params, frac: float):
    a = _positional_mask(params, frac / 2, reverse=False)
    b = _positional_mask(params, frac / 2, reverse=True)
    return jax.tree.map(jnp.logical_or, a, b)


def make_mask(strategy: str, *, params=None, u_prev=None, frac: float, rng=None):
    if strategy == "gradient_guided":
        assert u_prev is not None
        return gradient_guided_mask(u_prev, frac)
    if strategy == "random":
        assert rng is not None
        return random_mask(rng, params, frac)
    if strategy == "first":
        return first_layers_mask(params, frac)
    if strategy == "last":
        return last_layers_mask(params, frac)
    if strategy == "first_last":
        return first_last_mask(params, frac)
    if strategy == "full":
        return jax.tree.map(lambda p: jnp.ones(p.shape, bool), params)
    raise ValueError(strategy)


def mask_fraction(mask) -> float:
    n = tree_size(mask)
    sel = sum(int(jnp.sum(l)) for l in jax.tree.leaves(mask))
    return sel / max(n, 1)
