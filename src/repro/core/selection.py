"""Coordinate-subset selection strategies (paper §3.1.2 + Table 3).

`gradient_guided` implements the paper's method: pick the γ-fraction of
coordinates with the largest |u_{n-1}| (last Adam update of the previous
phase). The γ-quantile threshold is found by *bisection over per-leaf counts*
rather than a global sort — O(log(range)) passes of O(N) reductions, exactly
shardable under pjit, and scales to 4e11-parameter pytrees where a global
sort/concat is infeasible (DESIGN.md §5, hardware adaptation).

Also provides the Table-3 ablation strategies: random, first layers, last
layers, first&last.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# gradient-guided (the paper's strategy)
# ---------------------------------------------------------------------------


def _count_above(tree, thr) -> jax.Array:
    return sum(jnp.sum(jnp.abs(l.astype(jnp.float32)) > thr) for l in jax.tree.leaves(tree))


def global_threshold(tree, frac: float, iters: int = 32) -> jax.Array:
    """Bisection for t with |{x : |x| > t}| ~= frac * N. jit-friendly."""
    n_target = jnp.asarray(frac * tree_size(tree), jnp.float32)
    hi = jnp.maximum(
        jnp.stack([jnp.max(jnp.abs(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]).max(),
        1e-20,
    )
    lo = jnp.zeros(())

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = _count_above(tree, mid).astype(jnp.float32)
        # too many above -> raise threshold
        return jnp.where(cnt > n_target, mid, lo), jnp.where(cnt > n_target, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


_SMALL = 20_000_000  # below this, exact concat-quantile beats bisection


@functools.partial(jax.jit, static_argnames=("frac",))
def _mask_small(u_tree, frac: float):
    flat = jnp.concatenate([jnp.abs(l.astype(jnp.float32)).reshape(-1)
                            for l in jax.tree.leaves(u_tree)])
    k = max(int(frac * flat.size), 1)
    thr = jnp.sort(flat)[flat.size - k]
    return jax.tree.map(lambda u: (jnp.abs(u.astype(jnp.float32)) >= thr), u_tree)


@functools.partial(jax.jit, static_argnames=("frac",))
def _mask_large(u_tree, frac: float):
    thr = global_threshold(u_tree, frac)
    return jax.tree.map(lambda u: (jnp.abs(u.astype(jnp.float32)) > thr), u_tree)


def gradient_guided_mask(u_tree, frac: float):
    """Mask of the γ-fraction largest-|u| coordinates (paper Alg. 2 line 1).

    Small pytrees: exact global top-k threshold via one sort. Large pytrees
    (sharded, up to 4e11 params): bisection over per-leaf counts — no concat,
    no sort, log2(range) all-reduce-sized passes."""
    if tree_size(u_tree) <= _SMALL:
        return _mask_small(u_tree, frac)
    return _mask_large(u_tree, frac)


# ---------------------------------------------------------------------------
# ablation strategies (Table 3)
# ---------------------------------------------------------------------------


def random_mask(rng, params, frac: float):
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(rng, len(leaves))
    masks = [jax.random.bernoulli(k, frac, l.shape) for k, l in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, masks)


def _positional_mask(params, frac: float, *, reverse: bool):
    """Select whole leaves in flattened traversal order until γN params are
    covered (partial fill on the boundary leaf). Host-side, numpy."""
    leaves, treedef = jax.tree.flatten(params)
    order = list(range(len(leaves)))
    if reverse:
        order = order[::-1]
    budget = int(frac * sum(int(np.prod(l.shape)) for l in leaves))
    masks = [None] * len(leaves)
    for idx in order:
        n = int(np.prod(leaves[idx].shape))
        if budget >= n:
            masks[idx] = np.ones(leaves[idx].shape, bool)
            budget -= n
        elif budget > 0:
            flat = np.zeros(n, bool)
            flat[:budget] = True
            masks[idx] = flat.reshape(leaves[idx].shape)
            budget = 0
        else:
            masks[idx] = np.zeros(leaves[idx].shape, bool)
    return jax.tree.unflatten(treedef, [jnp.asarray(m) for m in masks])


def first_layers_mask(params, frac: float):
    return _positional_mask(params, frac, reverse=False)


def last_layers_mask(params, frac: float):
    return _positional_mask(params, frac, reverse=True)


def first_last_mask(params, frac: float):
    a = _positional_mask(params, frac / 2, reverse=False)
    b = _positional_mask(params, frac / 2, reverse=True)
    return jax.tree.map(jnp.logical_or, a, b)


def make_mask(strategy: str, *, params=None, u_prev=None, frac: float, rng=None):
    if strategy == "gradient_guided":
        assert u_prev is not None
        return gradient_guided_mask(u_prev, frac)
    if strategy == "random":
        assert rng is not None
        return random_mask(rng, params, frac)
    if strategy == "first":
        return first_layers_mask(params, frac)
    if strategy == "last":
        return last_layers_mask(params, frac)
    if strategy == "first_last":
        return first_last_mask(params, frac)
    if strategy == "full":
        return jax.tree.map(lambda p: jnp.ones(p.shape, bool), params)
    raise ValueError(strategy)


def mask_fraction(mask) -> float:
    n = tree_size(mask)
    sel = sum(int(jnp.sum(l)) for l in jax.tree.leaves(mask))
    return sel / max(n, 1)
