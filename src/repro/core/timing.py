"""Wall-clock stage timing for the fused hot path (observability layer).

The serving engine charges *modeled* device-seconds (`GPUCostModel`); the
stacked executables in `core.batched` / `core.selection` / `core.delta`
spend *real* wall-clock. This shim is the bridge: the hot-path call sites
record per-stage wall-clock here — first launch (compile + warm) attributed
separately from steady-state — and `serving.obs.drift_report` folds the
accumulated stats against the cost model's per-stage pricing.

Stats are process-global (like the executable caches they instrument) and
keyed by ``(stage, key)`` where ``key`` carries the pricing inputs the cost
model needs — e.g. ``("train_fused", (B, K))``. Callers that want per-run
numbers bracket with `snapshot()` / `delta(snap)`. Single-threaded by
construction, like the engine. `set_enabled(False)` turns every `record`
into a no-op (the perf_counter reads at the call sites are guarded by
`enabled()`, so the disabled overhead is one module-attr check per stage).
"""
from __future__ import annotations

_ENABLED = True

# (stage, key) -> {"calls", "first_calls", "first_s", "steady_s", "nbytes"}
_STATS: dict = {}

_FIELDS = ("calls", "first_calls", "first_s", "steady_s", "nbytes")


def enabled() -> bool:
    return _ENABLED


def set_enabled(on: bool) -> None:
    global _ENABLED
    _ENABLED = bool(on)


def reset() -> None:
    _STATS.clear()


def record(stage: str, seconds: float, *, first: bool = False,
           key: tuple = (), nbytes: int = 0) -> None:
    """Attribute ``seconds`` of wall-clock to ``stage``. ``first=True``
    marks a first launch for this executable (compile + warm) — kept out of
    the steady-state bucket so short runs don't report compile time as
    throughput. ``key`` carries the cost-model pricing inputs (e.g. (B, K)
    for a fused train launch); ``nbytes`` accumulates wire bytes for the
    byte-priced encode stages."""
    if not _ENABLED:
        return
    k = (stage, tuple(key))
    e = _STATS.get(k)
    if e is None:
        e = _STATS[k] = {"calls": 0, "first_calls": 0,
                         "first_s": 0.0, "steady_s": 0.0, "nbytes": 0}
    e["calls"] += 1
    if first:
        e["first_calls"] += 1
        e["first_s"] += seconds
    else:
        e["steady_s"] += seconds
    e["nbytes"] += int(nbytes)


def snapshot() -> dict:
    """Copy of the global stats — pair with `delta` to scope a run."""
    return {k: dict(v) for k, v in _STATS.items()}


def delta(snap: dict | None) -> dict:
    """Stats accumulated since ``snap`` (a `snapshot()` return); entries
    with no new calls are dropped."""
    snap = snap or {}
    out = {}
    for k, v in _STATS.items():
        base = snap.get(k)
        d = dict(v) if base is None else {f: v[f] - base[f] for f in _FIELDS}
        if d["calls"]:
            out[k] = d
    return out


def totals(stats: dict | None = None) -> dict:
    """Aggregate ``(stage, key)`` stats down to per-stage totals."""
    stats = _STATS if stats is None else stats
    out: dict = {}
    for (stage, _key), v in sorted(stats.items(),
                                   key=lambda kv: (kv[0][0], str(kv[0][1]))):
        e = out.setdefault(stage, {f: 0 for f in _FIELDS})
        for f in _FIELDS:
            e[f] += v[f]
    return out


def compile_s(stats: dict | None = None) -> float:
    """Total first-launch (compile + warm) seconds across all stages."""
    stats = _STATS if stats is None else stats
    return sum(v["first_s"] for v in stats.values())


def block(tree) -> None:
    """Synchronize: wait for every jax leaf in ``tree`` before reading the
    clock, so a stage's recorded time covers its execution, not just its
    dispatch."""
    import jax

    for leaf in jax.tree.leaves(tree):
        getattr(leaf, "block_until_ready", lambda: None)()
