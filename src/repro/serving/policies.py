"""Pluggable (session, gpu) scheduling policies for the serving engine.

A policy answers one question: some devices in the pool just went idle and
several sessions have work queued — which sessions run next, and on which
GPU? The primitive is still a ranking (`pick`: who is most deserving), but
the engine-facing surface is `assign`, which maps the ready queue onto the
free devices of a `resources.GPUPool`:

* `FairRoundRobin` — the paper's Appendix E strategy: a rotating turn
  pointer over waiting sessions (shares `next_in_turn` with
  `core.scheduler.RoundRobinScheduler`). Ties — several queued requests
  from the turn-winning client — break deterministically by request age,
  so multi-GPU runs reproduce regardless of queue arrival order.
* `EarliestDeadlineFirst` — each request carries a deadline (its session's
  next T_update boundary); the most overdue phase runs first.
* `GainAware` — ATR-style cycle reclamation generalized to the scheduler:
  rank sessions by recent scene dynamics (the ASR φ-signal, via sampling
  rate) times staleness, so dynamic feeds preempt near-static ones while a
  growing staleness term keeps static feeds from starving outright.
* `AffinityAware` — GainAware's ranking, placement-aware: a candidate's
  score is discounted by the weight-migration time the pool would charge
  on that device (zero where the session is already resident), by the
  device's modeled phase-time excess on heterogeneous pools, and by its
  stream backlog (dual-stream engine path) — so sessions stick to the
  fastest idle GPU holding their state and the pool's overhead taxes are
  mostly avoided rather than mostly paid.

The three base policies are deliberately affinity-*blind* in placement
(lowest-numbered free device) — they still pay the pool's migration charge
whenever they bounce a session across devices, which is exactly the gap
`AffinityAware` closes. Every policy's `coalesce` is cost-aware: a fused
grant's spare seats go to ready requests whose staging cost on the granted
device is zero or beaten by the fused stack's marginal train discount.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.scheduler import next_in_turn


@dataclass
class GPURequest:
    """A queued "label my backlog + run one training phase" request."""

    client: int
    t_request: float  # when the request became ready at the server
    n_frames: int  # unlabeled frames riding along
    k_iters: int
    deadline: float  # t_request + the session's current T_update
    phi: float  # recent φ-score signal (~0 static feed, ~1+ dynamic)
    t_update: float  # session's current update period (ATR-stretched)
    state_bytes: int = 0  # session training state (weights+opt+buffer)
    gpu: int | None = None  # device the grant landed on (engine fills)
    upload_nbytes: int = 0  # uplink bytes already spent carrying the frames
    # (a tail-dropped victim's upload was wasted air time — the engine's
    # dropped_frame_bytes counter reads this field at eviction)


@dataclass(frozen=True)
class Assignment:
    """One (request, device) pairing chosen by a policy."""

    req: GPURequest
    gpu: int


class SchedulingPolicy:
    name = "base"

    def pick(self, t_now: float, ready: list[GPURequest]) -> GPURequest:
        """Rank the ready queue: who is most deserving of the next grant?"""
        raise NotImplementedError

    def rank(self, t_now: float, *, clients: np.ndarray,
             t_request: np.ndarray, deadline: np.ndarray, phi: np.ndarray,
             t_update: np.ndarray, limit: int | None = None) -> np.ndarray:
        """Vectorized ranking over parallel request-field arrays (the
        engine's fleet path): return up to ``limit`` positions, best first
        — the exact sequence repeated `pick` would produce over the
        corresponding `GPURequest` list, which stays the reference (and
        the non-fleet) path. Assumes at most one request per client, which
        the engine's ready set guarantees. Policies without an array form
        (or with stateful pick logic that can't be replayed) simply don't
        override this, and the engine keeps the pick-loop."""
        raise NotImplementedError

    def place(self, t_now: float, req: GPURequest, free: list[int],
              pool) -> int:
        """Which free device serves ``req``. Base policies are affinity-
        blind: lowest-numbered free device (they pay whatever migration
        the pool charges)."""
        return min(free)

    def assign(self, t_now: float, ready: list[GPURequest],
               free: list[int], pool) -> list[Assignment]:
        """Map the ready queue onto the free devices: repeatedly pick the
        top-ranked request and place it, until requests or devices run out.
        With one device this degenerates to PR-1's single `pick`."""
        ready, free = list(ready), list(free)
        out: list[Assignment] = []
        while ready and free:
            req = self.pick(t_now, ready)
            gid = self.place(t_now, req, free, pool)
            out.append(Assignment(req=req, gpu=gid))
            ready.remove(req)
            free.remove(gid)
        return out

    def evict(self, t_now: float, overfull: list[GPURequest]) -> GPURequest:
        """Saturation: the backlog is over capacity; choose the request to
        drop. Default drops the newest arrival (tail drop)."""
        return max(overfull, key=lambda r: (r.t_request, r.client))

    def coalesce(self, t_now: float, granted: Assignment,
                 ready: list[GPURequest], pool,
                 max_fuse: int) -> list[GPURequest]:
        """Riders for a fused grant: additional ready requests that can train
        on ``granted.gpu`` in the SAME stacked launch (`core.batched`).
        Riders share the grant's iteration count, so one executable covers
        the stack. Candidate selection is *cost-aware*: a rider is taken
        when staging it on the granted device is cheaper than the fused
        stack's marginal discount — resident (or first-touch) riders stage
        for free and always qualify, exactly the PR-3 rule, while a
        foreign-resident or host-spilled session may now buy its way in
        when its migration time is smaller than the solo-vs-marginal train
        saving its seat unlocks. The stack (primary + riders) is bounded by
        ``max_fuse`` AND by the device's ``residency_cap`` — HBM that holds
        only N session states cannot co-train more than N, and a larger
        stack would LRU-evict its own members mid-launch. Rider *order* is a
        policy decision (`_rider_order`); base policies take the oldest."""
        limit = max_fuse - 1
        cap = getattr(pool, "residency_cap", None)
        if cap is not None:
            limit = min(limit, cap - 1)
        if limit <= 0:
            return []
        cost = pool.device(granted.gpu).cost
        k = granted.req.k_iters
        solo_s = k * cost.train_iter_s
        candidates = sorted((r for r in ready if r.k_iters == k),
                            key=self._rider_order(t_now))
        riders: list[GPURequest] = []
        stack = 1
        for r in candidates:
            if len(riders) >= limit:
                break
            mig = pool.migration_s(r.client, granted.gpu, r.state_bytes)
            saving = solo_s - (cost.train_batch_s(stack + 1, k)
                               - cost.train_batch_s(stack, k))
            if mig == 0.0 or mig < saving:
                riders.append(r)
                stack += 1
        return riders

    def _rider_order(self, t_now: float):
        """Sort key ranking rider candidates (best first)."""
        return lambda r: (r.t_request, r.client)


class FairRoundRobin(SchedulingPolicy):
    name = "fair"

    def __init__(self):
        self.turn = 0
        self.n_clients = 0

    def pick(self, t_now: float, ready: list[GPURequest]) -> GPURequest:
        self.n_clients = max([self.n_clients] + [r.client + 1 for r in ready])
        nxt = next_in_turn([r.client for r in ready], self.turn, self.n_clients)
        # unwrapped on purpose: next_in_turn reduces mod the current count,
        # which grows as later-indexed clients issue their first requests
        self.turn = nxt + 1
        # several queued requests from the winning client are possible under
        # saturation; serve oldest-first so the choice is a function of the
        # requests, not of queue arrival order (multi-GPU reproducibility)
        return min((r for r in ready if r.client == nxt),
                   key=lambda r: (r.t_request, r.deadline, r.n_frames))

    def rank(self, t_now: float, *, clients: np.ndarray,
             t_request: np.ndarray, deadline: np.ndarray, phi: np.ndarray,
             t_update: np.ndarray, limit: int | None = None) -> np.ndarray:
        # repeated pick over a fixed ready set IS ring order from the turn
        # pointer: the winner is the ring-first waiting client, and the next
        # pick starts just past it — which is the next one in the same ring
        # order (distinct clients have distinct ring positions, so one
        # argsort replays the whole rotation). The turn advances as if the
        # taken prefix had been picked one by one.
        n = max(self.n_clients, int(clients.max()) + 1, 1)
        self.n_clients = n
        order = np.argsort((clients - self.turn) % n, kind="stable")
        if limit is not None:
            order = order[:limit]
        if len(order):
            self.turn = int(clients[order[-1]]) + 1
        return order


class EarliestDeadlineFirst(SchedulingPolicy):
    name = "edf"

    def pick(self, t_now: float, ready: list[GPURequest]) -> GPURequest:
        return min(ready, key=lambda r: (r.deadline, r.client, r.t_request))

    def rank(self, t_now: float, *, clients: np.ndarray,
             t_request: np.ndarray, deadline: np.ndarray, phi: np.ndarray,
             t_update: np.ndarray, limit: int | None = None) -> np.ndarray:
        # lexsort keys are least-significant first: (deadline, client,
        # t_request) ascending, same tuple `pick` minimizes
        order = np.lexsort((t_request, clients, deadline))
        return order if limit is None else order[:limit]


@dataclass
class GainAware(SchedulingPolicy):
    """score = recent φ-signal + staleness_weight * waited / T_update.

    The first term routes cycles to dynamic scenes (where a training phase
    buys the most accuracy); the second grows linearly while a request sits
    queued, so even a frozen feed is served after a bounded wait — the same
    reclamation/backstop structure as ATR's slowdown mode. Under saturation
    the same score drives eviction: a static feed's queued request is the
    one sacrificed, not whichever arrival happened to find the queue full."""

    staleness_weight: float = 0.5
    name: str = field(default="gain", init=False)

    def _score(self, t_now: float, r: GPURequest) -> float:
        waited = max(t_now - r.t_request, 0.0)
        return r.phi + self.staleness_weight * waited / max(r.t_update, 1e-9)

    def pick(self, t_now: float, ready: list[GPURequest]) -> GPURequest:
        # max score; ties broken by client id for determinism
        return max(ready, key=lambda r: (self._score(t_now, r), -r.client,
                                         -r.t_request))

    def rank(self, t_now: float, *, clients: np.ndarray,
             t_request: np.ndarray, deadline: np.ndarray, phi: np.ndarray,
             t_update: np.ndarray, limit: int | None = None) -> np.ndarray:
        # same expression as `_score`, elementwise (same IEEE ops, so the
        # scores — and any ties — are bit-identical to the pick loop)
        waited = np.maximum(t_now - t_request, 0.0)
        score = phi + self.staleness_weight * waited / np.maximum(t_update,
                                                                  1e-9)
        # descending score, then ascending client and t_request — the
        # ascending lexsort of (-score, client, t_request)
        order = np.lexsort((t_request, clients, -score))
        return order if limit is None else order[:limit]

    def evict(self, t_now: float, overfull: list[GPURequest]) -> GPURequest:
        return min(overfull, key=lambda r: (self._score(t_now, r), r.client))

    def _rider_order(self, t_now: float):
        """Gain-ranked riders: the stacked launch's extra slots go to the
        highest-value eligible requests, not merely the oldest."""
        return lambda r: (-self._score(t_now, r), r.client)


@dataclass
class AffinityAware(GainAware):
    """Gain-aware ranking with cost-aware (request, device) placement.

    Jointly scores (request, device) pairs: the gain score minus every
    modeled second that running *there* — rather than on the best possible
    device — would cost, normalized by the request's update period (one
    period of overhead cancels one unit of φ). Three penalty terms:

    * migration — the staging time the pool would charge on that device
      (zero where the session is already resident), weighted by
      ``migration_weight``;
    * heterogeneity — on pools with asymmetric `GPUCostModel`s, the excess
      of that device's modeled phase time (labeling + solo training) over
      the cheapest device's; zero everywhere on a homogeneous pool, so this
      term changes nothing for the PR-2/PR-3 sweeps, weighted by
      ``compute_weight``;
    * stream backlog — how long that device's streams defer a train launch
      (`GPUPool.train_ready_wait_s`; nonzero only under the dual-stream
      engine path, where a label stream can run ahead of the grants),
      weighted by ``stream_weight``.

    A resident pairing on the fastest, idlest device costs nothing, so
    sessions gravitate there; a dynamic feed can still justify paying any
    of the three when its score gap is large enough."""

    migration_weight: float = 1.0
    compute_weight: float = 1.0
    stream_weight: float = 1.0
    name: str = field(default="affinity", init=False)

    def assign(self, t_now: float, ready: list[GPURequest],
               free: list[int], pool) -> list[Assignment]:
        def phase_s(r, g):
            c = pool.device(g).cost
            return (c.label_batch_s(r.n_frames)
                    + c.train_batch_s(1, r.k_iters))

        ready, free = list(ready), list(free)
        # hoisted once per assign() call — nothing below charges the pool,
        # so phase times and stream waits are invariants; only the
        # per-request floor moves as the free list shrinks
        phase = {(id(r), g): phase_s(r, g) for r in ready for g in free}
        wait = {g: pool.train_ready_wait_s(g, t_now) for g in free}
        out: list[Assignment] = []
        while ready and free:
            floor = {id(r): min(phase[id(r), g] for g in free) for r in ready}

            def net(pair):
                r, g = pair
                mig = pool.migration_s(r.client, g, r.state_bytes)
                het = phase[id(r), g] - floor[id(r)]
                overhead = (self.migration_weight * mig
                            + self.compute_weight * het
                            + self.stream_weight * wait[g])
                score = (self._score(t_now, r)
                         - overhead / max(r.t_update, 1e-9))
                return (score, -r.client, -r.t_request, -g)

            req, gid = max(((r, g) for r in ready for g in free), key=net)
            out.append(Assignment(req=req, gpu=gid))
            ready.remove(req)
            free.remove(gid)
        return out


POLICIES = {
    "fair": FairRoundRobin,
    "edf": EarliestDeadlineFirst,
    "gain": GainAware,
    "affinity": AffinityAware,
}


def make_policy(name_or_policy) -> SchedulingPolicy:
    if isinstance(name_or_policy, SchedulingPolicy):
        return name_or_policy
    try:
        return POLICIES[name_or_policy]()
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {name_or_policy!r}; "
            f"choose from {sorted(POLICIES)}") from None
