"""Pluggable GPU scheduling policies for the serving engine.

A policy answers one question: the GPU just went idle and several sessions
have work queued — who goes next? Three answers:

* `FairRoundRobin` — the paper's Appendix E strategy: a rotating turn
  pointer over waiting sessions (shares `next_in_turn` with
  `core.scheduler.RoundRobinScheduler`).
* `EarliestDeadlineFirst` — each request carries a deadline (its session's
  next T_update boundary); the most overdue phase runs first.
* `GainAware` — ATR-style cycle reclamation generalized to the scheduler:
  rank sessions by recent scene dynamics (the ASR φ-signal, via sampling
  rate) times staleness, so dynamic feeds preempt near-static ones while a
  growing staleness term keeps static feeds from starving outright.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.scheduler import next_in_turn


@dataclass
class GPURequest:
    """A queued "label my backlog + run one training phase" request."""

    client: int
    t_request: float  # when the request became ready at the server
    n_frames: int  # unlabeled frames riding along
    k_iters: int
    deadline: float  # t_request + the session's current T_update
    phi: float  # recent φ-score signal (~0 static feed, ~1+ dynamic)
    t_update: float  # session's current update period (ATR-stretched)


class SchedulingPolicy:
    name = "base"

    def pick(self, t_now: float, ready: list[GPURequest]) -> GPURequest:
        raise NotImplementedError

    def evict(self, t_now: float, overfull: list[GPURequest]) -> GPURequest:
        """Saturation: the backlog is over capacity; choose the request to
        drop. Default drops the newest arrival (tail drop)."""
        return max(overfull, key=lambda r: (r.t_request, r.client))


class FairRoundRobin(SchedulingPolicy):
    name = "fair"

    def __init__(self):
        self.turn = 0
        self.n_clients = 0

    def pick(self, t_now: float, ready: list[GPURequest]) -> GPURequest:
        self.n_clients = max([self.n_clients] + [r.client + 1 for r in ready])
        nxt = next_in_turn([r.client for r in ready], self.turn, self.n_clients)
        # unwrapped on purpose: next_in_turn reduces mod the current count,
        # which grows as later-indexed clients issue their first requests
        self.turn = nxt + 1
        return next(r for r in ready if r.client == nxt)


class EarliestDeadlineFirst(SchedulingPolicy):
    name = "edf"

    def pick(self, t_now: float, ready: list[GPURequest]) -> GPURequest:
        return min(ready, key=lambda r: (r.deadline, r.client))


@dataclass
class GainAware(SchedulingPolicy):
    """score = recent φ-signal + staleness_weight * waited / T_update.

    The first term routes cycles to dynamic scenes (where a training phase
    buys the most accuracy); the second grows linearly while a request sits
    queued, so even a frozen feed is served after a bounded wait — the same
    reclamation/backstop structure as ATR's slowdown mode. Under saturation
    the same score drives eviction: a static feed's queued request is the
    one sacrificed, not whichever arrival happened to find the queue full."""

    staleness_weight: float = 0.5
    name: str = field(default="gain", init=False)

    def _score(self, t_now: float, r: GPURequest) -> float:
        waited = max(t_now - r.t_request, 0.0)
        return r.phi + self.staleness_weight * waited / max(r.t_update, 1e-9)

    def pick(self, t_now: float, ready: list[GPURequest]) -> GPURequest:
        # max score; ties broken by client id for determinism
        return max(ready, key=lambda r: (self._score(t_now, r), -r.client))

    def evict(self, t_now: float, overfull: list[GPURequest]) -> GPURequest:
        return min(overfull, key=lambda r: (self._score(t_now, r), r.client))


POLICIES = {
    "fair": FairRoundRobin,
    "edf": EarliestDeadlineFirst,
    "gain": GainAware,
}


def make_policy(name_or_policy) -> SchedulingPolicy:
    if isinstance(name_or_policy, SchedulingPolicy):
        return name_or_policy
    try:
        return POLICIES[name_or_policy]()
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {name_or_policy!r}; "
            f"choose from {sorted(POLICIES)}") from None
