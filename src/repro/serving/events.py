"""Discrete-event core: a priority queue keyed on simulation time.

No per-frame ticking — every state change in the serving runtime (a frame
sampled on a device, a byte landing at the server, the GPU freeing up, a
delta arriving at an edge) is an `Event` popped in time order. Ties are
broken by insertion sequence, so runs are bit-for-bit deterministic
regardless of how many events share a timestamp.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any


@dataclass(frozen=True)
class Event:
    time: float
    seq: int  # insertion order; the FIFO tie-break at equal times
    kind: str
    client: int | None = None
    payload: Any = None


class EventQueue:
    """Min-heap of events ordered by (time, seq)."""

    def __init__(self):
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = 0
        self.pushed = 0
        self.popped = 0

    def push(self, time: float, kind: str, client: int | None = None,
             payload: Any = None) -> Event:
        ev = Event(time=float(time), seq=self._seq, kind=kind,
                   client=client, payload=payload)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._seq += 1
        self.pushed += 1
        return ev

    def pop(self) -> Event:
        _, _, ev = heapq.heappop(self._heap)
        self.popped += 1
        return ev

    def peek_time(self) -> float | None:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
