"""Discrete-event core: a priority queue keyed on simulation time.

No per-frame ticking — every state change in the serving runtime (a frame
sampled on a device, a byte landing at the server, the GPU freeing up, a
delta arriving at an edge) is an `Event` popped in time order. Ties are
broken by insertion sequence, so runs are bit-for-bit deterministic
regardless of how many events share a timestamp.

Fleet-scale addenda (PR 9):

* **Cohort events** — `client` may be an ``np.ndarray`` of client ids, in
  which case the event stands for ``len(client)`` logical per-client events
  that share a (time, kind). The queue's ``pushed``/``popped`` ledgers count
  *logical* events (``Event.n``), so ``events_processed`` in the engine's
  results is identical whether a schedule was driven per-object or by
  cohorts; the heap itself holds one entry per cohort, which is where the
  fleet path's throughput comes from.
* `push_many` — bulk insert with one heapify when the batch is large
  relative to the heap (heap *layout* may differ from repeated `push`, but
  pop order cannot: (time, seq) is a total order).
* `pop_batch` — drain every event sharing the minimum timestamp, returned
  in seq (push) order, exactly the order repeated `pop` would yield.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any


def _multiplicity(client: Any) -> int:
    """Logical event count: cohort arrays count each member."""
    if client is None or isinstance(client, int):
        return 1
    try:  # np.ndarray (or any sized cohort container)
        return len(client)
    except TypeError:
        return 1


@dataclass(frozen=True)
class Event:
    time: float
    seq: int  # insertion order; the FIFO tie-break at equal times
    kind: str
    client: Any = None  # int | None | np.ndarray cohort of client ids
    payload: Any = None
    n: int = 1  # logical multiplicity (len(client) for cohorts)


class EventQueue:
    """Min-heap of events ordered by (time, seq).

    ``pushed``/``popped`` count logical events: a cohort event weighs
    ``Event.n``, so schedule accounting is representation-independent.
    """

    def __init__(self):
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = 0
        self.pushed = 0
        self.popped = 0

    def push(self, time: float, kind: str, client: Any = None,
             payload: Any = None) -> Event:
        ev = Event(time=float(time), seq=self._seq, kind=kind,
                   client=client, payload=payload,
                   n=_multiplicity(client))
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._seq += 1
        self.pushed += ev.n
        return ev

    def push_many(self, items) -> list[Event]:
        """Bulk insert of ``(time, kind, client, payload)`` tuples.

        Seqs are assigned in iteration order (same tie-break as repeated
        `push`). When the batch is large relative to the existing heap a
        single extend+heapify replaces per-item sift-ups; either way the
        (time, seq) total order makes pop order identical.
        """
        evs = []
        for time, kind, client, payload in items:
            ev = Event(time=float(time), seq=self._seq, kind=kind,
                       client=client, payload=payload,
                       n=_multiplicity(client))
            self._seq += 1
            self.pushed += ev.n
            evs.append(ev)
        if not evs:
            return evs
        # heapify is O(heap); k pushes are O(k log heap) — pick the cheaper
        if len(evs) * max(len(self._heap), 1).bit_length() < \
                len(self._heap) + len(evs):
            for ev in evs:
                heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        else:
            self._heap.extend((ev.time, ev.seq, ev) for ev in evs)
            heapq.heapify(self._heap)
        return evs

    def pop(self) -> Event:
        _, _, ev = heapq.heappop(self._heap)
        self.popped += ev.n
        return ev

    def pop_batch(self) -> list[Event]:
        """Pop every event at the minimum timestamp, in seq order — the
        exact sequence repeated `pop` would produce for that timestamp."""
        if not self._heap:
            return []
        t0 = self._heap[0][0]
        out = []
        while self._heap and self._heap[0][0] == t0:
            _, _, ev = heapq.heappop(self._heap)
            self.popped += ev.n
            out.append(ev)
        return out

    def peek_time(self) -> float | None:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
