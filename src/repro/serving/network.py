"""Modeled client<->server network (§3.2 uplink / §3.1.2 downlink).

Each client owns two half-duplex `Link`s (uplink for frame batches, downlink
for `ModelDelta`s). A transfer occupies its link for ``bytes * 8 / rate``
seconds — concurrent sends on the same link serialize — then lands after a
propagation delay. Every byte is also charged to the client's
`BandwidthLedger`, so per-client Kbps falls out of the same accounting the
single-client benchmarks use. With finite rates, deltas arrive *stale*: the
server's weights have moved on by the time an edge applies them.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.bandwidth import BandwidthLedger


@dataclass(frozen=True)
class LinkSpec:
    """Per-client provisioning. Defaults sit near the paper's operating
    points: a few-hundred-Kbps video uplink, a Mbps-class downlink."""

    up_kbps: float = 1000.0
    down_kbps: float = 2000.0
    prop_delay_s: float = 0.05


@dataclass
class Link:
    """One direction of a client's pipe: rate limit + propagation delay."""

    rate_kbps: float
    prop_delay_s: float = 0.0
    busy_until: float = 0.0
    bytes_carried: int = 0
    transfers: int = 0

    def tx_seconds(self, nbytes: int) -> float:
        if self.rate_kbps <= 0:  # unmodeled link: instantaneous
            return 0.0
        return nbytes * 8.0 / (self.rate_kbps * 1e3)

    def transfer(self, t_now: float, nbytes: int) -> float:
        """Occupy the link starting no earlier than ``t_now``; returns the
        arrival time at the far end."""
        start = max(t_now, self.busy_until)
        self.busy_until = start + self.tx_seconds(nbytes)
        self.bytes_carried += int(nbytes)
        self.transfers += 1
        return self.busy_until + self.prop_delay_s


@dataclass
class ClientNetwork:
    """Both directions for one client, wired into its bandwidth ledger."""

    spec: LinkSpec = field(default_factory=LinkSpec)
    ledger: BandwidthLedger = field(default_factory=BandwidthLedger)

    def __post_init__(self):
        self.up = Link(self.spec.up_kbps, self.spec.prop_delay_s)
        self.down = Link(self.spec.down_kbps, self.spec.prop_delay_s)
        # flight recorder wiring (set by the engine when tracing): the span
        # covers link occupancy [start, busy_until]; propagation delay is
        # in-flight time, not link time, so it stays outside the span
        self.tracer = None
        self.client = -1
        self.last_span = None  # most recent transfer span (flow anchoring)

    def _traced_transfer(self, link: Link, direction: str, t_now: float,
                         nbytes: int, what: str) -> float:
        if self.tracer is None:
            return link.transfer(t_now, nbytes)
        start = max(t_now, link.busy_until)
        arrival = link.transfer(t_now, nbytes)
        self.last_span = self.tracer.client_span(
            self.client, direction, what, start, link.busy_until,
            {"bytes": int(nbytes)})
        return arrival

    def send_up(self, t_now: float, nbytes: int, what: str = "frames") -> float:
        self.ledger.uplink(nbytes, t_now, what)
        return self._traced_transfer(self.up, "up", t_now, nbytes, what)

    def send_down(self, t_now: float, nbytes: int, what: str = "delta") -> float:
        self.ledger.downlink(nbytes, t_now, what)
        return self._traced_transfer(self.down, "down", t_now, nbytes, what)

    def send_ctrl(self, t_now: float, nbytes: int) -> float:
        """The ASR rate-control message: a few bytes, but they queue behind
        the delta on the same downlink and pay the same propagation delay —
        the edge samples at its *old* rate until this lands."""
        return self.send_down(t_now, nbytes, what="asr-rate")

    def kbps(self, duration_s: float) -> tuple[float, float]:
        return self.ledger.kbps(duration_s)
