"""Modeled client<->server network (§3.2 uplink / §3.1.2 downlink).

Each client owns two half-duplex `Link`s (uplink for frame batches, downlink
for `ModelDelta`s). A transfer occupies its link for ``bytes * 8 / rate``
seconds — concurrent sends on the same link serialize — then lands after a
propagation delay. Every byte is also charged to the client's
`BandwidthLedger`, so per-client Kbps falls out of the same accounting the
single-client benchmarks use. With finite rates, deltas arrive *stale*: the
server's weights have moved on by the time an edge applies them.

Links are constant-rate by default; attach a `RateTrace` (directly, via
`LinkSpec.from_trace`, or through a `FaultPlan`) to replay a cellular-style
variable-bandwidth trace instead — transfer completion is then the exact
piecewise integral of the trace, still fully deterministic.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

from repro.core.bandwidth import BandwidthLedger

_M64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    """splitmix64 finalizer (`serving.faults` has the same one; a local
    copy because faults imports this module)."""
    x = (x + 0x9E3779B97F4A7C15) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 31)


@dataclass(frozen=True)
class RateTrace:
    """A cyclic variable-bandwidth replay: ``kbps[i]`` holds for the i-th
    ``interval_s`` slice of wall-clock, repeating past the end. Zero-rate
    slices model dead air (a burst gap), so at least one slice must be
    positive or no transfer could ever finish.

    ``phase_s`` shifts where in the cycle the replay starts: the link sees
    ``rate_at(t + phase_s)``. A fleet replaying ONE trace in phase fades
    and recovers in lock-step — every uplink stalls together, which is a
    different (and rarer) regime than a fleet of independently-faded
    links. `for_client` derives a deterministic per-client phase from the
    client id, decorrelating the fleet while staying fully reproducible;
    the default 0.0 is bit-identical to the unphased trace."""

    kbps: tuple[float, ...]
    interval_s: float = 1.0
    phase_s: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "kbps",
                           tuple(float(r) for r in self.kbps))
        if not self.kbps:
            raise ValueError("RateTrace needs at least one rate sample")
        if any(r < 0.0 for r in self.kbps):
            raise ValueError("RateTrace rates must be >= 0 kbps")
        if not any(r > 0.0 for r in self.kbps):
            raise ValueError("RateTrace needs a positive rate somewhere, "
                             "or transfers never finish")
        if self.interval_s <= 0.0:
            raise ValueError("RateTrace interval_s must be > 0")
        if self.phase_s < 0.0:
            raise ValueError("RateTrace phase_s must be >= 0 (it is an "
                             "offset into a cyclic trace; wrap negatives "
                             "by adding the period)")

    @property
    def mean_kbps(self) -> float:
        return sum(self.kbps) / len(self.kbps)

    @property
    def period_s(self) -> float:
        return len(self.kbps) * self.interval_s

    def with_phase(self, phase_s: float) -> "RateTrace":
        """This trace shifted to start ``phase_s`` into its cycle (wrapped
        to the period). Returns ``self`` unchanged for a 0 offset, so the
        unphased path keeps object identity (and bit-identity)."""
        phase_s = float(phase_s) % self.period_s
        if phase_s == self.phase_s:
            return self
        return RateTrace(self.kbps, self.interval_s, phase_s)

    def for_client(self, client: int) -> "RateTrace":
        """A deterministically client-phased copy: the offset is a
        splitmix64 hash of the client id mapped onto the trace period —
        stable across runs and processes, no RNG consumed. Client fades
        then decorrelate across the fleet instead of synchronizing."""
        frac = (_mix64(int(client) & _M64) >> 11) / float(1 << 53)
        return self.with_phase(self.phase_s + frac * self.period_s)

    def rate_at(self, t: float) -> float:
        """Instantaneous rate (kbps) at absolute time ``t``, cyclic."""
        t = t + self.phase_s
        return self.kbps[int(t // self.interval_s) % len(self.kbps)]

    def finish_time(self, start: float, nbits: float) -> float:
        """When a transfer of ``nbits`` beginning at ``start`` drains,
        walking the trace slice by slice (exact piecewise integral)."""
        if nbits <= 0.0:
            return start
        n, iv = len(self.kbps), self.interval_s
        start = start + self.phase_s  # walk in trace time, return wall time
        idx = int(start // iv)
        t, remaining = start, float(nbits)
        while True:
            rate_bps = self.kbps[idx % n] * 1e3
            seg_end = (idx + 1) * iv
            cap = rate_bps * (seg_end - t)
            if rate_bps > 0.0 and remaining <= cap:
                return t + remaining / rate_bps - self.phase_s
            remaining -= cap
            t = seg_end
            idx += 1


@dataclass(frozen=True)
class LinkSpec:
    """Per-client provisioning. Defaults sit near the paper's operating
    points: a few-hundred-Kbps video uplink, a Mbps-class downlink.
    Optional per-direction `RateTrace`s override the constant rates."""

    up_kbps: float = 1000.0
    down_kbps: float = 2000.0
    prop_delay_s: float = 0.05
    up_trace: RateTrace | None = None
    down_trace: RateTrace | None = None

    @classmethod
    def from_trace(cls, path_or_dict, *, prop_delay_s: float | None = None,
                   client: int | None = None) -> "LinkSpec":
        """Build a spec from a JSON trace fixture (path or parsed dict):
        ``{"interval_s": 1.0, "up_kbps": [...], "down_kbps": [...]}``.
        A direction without samples keeps the constant default; scalar
        rates are set to each trace's mean so rate-only consumers (cost
        models, back-of-envelope sizing) see the right average.

        ``client`` phase-shifts both traces deterministically from the
        client id (`RateTrace.for_client`), so a fleet built from one
        fixture fades out of lock-step; None (the default) keeps the
        fixture's own phase — bit-identical to the pre-phasing loader."""
        if isinstance(path_or_dict, dict):
            data = path_or_dict
        else:
            with open(path_or_dict) as f:
                data = json.load(f)
        iv = float(data.get("interval_s", 1.0))
        phase = float(data.get("phase_s", 0.0))
        kw: dict = {}
        up = data.get("up_kbps")
        if up:
            kw["up_trace"] = RateTrace(tuple(up), iv, phase)
            if client is not None:
                kw["up_trace"] = kw["up_trace"].for_client(client)
            kw["up_kbps"] = kw["up_trace"].mean_kbps
        down = data.get("down_kbps")
        if down:
            kw["down_trace"] = RateTrace(tuple(down), iv, phase)
            if client is not None:
                kw["down_trace"] = kw["down_trace"].for_client(client)
            kw["down_kbps"] = kw["down_trace"].mean_kbps
        delay = (prop_delay_s if prop_delay_s is not None
                 else data.get("prop_delay_s"))
        if delay is not None:
            kw["prop_delay_s"] = float(delay)
        return cls(**kw)


@dataclass
class Link:
    """One direction of a client's pipe: rate limit + propagation delay."""

    rate_kbps: float
    prop_delay_s: float = 0.0
    busy_until: float = 0.0
    bytes_carried: int = 0
    transfers: int = 0
    trace: RateTrace | None = None  # overrides rate_kbps when set

    def tx_seconds(self, nbytes: int) -> float:
        if self.rate_kbps <= 0:  # unmodeled link: instantaneous
            return 0.0
        return nbytes * 8.0 / (self.rate_kbps * 1e3)

    def transfer(self, t_now: float, nbytes: int) -> float:
        """Occupy the link starting no earlier than ``t_now``; returns the
        arrival time at the far end."""
        start = max(t_now, self.busy_until)
        if self.trace is not None:
            self.busy_until = self.trace.finish_time(start, nbytes * 8.0)
        else:
            self.busy_until = start + self.tx_seconds(nbytes)
        self.bytes_carried += int(nbytes)
        self.transfers += 1
        return self.busy_until + self.prop_delay_s


@dataclass
class ClientNetwork:
    """Both directions for one client, wired into its bandwidth ledger."""

    spec: LinkSpec = field(default_factory=LinkSpec)
    ledger: BandwidthLedger = field(default_factory=BandwidthLedger)

    def __post_init__(self):
        self.up = Link(self.spec.up_kbps, self.spec.prop_delay_s,
                       trace=self.spec.up_trace)
        self.down = Link(self.spec.down_kbps, self.spec.prop_delay_s,
                         trace=self.spec.down_trace)
        # flight recorder wiring (set by the engine when tracing): the span
        # covers link occupancy [start, busy_until]; propagation delay is
        # in-flight time, not link time, so it stays outside the span
        self.tracer = None
        self.client = -1
        self.last_span = None  # most recent transfer span (flow anchoring)

    def _traced_transfer(self, link: Link, direction: str, t_now: float,
                         nbytes: int, what: str) -> float:
        if self.tracer is None:
            return link.transfer(t_now, nbytes)
        start = max(t_now, link.busy_until)
        arrival = link.transfer(t_now, nbytes)
        self.last_span = self.tracer.client_span(
            self.client, direction, what, start, link.busy_until,
            {"bytes": int(nbytes)})
        return arrival

    def send_up(self, t_now: float, nbytes: int, what: str = "frames") -> float:
        self.ledger.uplink(nbytes, t_now, what)
        return self._traced_transfer(self.up, "up", t_now, nbytes, what)

    def send_down(self, t_now: float, nbytes: int, what: str = "delta") -> float:
        self.ledger.downlink(nbytes, t_now, what)
        return self._traced_transfer(self.down, "down", t_now, nbytes, what)

    def send_ctrl(self, t_now: float, nbytes: int) -> float:
        """The ASR rate-control message: a few bytes, but they queue behind
        the delta on the same downlink and pay the same propagation delay —
        the edge samples at its *old* rate until this lands."""
        return self.send_down(t_now, nbytes, what="asr-rate")

    def kbps(self, duration_s: float) -> tuple[float, float]:
        return self.ledger.kbps(duration_s)
