"""Event-driven AMS serving runtime — many edge devices, a GPU pool, a real(ish) network.

Paper-concept -> class map (Appendix D/E):

  ==========================================  =================================
  Paper concept                               Here
  ==========================================  =================================
  Shared-GPU round-robin (App. E)             `policies.FairRoundRobin`
  Deferred phases under saturation (Fig. 6)   `engine.ServingEngine` backlog +
                                              admission control / drop stats
  ATR cycle reclamation (App. D)              `policies.GainAware` (recent
                                              φ-score + staleness priority,
                                              φ-aware eviction when saturated)
  App. E scaling argument, many GPUs          `resources.GPUPool` (per-device
                                              stream clocks + session
                                              residency) + `policies.
                                              AffinityAware` (session, gpu)
                                              placement
  Server labels + trains concurrently (§4)    `resources.StreamModel`: label
                                              vs train streams per device,
                                              overlap with bounded slowdown,
                                              labeling preemptible at frame-
                                              batch boundaries
  Uplink frame batches / downlink deltas      `network.ClientNetwork` (links
  (§3.1.2, §3.2, Tables 1-2)                  occupy `bytes/rate` s, feed the
                                              per-client `BandwidthLedger`)
  Edge double-buffered swap (§3)              via `session.SegServingSession`
                                              wrapping `core.client.EdgeClient`
  ==========================================  =================================

Quickstart::

    from repro.serving import (LinkSpec, ClientNetwork, SegServingSession,
                               ServingEngine, ServingConfig)

    sessions = [
        SegServingSession(i, world_i, ams_session_i, pretrained,
                          net=ClientNetwork(LinkSpec(up_kbps=500,
                                                     down_kbps=2000)))
        for i, (world_i, ams_session_i) in enumerate(zip(worlds, ams))
    ]
    result = ServingEngine(sessions, policy="affinity",
                           cfg=ServingConfig(duration=120.0, n_gpus=4)).run()
    print(result["mean_miou"], result["per_gpu_utilization"],
          result["migrations"])

`sim.multiclient.run_multiclient` is a thin shim over this engine (with
``n_gpus``/``affinity`` kwargs; the defaults reproduce the single-GPU PR-1
runs bit-for-bit), and `benchmarks/serving_scale.py` drives it with
`StubSession`s to measure sustained sessions per GPU at large client counts.

Flight recorder (`serving.obs`): pass ``tracer=obs.Tracer()`` to the engine
(or ``run_multiclient``, or ``examples/multi_client.py --trace out.json``)
and every grant, migration, labeling launch, preemption cut, fused
train→select→encode stage and per-client uplink/downlink transfer lands as
a span in **simulated** time. ``tracer.dump("out.json")`` writes
deterministic Chrome trace-event JSON — open it at https://ui.perfetto.dev
("Open trace file"; processes are the server, each ``gpu<g>`` with
``stream:label``/``stream:train``/``grants`` threads, and each
``client<i>``; counter tracks carry queue depth, labeling backlog and
per-stream utilization). The engine's results dict is assembled from
`obs.MetricsRegistry`, an ``observability`` section reports the
modeled-vs-measured cost audit (`obs.drift_report` over `core.timing`
stage stats), and `obs.debug_snapshot` unifies the fused-path cache /
counter introspection hooks. Tracing defaults off and the recorder never
changes the schedule: two runs, traced or not, pop identical events.
"""
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.events import Event, EventQueue
from repro.serving.network import ClientNetwork, Link, LinkSpec
from repro.serving.obs import (
    MetricsRegistry,
    Tracer,
    debug_snapshot,
    drift_report,
    validate_trace,
)
from repro.serving.policies import (
    POLICIES,
    AffinityAware,
    Assignment,
    EarliestDeadlineFirst,
    FairRoundRobin,
    GainAware,
    GPURequest,
    SchedulingPolicy,
    make_policy,
)
from repro.serving.resources import (
    GPUDevice,
    GPUPool,
    MigrationModel,
    StreamModel,
)
from repro.serving.session import (
    SegServingSession,
    SessionBase,
    StubSession,
    train_many,
)

__all__ = [
    "Event", "EventQueue", "ClientNetwork", "Link", "LinkSpec",
    "SchedulingPolicy", "FairRoundRobin", "EarliestDeadlineFirst",
    "GainAware", "AffinityAware", "Assignment", "GPURequest", "POLICIES",
    "make_policy", "GPUDevice", "GPUPool", "MigrationModel", "StreamModel",
    "SegServingSession", "SessionBase", "StubSession", "train_many",
    "ServingConfig", "ServingEngine",
    "Tracer", "MetricsRegistry", "debug_snapshot", "drift_report",
    "validate_trace",
]
