"""Event-driven AMS serving runtime — many edge devices, one GPU, a real(ish) network.

Paper-concept -> class map (Appendix D/E):

  ==========================================  =================================
  Paper concept                               Here
  ==========================================  =================================
  Shared-GPU round-robin (App. E)             `policies.FairRoundRobin`
  Deferred phases under saturation (Fig. 6)   `engine.ServingEngine` backlog +
                                              admission control / drop stats
  ATR cycle reclamation (App. D)              `policies.GainAware` (recent
                                              φ-score + staleness priority,
                                              φ-aware eviction when saturated)
  Uplink frame batches / downlink deltas      `network.ClientNetwork` (links
  (§3.1.2, §3.2, Tables 1-2)                  occupy `bytes/rate` s, feed the
                                              per-client `BandwidthLedger`)
  Edge double-buffered swap (§3)              via `session.SegServingSession`
                                              wrapping `core.client.EdgeClient`
  ==========================================  =================================

Quickstart::

    from repro.serving import (LinkSpec, ClientNetwork, SegServingSession,
                               ServingEngine, ServingConfig)

    sessions = [
        SegServingSession(i, world_i, ams_session_i, pretrained,
                          net=ClientNetwork(LinkSpec(up_kbps=500,
                                                     down_kbps=2000)))
        for i, (world_i, ams_session_i) in enumerate(zip(worlds, ams))
    ]
    result = ServingEngine(sessions, policy="gain",
                           cfg=ServingConfig(duration=120.0)).run()
    print(result["mean_miou"], result["per_client_kbps"],
          result["delta_latency_mean_s"])

`sim.multiclient.run_multiclient` is now a thin shim over this engine, and
`benchmarks/serving_scale.py` drives it with `StubSession`s to measure pure
engine throughput (events/sec) at large client counts.
"""
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.events import Event, EventQueue
from repro.serving.network import ClientNetwork, Link, LinkSpec
from repro.serving.policies import (
    POLICIES,
    EarliestDeadlineFirst,
    FairRoundRobin,
    GainAware,
    GPURequest,
    SchedulingPolicy,
    make_policy,
)
from repro.serving.session import SegServingSession, SessionBase, StubSession

__all__ = [
    "Event", "EventQueue", "ClientNetwork", "Link", "LinkSpec",
    "SchedulingPolicy", "FairRoundRobin", "EarliestDeadlineFirst",
    "GainAware", "GPURequest", "POLICIES", "make_policy",
    "SegServingSession", "SessionBase", "StubSession",
    "ServingConfig", "ServingEngine",
]
