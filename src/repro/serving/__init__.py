"""Event-driven AMS serving runtime — many edge devices, a GPU pool, a real(ish) network.

Paper-concept -> class map (Appendix D/E):

  ==========================================  =================================
  Paper concept                               Here
  ==========================================  =================================
  Shared-GPU round-robin (App. E)             `policies.FairRoundRobin`
  Deferred phases under saturation (Fig. 6)   `engine.ServingEngine` backlog +
                                              admission control / drop stats
  ATR cycle reclamation (App. D)              `policies.GainAware` (recent
                                              φ-score + staleness priority,
                                              φ-aware eviction when saturated)
  App. E scaling argument, many GPUs          `resources.GPUPool` (per-device
                                              stream clocks + session
                                              residency) + `policies.
                                              AffinityAware` (session, gpu)
                                              placement
  Server labels + trains concurrently (§4)    `resources.StreamModel`: label
                                              vs train streams per device,
                                              overlap with bounded slowdown,
                                              labeling preemptible at frame-
                                              batch boundaries
  Uplink frame batches / downlink deltas      `network.ClientNetwork` (links
  (§3.1.2, §3.2, Tables 1-2)                  occupy `bytes/rate` s, feed the
                                              per-client `BandwidthLedger`)
  Edge double-buffered swap (§3)              via `session.SegServingSession`
                                              wrapping `core.client.EdgeClient`
  ==========================================  =================================

Quickstart::

    from repro.serving import (LinkSpec, ClientNetwork, SegServingSession,
                               ServingEngine, ServingConfig)

    sessions = [
        SegServingSession(i, world_i, ams_session_i, pretrained,
                          net=ClientNetwork(LinkSpec(up_kbps=500,
                                                     down_kbps=2000)))
        for i, (world_i, ams_session_i) in enumerate(zip(worlds, ams))
    ]
    result = ServingEngine(sessions, policy="affinity",
                           cfg=ServingConfig(duration=120.0, n_gpus=4)).run()
    print(result["mean_miou"], result["per_gpu_utilization"],
          result["migrations"])

`sim.multiclient.run_multiclient` is a thin shim over this engine (with
``n_gpus``/``affinity`` kwargs; the defaults reproduce the single-GPU PR-1
runs bit-for-bit), and `benchmarks/serving_scale.py` drives it with
`StubSession`s to measure sustained sessions per GPU at large client counts.
"""
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.events import Event, EventQueue
from repro.serving.network import ClientNetwork, Link, LinkSpec
from repro.serving.policies import (
    POLICIES,
    AffinityAware,
    Assignment,
    EarliestDeadlineFirst,
    FairRoundRobin,
    GainAware,
    GPURequest,
    SchedulingPolicy,
    make_policy,
)
from repro.serving.resources import (
    GPUDevice,
    GPUPool,
    MigrationModel,
    StreamModel,
)
from repro.serving.session import (
    SegServingSession,
    SessionBase,
    StubSession,
    train_many,
)

__all__ = [
    "Event", "EventQueue", "ClientNetwork", "Link", "LinkSpec",
    "SchedulingPolicy", "FairRoundRobin", "EarliestDeadlineFirst",
    "GainAware", "AffinityAware", "Assignment", "GPURequest", "POLICIES",
    "make_policy", "GPUDevice", "GPUPool", "MigrationModel", "StreamModel",
    "SegServingSession", "SessionBase", "StubSession", "train_many",
    "ServingConfig", "ServingEngine",
]
