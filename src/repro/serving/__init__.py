"""Event-driven AMS serving runtime — many edge devices, a GPU pool, a real(ish) network.

Paper-concept -> class map (Appendix D/E):

  ==========================================  =================================
  Paper concept                               Here
  ==========================================  =================================
  Shared-GPU round-robin (App. E)             `policies.FairRoundRobin`
  Deferred phases under saturation (Fig. 6)   `engine.ServingEngine` backlog +
                                              admission control / drop stats
  ATR cycle reclamation (App. D)              `policies.GainAware` (recent
                                              φ-score + staleness priority,
                                              φ-aware eviction when saturated)
  App. E scaling argument, many GPUs          `resources.GPUPool` (per-device
                                              stream clocks + session
                                              residency) + `policies.
                                              AffinityAware` (session, gpu)
                                              placement
  Server labels + trains concurrently (§4)    `resources.StreamModel`: label
                                              vs train streams per device,
                                              overlap with bounded slowdown,
                                              labeling preemptible at frame-
                                              batch boundaries
  Uplink frame batches / downlink deltas      `network.ClientNetwork` (links
  (§3.1.2, §3.2, Tables 1-2)                  occupy `bytes/rate` s, feed the
                                              per-client `BandwidthLedger`)
  Edge double-buffered swap (§3)              via `session.SegServingSession`
                                              wrapping `core.client.EdgeClient`
  ==========================================  =================================

Quickstart::

    from repro.serving import (LinkSpec, ClientNetwork, SegServingSession,
                               ServingEngine, ServingConfig)

    sessions = [
        SegServingSession(i, world_i, ams_session_i, pretrained,
                          net=ClientNetwork(LinkSpec(up_kbps=500,
                                                     down_kbps=2000)))
        for i, (world_i, ams_session_i) in enumerate(zip(worlds, ams))
    ]
    result = ServingEngine(sessions, policy="affinity",
                           cfg=ServingConfig(duration=120.0, n_gpus=4)).run()
    print(result["mean_miou"], result["per_gpu_utilization"],
          result["migrations"])

`sim.multiclient.run_multiclient` is a thin shim over this engine (with
``n_gpus``/``affinity`` kwargs; the defaults reproduce the single-GPU PR-1
runs bit-for-bit), and `benchmarks/serving_scale.py` drives it with
`StubSession`s to measure sustained sessions per GPU at large client counts.

Flight recorder (`serving.obs`): pass ``tracer=obs.Tracer()`` to the engine
(or ``run_multiclient``, or ``examples/multi_client.py --trace out.json``)
and every grant, migration, labeling launch, preemption cut, fused
train→select→encode stage and per-client uplink/downlink transfer lands as
a span in **simulated** time. ``tracer.dump("out.json")`` writes
deterministic Chrome trace-event JSON — open it at https://ui.perfetto.dev
("Open trace file"; processes are the server, each ``gpu<g>`` with
``stream:label``/``stream:train``/``grants`` threads, and each
``client<i>``; counter tracks carry queue depth, labeling backlog and
per-stream utilization). The engine's results dict is assembled from
`obs.MetricsRegistry`, an ``observability`` section reports the
modeled-vs-measured cost audit (`obs.drift_report` over `core.timing`
stage stats), and `obs.debug_snapshot` unifies the fused-path cache /
counter introspection hooks. Tracing defaults off and the recorder never
changes the schedule: two runs, traced or not, pop identical events.

Fault model (`serving.faults`): chaos is a *plan*, not a dice roll.
``ServingConfig(faults=FaultPlan(...))`` injects a seeded, fully
deterministic fault schedule — per-transfer link loss (splitmix64-hashed
draws, one counter per direction per client), link outage windows
(`OutageWindow`, up/down/both, fleet-wide or per-client), cyclic
`network.RateTrace` bandwidth replay (`LinkSpec.from_trace` loads the
``benchmarks/traces/*.json`` fixtures), device crash windows
(`CrashWindow`) and thermal slowdowns (`SlowdownWindow`). Frame uploads
retry with exponential backoff plus deterministic jitter and are abandoned
(frames dropped, bytes accounted) after ``max_retries``; delta downloads
use *supersede* semantics — a lost delta is retransmitted only while it is
still the newest one, otherwise the retransmit slot notes a ``supersede``
and the client waits for the fresh delta already in flight, inferring on
its stale model meanwhile (``chaos.final_staleness_max_s`` gauges the
damage). A device crash kills the in-flight grant; the ``gpu_done``
watchdog (armed per grant generation) recovers it — releases the device,
spills residency so survivors restage from scratch, and requeues every
member session — while admission control sheds new requests only when the
whole pool is dead. ``FaultPlan.none()`` (the default) is bit-identical to
PR-7: no extra events, no RNG draws, byte-identical traces. The reference
chaos gate lives in ``benchmarks/serving_scale.py --smoke --chaos`` /
``scripts/ci.sh --chaos``.

Fleet control plane (`serving.fleet`): at 10^4-10^5 clients the per-object
path drowns in Python — one heap entry, one dict lookup, one bound-method
call per client per tick. ``FleetState(n, ...)`` stores the whole stub
fleet as struct-of-arrays numpy columns and the engine, handed one, switches
to *cohort events*: clients sharing a timestamp ride a single heap entry
(`Event.client` becomes an index array, ``Event.n`` its multiplicity) and
each event kind is handled by one vectorized batch handler. Policies grow an
array-native ``rank(t, clients=..., ...) -> argsort`` beside the per-object
``pick``, and admission prices unique parameter rows once and parks by a
single argsort+cumsum. The contract is **bit-identical results**: same
events_processed, same mIoU/latency floats, byte-identical flight-recorder
traces under ``FaultPlan.none()`` — anything the vector path cannot
reproduce exactly (tracing, chaos, per-link traces) silently drops to the
scalar lane per cohort. ``telemetry="moments"`` (also on `StubSession`)
folds per-sample lists into running (count, sum, max) so memory stays O(n)
at 10^5 clients; means then agree to ~1 ulp rather than bit-for-bit. The
gate lives in ``benchmarks/serving_scale.py --smoke --fleet`` /
``scripts/ci.sh --fleet``; the ``fleet`` section of BENCH_serving.json
records the 10^3 -> 10^5 sweep (events/sec, RSS) and the measured
fleet-vs-per-object throughput ratio at 10^4.

Sharded execution (`launch.host_mesh` + `core.batched`): the pool's
modeled per-device parallelism can run on *real* jax devices.
``GPUPool(device_backend="jax")`` binds every modeled `GPUDevice` to a
concrete ``jax.Device`` (round-robin over the live backend;
``"modeled"``, the default, keeps ``jax_device=None`` and is
bit-identical), and ``core.batched.train_phases_sharded`` executes
co-resident groups on distinct devices as one multi-device step — either
per-device async dispatch (byte-identical to the serial fused path) or a
single ``shard_map`` along the session axis (``spmd=True``, fp16 wire
deltas within 1 ULP). Force an N-device mesh in a CPU container with
``REPRO_HOST_DEVICES=N source scripts/env.sh`` (the flag must be set
before jax initializes — `launch.host_mesh.host_devices` explains when it
is too late). Per-device measured-vs-modeled seconds surface in
``obs.drift_report()[...]["per_device"]``; the gate lives in
``benchmarks/serving_scale.py --smoke --sharded`` /
``scripts/ci.sh --sharded``.
"""
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.events import Event, EventQueue
from repro.serving.faults import (
    CrashWindow,
    FaultInjector,
    FaultPlan,
    OutageWindow,
    SlowdownWindow,
)
from repro.serving.fleet import FleetSessionView, FleetState
from repro.serving.network import ClientNetwork, Link, LinkSpec, RateTrace
from repro.serving.obs import (
    MetricsRegistry,
    Tracer,
    debug_snapshot,
    drift_report,
    validate_trace,
)
from repro.serving.policies import (
    POLICIES,
    AffinityAware,
    Assignment,
    EarliestDeadlineFirst,
    FairRoundRobin,
    GainAware,
    GPURequest,
    SchedulingPolicy,
    make_policy,
)
from repro.serving.resources import (
    GPUDevice,
    GPUPool,
    MigrationModel,
    StreamModel,
)
from repro.serving.session import (
    SegServingSession,
    SessionBase,
    StubSession,
    train_many,
)

__all__ = [
    "Event", "EventQueue", "ClientNetwork", "Link", "LinkSpec",
    "SchedulingPolicy", "FairRoundRobin", "EarliestDeadlineFirst",
    "GainAware", "AffinityAware", "Assignment", "GPURequest", "POLICIES",
    "make_policy", "GPUDevice", "GPUPool", "MigrationModel", "StreamModel",
    "SegServingSession", "SessionBase", "StubSession", "train_many",
    "ServingConfig", "ServingEngine",
    "Tracer", "MetricsRegistry", "debug_snapshot", "drift_report",
    "validate_trace",
    "FaultPlan", "FaultInjector", "OutageWindow", "CrashWindow",
    "SlowdownWindow", "RateTrace",
    "FleetState", "FleetSessionView",
]
