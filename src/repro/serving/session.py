"""Session adapters: what the serving engine needs from one client.

The engine is deliberately ignorant of JAX, video, and segmentation — it
schedules opaque sessions through a small duck-typed surface:

  edge side   : ``sampling_rate``, ``eval_interval_s``, ``capture(t)``,
                ``take_outbox()``, ``upload_bytes(n)``, ``evaluate(t)``,
                ``apply_delta(delta, t_sent, t_now)``
  server side : ``t_update``, ``k_iters``, ``label_and_ingest(idxs, t)``,
                ``train(t) -> delta | None`` (delta needs ``.total_bytes``)

`SessionBase` holds the shared edge-side plumbing (outbox, network,
telemetry). `SegServingSession` binds the real pipeline (SegWorld +
AMSSession + double-buffered EdgeClient). `StubSession` is a compute-free
stand-in with identical timing/byte behaviour, used to measure engine
throughput at client counts where real training would drown the measurement.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.core.client import EdgeClient
from repro.core.server import AMSSession
from repro.data import codec
from repro.metrics.miou import miou
from repro.serving.network import ClientNetwork, LinkSpec


class SessionBase:
    """Edge-side plumbing shared by every session flavor: the device outbox,
    the per-client network, and the telemetry the engine reads. Subclasses
    add the actual compute (or a model of it)."""

    def __init__(self, idx: int, net: ClientNetwork | None = None):
        self.idx = idx
        self.net = net or ClientNetwork(LinkSpec())
        self.net.client = idx  # flight-recorder identity for transfer spans
        self._outbox: list[int] = []  # sampled frame indices awaiting upload
        self.admitted = True
        self.state_bytes = 0  # server-side training state (migration cost)
        self.delta_bytes_hint = 0  # expected wire-delta size (update pricing)
        self.ams_session = None  # real AMS core, if any (fused-training hook)
        self._edge_rate: float | None = None  # last *delivered* ASR rate
        # telemetry
        self.mious: list[float] = []
        self.delta_latencies: list[float] = []
        self.phases = 0
        self.phase_devices: list[int] = []  # which GPU served each phase
        self.phase_streams: list[str] = []  # which device stream ran it

    def take_outbox(self) -> list[int]:
        out, self._outbox = self._outbox, []
        return out

    @property
    def edge_sampling_rate(self) -> float:
        """The rate the device actually samples at. With the rate-control
        message modeled (``ServingConfig.asr_ctrl_bytes > 0``) this is the
        last rate *delivered* over the downlink; otherwise the server-side
        rate applies instantly (the PR-1 simplification)."""
        return self.sampling_rate if self._edge_rate is None else self._edge_rate

    def apply_rate_ctrl(self, rate: float) -> None:
        self._edge_rate = rate

    def note_device(self, gid: int, stream: str = "train") -> None:
        """Record where a phase physically ran: device id and, under the
        dual-stream device model, which execution stream carried it
        (training phases live on ``train``; the ``label`` stream only ever
        carries teacher launches, which are not per-phase events)."""
        self.phase_devices.append(gid)
        self.phase_streams.append(stream)

    # ---- telemetry folds (what the engine's results read) ---------------
    # Sessions that fold samples into running moments instead of lists
    # (StubSession(telemetry="moments"), fleet views) override these; the
    # defaults read the lists, bit-identical to the historical inline code.
    def miou_mean(self) -> float:
        return float(np.mean(self.mious)) if self.mious else float("nan")

    def latency_values(self):
        """Per-delta latency samples, or None when only moments are kept."""
        return self.delta_latencies

    def latency_summary(self) -> tuple[int, float, float]:
        vals = self.delta_latencies
        return (len(vals), float(sum(vals)),
                float(max(vals)) if vals else 0.0)


class SegServingSession(SessionBase):
    """One edge device streaming a `SegWorld` video through a real
    `AMSSession`, with client-side weights held in an `EdgeClient` (so deltas
    land in the inactive replica and swap — never blocking inference)."""

    def __init__(self, idx: int, world, session: AMSSession, params0,
                 net: ClientNetwork | None = None, eval_stride: int = 6):
        super().__init__(idx, net)
        self.world = world
        self.session = session
        self.ams_session = session  # fused-training hook (core.batched)
        self.edge = EdgeClient(world.predict, jax.tree.map(lambda x: x, params0))
        self.fps = world.video.cfg.fps
        self.eval_interval_s = eval_stride / self.fps
        self._n_pixels = world.video.cfg.height * world.video.cfg.width
        # what a GPU must stage to host this session: params + Adam moments
        # (x3) plus the horizon replay buffer of decoded frames (float32 RGB
        # at the ~1 fps nominal sampling rate)
        param_bytes = sum(np.asarray(x).nbytes
                          for x in jax.tree.leaves(params0))
        buffer_bytes = int(session.cfg.t_horizon) * self._n_pixels * 3 * 4
        self.state_bytes = 3 * param_bytes + buffer_bytes
        # expected delta wire size, for amortized update-pipeline pricing at
        # admission: γN fp16 values + the (uncompressed-bound) mask bits
        n_params = sum(np.asarray(x).size for x in jax.tree.leaves(params0))
        self.delta_bytes_hint = int(session.cfg.gamma * n_params * 2
                                    + n_params / 8)

    # ---- edge side -----------------------------------------------------
    @property
    def sampling_rate(self) -> float:
        return self.session.sampling_rate

    @property
    def phi_signal(self) -> float:
        """Recent φ relative to the ASR target: ~0 for a frozen feed, ~1 at
        the controller's set point, >1 while the scene outruns it."""
        ema = self.session.asr.phi_ema
        if ema < 0:  # nothing observed yet: assume dynamic (serve eagerly)
            return 1.0
        return ema / max(self.session.asr.phi_target, 1e-9)

    def capture(self, t: float) -> None:
        idx = min(int(t * self.fps), self.world.video.cfg.n_frames - 1)
        self._outbox.append(idx)

    def upload_bytes(self, n_frames: int) -> int:
        """H.264 two-pass over the T_update buffer (paper §3.2) + a small
        control message so even an empty upload asks for a phase."""
        return 256 + codec.h264_buffer_bytes(n_frames, self._n_pixels,
                                             self.t_update)

    def evaluate(self, t: float) -> None:
        idx = min(int(t * self.fps), self.world.video.cfg.n_frames - 1)
        img, _ = self.world.video.frame(idx)
        tlabel = self.world.teacher.label(idx)
        pred = np.asarray(self.edge.infer(img[None])[0])
        self.mious.append(miou(pred, tlabel, self.world.video.cfg.n_classes))

    def apply_delta(self, delta, t_sent: float, t_now: float) -> None:
        self.edge.apply_update(delta)
        self.delta_latencies.append(t_now - t_sent)

    # ---- server side ---------------------------------------------------
    @property
    def t_update(self) -> float:
        return self.session.t_update

    @property
    def k_iters(self) -> int:
        return self.session.cfg.k_iters

    def label_and_ingest(self, idxs: list[int], t: float) -> None:
        if not idxs:
            return
        frames = np.stack([self.world.video.frame(i)[0] for i in idxs])
        labels = np.stack([self.world.teacher.label(i) for i in idxs])
        self.session.receive_labeled(frames, labels, t)

    def train(self, t: float):
        delta = self.session.train_phase(t)
        if delta is not None:
            self.phases += 1
        return delta


@dataclass
class StubDelta:
    total_bytes: int


class StubSession(SessionBase):
    """Compute-free session with the same surface and modeled byte sizes.

    Accuracy is a deterministic freshness curve: mIoU decays linearly with
    the age of the client's weights at a per-session ``dynamics`` rate, so
    scheduler quality still shows up in the aggregate numbers while a single
    event costs microseconds — this is what lets `serving_scale` push client
    counts into the dozens and report engine events/sec rather than JAX time.
    """

    def __init__(self, idx: int, *, fps: float = 4.0, t_update: float = 10.0,
                 k_iters: int = 20, rate: float = 1.0, dynamics: float = 0.01,
                 frame_bytes: int = 7000, delta_bytes: int = 20_000,
                 state_bytes: int = 32_000_000, eval_stride: int = 6,
                 net: ClientNetwork | None = None,
                 telemetry: str = "full"):
        super().__init__(idx, net)
        if telemetry not in ("full", "moments"):
            raise ValueError("telemetry must be 'full' or 'moments', "
                             f"got {telemetry!r}")
        # "full" keeps every mIoU/latency sample (bit-identical, the
        # default); "moments" folds them into running (count, sum, max)
        # so a huge fleet stops accumulating unbounded Python lists
        self.telemetry = telemetry
        self._m_n = 0
        self._m_sum = 0.0
        self._lat_n = 0
        self._lat_sum = 0.0
        self._lat_max = 0.0
        self.state_bytes = state_bytes  # modeled weights+opt+buffer residency
        self.fps = fps
        self.sampling_rate = rate
        self.phi_signal = rate  # stubs: the configured rate IS the dynamics
        self.eval_interval_s = eval_stride / fps
        self.t_update = t_update
        self.k_iters = k_iters
        self.dynamics = dynamics  # mIoU lost per second of weight staleness
        self._frame_bytes = frame_bytes
        self._delta_bytes = delta_bytes
        self.delta_bytes_hint = delta_bytes  # stubs: the modeled size is exact
        self._ingested = 0
        self._last_update_t = 0.0

    def capture(self, t: float) -> None:
        self._outbox.append(int(t * self.fps))

    def upload_bytes(self, n_frames: int) -> int:
        return 256 + n_frames * self._frame_bytes

    def evaluate(self, t: float) -> None:
        staleness = t - self._last_update_t
        v = max(0.2, 0.9 - self.dynamics * staleness)
        if self.telemetry == "full":
            self.mious.append(v)
        else:
            self._m_n += 1
            self._m_sum += v

    def apply_delta(self, delta, t_sent: float, t_now: float) -> None:
        self._last_update_t = t_now
        lat = t_now - t_sent
        if self.telemetry == "full":
            self.delta_latencies.append(lat)
        else:
            self._lat_n += 1
            self._lat_sum += lat
            if lat > self._lat_max:
                self._lat_max = lat

    def miou_mean(self) -> float:
        if self.telemetry == "full":
            return super().miou_mean()
        return self._m_sum / self._m_n if self._m_n else float("nan")

    def latency_values(self):
        if self.telemetry == "full":
            return self.delta_latencies
        return None

    def latency_summary(self) -> tuple[int, float, float]:
        if self.telemetry == "full":
            return super().latency_summary()
        return (self._lat_n, self._lat_sum, self._lat_max)

    def label_and_ingest(self, idxs: list[int], t: float) -> None:
        self._ingested += len(idxs)

    def train(self, t: float):
        if self._ingested == 0:
            return None
        self.phases += 1
        return StubDelta(total_bytes=self._delta_bytes)


def train_many(sessions: list, t: float, device=None) -> list:
    """Train several co-granted sessions, fusing where the math allows.

    Sessions exposing a real AMS core (``ams_session``) run through
    `core.batched.train_phases_fused` as one stacked scan/vmap launch (same
    grouping rules: shared loss callable, shapes, K, optimizer). Everything
    else — stubs, single stragglers — falls back to its own ``train``. The
    returned list is delta-or-None per session, in input order.

    ``device`` is the granted pool slot's ``jax.Device`` binding
    (`GPUPool(device_backend="jax")`): the fused stacked launch then runs
    on that device instead of the default one. None places nothing."""
    out: list = [None] * len(sessions)
    fusable = [i for i, s in enumerate(sessions)
               if getattr(s, "ams_session", None) is not None]
    rest = list(range(len(sessions)))
    if len(fusable) >= 2:
        from repro.core.batched import train_phases_fused

        deltas = train_phases_fused([sessions[i].ams_session for i in fusable],
                                    t, device=device)
        for i, d in zip(fusable, deltas):
            if d is not None:
                sessions[i].phases += 1
            out[i] = d
        fused = set(fusable)  # hoisted: rebuilding per element made this O(B²)
        rest = [i for i in rest if i not in fused]
    for i in rest:
        out[i] = sessions[i].train(t)
    return out
