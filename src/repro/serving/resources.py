"""Pooled GPU resources: per-device busy clocks, cost models, and residency.

PR 1's engine modeled the server's accelerator as one boolean (`gpu_busy`).
This module makes the GPU a first-class pooled resource:

* `GPUDevice` — one accelerator: a busy flag the event loop toggles, a
  `GPUCostModel` (devices may be heterogeneous), and busy-seconds telemetry.
* `MigrationModel` — what it costs to move one session's server-side state
  (student weights + optimizer moments + the horizon replay buffer) onto a
  device it is not resident on: a setup charge (stream/allocator/autotune
  warm-up dominates in practice) plus bytes over an interconnect.
* `GPUPool` — the devices plus *residency tracking*: each session's training
  state lives on exactly one device (its "home"); granting a session to a
  foreign device pays the migration transfer **on that device's clock** and
  re-homes it. An optional per-device `residency_cap` models finite HBM:
  past it the least-recently-granted session spills to host and pays a full
  restage on its next grant anywhere.

First touch is free: an admitted session's state is staged onto its first
device before the run starts (admission-time prefetch), so a 1-GPU pool
reproduces the PR-1 single-flag engine exactly — there is nowhere to
migrate to and nothing is ever evicted.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.scheduler import GPUCostModel


@dataclass(frozen=True)
class MigrationModel:
    """Cost of re-homing one session's training state onto another device.

    ``setup_s`` is the fixed charge (context/stream setup, allocator growth,
    kernel autotune re-warm); ``gbps`` the effective interconnect rate for
    the state bytes themselves (PCIe/NVLink staging, conservatively low
    because real moves serialize through host checkpointing)."""

    gbps: float = 2.0
    setup_s: float = 0.1

    def transfer_s(self, nbytes: int) -> float:
        if self.gbps <= 0:  # unmodeled interconnect: instantaneous
            return 0.0
        return self.setup_s + nbytes * 8.0 / (self.gbps * 1e9)


@dataclass
class GPUDevice:
    """One accelerator in the pool: busy flag + cost model + telemetry."""

    gid: int
    cost: GPUCostModel = field(default_factory=GPUCostModel)
    busy: bool = False
    busy_s: float = 0.0
    grants: int = 0


class GPUPool:
    """Per-device busy clocks + session-state residency for the engine.

    The pool is pure bookkeeping — it never decides *who* runs (that is the
    `SchedulingPolicy`) or *when* (the event loop). It answers: which devices
    are free, what would running session c on device g cost in migration
    time, and it enforces that no device is ever double-booked."""

    def __init__(self, n_gpus: int = 1, cost: GPUCostModel | None = None,
                 costs: list[GPUCostModel] | None = None,
                 migration: MigrationModel | None = None,
                 residency_cap: int | None = None):
        if residency_cap is not None and residency_cap < 1:
            raise ValueError(
                f"residency_cap must be >= 1 (or None for unbounded HBM), "
                f"got {residency_cap}")
        if costs is None:
            costs = [cost or GPUCostModel()] * max(n_gpus, 1)
        self.devices = [GPUDevice(gid=g, cost=c) for g, c in enumerate(costs)]
        self.migration = migration or MigrationModel()
        self.residency_cap = residency_cap
        self._home: dict[int, int] = {}  # client -> device holding its state
        self._last_grant: dict[int, dict[int, float]] = {
            d.gid: {} for d in self.devices}  # gid -> {client: t of last grant}
        self._spilled: set[int] = set()  # evicted to host; next grant restages
        # telemetry
        self.migrations = 0
        self.migration_s_total = 0.0
        self.evictions = 0
        self.rider_grants = 0  # sessions co-trained via fused coalescing

    # ---- capacity ------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.devices)

    def device(self, gid: int) -> GPUDevice:
        return self.devices[gid]

    def free_ids(self) -> list[int]:
        return [d.gid for d in self.devices if not d.busy]

    def has_free(self) -> bool:
        return any(not d.busy for d in self.devices)

    # ---- residency -----------------------------------------------------
    def home_of(self, client: int) -> int | None:
        return self._home.get(client)

    def is_resident(self, client: int, gid: int) -> bool:
        return self._home.get(client) == gid and client not in self._spilled

    def migration_s(self, client: int, gid: int, state_bytes: int) -> float:
        """Time device ``gid`` would spend staging ``client``'s state before
        it can train there. Zero when already resident; zero on first touch
        (admission-time prefetch); a full restage after a host spill."""
        home = self._home.get(client)
        if client in self._spilled:
            return self.migration.transfer_s(state_bytes)
        if home is None or home == gid:
            return 0.0
        return self.migration.transfer_s(state_bytes)

    # ---- grant / release ----------------------------------------------
    def grant(self, gid: int, client: int, t: float, dur_s: float,
              horizon_s: float, mig_s: float = 0.0) -> None:
        """Occupy ``gid`` for ``dur_s`` (which already includes ``mig_s``)
        and re-home ``client`` there. Raises on double-booking — the policy
        layer must only hand out free devices."""
        dev = self.devices[gid]
        if dev.busy:
            raise RuntimeError(
                f"device {gid} double-booked at t={t:.3f} (client {client})")
        dev.busy = True
        dev.grants += 1
        # phases granted near the horizon spill past it; only the in-window
        # part counts toward utilization (keeps busy_s <= horizon per device)
        dev.busy_s += min(dur_s, max(horizon_s - t, 0.0))
        if mig_s > 0.0:
            self.migrations += 1
            self.migration_s_total += mig_s
        self._note_residency(gid, client, t)

    def attach(self, gid: int, client: int, t: float) -> None:
        """Residency bookkeeping for a fused *rider*: a session co-trained on
        an already-granted device (`engine` coalescing). Riders are picked
        for zero staging cost (resident there, or first touch), so no
        migration is charged and the device's busy state is untouched — but
        the session is (re-)homed and its LRU slot refreshed like any grant."""
        self.rider_grants += 1
        self._note_residency(gid, client, t)

    def _note_residency(self, gid: int, client: int, t: float) -> None:
        prev = self._home.get(client)
        if prev is not None and prev != gid:
            self._last_grant[prev].pop(client, None)
        self._home[client] = gid
        self._last_grant[gid][client] = t
        self._spilled.discard(client)
        cap = self.residency_cap
        if cap is not None and len(self._last_grant[gid]) > cap:
            lru = self._last_grant[gid]
            victim = min((c for c in lru if c != client),
                         key=lambda c: (lru[c], c))
            del lru[victim]
            del self._home[victim]
            self._spilled.add(victim)
            self.evictions += 1

    def extend_busy(self, gid: int, t: float, extra_s: float,
                    horizon_s: float) -> None:
        """Keep a granted device busy past its phase (delta compression)."""
        dev = self.devices[gid]
        dev.busy_s += min(extra_s, max(horizon_s - t, 0.0))

    def release(self, gid: int) -> None:
        self.devices[gid].busy = False

    # ---- telemetry -----------------------------------------------------
    def utilization(self, horizon_s: float) -> list[float]:
        return [d.busy_s / max(horizon_s, 1e-9) for d in self.devices]
