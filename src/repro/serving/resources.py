"""Pooled GPU resources: per-device *stream* clocks, cost models, residency.

PR 1's engine modeled the server's accelerator as one boolean (`gpu_busy`);
PR 2 made it a pool of per-device busy clocks. This PR splits each device
clock into named **execution streams** — the AMS server concurrently runs a
heavy teacher for labeling and continual student training (paper §4), and a
single clock forces the cross-client labeling batch to serialize against the
fused train launch it feeds:

* `StreamModel` — how the two streams of one device interact: ``serialized``
  (mutual exclusion; with preemption off this is the bit-identical PR-3
  default) or ``overlap`` (concurrent execution, each launch stretched by a
  bounded ``slowdown`` factor while the other stream is busy). ``preempt``
  makes labeling launches splittable at frame-batch boundaries: a
  higher-priority train grant cuts the in-flight launch, the remainder
  requeues, and ``preempt_cost_s`` is charged on the label stream.
* `GPUDevice` — one accelerator: the grant flag the event loop toggles, a
  `GPUCostModel` (devices may be heterogeneous), and per-stream occupancy
  records (`label` / `train`).
* `MigrationModel` — what it costs to move one session's server-side state
  (student weights + optimizer moments + the horizon replay buffer) onto a
  device it is not resident on.
* `GPUPool` — the devices plus *residency tracking*: each session's training
  state lives on exactly one device (its "home"); granting a session to a
  foreign device pays the migration transfer **on that device's train
  stream** and re-homes it. An optional per-device `residency_cap` models
  finite HBM: past it the least-recently-granted session spills to host.

First touch is free: an admitted session's state is staged onto its first
device before the run starts (admission-time prefetch), so a 1-GPU pool
reproduces the PR-1 single-flag engine exactly — there is nowhere to
migrate to and nothing is ever evicted.

Time model of a stream charge: each stream executes its launches serially;
`charge` places a work item at ``max(now, stream free time)`` (and, when the
model serializes the streams, after the *other* stream too). In overlap mode
the item's duration is stretched while the other stream is occupied — the
contention snapshot is taken at launch time, so work arriving later does not
retroactively slow an in-flight launch (the later arrival bears the
contention cost). Preemption may truncate the **latest** charges of the
label stream; earlier history is immutable.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.scheduler import GPUCostModel

STREAMS = ("label", "train")


@dataclass(frozen=True)
class StreamModel:
    """How one device's label and train streams share the silicon.

    ``serialized`` + ``preempt=False`` is exactly the PR-3 single-clock
    behavior (the engine keeps its legacy fast path for it, bit-for-bit).
    ``serialized`` + ``preempt=True`` still mutually excludes the streams but
    lets a train grant split an in-flight labeling launch at a frame-batch
    boundary. ``overlap`` runs the streams concurrently: while both are
    occupied each launch progresses at ``1/slowdown`` of its solo rate
    (``slowdown=1`` is full overlap, larger values model SM/memory-bandwidth
    contention; the serialized limit is ``slowdown -> inf``)."""

    mode: str = "serialized"  # "serialized" | "overlap"
    slowdown: float = 1.0  # overlap: duration stretch while both streams busy
    preempt: bool = False  # label launches splittable at frame-batch bounds
    preempt_cost_s: float = 0.0  # label-stream charge per real preemption
    # priority aging: a frame batch requeued this many times becomes
    # uncuttable — repeated preemption cannot push one victim's labels back
    # forever, so label staleness is bounded by ~max_seg_preempts launches
    max_seg_preempts: int = 2

    def __post_init__(self):
        if self.mode not in ("serialized", "overlap"):
            raise ValueError(
                f"stream mode must be 'serialized' or 'overlap', "
                f"got {self.mode!r}")
        if self.slowdown < 1.0:
            raise ValueError(
                f"slowdown is a stretch factor >= 1.0, got {self.slowdown}")
        if self.preempt_cost_s < 0.0:
            raise ValueError("preempt_cost_s must be >= 0")
        if self.max_seg_preempts < 1:
            raise ValueError(
                f"max_seg_preempts must be >= 1, got {self.max_seg_preempts}")

    @property
    def legacy(self) -> bool:
        """True when this model is indistinguishable from the PR-3 single
        busy clock — the engine then takes its bit-identical legacy path."""
        return self.mode == "serialized" and not self.preempt

    @property
    def overlapped(self) -> bool:
        return self.mode == "overlap"

    # ---- piecewise time math -------------------------------------------
    def finish_time(self, start: float, work_s: float,
                    other_until: float) -> float:
        """When ``work_s`` seconds of solo-rate work started at ``start``
        completes, given the other stream is occupied until ``other_until``
        (overlap mode: contended progress accrues at ``1/slowdown``)."""
        if work_s <= 0.0:
            return start
        if (not self.overlapped or self.slowdown <= 1.0
                or other_until <= start):
            return start + work_s
        contended_capacity = (other_until - start) / self.slowdown
        if work_s <= contended_capacity:
            return start + work_s * self.slowdown
        return other_until + (work_s - contended_capacity)

    def stream_demand_s(self, label_s: float, train_s: float) -> float:
        """Steady-state device-seconds one update period of labeling plus
        training occupies under this model (admission projection):
        serialized is the plain sum; overlap interpolates between the
        busier stream (full overlap) and the sum (slowdown -> inf)."""
        if not self.overlapped:
            return label_s + train_s
        lo, hi = min(label_s, train_s), max(label_s, train_s)
        return hi + lo * (self.slowdown - 1.0) / max(self.slowdown, 1.0)


@dataclass(frozen=True)
class MigrationModel:
    """Cost of re-homing one session's training state onto another device.

    ``setup_s`` is the fixed charge (context/stream setup, allocator growth,
    kernel autotune re-warm); ``gbps`` the effective interconnect rate for
    the state bytes themselves (PCIe/NVLink staging, conservatively low
    because real moves serialize through host checkpointing)."""

    gbps: float = 2.0
    setup_s: float = 0.1

    def transfer_s(self, nbytes: int) -> float:
        if self.gbps <= 0:  # unmodeled interconnect: instantaneous
            return 0.0
        return self.setup_s + nbytes * 8.0 / (self.gbps * 1e9)


@dataclass
class _Charge:
    """One stream occupancy record: [start, end) plus the contention
    snapshot taken at launch (the other stream's free time then) — kept so
    truncation can recompute overlap without replaying history."""

    start: float
    end: float
    other_snap: float  # other stream's busy-until at launch time
    span: object = None  # flight-recorder span, when a tracer is attached

    @property
    def overlap_s(self) -> float:
        return max(0.0, min(self.end, self.other_snap) - self.start)


def _clipped_total(charges: list[_Charge], horizon_s: float) -> float:
    return sum(max(0.0, min(c.end, horizon_s) - max(c.start, 0.0))
               for c in charges)


def _union_total(intervals: list[tuple[float, float]],
                 horizon_s: float) -> float:
    """Measure of the union of intervals clipped to [0, horizon]."""
    spans = sorted((max(a, 0.0), min(b, horizon_s)) for a, b in intervals
                   if min(b, horizon_s) > max(a, 0.0))
    total, cur_a, cur_b = 0.0, None, None
    for a, b in spans:
        if cur_b is None or a > cur_b:
            if cur_b is not None:
                total += cur_b - cur_a
            cur_a, cur_b = a, b
        else:
            cur_b = max(cur_b, b)
    if cur_b is not None:
        total += cur_b - cur_a
    return total


@dataclass
class GPUDevice:
    """One accelerator in the pool: grant flag + cost model + telemetry.

    ``busy``/``busy_s``/``grants`` keep their PR-2 semantics (the legacy
    single-clock path reads and writes them unchanged). The dual-stream
    engine path records occupancy as per-stream `_Charge` lists instead and
    leaves ``busy_s`` untouched; `label_s`/`train_s` attribute busy seconds
    to streams in *both* paths (in the legacy path the engine splits each
    grant's in-window seconds into its label and train components)."""

    gid: int
    cost: GPUCostModel = field(default_factory=GPUCostModel)
    # concrete jax.Device this pool slot executes on (device_backend="jax");
    # None under the default modeled backend — the math then runs wherever
    # jax puts it (the default device) and only the *clocks* are per-device
    jax_device: object = None
    busy: bool = False
    crashed: bool = False  # fault injection: dead devices take no grants
    busy_s: float = 0.0
    grants: int = 0
    label_s: float = 0.0  # legacy-path stream attribution (in-window seconds)
    train_s: float = 0.0
    stream_until: dict = field(
        default_factory=lambda: {s: 0.0 for s in STREAMS})
    charges: dict = field(
        default_factory=lambda: {s: [] for s in STREAMS})
    # frame-batch completion boundaries of scheduled labeling launches —
    # the points a preemption could cut the label stream at (`label_bounds`
    # records them; `truncate_label` drops the ones a cut removed)
    label_cuts: list = field(default_factory=list)

    # ---- stream telemetry ----------------------------------------------
    def stream_busy_s(self, stream: str, horizon_s: float) -> float:
        if self.charges[stream]:
            return _clipped_total(self.charges[stream], horizon_s)
        return self.label_s if stream == "label" else self.train_s

    def union_busy_s(self, horizon_s: float) -> float:
        """Wall-clock seconds this device had *any* stream occupied (the
        dual-stream analogue of ``busy_s``; equal to it when charges exist
        on one stream only)."""
        if not any(self.charges[s] for s in STREAMS):
            return self.busy_s
        return _union_total([(c.start, c.end) for s in STREAMS
                             for c in self.charges[s]], horizon_s)

    def overlap_s(self) -> float:
        """Seconds both streams were concurrently busy (each charge counts
        its own concurrency against the other stream's schedule at launch,
        so an overlapping pair is counted once — by the later charge)."""
        return sum(c.overlap_s for s in STREAMS for c in self.charges[s])


class GPUPool:
    """Per-device stream clocks + session-state residency for the engine.

    The pool is pure bookkeeping — it never decides *who* runs (that is the
    `SchedulingPolicy`) or *when* (the event loop). It answers: which devices
    are free, what would running session c on device g cost in migration
    time, when could each stream accept work, and it enforces that no device
    is ever double-granted."""

    def __init__(self, n_gpus: int = 1, cost: GPUCostModel | None = None,
                 costs: list[GPUCostModel] | None = None,
                 migration: MigrationModel | None = None,
                 residency_cap: int | None = None,
                 streams: StreamModel | None = None,
                 device_backend: str = "modeled"):
        if residency_cap is not None and residency_cap < 1:
            raise ValueError(
                f"residency_cap must be >= 1 (or None for unbounded HBM), "
                f"got {residency_cap}")
        if device_backend not in ("modeled", "jax"):
            raise ValueError(
                f"device_backend must be 'modeled' or 'jax', "
                f"got {device_backend!r}")
        if costs is None:
            costs = [cost or GPUCostModel()] * max(n_gpus, 1)
        self.devices = [GPUDevice(gid=g, cost=c) for g, c in enumerate(costs)]
        # device_backend="jax": bind every pool slot to a concrete
        # jax.Device so fused lifecycles for co-resident groups on
        # *different* slots really dispatch on different devices
        # (launch.host_mesh forces N host devices on CPU-only hosts).
        # Round-robin when the pool is wider than the live device list —
        # the clocks stay per-slot either way, but `distinct_jax_devices`
        # tells benchmarks how much real parallelism is available.
        # "modeled" (the default) binds nothing and is bit-identical to
        # the pre-knob pool: no jax import, no device_put, no placement.
        self.device_backend = device_backend
        if device_backend == "jax":
            import jax

            live = jax.devices()
            for d in self.devices:
                d.jax_device = live[d.gid % len(live)]
        self.migration = migration or MigrationModel()
        self.streams = streams or StreamModel()
        self.tracer = None  # flight recorder (serving.obs.Tracer), optional
        self.residency_cap = residency_cap
        self._home: dict[int, int] = {}  # client -> device holding its state
        self._last_grant: dict[int, dict[int, float]] = {
            d.gid: {} for d in self.devices}  # gid -> {client: t of last grant}
        self._spilled: set[int] = set()  # evicted to host; next grant restages
        # telemetry
        self.migrations = 0
        self.migration_s_total = 0.0
        self.evictions = 0
        self.rider_grants = 0  # sessions co-trained via fused coalescing
        self.preemptions = 0  # in-flight labeling launches split by a grant
        self.preempted_frames = 0  # frames requeued by those splits
        self.preempt_s_total = 0.0  # modeled preemption cost paid
        self.crashes = 0  # injected device crashes
        self.crash_spills = 0  # sessions whose residency a crash destroyed

    # ---- capacity ------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.devices)

    def device(self, gid: int) -> GPUDevice:
        return self.devices[gid]

    def jax_devices(self) -> list:
        """Per-slot jax.Device bindings (list of None under "modeled")."""
        return [d.jax_device for d in self.devices]

    @property
    def distinct_jax_devices(self) -> int:
        """How many *different* real devices back the pool (0 = modeled).

        A 4-slot pool on a 1-device host binds 4 slots to the same device:
        correctness holds but a "sharded" launch is physically serial, so
        benchmarks gate their wall-clock claims on this being > 1."""
        return len({id(d.jax_device) for d in self.devices
                    if d.jax_device is not None})

    def free_ids(self) -> list[int]:
        return [d.gid for d in self.devices
                if not d.busy and not d.crashed]

    def has_free(self) -> bool:
        return any(not d.busy and not d.crashed for d in self.devices)

    def n_alive(self) -> int:
        return sum(1 for d in self.devices if not d.crashed)

    # ---- fault injection ------------------------------------------------
    def crash(self, gid: int, t: float) -> int:
        """Device ``gid`` dies at ``t``: it takes no further grants and all
        session state resident on it is lost — those sessions spill to host
        and their next grant pays a full restage on whichever surviving
        device the policy picks (the normal migration machinery rebuilds
        residency). The engine handles any grant in flight (watchdog +
        requeue); here we only flip the flag and drop residency. Returns
        how many residents were spilled."""
        dev = self.devices[gid]
        dev.crashed = True
        victims = list(self._last_grant[gid])
        for c in victims:
            del self._last_grant[gid][c]
            self._home.pop(c, None)
            self._spilled.add(c)
        self.crashes += 1
        self.crash_spills += len(victims)
        return len(victims)

    def recover(self, gid: int) -> None:
        """Device ``gid`` rejoins the pool (empty: its HBM was lost)."""
        self.devices[gid].crashed = False

    # ---- residency -----------------------------------------------------
    def home_of(self, client: int) -> int | None:
        return self._home.get(client)

    def is_resident(self, client: int, gid: int) -> bool:
        return self._home.get(client) == gid and client not in self._spilled

    def migration_s(self, client: int, gid: int, state_bytes: int) -> float:
        """Time device ``gid`` would spend staging ``client``'s state before
        it can train there. Zero when already resident; zero on first touch
        (admission-time prefetch); a full restage after a host spill."""
        home = self._home.get(client)
        if client in self._spilled:
            return self.migration.transfer_s(state_bytes)
        if home is None or home == gid:
            return 0.0
        return self.migration.transfer_s(state_bytes)

    # ---- stream clocks (dual-stream engine path) -----------------------
    def stream_free_at(self, gid: int, stream: str) -> float:
        return self.devices[gid].stream_until[stream]

    def train_ready_wait_s(self, gid: int, t: float) -> float:
        """Seconds after ``t`` before a train launch could begin on ``gid``
        under this stream model (policies use it for placement). Serialized
        streams wait for both clocks; overlapped only for the train stream.

        With ``preempt=True`` the label stream's contribution is bounded by
        the next frame-batch boundary plus the preemption charge — a grant
        would cut the in-flight labeling launch there rather than wait out
        its tail — so preemptible devices are no longer taxed by the
        no-preempt upper bound (`AffinityAware` reads this). The estimate is
        deliberately optimistic about cuttability: the engine's disruption
        guard and segment aging can refuse a specific cut, which placement
        cannot know in advance."""
        dev = self.devices[gid]
        until = dev.stream_until["train"]
        if not self.streams.overlapped:
            label_until = dev.stream_until["label"]
            if self.streams.preempt and label_until > t:
                dev.label_cuts = [b for b in dev.label_cuts if b > t]
                if dev.label_cuts:
                    label_until = min(
                        label_until,
                        min(dev.label_cuts) + self.streams.preempt_cost_s)
            until = max(until, label_until)
        return max(0.0, until - t)

    def charge(self, gid: int, stream: str, t: float, work_s: float,
               name: str | None = None,
               args: dict | None = None) -> tuple[float, float]:
        """Occupy ``stream`` on ``gid`` for ``work_s`` seconds of solo-rate
        work, starting no earlier than ``t``: the item queues behind the
        stream (and, when serialized, behind the other stream too) and is
        stretched by the overlap model while the other stream is busy.
        Returns the placed ``(start, end)``. With a tracer attached and a
        ``name`` given, the charge carries a flight-recorder span (later
        truncation edits the span with the schedule)."""
        dev = self.devices[gid]
        other = "train" if stream == "label" else "label"
        start = max(t, dev.stream_until[stream])
        if not self.streams.overlapped:
            start = max(start, dev.stream_until[other])
        snap = dev.stream_until[other]
        end = self.streams.finish_time(start, work_s, snap)
        c = _Charge(start=start, end=end, other_snap=snap)
        if self.tracer is not None and name is not None:
            c.span = self.tracer.gpu_span(gid, stream, name, start, end, args)
        dev.charges[stream].append(c)
        dev.stream_until[stream] = end
        return start, end

    def label_bounds(self, gid: int, t: float, cum_works: list[float],
                     name: str | None = None,
                     args: dict | None = None) -> tuple[float, list[float]]:
        """Charge one labeling launch whose frame batches complete at the
        cumulative solo-rate work marks ``cum_works`` (monotone, last =
        total). Returns ``(start, [absolute boundary times])`` — the points
        the launch may later be preempted at."""
        dev = self.devices[gid]
        start, _ = self.charge(gid, "label", t, cum_works[-1],
                               name=name, args=args)
        snap = dev.charges["label"][-1].other_snap
        bounds = [self.streams.finish_time(start, w, snap) for w in cum_works]
        if self.streams.preempt and not self.streams.overlapped:
            # where a later grant could cut in — recorded only for the
            # serialized+preempt model, the one config whose wait estimate
            # reads them. Pruning happens HERE (drop bounds already past
            # this launch's start), not only in the read path: a pool run
            # under a policy that never queries the wait estimate must not
            # accumulate the whole run's launch history
            dev.label_cuts = ([b for b in dev.label_cuts if b > start]
                              + bounds)
        return start, bounds

    def truncate_label(self, gid: int, new_end: float, *,
                       preempted_frames: int, cancel: bool = False) -> float:
        """Preemption bookkeeping: cut the label stream's LATEST charge to
        ``new_end`` (the frame-batch boundary) and charge the model's
        preemption cost after it. ``cancel=True`` removes a launch that had
        not started yet (free reordering — no cost, not a preemption).
        Returns when the label stream is free again."""
        dev = self.devices[gid]
        dev.label_cuts = [b for b in dev.label_cuts if b <= new_end]
        last = dev.charges["label"][-1]
        if cancel:
            dev.charges["label"].pop()
            if last.span is not None:
                last.span.cancelled = True
        else:
            last.end = new_end
            if last.span is not None:
                # a preemption is a schedule edit, so it is a span edit
                last.span.end = new_end
                if last.span.args is not None:
                    last.span.args = dict(last.span.args, preempted=True)
            self.preemptions += 1
            self.preempted_frames += preempted_frames
            if self.tracer is not None:
                self.tracer.gpu_instant(gid, "label", "preempt", new_end,
                                        {"frames": int(preempted_frames)})
            cost = self.streams.preempt_cost_s
            if cost > 0.0:
                self.preempt_s_total += cost
                c = _Charge(start=new_end, end=new_end + cost,
                            other_snap=dev.stream_until["train"])
                if self.tracer is not None:
                    c.span = self.tracer.gpu_span(
                        gid, "label", "preempt_cost", new_end,
                        new_end + cost, {"frames": int(preempted_frames)})
                dev.charges["label"].append(c)
                new_end = new_end + cost
        dev.stream_until["label"] = (dev.charges["label"][-1].end
                                     if dev.charges["label"] else 0.0)
        return dev.stream_until["label"]

    # ---- grant / release ----------------------------------------------
    def grant(self, gid: int, client: int, t: float, dur_s: float,
              horizon_s: float, mig_s: float = 0.0,
              label_s: float = 0.0) -> None:
        """Legacy single-clock grant: occupy ``gid`` for ``dur_s`` (which
        already includes ``mig_s`` and ``label_s``) and re-home ``client``
        there. Raises on double-booking — the policy layer must only hand
        out free devices. ``label_s`` is the labeling component of the
        grant, attributed to the label stream for telemetry (it runs
        ``mig_s`` after the grant start); the rest is train-stream time."""
        dev = self.devices[gid]
        if dev.busy:
            raise RuntimeError(
                f"device {gid} double-booked at t={t:.3f} (client {client})")
        dev.busy = True
        dev.grants += 1
        # phases granted near the horizon spill past it; only the in-window
        # part counts toward utilization (keeps busy_s <= horizon per device)
        in_window = min(dur_s, max(horizon_s - t, 0.0))
        dev.busy_s += in_window
        label_in = max(0.0, min(t + mig_s + label_s, horizon_s)
                       - min(t + mig_s, horizon_s))
        dev.label_s += label_in
        dev.train_s += in_window - label_in
        if mig_s > 0.0:
            self.migrations += 1
            self.migration_s_total += mig_s
        self._note_residency(gid, client, t)

    def grant_streams(self, gid: int, client: int, t: float) -> None:
        """Dual-stream grant: flag the device as granted and re-home
        ``client``; the actual time is charged per work item via `charge`
        (migration/training on the train stream, labeling via
        `label_bounds`)."""
        dev = self.devices[gid]
        if dev.busy:
            raise RuntimeError(
                f"device {gid} double-booked at t={t:.3f} (client {client})")
        dev.busy = True
        dev.grants += 1
        self._note_residency(gid, client, t)

    def note_migration(self, mig_s: float) -> None:
        if mig_s > 0.0:
            self.migrations += 1
            self.migration_s_total += mig_s

    def attach(self, gid: int, client: int, t: float,
               mig_s: float = 0.0) -> None:
        """Residency bookkeeping for a fused *rider*: a session co-trained on
        an already-granted device (`engine` coalescing). A cost-aware
        `coalesce` may take a rider whose staging is cheaper than the fused
        stack discount — its ``mig_s`` is counted here (the engine charges
        the time to the granting device); the device's busy state is
        untouched, but the session is (re-)homed and its LRU slot refreshed
        like any grant."""
        self.rider_grants += 1
        self.note_migration(mig_s)
        self._note_residency(gid, client, t)

    def _note_residency(self, gid: int, client: int, t: float) -> None:
        prev = self._home.get(client)
        if prev is not None and prev != gid:
            self._last_grant[prev].pop(client, None)
        self._home[client] = gid
        self._last_grant[gid][client] = t
        self._spilled.discard(client)
        cap = self.residency_cap
        if cap is not None and len(self._last_grant[gid]) > cap:
            lru = self._last_grant[gid]
            victim = min((c for c in lru if c != client),
                         key=lambda c: (lru[c], c))
            del lru[victim]
            del self._home[victim]
            self._spilled.add(victim)
            self.evictions += 1

    def extend_busy(self, gid: int, t: float, extra_s: float,
                    horizon_s: float) -> None:
        """Keep a granted device busy past its phase (delta compression) —
        legacy-path accounting, attributed to the train stream."""
        dev = self.devices[gid]
        in_window = min(extra_s, max(horizon_s - t, 0.0))
        dev.busy_s += in_window
        dev.train_s += in_window

    def release(self, gid: int) -> None:
        self.devices[gid].busy = False

    # ---- telemetry -----------------------------------------------------
    def utilization(self, horizon_s: float) -> list[float]:
        return [d.union_busy_s(horizon_s) / max(horizon_s, 1e-9)
                for d in self.devices]

    def stream_utilization(self, horizon_s: float) -> dict[str, list[float]]:
        return {s: [d.stream_busy_s(s, horizon_s) / max(horizon_s, 1e-9)
                    for d in self.devices] for s in STREAMS}

    def overlap_s_total(self) -> float:
        return sum(d.overlap_s() for d in self.devices)
