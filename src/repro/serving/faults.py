"""Deterministic fault injection for the serving runtime (chaos testing).

The paper's prototype lives on real cellular links and a shared GPU server;
neither is fault-free. This module is the single description of everything
that can go wrong in a run — a seeded, declarative `FaultPlan`:

* **link outages** (`OutageWindow`) — an uplink/downlink is dead for a time
  window, for one client or the whole fleet. Client disconnect/reconnect is
  the same thing in both directions (``disconnects``).
* **per-transfer loss** (``up_loss`` / ``down_loss``) — each transfer is
  independently lost with a fixed probability. The bytes still occupy the
  link (wasted air time is the point); the payload never lands.
* **burst/jitter rate traces** (``up_rate_trace`` / ``down_rate_trace``) —
  a `network.RateTrace` applied to every client's links, replacing the
  constant-rate model with a cellular-style variable-bandwidth replay.
* **device crashes** (`CrashWindow`) — a pool device is dead for a window:
  residency on it is lost (sessions spill to host and restage on a survivor
  via the normal migration machinery), a grant in flight dies with it (the
  engine's ``gpu_done`` watchdog detects and requeues the fused group), and
  the scheduler stops placing work on it until the window ends.
* **device slowdowns** (`SlowdownWindow`) — grants placed while the window
  covers the device run ``factor``x slower (thermal throttling, a noisy
  neighbor).

Determinism is the contract: every stochastic decision (per-transfer loss,
retry backoff jitter) is a pure function of ``(plan.seed, decision keys)``
via a splitmix64-style hash — no global RNG is consumed, and two runs of
the same plan are byte-identical (the property CI asserts). The default
`FaultPlan.none()` configures nothing, and the engine's fault hooks are all
behind an ``active`` check, so a fault-free engine is bit-identical to the
pre-chaos code (golden-tested).

`FaultInjector` is the runtime view: it normalizes/merges windows once and
answers the engine's point queries (is this link down at t? is this
transfer lost? how long is the next backoff?).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.serving.network import RateTrace

_M64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    """splitmix64 finalizer: avalanche one 64-bit lane."""
    x = (x + 0x9E3779B97F4A7C15) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 31)


def _u01(seed: int, *keys: int) -> float:
    """Deterministic uniform in [0, 1) from the seed and integer keys."""
    h = _mix64(seed & _M64)
    for k in keys:
        h = _mix64(h ^ (k & _M64))
    return (h >> 11) / float(1 << 53)


@dataclass(frozen=True)
class OutageWindow:
    """One link-outage interval. ``client=None`` hits the whole fleet;
    ``direction`` is ``"up"``, ``"down"`` or ``"both"``."""

    start: float
    end: float
    direction: str = "both"
    client: int | None = None

    def __post_init__(self):
        if self.direction not in ("up", "down", "both"):
            raise ValueError(f"direction must be up/down/both, "
                             f"got {self.direction!r}")
        if self.end < self.start:
            raise ValueError(f"outage window ends before it starts: "
                             f"[{self.start}, {self.end}]")


@dataclass(frozen=True)
class CrashWindow:
    """Device ``gid`` is dead during [start, end); it rejoins at ``end``."""

    gid: int
    start: float
    end: float

    def __post_init__(self):
        if self.end <= self.start:
            raise ValueError(f"crash window empty: [{self.start}, {self.end}]")


@dataclass(frozen=True)
class SlowdownWindow:
    """Grants placed on ``gid`` while covered run ``factor``x slower."""

    gid: int
    start: float
    end: float
    factor: float = 1.5

    def __post_init__(self):
        if self.factor < 1.0:
            raise ValueError(f"slowdown factor must be >= 1.0, "
                             f"got {self.factor}")


@dataclass(frozen=True)
class FaultPlan:
    """Seeded, declarative chaos schedule for one engine run.

    The default instance (== `FaultPlan.none()`) configures no faults and
    the engine treats it as "chaos off": no extra events, no extra RNG, a
    bit-identical schedule. The retry knobs only matter once something can
    actually fail."""

    seed: int = 0
    # per-transfer loss probability (bytes burn the link; payload is lost)
    up_loss: float = 0.0
    down_loss: float = 0.0
    # scheduled windows
    outages: tuple[OutageWindow, ...] = ()
    disconnects: tuple[OutageWindow, ...] = ()  # client off-air, both ways
    crashes: tuple[CrashWindow, ...] = ()
    slowdowns: tuple[SlowdownWindow, ...] = ()
    # fleet-wide variable-bandwidth replay (network.RateTrace)
    up_rate_trace: RateTrace | None = None
    down_rate_trace: RateTrace | None = None
    # retry policy: exponential backoff with deterministic jitter
    max_retries: int = 5
    backoff_base_s: float = 0.5
    backoff_factor: float = 2.0
    backoff_jitter: float = 0.25  # +/- fraction of the backoff, hashed
    detect_timeout_s: float = 0.2  # sender's loss/outage detection lag
    # gpu_done straggler timeout, measured past the planned completion
    watchdog_s: float = 5.0

    def __post_init__(self):
        for name in ("up_loss", "down_loss"):
            p = getattr(self, name)
            if not 0.0 <= p < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {p}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_factor < 1.0 or self.backoff_base_s < 0.0:
            raise ValueError("backoff must not shrink: need base >= 0 and "
                             "factor >= 1")
        if not 0.0 <= self.backoff_jitter < 1.0:
            raise ValueError(f"backoff_jitter must be in [0, 1), "
                             f"got {self.backoff_jitter}")
        if self.watchdog_s <= 0.0 or self.detect_timeout_s < 0.0:
            raise ValueError("watchdog_s must be > 0, detect_timeout_s >= 0")
        for w in self.disconnects:
            if w.client is None:
                raise ValueError("a disconnect window needs a client "
                                 "(fleet-wide loss is an OutageWindow)")
        by_gid: dict[int, list[CrashWindow]] = {}
        for w in self.crashes:
            by_gid.setdefault(w.gid, []).append(w)
        for gid, ws in by_gid.items():
            ws = sorted(ws, key=lambda w: w.start)
            for a, b in zip(ws, ws[1:]):
                if b.start < a.end:
                    raise ValueError(
                        f"overlapping crash windows on device {gid}: "
                        f"[{a.start}, {a.end}] and [{b.start}, {b.end}]")

    @staticmethod
    def none() -> "FaultPlan":
        """The fault-free plan: hooks disabled, schedule bit-identical to
        an engine that never heard of faults (golden-tested)."""
        return FaultPlan()

    @property
    def active(self) -> bool:
        return bool(self.up_loss > 0.0 or self.down_loss > 0.0
                    or self.outages or self.disconnects or self.crashes
                    or self.slowdowns or self.up_rate_trace is not None
                    or self.down_rate_trace is not None)

    @staticmethod
    def reference(duration: float, n_gpus: int = 2) -> "FaultPlan":
        """The chaos benchmark's plan (`serving_scale --chaos`): lossy
        links, a fleet-wide uplink outage, a long downlink outage (longer
        than one update period, so deferred deltas get superseded by fresh
        ones), one mid-run device crash while the pool is loaded, and a
        thermal slowdown on the survivor — every recovery path exercised
        in one deterministic run."""
        return FaultPlan(
            seed=7,
            up_loss=0.12,
            down_loss=0.12,
            outages=(OutageWindow(start=0.25 * duration,
                                  end=0.25 * duration + 12.0,
                                  direction="up"),
                     OutageWindow(start=0.65 * duration,
                                  end=0.65 * duration + 16.0,
                                  direction="down")),
            crashes=(CrashWindow(gid=n_gpus - 1, start=0.5 * duration,
                                 end=0.5 * duration + 0.12 * duration),),
            slowdowns=(SlowdownWindow(gid=0, start=0.75 * duration,
                                      end=0.85 * duration, factor=1.5),),
        )


def _merge(intervals: list[tuple[float, float]]) -> list[tuple[float, float]]:
    out: list[tuple[float, float]] = []
    for a, b in sorted(intervals):
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return out


class FaultInjector:
    """Runtime view of a `FaultPlan`: merged window indexes + deterministic
    point draws. Holds per-(direction, client) draw counters so that the
    n-th transfer of a client is always judged by the same hash — replaying
    a run replays its losses exactly."""

    # key-space tags, so draws for different purposes never collide
    _TAG_LOSS = {"up": 1, "down": 2}
    _TAG_BACKOFF = 3

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        # (direction, client-or-None) -> merged outage intervals
        self._outages: dict[tuple[str, int | None], list] = {}
        for w in plan.outages + plan.disconnects:
            dirs = ("up", "down") if w.direction == "both" else (w.direction,)
            for d in dirs:
                self._outages.setdefault((d, w.client), []).append(
                    (w.start, w.end))
        for k, ivs in self._outages.items():
            self._outages[k] = _merge(ivs)
        self._slow = sorted(plan.slowdowns, key=lambda w: (w.gid, w.start))
        self._draws: dict[tuple[int, int], int] = {}

    # ---- point queries --------------------------------------------------
    def outage_until(self, direction: str, client: int, t: float
                     ) -> float | None:
        """If the client's ``direction`` link is down at ``t``, when the
        covering outage window ends; None when the link is up."""
        for key in ((direction, None), (direction, client)):
            for a, b in self._outages.get(key, ()):
                if a <= t < b:
                    return b
        return None

    def transfer_lost(self, direction: str, client: int) -> bool:
        """Deterministic per-transfer loss draw: keyed by the plan seed,
        the direction, the client, and that client's transfer count in
        this direction (advanced on every call)."""
        p = self.plan.up_loss if direction == "up" else self.plan.down_loss
        tag = self._TAG_LOSS[direction]
        n = self._draws.get((tag, client), 0)
        self._draws[(tag, client)] = n + 1
        if p <= 0.0:
            return False
        return _u01(self.plan.seed, tag, client, n) < p

    def backoff_s(self, client: int, attempt: int) -> float:
        """Exponential backoff with deterministic jitter for the (attempt)th
        retry of ``client`` — jitter is hashed, not drawn, so re-runs and
        concurrent clients never correlate or diverge."""
        base = self.plan.backoff_base_s * self.plan.backoff_factor ** attempt
        j = self.plan.backoff_jitter
        if j <= 0.0:
            return base
        u = _u01(self.plan.seed, self._TAG_BACKOFF, client, attempt)
        return base * (1.0 + j * (2.0 * u - 1.0))

    def slowdown_factor(self, gid: int, t: float) -> float:
        for w in self._slow:
            if w.gid == gid and w.start <= t < w.end:
                return w.factor
        return 1.0

    # ---- window telemetry ----------------------------------------------
    def outage_windows(self) -> list[tuple[str, int | None, float, float]]:
        """Merged (direction, client-or-None, start, end) outage windows —
        the tracer's `outage` spans and the outage-seconds gauge read
        these."""
        return [(d, c, a, b) for (d, c), ivs in sorted(
                    self._outages.items(),
                    key=lambda kv: (kv[0][0], -1 if kv[0][1] is None
                                    else kv[0][1]))
                for a, b in ivs]

    def link_outage_s(self, duration: float, n_clients: int) -> float:
        """Total client-link-seconds of scheduled outage inside the run
        (a fleet-wide window counts once per client)."""
        total = 0.0
        for _, c, a, b in self.outage_windows():
            w = max(0.0, min(b, duration) - max(a, 0.0))
            total += w * (n_clients if c is None else 1)
        return total

    def crash_s(self, duration: float) -> float:
        """Total device-seconds of scheduled crash downtime in the run."""
        return sum(max(0.0, min(w.end, duration) - max(w.start, 0.0))
                   for w in self.plan.crashes)
