"""Event-driven AMS serving runtime (Appendix E at scale).

Replaces the per-frame tick loop of `sim.multiclient` with a discrete-event
simulation: N sessions share a *pool* of GPUs (`resources.GPUPool`) and a
modeled network, and nothing advances except by popping the next event. The
lifecycle of one update period, in events:

    sample    (edge)   frame captured at the ASR rate into the device outbox
    upload    (edge)   every T_update the outbox ships over the rate-limited
                       uplink (H.264 buffer bytes -> link occupancy)
    request   (server) the batch lands; admission control either queues a
                       GPURequest or drops it (saturation telemetry)
    <grants>           whenever any device idles, the scheduling policy maps
                       the ready queue onto the free devices as (session,
                       gpu) assignments; each granted device stages the
                       session's state if it is not resident (migration time
                       on that device's clock), labels the queued backlog in
                       one batched teacher launch, then runs the session's
                       K-iteration training phase. With ``fuse_train > 1``
                       the grant also takes ready *riders* whose staging is
                       cheaper than the fused-stack discount: the whole stack
                       trains as ONE fused scan/vmap launch (`core.batched`)
                       priced sublinearly by `GPUCostModel.train_batch_s`
    label_seg (gpu g)  [dual-stream path] one frame batch of a labeling
                       launch completes on g's label stream; the labels land
                       in the owning session's replay buffer
    gpu_done  (gpu g)  the phase ends on device g; the fresh ModelDelta is
                       compressed on g's clock (delta_comp_s, optional) and
                       ships over the client's downlink, followed by the ASR
                       rate-control message (asr_ctrl_bytes, optional)
    gpu_free  (gpu g)  g finishes compressing and rejoins the free set
    delta     (edge)   the — by now stale — delta lands and swaps in via the
                       double-buffered EdgeClient
    rate_ctrl (edge)   the ASR's new sampling rate takes effect on-device
    eval      (edge)   mIoU of the client-side weights against the teacher

Device time is charged through `resources.StreamModel`: every work item —
teacher labeling, solo/fused training, migration, delta compression — lands
on a named per-device stream (``label`` or ``train``). The default model
(serialized streams, no preemption) is the PR-3 single busy clock and takes
a legacy fast path that reproduces it bit-for-bit. With ``overlap`` the two
streams run concurrently (bounded ``slowdown`` while both are busy), so a
cross-client labeling batch no longer serializes against the fused train
launch it feeds; with ``preempt`` an in-flight labeling launch is split at a
frame-batch boundary when a train grant needs its labels (or, serialized,
the clock) sooner — the remainder requeues behind the grant at a modeled
preemption cost, so train-phase latency no longer inherits the tail of
whoever's labeling.

Defaults reproduce PR 1 bit-for-bit: ``n_gpus=1`` means one device, nothing
to migrate to, no `gpu_free`/`rate_ctrl` events (compression and the rate
message are off until their knobs are set), and the policy's `assign`
degenerates to the old single `pick`. Eval still reads ground truth directly
(it is measurement, not traffic). Everything else — who gets which GPU, when
bytes move, how stale a delta is — is modeled.

Chaos mode (``cfg.faults``, `serving.faults.FaultPlan`) adds the failure
events: ``upload_retry`` (a lost/deferred frame batch retries with
exponential backoff + deterministic jitter, bounded by ``max_retries``),
``delta_retx`` (a lost delta retransmits ONLY if the server has not
produced a newer one — supersede semantics; the edge keeps inferring on
its stale model meanwhile), ``crash``/``recover`` (a device dies: its
residents spill, its in-flight grant is killed and the armed ``watchdog``
requeues the fused group on a survivor via the normal migration
machinery), and admission sheds load while the whole pool is down. The
default `FaultPlan.none()` arms none of it and is bit-identical.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import timing
from repro.core.batched import update_pipeline_info
from repro.core.scheduler import GPUCostModel
from repro.serving.events import EventQueue
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.fleet import FleetState
from repro.serving.obs import (PID_SERVER, TID_DOWN, MetricsRegistry,
                               drift_report)
from repro.serving.policies import (Assignment, GPURequest, SchedulingPolicy,
                                    make_policy)
from repro.serving.resources import GPUPool, MigrationModel, StreamModel
from repro.serving.session import train_many


def _phi_of(session) -> float:
    """Scene-dynamics signal for scheduling; falls back to the sampling rate
    for sessions that don't expose a φ EMA."""
    return getattr(session, "phi_signal", session.sampling_rate)


@dataclass(frozen=True)
class ServingConfig:
    duration: float = 120.0
    max_queue: int = 16  # server backlog cap per-request admission
    admission_util_cap: float | None = None  # projected per-GPU-load cap
    batch_labeling: bool = True
    sample_eps: float = 1e-6  # floor on sampling rate when scheduling
    # ---- pool knobs (n_gpus=1 + defaults == the PR-1 single-GPU engine) --
    n_gpus: int = 1
    migration: MigrationModel = field(default_factory=MigrationModel)
    residency_cap: int | None = None  # sessions resident per device (None: HBM unbounded)
    # ---- fidelity knobs (0 == unmodeled, the PR-1 behavior) --------------
    asr_ctrl_bytes: int = 0  # rate-control message on the downlink
    # ---- fused cross-session training (core.batched) ---------------------
    # max sessions per stacked train launch: a granted device also takes up
    # to fuse_train-1 ready "riders" whose staging cost is beaten by the
    # fused-stack discount, and runs the whole stack as one scan/vmap
    # executable priced by `GPUCostModel.train_batch_s`. 1 == coalescing
    # off, PR-2 bit-identical.
    fuse_train: int = 1
    # fused post-train update pipeline: a fused grant's B deltas are
    # produced by ONE stacked selection launch + ONE batched encode, priced
    # by the amortized `GPUCostModel.update_batch_s` instead of B serial
    # `update_solo_s` charges. No-op until the update path is priced
    # (select_s / delta_comp_s_per_mb), so defaults stay bit-identical;
    # False keeps the per-session pricing (the A/B lever for benchmarks).
    fuse_updates: bool = True
    # ---- dual-stream device model (resources.StreamModel) ----------------
    # label vs train stream interaction per device. The default (serialized,
    # no preemption) is the PR-3 single busy clock, bit-for-bit.
    streams: StreamModel = field(default_factory=StreamModel)
    # ---- fault injection (serving.faults) --------------------------------
    # seeded chaos schedule: link loss/outages, rate-trace replay, device
    # crash/slowdown windows. The default `FaultPlan.none()` disables every
    # hook — the engine's schedule is bit-identical to the fault-free code.
    faults: FaultPlan = field(default_factory=FaultPlan)
    # ---- device placement (resources.GPUPool) ----------------------------
    # "jax" binds every pool slot to a concrete jax.Device and fused grant
    # math runs on the granted slot's device (launch.host_mesh forces N
    # host devices on CPU); "modeled" (default) binds nothing and is
    # bit-identical to the placement-free engine.
    device_backend: str = "modeled"
    # per-client phase offsets for fleet-wide FaultPlan rate traces: each
    # client's cyclic bandwidth replay starts at a deterministic
    # client-id-hashed point in the trace period, so fleet-wide fades
    # decorrelate instead of synchronizing every uplink. False (default)
    # replays every link in phase — bit-identical to PR 9.
    trace_phase_per_client: bool = False


@dataclass
class _Segment:
    """One frame batch on a device's label stream — the preemption quantum.

    Created when a backlog's unlabeled frames are put on a stream (either
    as a grant's own labeling or as cross-client prefetch). Carries its
    scheduled completion ``bound``; requeued segments get a fresh bound in
    their new launch."""

    client: int
    idxs: list
    bound: float = 0.0  # absolute completion time in its current launch
    done: bool = False
    preempts: int = 0  # times this batch was requeued by someone else's cut


@dataclass
class _LabelLaunch:
    """One batched labeling launch charged on a device's label stream."""

    gid: int
    start: float
    end: float
    segs: list
    cut: float | None = None  # preemption boundary: segments past it requeued

    def live_at(self, t: float) -> bool:
        return self.cut is None and self.end > t


@dataclass
class _Backlog:
    """Server-side state for one queued request."""

    req: GPURequest
    idxs: list  # frame indices not yet put on a label stream
    segment: _Segment | None = None  # labeling segment, once scheduled


class ServingEngine:
    def __init__(self, sessions, policy: str | SchedulingPolicy = "fair",
                 cost: GPUCostModel | None = None,
                 cfg: ServingConfig | None = None,
                 pool: GPUPool | None = None,
                 tracer=None):
        if isinstance(sessions, FleetState):
            # fleet mode: struct-of-arrays storage; `self.sessions` is a
            # lazy sequence of per-client flyweight views, so every scalar
            # path below runs unchanged against the arrays
            self.fleet = sessions
            self.sessions = sessions.views()
        else:
            self.fleet = None
            self.sessions = list(sessions)
        self.policy = make_policy(policy)
        self.cost = cost or GPUCostModel()
        self.cfg = cfg or ServingConfig()
        self.pool = pool or GPUPool(
            n_gpus=self.cfg.n_gpus, cost=self.cost,
            migration=self.cfg.migration,
            residency_cap=self.cfg.residency_cap,
            streams=self.cfg.streams,
            device_backend=self.cfg.device_backend)
        self.q = EventQueue()
        self._queue: list[_Backlog] = []
        self._active: set[int] = set()  # clients mid-phase on some device
        self._label_sched: dict[int, list[_LabelLaunch]] = {
            d.gid: [] for d in self.pool.devices}
        self._handlers = {
            "sample": self._on_sample, "eval": self._on_eval,
            "upload": self._on_upload, "request": self._on_request,
            "gpu_done": self._on_gpu_done, "gpu_free": self._on_gpu_free,
            "label_seg": self._on_label_seg,
            "delta": self._on_delta, "rate_ctrl": self._on_rate_ctrl,
            "upload_retry": self._on_upload_retry,
            "delta_retx": self._on_delta_retx,
            "crash": self._on_crash, "recover": self._on_recover,
            "watchdog": self._on_watchdog}
        # fleet mode only: handlers for cohort events (Event.client is an
        # ndarray of client ids sharing one (time, kind))
        self._batch_handlers = {
            "sample": self._on_sample_batch, "eval": self._on_eval_batch,
            "upload": self._on_upload_batch,
            "request": self._on_request_batch}
        # vectorized policy path: one `rank` call over parallel request
        # arrays replaces the per-grant pick loop. Only sound when the
        # policy keeps the base `assign`/`place` (AffinityAware's joint
        # assignment, for one, must keep its own loop)
        self._ranked_assign = (
            self.fleet is not None
            and type(self.policy).assign is SchedulingPolicy.assign
            and type(self.policy).place is SchedulingPolicy.place
            and type(self.policy).rank is not SchedulingPolicy.rank)
        # fault injection (serving.faults). Like tracing, every hook is
        # behind the `_chaos` flag, so a fault-free plan does no extra work,
        # pushes no extra events, and keeps the schedule bit-identical
        self._chaos = self.cfg.faults.active
        self._inj = FaultInjector(self.cfg.faults) if self._chaos else None
        self._grant_gen = 0  # monotone grant ids (crash/watchdog matching)
        self._live_grants: dict[int, dict] = {}  # gen -> in-flight grant
        self._grant_on: dict[int, int] = {}  # gid -> gen of its live grant
        self._delta_seq: dict[int, int] = {}  # client -> freshest delta id
        self._last_delta_arrival: dict[int, float] = {}  # staleness telemetry
        if self._chaos:
            plan = self.cfg.faults
            # trace_phase_per_client decorrelates the fleet-wide replay:
            # each client's link starts at a deterministic id-hashed point
            # of the cyclic trace (network.RateTrace.for_client); off
            # (default) every link replays in phase, bit-identical to the
            # unphased engine (for_client(0-offset) is `is`-same object)
            phased = self.cfg.trace_phase_per_client
            for s in self.sessions:
                if plan.up_rate_trace is not None:
                    s.net.up.trace = (plan.up_rate_trace.for_client(s.idx)
                                      if phased else plan.up_rate_trace)
                if plan.down_rate_trace is not None:
                    s.net.down.trace = (plan.down_rate_trace.for_client(s.idx)
                                        if phased else plan.down_rate_trace)
        # flight recorder (serving.obs.Tracer). None = tracing off: every
        # emission site is behind an `is not None` check, so the disabled
        # engine does no extra work and its schedule is bit-identical
        self.tracer = tracer
        if tracer is not None:
            tracer.setup_engine(self.pool, self.sessions, self.cfg)
            self.pool.tracer = tracer
            for s in self.sessions:
                # a sample_clients subset leaves unsampled links untraced —
                # their transfers take the no-tracer fast path, zero spans
                if tracer.traces_client(s.idx):
                    s.net.tracer = tracer
                    s.net.client = s.idx
        self._grant_spans: dict = {}  # gid -> open device-grant span
        self._grant_seq = 0  # stable grant ids (span nesting + flows)
        # telemetry: every counter lives in the registry, and the results
        # dict is assembled from it — `obs.MetricsRegistry` is the single
        # source (same keys/values as the historical inline dict)
        m = self.metrics = MetricsRegistry()
        self.served = m.counter("phases_served")
        self.deferred = m.counter("phases_deferred")
        self.dropped_requests = m.counter("dropped_requests")
        self.label_batches = m.counter("label_batches")
        self.labels_total = m.counter("labels_total")
        self.max_backlog = m.gauge("max_backlog", 0)
        self.fused_launches = m.counter("fused_launches")  # >= 1 rider
        self.fused_sessions = m.counter("fused_sessions")
        # update-pipeline telemetry (post-train selection + delta encode)
        self.update_batched_launches = m.counter(
            "update_pipeline.batched_launches")
        self.update_batched_sessions = m.counter(
            "update_pipeline.batched_sessions")
        self.update_s_charged = m.counter(
            "update_pipeline.update_s_charged", 0.0)
        self.update_s_sequential = m.counter(
            "update_pipeline.update_s_sequential", 0.0)
        # request conservation (the chaos gate's books must balance:
        # enqueued == granted + dropped + unserved backlog, always)
        self.requests_enqueued = m.counter("requests_enqueued")
        self.requests_granted = m.counter("requests_granted")
        # wasted uplink: a tail-dropped victim's frames already crossed the
        # air — their bytes were spent for nothing (saturation telemetry)
        self.dropped_frame_bytes = m.counter("dropped_frame_bytes")
        # chaos telemetry (all zero in fault-free runs)
        self.chaos_upload_retries = m.counter("chaos.upload_retries")
        self.chaos_uploads_lost = m.counter("chaos.uploads_lost")
        self.chaos_uploads_abandoned = m.counter("chaos.uploads_abandoned")
        self.chaos_upload_bytes_wasted = m.counter(
            "chaos.upload_bytes_wasted")
        self.chaos_deltas_lost = m.counter("chaos.deltas_lost")
        self.chaos_deltas_retransmitted = m.counter(
            "chaos.deltas_retransmitted")
        self.chaos_retransmitted_bytes = m.counter(
            "chaos.retransmitted_bytes")
        self.chaos_deltas_superseded = m.counter("chaos.deltas_superseded")
        self.chaos_superseded_bytes = m.counter("chaos.superseded_bytes")
        self.chaos_deltas_abandoned = m.counter("chaos.deltas_abandoned")
        self.chaos_requests_shed = m.counter("chaos.requests_shed")
        self.chaos_grants_killed = m.counter("chaos.grants_killed")
        self.chaos_grants_recovered = m.counter("chaos.grants_recovered")
        self.chaos_sessions_recovered = m.counter(
            "chaos.sessions_recovered")
        self.chaos_watchdog_fires = m.counter("chaos.watchdog_fires")
        self.chaos_slowed_grants = m.counter("chaos.slowed_grants")

    # ---- admission control ---------------------------------------------
    def _admit_sessions(self) -> None:
        """Project each session's steady-state GPU demand against the pool's
        aggregate budget (``admission_util_cap`` per device). Instead of
        rejecting whichever sessions happen to be indexed last (the PR-1
        rule), admission is gain-aware: sessions are considered in
        descending-φ order, so when the pool is oversubscribed it is the
        lowest-φ (near-static) sessions that get *parked* — they run
        inference-only on stale weights; their accuracy decay is the
        saturation signal, not a crash."""
        if self.fleet is not None:
            self._admit_fleet()
            return
        cap = self.cfg.admission_util_cap
        budget = None if cap is None else cap * self.pool.n
        rho = []
        for s in self.sessions:
            est_frames = s.sampling_rate * s.t_update
            # project with the batched per-frame labeling rate. Slightly
            # conservative on purpose: the launch overhead amortizes across
            # co-queued sessions at service time, which can't be known here
            if self.cfg.batch_labeling:
                label_s = self.cost.label_batch_s(est_frames)
            else:
                label_s = est_frames * self.cost.teacher_infer_s
            fuse = max(self.cfg.fuse_train, 1)
            if fuse > 1:
                # project the amortized per-session share of a full fused
                # launch — the same sublinear cost the grants will pay
                train_s = self.cost.train_batch_s(fuse, s.k_iters) / fuse
            else:
                train_s = s.k_iters * self.cost.train_iter_s
            # post-train update production (selection + delta encode) runs
            # on the same train stream; priced amortized when fused grants
            # will batch it (zero while the update path is unmodeled)
            hint = getattr(s, "delta_bytes_hint", 0)
            if fuse > 1 and self.cfg.fuse_updates:
                update_s = self.cost.update_batch_s([hint] * fuse) / fuse
            else:
                update_s = self.cost.update_solo_s(hint)
            # overlap-aware projection: concurrent streams demand less than
            # the serialized sum (serialized: exactly label_s + train_s)
            demand = self.cfg.streams.stream_demand_s(label_s,
                                                      train_s + update_s)
            rho.append(demand / max(s.t_update, 1e-9))
        if budget is None:  # index order: keeps the load sum bit-identical
            order = range(len(self.sessions))
        else:
            order = sorted(range(len(self.sessions)),
                           key=lambda i: (-_phi_of(self.sessions[i]), i))
        load = 0.0
        full = False
        for i in order:
            s = self.sessions[i]
            # strict priority: once the budget refuses a session, everything
            # ranked below it is parked too — "the parked set is the lowest-φ
            # suffix" is an invariant, not a tendency (no skip-ahead where a
            # small near-static session trains while a dynamic one is parked)
            if full or (budget is not None and load + rho[i] > budget):
                s.admitted = False  # parked
                full = True
            else:
                s.admitted = True
                load += rho[i]
        self.offered_load = load

    def _admit_fleet(self) -> None:
        """`_admit_sessions` over the fleet arrays. Demand is priced once
        per *unique* (rate, T_update, K, delta-hint) row using the same
        scalar cost-model calls the per-object loop makes — bit-identical
        by construction, no float-formula mirroring — then scattered back.
        Parking is one stable argsort by (-φ, idx) plus a cumsum: the
        parked set is a *suffix* of a total strict-priority order and the
        load sum is sequential, so an argpartition (no total order, pairwise
        sums) could not reproduce the per-object books."""
        f, cfg = self.fleet, self.cfg
        cap = cfg.admission_util_cap
        budget = None if cap is None else cap * self.pool.n
        cols = np.column_stack([f.sampling_rate, f.t_update,
                                f.k_iters.astype(np.float64),
                                f.delta_bytes.astype(np.float64)])
        rows, inv = np.unique(cols, axis=0, return_inverse=True)
        fuse = max(cfg.fuse_train, 1)
        rho_u = np.empty(len(rows))
        for j, (s_rate, t_upd, k, hint) in enumerate(rows):
            k, hint = int(k), int(hint)
            est_frames = s_rate * t_upd
            if cfg.batch_labeling:
                label_s = self.cost.label_batch_s(est_frames)
            else:
                label_s = est_frames * self.cost.teacher_infer_s
            if fuse > 1:
                train_s = self.cost.train_batch_s(fuse, k) / fuse
            else:
                train_s = k * self.cost.train_iter_s
            if fuse > 1 and cfg.fuse_updates:
                update_s = self.cost.update_batch_s([hint] * fuse) / fuse
            else:
                update_s = self.cost.update_solo_s(hint)
            demand = cfg.streams.stream_demand_s(label_s,
                                                 train_s + update_s)
            rho_u[j] = demand / max(t_upd, 1e-9)
        rho = rho_u[inv]
        if budget is None:
            f.admitted[:] = True
            # cumsum is a sequential scan — same IEEE addition order as the
            # per-object `load += rho[i]` loop (np.sum's pairwise tree isn't)
            self.offered_load = float(np.cumsum(rho)[-1]) if len(rho) else 0.0
            return
        order = np.argsort(-f.phi, kind="stable")  # (-φ, idx) ascending
        csum = np.cumsum(rho[order])
        over = csum > budget
        first_bad = int(np.argmax(over)) if over.any() else len(order)
        adm = np.zeros(f.n, dtype=bool)
        adm[order[:first_bad]] = True
        f.admitted[:] = adm
        self.offered_load = float(csum[first_bad - 1]) if first_bad else 0.0

    # ---- event handlers ------------------------------------------------
    def _on_sample(self, ev) -> None:
        s = self.sessions[ev.client]
        s.capture(ev.time)
        nxt = ev.time + 1.0 / max(s.edge_sampling_rate, self.cfg.sample_eps)
        if nxt < self.cfg.duration:
            self.q.push(nxt, "sample", ev.client)

    def _on_eval(self, ev) -> None:
        s = self.sessions[ev.client]
        s.evaluate(ev.time)
        nxt = ev.time + s.eval_interval_s
        if nxt < self.cfg.duration:
            self.q.push(nxt, "eval", ev.client)

    def _on_upload(self, ev) -> None:
        self._upload_one(ev.time, ev.client)

    def _upload_one(self, t: float, client: int) -> None:
        s = self.sessions[client]
        idxs = s.take_outbox()
        nbytes = s.upload_bytes(len(idxs))
        if self._chaos:
            self._try_upload(t, client, idxs, nbytes, 0)
        else:
            arrival = s.net.send_up(t, nbytes)
            self.q.push(arrival, "request", client, (idxs, nbytes))
        nxt = t + s.t_update
        if nxt < self.cfg.duration:
            self.q.push(nxt, "upload", client)

    def _try_upload(self, t: float, client: int, idxs, nbytes: int,
                    attempt: int) -> None:
        """Chaos uplink path: an outage defers the send (no link occupancy),
        a lost transfer burns the link and retries with exponential backoff
        + deterministic jitter; past ``max_retries`` the batch is abandoned
        (the edge keeps sampling — degradation, not a stall)."""
        inj, plan = self._inj, self.cfg.faults
        s = self.sessions[client]
        if inj.outage_until("up", client, t) is not None:
            if attempt >= plan.max_retries:
                self.chaos_uploads_abandoned.inc()
                self.dropped_frame_bytes.inc(nbytes)
                return
            self.chaos_upload_retries.inc()
            retry_t = (t + plan.detect_timeout_s
                       + inj.backoff_s(client, attempt))
            self.q.push(retry_t, "upload_retry", client,
                        (idxs, nbytes, attempt + 1))
            return
        what = "frames" if attempt == 0 else "retry"
        arrival = s.net.send_up(t, nbytes, what=what)
        if inj.transfer_lost("up", client):
            # the bytes crossed the air and vanished: wasted uplink
            self.chaos_uploads_lost.inc()
            self.chaos_upload_bytes_wasted.inc(nbytes)
            if attempt >= plan.max_retries:
                self.chaos_uploads_abandoned.inc()
                self.dropped_frame_bytes.inc(nbytes)
                return
            self.chaos_upload_retries.inc()
            retry_t = (arrival + plan.detect_timeout_s
                       + inj.backoff_s(client, attempt))
            self.q.push(retry_t, "upload_retry", client,
                        (idxs, nbytes, attempt + 1))
            return
        self.q.push(arrival, "request", client, (idxs, nbytes))

    def _on_upload_retry(self, ev) -> None:
        idxs, nbytes, attempt = ev.payload
        self._try_upload(ev.time, ev.client, idxs, nbytes, attempt)

    def _on_request(self, ev) -> None:
        s = self.sessions[ev.client]
        idxs, nbytes = ev.payload
        req = GPURequest(client=ev.client, t_request=ev.time,
                         n_frames=len(idxs), k_iters=s.k_iters,
                         deadline=ev.time + s.t_update,
                         phi=_phi_of(s), t_update=s.t_update,
                         state_bytes=getattr(s, "state_bytes", 0),
                         upload_nbytes=int(nbytes))
        self._enqueue(ev.time, req, list(idxs))

    # ---- fleet cohort handlers ------------------------------------------
    # A cohort event carries an ndarray of client ids sharing one (time,
    # kind); the handlers update whole array slices and re-group the
    # follow-on events into cohorts by their (identical-within-group) next
    # timestamps. Every expression mirrors its scalar twin operand-for-
    # operand, and every cohort is pushed in ascending client order, so the
    # (time, seq) pop sequence — and therefore the schedule — is the one
    # the per-object engine produces.
    def _push_cohorts(self, times: np.ndarray, kind: str,
                      clients: np.ndarray, payload_arrays=None) -> None:
        """Push per-client events grouped into cohorts of equal timestamp,
        ascending in time (matching the seq order a scalar loop over the
        same clients would assign)."""
        if not len(times):
            return
        order = np.argsort(times, kind="stable")
        st = times[order]
        cuts = np.flatnonzero(st[1:] != st[:-1]) + 1
        for grp in np.split(order, cuts):
            payload = (None if payload_arrays is None
                       else tuple(a[grp] for a in payload_arrays))
            self.q.push(float(times[grp[0]]), kind, clients[grp], payload)

    def _on_sample_batch(self, t: float, clients: np.ndarray,
                         payload=None) -> None:
        f = self.fleet
        f.outbox_depth[clients] += 1
        nxt = t + 1.0 / np.maximum(f.effective_rate(clients),
                                   self.cfg.sample_eps)
        live = nxt < self.cfg.duration
        if live.any():
            self._push_cohorts(nxt[live], "sample", clients[live])

    def _on_eval_batch(self, t: float, clients: np.ndarray,
                       payload=None) -> None:
        f = self.fleet
        vals = np.maximum(0.2, 0.9 - f.dynamics[clients]
                          * (t - f.last_update_t[clients]))
        f.record_mious(clients, vals)
        nxt = t + f.eval_interval_s[clients]
        live = nxt < self.cfg.duration
        if live.any():
            self._push_cohorts(nxt[live], "eval", clients[live])

    def _on_upload_batch(self, t: float, clients: np.ndarray,
                         payload=None) -> None:
        f = self.fleet
        if self._chaos or self.tracer is not None or f.any_link_traces:
            # chaos retries and trace spans interleave per-client pushes
            # whose seq assignment the cohort math can't reproduce — take
            # the exact scalar lane instead (same code as per-object)
            for c in clients.tolist():
                self._upload_one(t, c)
            return
        depth = f.outbox_depth[clients].copy()
        f.outbox_depth[clients] = 0
        nbytes = 256 + depth * f.frame_bytes[clients]
        f.up_bytes[clients] += nbytes  # ledger + Link.bytes_carried in one
        f.up_transfers[clients] += 1
        start = np.maximum(t, f.up_busy[clients])
        rate = f.up_kbps[clients]
        tx = np.divide(nbytes * 8.0, rate * 1e3,
                       out=np.zeros(len(clients)), where=rate > 0)
        busy = start + tx
        f.up_busy[clients] = busy
        self._push_cohorts(busy + f.prop_delay_s[clients], "request",
                           clients, (depth, nbytes))
        nxt = t + f.t_update[clients]
        live = nxt < self.cfg.duration
        if live.any():
            self._push_cohorts(nxt[live], "upload", clients[live])

    def _on_request_batch(self, t: float, clients: np.ndarray,
                          payload) -> None:
        depths, nbytes = payload
        cl = clients.tolist()
        dp = depths.tolist()
        nb = nbytes.tolist()
        # bulk tail-drop: with the base (tail-drop) evict rule, no tracer
        # and no chaos, a full queue whose worst entry still precedes
        # (t, next client) makes every remaining cohort member its own
        # victim — account them all at once instead of building a
        # GPURequest each just to drop it. (The per-object path's
        # `_refresh_phi` before evict is a no-op for stub fleets: φ is a
        # configured constant, never an EMA.)
        fast_drop = (self.tracer is None and not self._chaos
                     and type(self.policy).evict is SchedulingPolicy.evict)
        n = len(cl)
        for i in range(n):
            if fast_drop and len(self._queue) >= self.cfg.max_queue:
                worst = max((b.req.t_request, b.req.client)
                            for b in self._queue)
                if worst < (t, cl[i]):
                    k = n - i
                    self.requests_enqueued.inc(k)
                    if not self.pool.has_free():
                        self.deferred.inc(k)
                    self.dropped_requests.inc(k)
                    self.dropped_frame_bytes.inc(int(sum(nb[i:])))
                    return
            c = cl[i]
            s = self.sessions[c]
            req = GPURequest(client=c, t_request=t, n_frames=dp[i],
                             k_iters=s.k_iters, deadline=t + s.t_update,
                             phi=_phi_of(s), t_update=s.t_update,
                             state_bytes=getattr(s, "state_bytes", 0),
                             upload_nbytes=int(nb[i]))
            self._enqueue(t, req, [0] * dp[i])

    def _enqueue(self, t: float, req: GPURequest, idxs: list) -> None:
        """Admission for a server-side request — fresh arrivals and
        watchdog-recovered requeues both land here, so the conservation
        books (enqueued == granted + dropped + backlog) balance by
        construction."""
        self.requests_enqueued.inc()
        if self._chaos and self.pool.n_alive() == 0:
            # the whole pool is down: shed at admission instead of queueing
            # unboundedly behind devices that cannot drain the backlog
            self.chaos_requests_shed.inc()
            self.dropped_requests.inc()
            self.dropped_frame_bytes.inc(req.upload_nbytes)
            return
        if not self.pool.has_free():
            self.deferred.inc()
        if len(self._queue) >= self.cfg.max_queue:
            # saturated: the policy chooses the sacrifice (tail drop by
            # default; gain-aware evicts the lowest-value queued request)
            self._refresh_phi()
            victim = self.policy.evict(t, [b.req for b in self._queue] + [req])
            self.dropped_requests.inc()  # the victim's frames are lost
            self.dropped_frame_bytes.inc(victim.upload_nbytes)
            if victim is req:
                return
            self._queue.remove(next(b for b in self._queue if b.req is victim))
        self._queue.append(_Backlog(req=req, idxs=idxs))
        self.max_backlog.set_max(len(self._queue))
        if self.tracer is not None:
            self._trace_queue(t)
        self._maybe_start(t)

    def _maybe_start(self, t: float) -> None:
        # no new grants past the horizon: the backlog is left unserved (and
        # reported) rather than drained in overtime, which would overstate
        # both utilization and served-phase counts
        if not self._queue or t >= self.cfg.duration:
            return
        free = self.pool.free_ids()
        if not free:
            return
        self._refresh_phi()
        # one candidate per *idle* client: a session's training state is
        # singular, so a client mid-phase on some device is ineligible (two
        # devices cannot train the same weights concurrently), and only its
        # oldest queued request competes — every policy's ranking already
        # reduces same-client duplicates to the oldest one
        ready: dict[int, GPURequest] = {}
        for b in self._queue:
            c = b.req.client
            if c in self._active:
                continue
            if c not in ready or b.req.t_request < ready[c].t_request:
                ready[c] = b.req
        if not ready:
            return
        if self._ranked_assign:
            assignments = self._assign_ranked(t, list(ready.values()), free)
        else:
            assignments = self.policy.assign(
                t, list(ready.values()), free, self.pool)
        taken = [a.req for a in assignments]
        for a in assignments:
            riders = []
            if self.cfg.fuse_train > 1:
                # fill the stacked launch: ready requests not claimed this
                # round that are free (or cheap enough — see the cost-aware
                # `coalesce`) to train on the granted device
                leftover = [r for r in ready.values()
                            if not any(r is x for x in taken)]
                riders = self.policy.coalesce(t, a, leftover, self.pool,
                                              self.cfg.fuse_train)
                taken.extend(riders)
            backlog = next(b for b in self._queue if b.req is a.req)
            self._queue.remove(backlog)
            rider_backlogs = []
            for r in riders:
                rb = next(b for b in self._queue if b.req is r)
                self._queue.remove(rb)
                rider_backlogs.append(rb)
            self.requests_granted.inc(1 + len(rider_backlogs))
            self._start_service(t, backlog, a.gpu, rider_backlogs)
        if self.tracer is not None:
            self._trace_queue(t)

    def _assign_ranked(self, t, reqs, free):
        """Vectorized policy path: one `rank` call over parallel request
        arrays replaces the pick-loop, devices are handed out in ascending
        id order — exactly what base `place` (min of a shrinking free list)
        does. Stateful policies (fair's turn pointer) advance as if the
        taken prefix had been picked one by one."""
        k = len(reqs)
        clients = np.fromiter((r.client for r in reqs), np.int64, k)
        t_req = np.fromiter((r.t_request for r in reqs), np.float64, k)
        deadline = np.fromiter((r.deadline for r in reqs), np.float64, k)
        phi = np.fromiter((r.phi for r in reqs), np.float64, k)
        t_upd = np.fromiter((r.t_update for r in reqs), np.float64, k)
        free_sorted = sorted(free)
        order = self.policy.rank(t, clients=clients, t_request=t_req,
                                 deadline=deadline, phi=phi, t_update=t_upd,
                                 limit=len(free_sorted))
        return [Assignment(req=reqs[int(j)], gpu=g)
                for j, g in zip(order, free_sorted)]

    def _trace_queue(self, t: float) -> None:
        """Server-process counter tracks: the ready queue in requests and in
        unlabeled frames (the labeling backlog a grant would clear)."""
        tr = self.tracer
        tr.counter(PID_SERVER, "queue_depth", t,
                   {"requests": len(self._queue)})
        tr.counter(PID_SERVER, "backlog_frames", t,
                   {"frames": sum(len(b.idxs) for b in self._queue)})

    def _refresh_phi(self) -> None:
        # a request's φ is snapshotted at arrival; batched labeling can move
        # the session's φ EMA while it queues, so re-read before any policy
        # decision — otherwise a feed that just turned dynamic is ranked
        # (and evicted) by its stale near-static score
        for b in self._queue:
            b.req.phi = _phi_of(self.sessions[b.req.client])

    def _rider_migration_s(self, gid: int, riders: list[_Backlog]) -> list[float]:
        return [self.pool.migration_s(b.req.client, gid, b.req.state_bytes)
                for b in riders]

    def _start_service(self, t: float, backlog: _Backlog, gid: int,
                       riders: list[_Backlog] | None = None) -> None:
        if not self.cfg.streams.legacy:
            self._start_service_streams(t, backlog, gid, riders or [])
            return
        dev = self.pool.device(gid)
        riders = riders or []
        # injected device slowdown (thermal throttle / noisy neighbor):
        # compute stretches, data movement (migration) does not
        slow = (self._inj.slowdown_factor(gid, t) if self._chaos else 1.0)
        if slow > 1.0:
            self.chaos_slowed_grants.inc()
        # cross-client batched labeling: one launch on the granted device
        # clears every still-queued session's unlabeled frames, not just the
        # picked one (a co-granted device then finds its backlog pre-labeled)
        if self.cfg.batch_labeling:
            to_label = [backlog, *riders] + [b for b in self._queue if b.idxs]
        else:
            to_label = [backlog, *riders]
        n_label = sum(len(b.idxs) for b in to_label)
        label_s = dev.cost.label_batch_s(n_label) * slow
        if n_label:
            self.label_batches.inc()
            self.labels_total.inc(n_label)
        # staging a non-resident session's state runs on this device's clock
        # *before* the labeling launch, so labels land at t + mig_s + label_s;
        # a cost-aware rider's staging runs after (labels don't need it)
        mig_s = self.pool.migration_s(backlog.req.client, gid,
                                      backlog.req.state_bytes)
        rider_migs = self._rider_migration_s(gid, riders)
        t_labeled = t + mig_s + label_s
        for b in to_label:
            self.sessions[b.req.client].label_and_ingest(b.idxs, t_labeled)
            b.idxs = []
        n_sessions = 1 + len(riders)
        train_s = dev.cost.train_batch_s(n_sessions, backlog.req.k_iters) * slow
        dur = mig_s + label_s + sum(rider_migs) + train_s
        self.pool.grant(gid, backlog.req.client, t, dur, self.cfg.duration,
                        mig_s, label_s)
        tr = self.tracer
        if tr is not None:
            # legacy single-clock path: the pool keeps no per-charge
            # schedule, so the engine emits the component spans itself
            # (they tile [t, t+dur] in the order the clock charges them)
            self._grant_seq += 1
            self._grant_spans[gid] = tr.grant_span(
                gid, "grant", t, {"seq": self._grant_seq,
                                  "client": backlog.req.client,
                                  "riders": len(riders)})
            sub = {"grant": self._grant_seq}
            if mig_s > 0.0:
                tr.gpu_span(gid, "train", "migrate", t, t + mig_s, dict(sub))
            if n_label:
                tr.gpu_span(gid, "label", "label_batch", t + mig_s,
                            t_labeled, dict(sub, frames=n_label))
            rmig = sum(rider_migs)
            if rmig > 0.0:
                tr.gpu_span(gid, "train", "migrate_riders", t_labeled,
                            t_labeled + rmig, dict(sub))
            tr.gpu_span(gid, "train", "train", t + dur - train_s, t + dur,
                        dict(sub, b=n_sessions, k=backlog.req.k_iters))
        for b in [backlog, *riders]:
            b.req.gpu = gid
            self._active.add(b.req.client)
        for b, r_mig in zip(riders, rider_migs):
            self.pool.attach(gid, b.req.client, t, mig_s=r_mig)
        if riders:
            self.fused_launches.inc()
            self.fused_sessions.inc(n_sessions)
        gen = self._note_grant(gid, [backlog, *riders], t + dur)
        self.q.push(t + dur, "gpu_done", backlog.req.client,
                    (gid, tuple(b.req.client for b in riders), gen))

    def _note_grant(self, gid: int, members: list, done_t: float) -> int:
        """Register a grant generation. Under chaos the grant is tracked as
        in-flight and a watchdog is armed past its planned completion: if
        the device dies mid-grant, ``gpu_done`` never lands and the watchdog
        is what detects the straggler and requeues the fused group."""
        self._grant_gen += 1
        gen = self._grant_gen
        if self._chaos:
            self._live_grants[gen] = {
                "gid": gid, "done_t": done_t, "dead": False,
                "clients": [b.req.client for b in members]}
            self._grant_on[gid] = gen
            self.q.push(done_t + self.cfg.faults.watchdog_s, "watchdog",
                        members[0].req.client, gen)
        return gen

    # ---- dual-stream service path --------------------------------------
    def _take_segment(self, b: _Backlog) -> _Segment:
        seg = _Segment(client=b.req.client, idxs=b.idxs)
        b.idxs = []
        b.segment = seg
        return seg

    def _charge_label_launch(self, gid: int, t: float, segs: list[_Segment],
                             scale: float = 1.0) -> _LabelLaunch | None:
        """One batched labeling launch for ``segs`` on ``gid``'s label
        stream; each segment completes at a frame-batch boundary and gets
        its own `label_seg` event (the preemption quanta). ``scale`` > 1 is
        an injected device slowdown stretching the whole launch."""
        segs = [s for s in segs if s.idxs]
        if not segs:
            return None
        cost = self.pool.device(gid).cost
        rate = cost.teacher_infer_s * cost.label_batch_discount * scale
        cum, work = [], cost.label_batch_overhead_s * scale
        for s in segs:
            work += len(s.idxs) * rate
            cum.append(work)
        args = None
        if self.pool.tracer is not None:
            args = {"frames": sum(len(s.idxs) for s in segs),
                    "segments": len(segs)}
        start, bounds = self.pool.label_bounds(gid, t, cum,
                                               name="label_batch", args=args)
        launch = _LabelLaunch(gid=gid, start=start, end=bounds[-1], segs=segs)
        for s, b in zip(segs, bounds):
            s.bound = b
            s.done = False
            self.q.push(b, "label_seg", s.client, (launch, s))
        self._label_sched[gid].append(launch)
        self.label_batches.inc()
        return launch

    def _preempt_labels(self, gid: int, t: float,
                        member_segs: list[_Segment]) -> list[_Segment]:
        """Split/cancel in-flight labeling on ``gid`` so a grant's own
        labeling (and train phase) need not wait for the tail of whoever's
        labeling. Launches that have not started are cancelled outright
        (free reordering); the one mid-flight is cut at the next frame-batch
        boundary when that beats waiting for its natural end, paying the
        model's preemption cost. Returns the requeued segments, member
        segments first, in their original order."""
        requeued: list[_Segment] = []
        members = {id(s) for s in member_segs}
        max_preempts = self.cfg.streams.max_seg_preempts

        def feeds_active_phase(segs):
            # a mid-phase client's train charge was placed against these
            # bounds — requeueing them would slip labels past the phase
            # that consumes them (the preemptor's own members are not yet
            # active, so they requeue freely)
            return any(not s.done and id(s) not in members
                       and s.client in self._active for s in segs)

        def has_aged_out(segs):
            # priority aging: a frame batch already requeued max_seg_preempts
            # times is uncuttable — its labels cannot be pushed back again,
            # so repeated preemption can't grow one victim's label staleness
            # without bound (the preemptor's own members requeue into the
            # grant's OWN launch, which moves them earlier, so they never age)
            return any(not s.done and id(s) not in members
                       and s.preempts >= max_preempts for s in segs)

        def note_requeue(segs):
            for s in segs:
                if id(s) not in members:
                    s.preempts += 1

        live = [l for l in self._label_sched[gid] if l.live_at(t)]
        # latest charge first: `truncate_label` edits the label stream's
        # tail, so once any launch is KEPT nothing earlier may be touched
        # (and cutting behind a kept launch would free no stream time)
        for launch in reversed(live):
            if launch.start >= t:  # never started: cancel, requeue all
                if feeds_active_phase(launch.segs) or has_aged_out(launch.segs):
                    break
                launch.cut = launch.start
                self.pool.truncate_label(gid, launch.start,
                                         preempted_frames=0, cancel=True)
                self.label_batches.inc(-1)  # never ran; its relaunch recounts
                note_requeue(launch.segs)
                requeued[:0] = launch.segs
                continue
            cut = min((s.bound for s in launch.segs if s.bound > t),
                      default=launch.end)
            tail = [s for s in launch.segs if s.bound > cut]
            if feeds_active_phase(tail) or has_aged_out(tail):
                break
            # a cut buys (end - cut) of label-stream headroom for the
            # grant, but the requeued tail re-pays the launch overhead and
            # the stream eats the preemption charge: only split when the
            # reclaimed tail strictly exceeds that disruption, else the
            # device thrashes at saturation (preempting pure overhead)
            disruption = (self.pool.streams.preempt_cost_s
                          + self.pool.device(gid).cost.label_batch_overhead_s)
            if not tail or launch.end - cut <= disruption:
                break
            launch.cut = cut
            launch.end = cut
            self.pool.truncate_label(
                gid, cut,
                preempted_frames=sum(len(s.idxs) for s in tail))
            note_requeue(tail)
            requeued[:0] = tail
        requeued.sort(key=lambda s: 0 if id(s) in members else 1)
        return requeued

    def _start_service_streams(self, t: float, backlog: _Backlog, gid: int,
                               riders: list[_Backlog]) -> None:
        """The dual-stream grant: migration and the training phase are
        charged to the device's *train* stream, labeling launches to its
        *label* stream, and the train charge waits only for the labels the
        stack itself consumes — cross-client prefetch labeling runs behind
        it (concurrently, under an ``overlap`` model). Everything is placed
        at grant time (boundaries are deterministic), so preemption is a
        schedule edit, not a rollback."""
        members = [backlog, *riders]
        slow = (self._inj.slowdown_factor(gid, t) if self._chaos else 1.0)
        if slow > 1.0:
            self.chaos_slowed_grants.inc()
        tr = self.tracer
        sub = None
        if tr is not None:
            self._grant_seq += 1
            self._grant_spans[gid] = tr.grant_span(
                gid, "grant", t, {"seq": self._grant_seq,
                                  "client": backlog.req.client,
                                  "riders": len(riders)})
            sub = {"grant": self._grant_seq}
        self._label_sched[gid] = [l for l in self._label_sched[gid]
                                  if l.live_at(t)]  # prune history
        # --- labeling: what the stack needs vs what can prefetch ---------
        # the preemption decision comes FIRST: every train-stream charge
        # below (migration included) is placed against the label stream's
        # post-cut schedule, so a serialized grant doesn't pay a preemption
        # that its own staging would have swallowed anyway
        waiting = [b.segment for b in members
                   if b.segment is not None and not b.segment.done]
        # preempting this device's label stream only helps when the grant
        # would otherwise queue behind it: fresh frames to label, a
        # member's segment sitting in one of its live launches — or, under
        # a SERIALIZED model, any live launch at all (it holds the one
        # clock the migration/train charges need)
        live = [l for l in self._label_sched[gid] if l.live_at(t)]
        member_here = any(any(s is w for w in waiting)
                          for l in live for s in l.segs)
        if self.cfg.streams.preempt and live and (
                member_here or not self.cfg.streams.overlapped
                or any(b.idxs for b in members)):
            requeued = self._preempt_labels(gid, t, waiting)
        else:
            requeued = []
        # --- staging: primary + cost-aware riders on the train stream ---
        mig_s = self.pool.migration_s(backlog.req.client, gid,
                                      backlog.req.state_bytes)
        rider_migs = self._rider_migration_s(gid, riders)
        total_mig = mig_s + sum(rider_migs)
        if total_mig > 0.0:
            _, mig_end = self.pool.charge(gid, "train", t, total_mig,
                                          name="migrate", args=sub)
        else:
            mig_end = t
        own = ([s for s in requeued if any(s is b.segment for b in members)]
               + [self._take_segment(b) for b in members if b.idxs])
        self._charge_label_launch(gid, t, own, scale=slow)
        waiting = [b.segment for b in members
                   if b.segment is not None and not b.segment.done]
        t_labeled = max([t] + [s.bound for s in waiting])
        # --- the training phase itself -----------------------------------
        n_sessions = len(members)
        train_s = self.pool.device(gid).cost.train_batch_s(
            n_sessions, backlog.req.k_iters) * slow
        _, done_t = self.pool.charge(
            gid, "train", max(mig_end, t_labeled), train_s, name="train",
            args=None if sub is None else dict(sub, b=n_sessions,
                                               k=backlog.req.k_iters))
        # --- background prefetch: requeued non-member + still-queued -----
        bg = [s for s in requeued if not any(s is b.segment for b in members)]
        if self.cfg.batch_labeling:
            bg += [self._take_segment(b) for b in self._queue if b.idxs]
        self._charge_label_launch(gid, t, bg, scale=slow)
        # --- bookkeeping (same shape as the legacy path) ------------------
        self.pool.grant_streams(gid, backlog.req.client, t)
        self.pool.note_migration(mig_s)
        for b in [backlog, *riders]:
            b.req.gpu = gid
            self._active.add(b.req.client)
        for b, r_mig in zip(riders, rider_migs):
            self.pool.attach(gid, b.req.client, t, mig_s=r_mig)
        if riders:
            self.fused_launches.inc()
            self.fused_sessions.inc(n_sessions)
        gen = self._note_grant(gid, members, done_t)
        self.q.push(done_t, "gpu_done", backlog.req.client,
                    (gid, tuple(b.req.client for b in riders), gen))

    def _on_label_seg(self, ev) -> None:
        launch, seg = ev.payload
        if launch.cut is not None and seg.bound > launch.cut:
            return  # requeued by a preemption; a fresh event exists
        if seg.done:
            return
        seg.done = True
        self.labels_total.inc(len(seg.idxs))
        self.sessions[seg.client].label_and_ingest(seg.idxs, ev.time)

    def _on_gpu_done(self, ev) -> None:
        gid, rider_clients, gen = ev.payload
        if self._chaos:
            info = self._live_grants.get(gen)
            if info is None or info["dead"]:
                # the device died mid-grant: this completion never happened.
                # The armed watchdog is the detector — it requeues the fused
                # group and releases the device
                return
            del self._live_grants[gen]
            self._grant_on.pop(gid, None)
        clients = [ev.client, *rider_clients]
        for c in clients:
            self._active.discard(c)
        if len(clients) == 1:
            deltas = [self.sessions[ev.client].train(ev.time)]
        else:
            # the stacked launch just finished: run the actual fused math —
            # on the granted pool slot's own jax device when the pool binds
            # one (device_backend="jax"); None places nothing (bit-identical)
            deltas = train_many([self.sessions[c] for c in clients], ev.time,
                                device=self.pool.device(gid).jax_device)
        self.served.inc(len(clients))
        legacy = self.cfg.streams.legacy
        cost = self.pool.device(gid).cost
        t_free = ev.time
        tr = self.tracer
        gspan = self._grant_spans.pop(gid, None)
        sub = None if gspan is None else {"grant": gspan.args["seq"]}

        def charge_update(upd_s: float) -> tuple[float, float]:
            nonlocal t_free
            if upd_s <= 0.0:
                return (t_free, t_free)
            if legacy:
                start = t_free
                self.pool.extend_busy(gid, t_free, upd_s, self.cfg.duration)
                t_free = t_free + upd_s
                return (start, t_free)
            start, t_free = self.pool.charge(gid, "train", t_free, upd_s)
            return (start, t_free)

        def trace_update(u0: float, u1: float, sel_s: float, enc_s: float,
                         b: int) -> None:
            # split the charged update seconds into modeled selection vs
            # encode shares. Fused grants emit the pair even when the
            # pipeline is unpriced (zero-duration), so the trace always
            # shows train -> select -> encode nested in the device grant
            total = sel_s + enc_s
            frac = sel_s / total if total > 0.0 else 0.5
            mid = u0 + (u1 - u0) * frac
            tr.gpu_span(gid, "train", "select", u0, mid, dict(sub, b=b))
            tr.gpu_span(gid, "train", "encode", mid, u1, dict(sub, b=b))

        # price the post-train update pipeline: a fused grant's selections
        # and delta encodes ran as ONE stacked launch + ONE batched
        # device->host encode (`core.batched`), so the device is charged the
        # amortized `update_batch_s` once and every delta ships after it —
        # not B serial select/compress round-trips
        sent_bytes = [d.total_bytes for d in deltas if d is not None]
        batched_update = self.cfg.fuse_updates and len(sent_bytes) > 1
        if batched_update:
            upd_s = cost.update_batch_s(sent_bytes)
            if upd_s > 0.0:
                # counters track *priced* amortization only — an unpriced
                # pipeline charges nothing, so it reports nothing here
                # (structural batching still shows in the stacked_* counts)
                self.update_batched_launches.inc()
                self.update_batched_sessions.inc(len(sent_bytes))
                self.update_s_charged.inc(upd_s)
                self.update_s_sequential.inc(sum(cost.update_solo_s(b)
                                                 for b in sent_bytes))
            u0, u1 = charge_update(upd_s)
            if sub is not None:
                trace_update(u0, u1, cost.select_s * len(sent_bytes),
                             sum(cost.delta_comp_s(b) for b in sent_bytes),
                             len(sent_bytes))
        for c, delta in zip(clients, deltas):
            s = self.sessions[c]
            if delta is not None:
                # a real phase ran here (no-op grants don't record one);
                # training phases always execute on the train stream
                s.note_device(gid, "train")
                if not batched_update:
                    upd_s = cost.update_solo_s(delta.total_bytes)
                    self.update_s_charged.inc(upd_s)
                    self.update_s_sequential.inc(upd_s)
                    u0, u1 = charge_update(upd_s)
                    if sub is not None and upd_s > 0.0:
                        trace_update(u0, u1, cost.select_s,
                                     cost.delta_comp_s(delta.total_bytes), 1)
                if self._chaos:
                    # freshest-delta bookkeeping: any older in-flight retry
                    # for this client is now stale and will supersede
                    self._delta_seq[c] = self._delta_seq.get(c, 0) + 1
                    self._send_delta(t_free, c, delta, t_free,
                                     self._delta_seq[c], 0, gspan)
                else:
                    arrival = s.net.send_down(t_free, delta.total_bytes)
                    if gspan is not None and s.net.last_span is not None:
                        tr.flow(gspan, s.net.last_span)
                    self.q.push(arrival, "delta", c, (delta, t_free))
            if self.cfg.asr_ctrl_bytes > 0:
                # the ASR's new rate rides the downlink too (PR-1 modeled it
                # as free); the edge samples at its old rate until it lands
                arrival = s.net.send_ctrl(t_free, self.cfg.asr_ctrl_bytes)
                self.q.push(arrival, "rate_ctrl", c, float(s.sampling_rate))
        if gspan is not None:
            # close the grant at its last charged second BEFORE any regrant
            # of this device can open the next one
            gspan.end = t_free
            d = self.pool.device(gid)
            horizon = max(ev.time, 1e-9)
            tr.counter(tr.gpu_pid(gid), "stream_util", ev.time, {
                "label": d.stream_busy_s("label", horizon) / horizon,
                "train": d.stream_busy_s("train", horizon) / horizon})
        if t_free > ev.time:
            self.q.push(t_free, "gpu_free", ev.client, gid)
        else:
            self.pool.release(gid)
        # schedule even while this device compresses: the finished clients
        # are eligible again and other devices may be idle
        self._maybe_start(ev.time)

    def _on_gpu_free(self, ev) -> None:
        self.pool.release(ev.payload)
        self._maybe_start(ev.time)

    # ---- chaos: lossy downlink with supersede semantics -----------------
    def _send_delta(self, t: float, c: int, delta, t_produced: float,
                    seq: int, attempt: int, gspan=None) -> None:
        """Ship a delta over a lossy downlink. An outage defers the send, a
        lost transfer schedules a retransmit after backoff — but a retx is
        *supersede-checked* first (`_on_delta_retx`): if the server has
        produced a newer delta by then, the stale one is never resent
        (retransmitting old weights wastes the paper's precious downlink).
        The arrival event carries the ORIGINAL production time, so delta
        latency honestly reflects retry-induced staleness."""
        inj, plan = self._inj, self.cfg.faults
        s = self.sessions[c]
        if inj.outage_until("down", c, t) is not None:
            if attempt >= plan.max_retries:
                self.chaos_deltas_abandoned.inc()
                return
            retry_t = t + plan.detect_timeout_s + inj.backoff_s(c, attempt)
            self.q.push(retry_t, "delta_retx", c,
                        (delta, t_produced, seq, attempt + 1))
            return
        nbytes = delta.total_bytes
        if attempt > 0:
            self.chaos_deltas_retransmitted.inc()
            self.chaos_retransmitted_bytes.inc(nbytes)
        arrival = s.net.send_down(t, nbytes,
                                  what="delta" if attempt == 0 else "retry")
        if gspan is not None and s.net.last_span is not None:
            self.tracer.flow(gspan, s.net.last_span)
        if inj.transfer_lost("down", c):
            self.chaos_deltas_lost.inc()
            if attempt >= plan.max_retries:
                self.chaos_deltas_abandoned.inc()
                return
            retry_t = (arrival + plan.detect_timeout_s
                       + inj.backoff_s(c, attempt))
            self.q.push(retry_t, "delta_retx", c,
                        (delta, t_produced, seq, attempt + 1))
            return
        self.q.push(arrival, "delta", c, (delta, t_produced))

    def _on_delta_retx(self, ev) -> None:
        delta, t_produced, seq, attempt = ev.payload
        c = ev.client
        if self._delta_seq.get(c, 0) != seq:
            # a fresher delta exists (shipped or shipping): drop this one
            self.chaos_deltas_superseded.inc()
            self.chaos_superseded_bytes.inc(delta.total_bytes)
            if self.tracer is not None and self.tracer.traces_client(c):
                self.tracer.instant(self.tracer.client_pid(c), TID_DOWN,
                                    "supersede", ev.time,
                                    {"bytes": int(delta.total_bytes)})
            return
        self._send_delta(ev.time, c, delta, t_produced, seq, attempt)

    # ---- chaos: device crash / recovery ---------------------------------
    def _on_crash(self, ev) -> None:
        gid, _until = ev.payload
        self.pool.crash(gid, ev.time)
        gen = self._grant_on.get(gid)
        if gen is not None:
            info = self._live_grants.get(gen)
            if info is not None and not info["dead"]:
                # the grant in flight dies with the device; its gpu_done is
                # suppressed and the watchdog will recover the fused group
                info["dead"] = True
                self.chaos_grants_killed.inc()

    def _on_recover(self, ev) -> None:
        self.pool.recover(ev.payload)
        self._maybe_start(ev.time)

    def _on_watchdog(self, ev) -> None:
        gen = ev.payload
        info = self._live_grants.pop(gen, None)
        if info is None:
            return  # the grant completed normally; the watchdog disarms
        gid = info["gid"]
        self._grant_on.pop(gid, None)
        self.chaos_watchdog_fires.inc()
        self.chaos_grants_recovered.inc()
        self.chaos_sessions_recovered.inc(len(info["clients"]))
        gspan = self._grant_spans.pop(gid, None)
        if gspan is not None:
            # close the dead grant at its planned end so its component
            # spans stay nested; mark it so the trace shows the casualty
            gspan.end = info["done_t"]
            if gspan.args is not None:
                gspan.args = dict(gspan.args, crashed=True)
        self.pool.release(gid)
        for c in info["clients"]:
            self._active.discard(c)
            s = self.sessions[c]
            # requeue with no frames: the phase's labels already landed (or
            # died with the device); the session just needs its training
            # phase re-run — residency was spilled by the crash, so the
            # regrant pays a full restage on a surviving device
            req = GPURequest(client=c, t_request=ev.time, n_frames=0,
                             k_iters=s.k_iters,
                             deadline=ev.time + s.t_update,
                             phi=_phi_of(s), t_update=s.t_update,
                             state_bytes=getattr(s, "state_bytes", 0))
            self._enqueue(ev.time, req, [])

    def _on_delta(self, ev) -> None:
        delta, t_sent = ev.payload
        self.sessions[ev.client].apply_delta(delta, t_sent, ev.time)
        if self._chaos:
            self._last_delta_arrival[ev.client] = ev.time

    def _on_rate_ctrl(self, ev) -> None:
        self.sessions[ev.client].apply_rate_ctrl(ev.payload)

    # ---- main loop ------------------------------------------------------
    def _init_events(self) -> None:
        self._admit_sessions()
        if self.fleet is not None:
            self._init_events_fleet()
        else:
            for i, s in enumerate(self.sessions):
                if self.cfg.asr_ctrl_bytes > 0:
                    # the boot-time rate is already on-device; every *change*
                    # from here on must be delivered over the downlink
                    s.apply_rate_ctrl(s.sampling_rate)
                self.q.push(0.0, "eval", i)
                if s.admitted:
                    self.q.push(0.0, "sample", i)
                    self.q.push(min(s.t_update, self.cfg.duration * 0.999),
                                "upload", i)
        if self._chaos:
            dur = self.cfg.duration
            for w in self.cfg.faults.crashes:
                if w.gid >= self.pool.n or w.start >= dur:
                    continue
                self.q.push(w.start, "crash", None, (w.gid, w.end))
                self.q.push(w.end, "recover", None, w.gid)
                if self.tracer is not None:
                    self.tracer.gpu_fault_span(
                        w.gid, "crash", w.start, min(w.end, dur))
            if self.tracer is not None:
                for d, c, a, b in self._inj.outage_windows():
                    if a >= dur:
                        continue
                    targets = ([c] if c is not None
                               else [s.idx for s in self.sessions])
                    for ci in targets:
                        self.tracer.client_fault_span(
                            ci, "outage", max(a, 0.0), min(b, dur),
                            {"direction": d})

    def _init_events_fleet(self) -> None:
        """Cohort twin of the per-session init loop: same events at the
        same times; samples before uploads (seq order) just as the
        interleaved scalar pushes would land."""
        f, cfg = self.fleet, self.cfg
        if cfg.asr_ctrl_bytes > 0:
            f.edge_rate[:] = f.sampling_rate
        all_c = np.arange(f.n, dtype=np.int64)
        self._push_cohorts(np.zeros(f.n), "eval", all_c)
        adm = all_c[f.admitted]
        if len(adm):
            self._push_cohorts(np.zeros(len(adm)), "sample", adm)
            self._push_cohorts(np.minimum(f.t_update[adm],
                                          cfg.duration * 0.999),
                               "upload", adm)

    def _dispatch(self, ev) -> None:
        self._handlers[ev.kind](ev)

    def run(self) -> dict:
        self._init_events()
        handlers = self._handlers
        self._update_snap = update_pipeline_info()  # process-global counters
        self._timing_snap = timing.snapshot()  # wall-clock stage stats
        t0 = time.time()
        if self.fleet is not None:
            # fleet loop: drain the timestamp in one batch; cohort events
            # (ndarray client) go to the array handlers, everything else —
            # grants, deltas, chaos — takes the scalar handlers unchanged
            batch = self._batch_handlers
            while self.q:
                for ev in self.q.pop_batch():
                    if type(ev.client) is np.ndarray:
                        batch[ev.kind](ev.time, ev.client, ev.payload)
                    else:
                        handlers[ev.kind](ev)
        else:
            while self.q:
                ev = self.q.pop()
                handlers[ev.kind](ev)
        wall = time.time() - t0
        return self._results(wall)

    def _results(self, wall_s: float) -> dict:
        """Fold the run into the results dict. Every value routes through
        `self.metrics` (counters accumulated during the run, gauges set
        here), so the registry IS the results — `as_results` preserves the
        historical keys and values bit-for-bit."""
        cfg = self.cfg
        m = self.metrics
        per_client = [s.miou_mean() for s in self.sessions]
        kbps = [s.net.kbps(cfg.duration) for s in self.sessions]
        lat = m.histogram("delta_latency_s")
        lat_lists = [s.latency_values() for s in self.sessions]
        if all(v is not None for v in lat_lists):  # telemetry="full"
            lat.extend(l for v in lat_lists for l in v)
            lat_mean, lat_max = lat.mean(), lat.max()
        else:
            # "moments" sessions fold their samples into running
            # (count, sum, max) — O(1) memory; the histogram stays empty
            n_tot, s_tot, mx = 0, 0.0, 0.0
            for s in self.sessions:
                c, sm, m_ = s.latency_summary()
                n_tot += c
                s_tot += sm
                mx = max(mx, m_)
            lat_mean = s_tot / n_tot if n_tot else 0.0
            lat_max = mx
        n_req = (self.served.value + self.dropped_requests.value
                 + len(self._queue))
        busy_s = sum(d.union_busy_s(cfg.duration) for d in self.pool.devices)
        # this run's wall-clock stage stats (core.timing is process-global;
        # the delta against the snapshot isolates what THIS engine ran)
        stage_stats = timing.delta(getattr(self, "_timing_snap", None))
        compile_s = timing.compile_s(stage_stats)
        m.set("n_clients", len(self.sessions))
        m.set("miou_per_client", per_client)
        m.set("mean_miou", float(np.mean(per_client)))
        m.set("gpu_utilization", busy_s / max(cfg.duration * self.pool.n,
                                              1e-9))
        m.set("phases_per_client", [s.phases for s in self.sessions])
        m.set("scheduler", self.policy.name)
        m.set("admitted_clients", sum(s.admitted for s in self.sessions))
        m.set("parked_clients", [s.idx for s in self.sessions
                                 if not s.admitted])
        m.set("offered_load", self.offered_load)
        m.set("unserved_backlog", len(self._queue))
        m.set("deferral_rate", self.deferred.value / max(n_req, 1))
        # fused training telemetry
        m.set("rider_grants", self.pool.rider_grants)
        # fused post-train update pipeline (stacked select + batched
        # encode): modeled pricing plus the real `core.batched` counters
        # for this run (a stub fleet never enters the real fused math,
        # so its stacked_* counters stay zero by construction)
        m.set("update_pipeline.update_s_saved",
              self.update_s_sequential.value - self.update_s_charged.value)
        for k, v in update_pipeline_info().items():
            m.set(f"update_pipeline.{k}",
                  v - getattr(self, "_update_snap", {}).get(k, v))
        # pool telemetry
        m.set("n_gpus", self.pool.n)
        m.set("per_gpu_utilization", self.pool.utilization(cfg.duration))
        m.set("per_gpu_grants", [d.grants for d in self.pool.devices])
        m.set("migrations", self.pool.migrations)
        m.set("migration_s_total", self.pool.migration_s_total)
        m.set("residency_evictions", self.pool.evictions)
        m.set("devices_per_client", [sorted(set(s.phase_devices))
                                     for s in self.sessions])
        # dual-stream telemetry
        m.set("stream_mode", cfg.streams.mode)
        m.set("per_gpu_stream_utilization",
              self.pool.stream_utilization(cfg.duration))
        m.set("overlap_s", self.pool.overlap_s_total())
        m.set("preemptions", self.pool.preemptions)
        m.set("preempted_frames", self.pool.preempted_frames)
        m.set("preempt_s_total", self.pool.preempt_s_total)
        # network telemetry
        m.set("per_client_kbps", kbps)
        m.set("mean_up_kbps", float(np.mean([u for u, _ in kbps])))
        m.set("mean_down_kbps", float(np.mean([d for _, d in kbps])))
        m.set("delta_latency_mean_s", lat_mean)
        m.set("delta_latency_max_s", lat_max)
        # fault telemetry (plan-level gauges only exist in chaos runs; the
        # chaos.* counters are always registered and zero without faults)
        if self._chaos:
            m.set("chaos.link_outage_s",
                  self._inj.link_outage_s(cfg.duration, len(self.sessions)))
            m.set("chaos.crash_s", self._inj.crash_s(cfg.duration))
            m.set("chaos.device_crashes", self.pool.crashes)
            m.set("chaos.crash_spills", self.pool.crash_spills)
            stale = [cfg.duration - self._last_delta_arrival.get(s.idx, 0.0)
                     for s in self.sessions if s.admitted]
            m.set("chaos.final_staleness_max_s",
                  max(stale) if stale else 0.0)
        m.set("events_processed", self.q.popped)
        m.set("events_per_sec", self.q.popped / max(wall_s, 1e-9))
        # steady-state engine throughput: the XLA compile / first-launch
        # seconds the timing hooks attributed are excluded from the clock,
        # so this no longer punishes the first fleet a process runs
        m.set("events_per_sec_steady",
              self.q.popped / max(wall_s - compile_s, 1e-9))
        m.set("wall_s", wall_s)
        m.set("observability", {
            "tracing": self.tracer is not None,
            "compile_s": compile_s,
            "stage_timings": timing.totals(stage_stats),
            "drift": drift_report(self.cost, stage_stats),
        })
        return m.as_results()
