"""Event-driven AMS serving runtime (Appendix E at scale).

Replaces the per-frame tick loop of `sim.multiclient` with a discrete-event
simulation: N sessions share one GPU and a modeled network, and nothing
advances except by popping the next event. The lifecycle of one update
period, in events:

    sample  (edge)   frame captured at the ASR rate into the device outbox
    upload  (edge)   every T_update the outbox ships over the rate-limited
                     uplink (H.264 buffer bytes -> link occupancy)
    request (server) the batch lands; admission control either queues a
                     GPURequest or drops it (saturation telemetry)
    <GPU grant>      when the GPU idles, the scheduling policy picks among
                     queued requests; the teacher labels the *whole* queued
                     backlog in one batched launch (amortized cost), then
                     the picked session runs its K-iteration training phase
    gpu_done         the fresh ModelDelta ships over the client's downlink
    delta   (edge)   the — by now stale — delta lands and swaps in via the
                     double-buffered EdgeClient
    eval    (edge)   mIoU of the client-side weights against the teacher

Simplifications kept from the seed: ASR rate updates reach the device for
free (a few bytes of control traffic), and eval reads ground truth directly
(it is measurement, not traffic). Everything else — who gets the GPU, when
bytes move, how stale a delta is — is modeled.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.scheduler import GPUCostModel
from repro.serving.events import EventQueue
from repro.serving.policies import GPURequest, SchedulingPolicy, make_policy


def _phi_of(session) -> float:
    """Scene-dynamics signal for scheduling; falls back to the sampling rate
    for sessions that don't expose a φ EMA."""
    return getattr(session, "phi_signal", session.sampling_rate)


@dataclass(frozen=True)
class ServingConfig:
    duration: float = 120.0
    max_queue: int = 16  # server backlog cap per-request admission
    admission_util_cap: float | None = None  # projected-GPU-load session cap
    batch_labeling: bool = True
    sample_eps: float = 1e-6  # floor on sampling rate when scheduling


@dataclass
class _Backlog:
    """Server-side state for one queued request."""

    req: GPURequest
    idxs: list  # frame indices not yet teacher-labeled


class ServingEngine:
    def __init__(self, sessions, policy: str | SchedulingPolicy = "fair",
                 cost: GPUCostModel | None = None,
                 cfg: ServingConfig | None = None):
        self.sessions = list(sessions)
        self.policy = make_policy(policy)
        self.cost = cost or GPUCostModel()
        self.cfg = cfg or ServingConfig()
        self.q = EventQueue()
        self._queue: list[_Backlog] = []
        self._gpu_busy = False
        # telemetry
        self.busy_s = 0.0
        self.served = 0
        self.deferred = 0
        self.dropped_requests = 0
        self.label_batches = 0
        self.labels_total = 0
        self.max_backlog = 0

    # ---- admission control ---------------------------------------------
    def _admit_sessions(self) -> None:
        """Project each session's steady-state GPU demand and stop admitting
        past the utilization cap; rejected sessions run inference-only (their
        accuracy decay is the saturation signal, not a crash)."""
        cap = self.cfg.admission_util_cap
        load = 0.0
        for s in self.sessions:
            est_frames = s.sampling_rate * s.t_update
            # project with the batched per-frame labeling rate. Slightly
            # conservative on purpose: the launch overhead amortizes across
            # co-queued sessions at service time, which can't be known here
            if self.cfg.batch_labeling:
                label_s = self.cost.label_batch_s(est_frames)
            else:
                label_s = est_frames * self.cost.teacher_infer_s
            rho = (label_s + s.k_iters * self.cost.train_iter_s) / max(s.t_update, 1e-9)
            if cap is not None and load + rho > cap:
                s.admitted = False
            else:
                s.admitted = True
                load += rho
        self.offered_load = load

    # ---- event handlers ------------------------------------------------
    def _on_sample(self, ev) -> None:
        s = self.sessions[ev.client]
        s.capture(ev.time)
        nxt = ev.time + 1.0 / max(s.sampling_rate, self.cfg.sample_eps)
        if nxt < self.cfg.duration:
            self.q.push(nxt, "sample", ev.client)

    def _on_eval(self, ev) -> None:
        s = self.sessions[ev.client]
        s.evaluate(ev.time)
        nxt = ev.time + s.eval_interval_s
        if nxt < self.cfg.duration:
            self.q.push(nxt, "eval", ev.client)

    def _on_upload(self, ev) -> None:
        s = self.sessions[ev.client]
        idxs = s.take_outbox()
        arrival = s.net.send_up(ev.time, s.upload_bytes(len(idxs)))
        self.q.push(arrival, "request", ev.client, idxs)
        nxt = ev.time + s.t_update
        if nxt < self.cfg.duration:
            self.q.push(nxt, "upload", ev.client)

    def _on_request(self, ev) -> None:
        s = self.sessions[ev.client]
        if self._gpu_busy:
            self.deferred += 1
        req = GPURequest(client=ev.client, t_request=ev.time,
                         n_frames=len(ev.payload), k_iters=s.k_iters,
                         deadline=ev.time + s.t_update,
                         phi=_phi_of(s), t_update=s.t_update)
        if len(self._queue) >= self.cfg.max_queue:
            # saturated: the policy chooses the sacrifice (tail drop by
            # default; gain-aware evicts the lowest-value queued request)
            self._refresh_phi()
            victim = self.policy.evict(ev.time, [b.req for b in self._queue] + [req])
            self.dropped_requests += 1  # the victim's frames are lost
            if victim is req:
                return
            self._queue.remove(next(b for b in self._queue if b.req is victim))
        self._queue.append(_Backlog(req=req, idxs=list(ev.payload)))
        self.max_backlog = max(self.max_backlog, len(self._queue))
        self._maybe_start(ev.time)

    def _maybe_start(self, t: float) -> None:
        # no new grants past the horizon: the backlog is left unserved (and
        # reported) rather than drained in overtime, which would overstate
        # both utilization and served-phase counts
        if not self._gpu_busy and self._queue and t < self.cfg.duration:
            self._start_service(t)

    def _refresh_phi(self) -> None:
        # a request's φ is snapshotted at arrival; batched labeling can move
        # the session's φ EMA while it queues, so re-read before any policy
        # decision — otherwise a feed that just turned dynamic is ranked
        # (and evicted) by its stale near-static score
        for b in self._queue:
            b.req.phi = _phi_of(self.sessions[b.req.client])

    def _start_service(self, t: float) -> None:
        self._refresh_phi()
        picked = self.policy.pick(t, [b.req for b in self._queue])
        backlog = next(b for b in self._queue if b.req is picked)
        self._queue.remove(backlog)
        # cross-client batched labeling: one launch clears every queued
        # session's unlabeled frames, not just the picked one
        if self.cfg.batch_labeling:
            to_label = [backlog] + [b for b in self._queue if b.idxs]
        else:
            to_label = [backlog]
        n_label = sum(len(b.idxs) for b in to_label)
        label_s = self.cost.label_batch_s(n_label)
        if n_label:
            self.label_batches += 1
            self.labels_total += n_label
        t_labeled = t + label_s
        for b in to_label:
            self.sessions[b.req.client].label_and_ingest(b.idxs, t_labeled)
            b.idxs = []
        dur = label_s + backlog.req.k_iters * self.cost.train_iter_s
        # a phase granted near the horizon spills past it; only the in-window
        # part counts toward utilization (keeps busy_s/duration <= 1)
        self.busy_s += min(dur, self.cfg.duration - t)
        self._gpu_busy = True
        self.q.push(t + dur, "gpu_done", backlog.req.client)

    def _on_gpu_done(self, ev) -> None:
        s = self.sessions[ev.client]
        delta = s.train(ev.time)
        self.served += 1
        self._gpu_busy = False
        if delta is not None:
            arrival = s.net.send_down(ev.time, delta.total_bytes)
            self.q.push(arrival, "delta", ev.client, (delta, ev.time))
        self._maybe_start(ev.time)

    def _on_delta(self, ev) -> None:
        delta, t_sent = ev.payload
        self.sessions[ev.client].apply_delta(delta, t_sent, ev.time)

    # ---- main loop ------------------------------------------------------
    def run(self) -> dict:
        cfg = self.cfg
        self._admit_sessions()
        handlers = {"sample": self._on_sample, "eval": self._on_eval,
                    "upload": self._on_upload, "request": self._on_request,
                    "gpu_done": self._on_gpu_done, "delta": self._on_delta}
        for i, s in enumerate(self.sessions):
            self.q.push(0.0, "eval", i)
            if s.admitted:
                self.q.push(0.0, "sample", i)
                self.q.push(min(s.t_update, cfg.duration * 0.999), "upload", i)
        t0 = time.time()
        while self.q:
            ev = self.q.pop()
            handlers[ev.kind](ev)
        wall = time.time() - t0
        return self._results(wall)

    def _results(self, wall_s: float) -> dict:
        cfg = self.cfg
        per_client = [float(np.mean(s.mious)) if s.mious else float("nan")
                      for s in self.sessions]
        kbps = [s.net.kbps(cfg.duration) for s in self.sessions]
        lat = [l for s in self.sessions for l in s.delta_latencies]
        phases = [s.phases for s in self.sessions]
        n_req = self.served + self.dropped_requests + len(self._queue)
        return {
            "n_clients": len(self.sessions),
            "miou_per_client": per_client,
            "mean_miou": float(np.mean(per_client)),
            "gpu_utilization": self.busy_s / max(cfg.duration, 1e-9),
            "phases_served": self.served,
            "phases_deferred": self.deferred,
            "phases_per_client": phases,
            "scheduler": self.policy.name,
            "admitted_clients": sum(s.admitted for s in self.sessions),
            "offered_load": self.offered_load,
            "dropped_requests": self.dropped_requests,
            "unserved_backlog": len(self._queue),
            "deferral_rate": self.deferred / max(n_req, 1),
            "max_backlog": self.max_backlog,
            "label_batches": self.label_batches,
            "labels_total": self.labels_total,
            "per_client_kbps": kbps,
            "mean_up_kbps": float(np.mean([u for u, _ in kbps])),
            "mean_down_kbps": float(np.mean([d for _, d in kbps])),
            "delta_latency_mean_s": float(np.mean(lat)) if lat else 0.0,
            "delta_latency_max_s": float(np.max(lat)) if lat else 0.0,
            "events_processed": self.q.popped,
            "events_per_sec": self.q.popped / max(wall_s, 1e-9),
            "wall_s": wall_s,
        }
