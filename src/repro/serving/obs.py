"""Flight recorder for the serving engine: span tracing, metrics, drift audit.

Three instruments, one module:

* `Tracer` — records spans in **simulated** time for every engine event
  (grants, migration, labeling launches, preemption cuts, the fused
  train→select→encode pipeline stages, per-client uplink/downlink
  transfers) and exports deterministic Chrome trace-event JSON: one
  process per GPU with one thread per device stream (plus a grants track),
  one process per client with uplink/downlink threads, and counter tracks
  for queue depth / backlog / per-stream utilization. Open the file at
  https://ui.perfetto.dev ("Open trace file") or chrome://tracing.
* `MetricsRegistry` — typed counters/gauges/histograms with dotted names;
  the engine's results dict is assembled from it (`as_results`), ending
  the per-PR accretion of inline telemetry blocks.
* `drift_report` — folds the wall-clock stage stats from `core.timing`
  (compile vs steady split) against a `GPUCostModel`'s per-stage pricing:
  modeled vs measured seconds per pipeline stage, the audit the ROADMAP's
  "real sharded execution" item needs before modeled time can be trusted.

Determinism: timestamps are simulated seconds (microsecond-quantized),
span/flow ids are sequential creation ids, events are emitted sorted by
``(ts, id)``, and the JSON is dumped with sorted keys — two identical runs
produce byte-identical trace files (same discipline as the gzip ``mtime=0``
wire-format fix).
"""
from __future__ import annotations

import json

import numpy as np

# ---------------------------------------------------------------------------
# trace-event layout: pids / tids
# ---------------------------------------------------------------------------

PID_SERVER = 0
GPU_PID_BASE = 1  # gpu g -> pid GPU_PID_BASE + g
TID_LABEL, TID_TRAIN, TID_GRANT = 1, 2, 3
TID_FAULT = 4  # injected crash windows (chaos runs only)
STREAM_TIDS = {"label": TID_LABEL, "train": TID_TRAIN}
TID_UP, TID_DOWN = 1, 2
TID_CLIENT_FAULT = 3  # injected link outages (chaos runs only)


def _us(t: float) -> int:
    # round() is monotone, so interval orderings placed in float seconds
    # survive quantization: a charge placed after another stays after it
    return int(round(t * 1e6))


class Span:
    """One open or closed trace span. Mutable until export: preemption
    edits ``end`` (schedule truncation), cancellation drops it entirely —
    a cut is a schedule edit in the simulator, so it is one in the trace."""

    __slots__ = ("pid", "tid", "name", "cat", "start", "end", "args", "seq",
                 "cancelled")

    def __init__(self, pid, tid, name, start, end, cat, args, seq):
        self.pid = pid
        self.tid = tid
        self.name = name
        self.cat = cat
        self.start = start
        self.end = end
        self.args = args
        self.seq = seq
        self.cancelled = False


class Tracer:
    """Deterministic Chrome-trace recorder for one engine run."""

    def __init__(self, max_clients: int = 1000,
                 sample_clients: int | None = None):
        # per-client span volume scales linearly with the fleet: tracing a
        # 10⁵-client fleet would emit a multi-GB, unopenable trace, so the
        # recorder refuses past this cap (raise it explicitly to insist).
        # sample_clients instead traces a deterministic evenly-spaced
        # subset of that size when the fleet exceeds the cap: server/GPU
        # tracks stay complete, per-client transfer tracks exist only for
        # the sampled clients (the schedule itself is untouched — sampling
        # drops spans, never events)
        if sample_clients is not None and sample_clients < 1:
            raise ValueError(
                f"sample_clients must be >= 1 (or None to refuse big "
                f"fleets), got {sample_clients}")
        self.max_clients = max_clients
        self.sample_clients = sample_clients
        self._sampled: frozenset | None = None  # None = trace every client
        self._spans: list[Span] = []
        self._counters: list = []   # (seq, t, pid, name, values)
        self._instants: list = []   # (seq, t, pid, tid, name, args)
        self._flows: list = []      # (flow_id, src Span, dst Span)
        self._procs: dict[int, str] = {}
        self._threads: dict[tuple[int, int], str] = {}
        self._seq = 0
        self._flow_seq = 0
        self.meta: dict = {}
        self._client_base = 1001

    # ---- registration ---------------------------------------------------
    def _next(self) -> int:
        self._seq += 1
        return self._seq

    def process(self, pid: int, name: str) -> None:
        self._procs.setdefault(pid, name)

    def thread(self, pid: int, tid: int, name: str) -> None:
        self._threads.setdefault((pid, tid), name)

    def setup_engine(self, pool, sessions, cfg) -> None:
        """Register the run's processes/threads and the trace metadata the
        schema validator reads (stream mode, pool/fleet size)."""
        n_fleet = len(sessions)
        if n_fleet > self.max_clients:
            if self.sample_clients is None:
                raise ValueError(
                    f"refusing to trace {n_fleet} clients (cap "
                    f"{self.max_clients}): per-client transfer spans would "
                    f"make the trace unopenably large. Trace a small fleet "
                    f"(the schedule is deterministic, so a subsample "
                    f"reproduces), pass Tracer(sample_clients=k) for a "
                    f"deterministic k-client subset, or "
                    f"Tracer(max_clients=...) to insist on everything.")
            # deterministic, stable, evenly spaced over the sorted client
            # ids: the same fleet always samples the same clients, and the
            # subset spans the id range (ids often encode admission order)
            ids = sorted(s.idx for s in sessions)
            k = min(self.sample_clients, n_fleet)
            self._sampled = frozenset(ids[(j * n_fleet) // k]
                                      for j in range(k))
        self.meta = {
            "n_gpus": pool.n,
            "n_clients": len(sessions),
            "stream_mode": pool.streams.mode,
            "preempt": pool.streams.preempt,
            "fuse_train": cfg.fuse_train,
            "fuse_updates": cfg.fuse_updates,
        }
        self._client_base = max(1001, GPU_PID_BASE + pool.n + 1)
        # fault tracks appear only in chaos runs, so fault-free traces stay
        # byte-identical to the pre-chaos recorder
        chaos = getattr(getattr(cfg, "faults", None), "active", False)
        self.process(PID_SERVER, "serving-engine")
        self.thread(PID_SERVER, 0, "events")
        for d in pool.devices:
            pid = self.gpu_pid(d.gid)
            self.process(pid, f"gpu{d.gid}")
            self.thread(pid, TID_LABEL, "stream:label")
            self.thread(pid, TID_TRAIN, "stream:train")
            self.thread(pid, TID_GRANT, "grants")
            if chaos:
                self.thread(pid, TID_FAULT, "faults")
        if self._sampled is not None:
            self.meta["sampled_clients"] = len(self._sampled)
        for s in sessions:
            if not self.traces_client(s.idx):
                continue
            pid = self.client_pid(s.idx)
            self.process(pid, f"client{s.idx}")
            self.thread(pid, TID_UP, "uplink")
            self.thread(pid, TID_DOWN, "downlink")
            if chaos:
                self.thread(pid, TID_CLIENT_FAULT, "faults")

    def traces_client(self, client: int) -> bool:
        """Whether per-client spans for ``client`` are recorded (always
        True unless a ``sample_clients`` subset is active)."""
        return self._sampled is None or client in self._sampled

    def gpu_pid(self, gid: int) -> int:
        return GPU_PID_BASE + gid

    def client_pid(self, client: int) -> int:
        return self._client_base + client

    # ---- recording ------------------------------------------------------
    def span(self, pid: int, tid: int, name: str, start: float,
             end: float | None = None, *, cat: str = "span",
             args: dict | None = None) -> Span:
        s = Span(pid, tid, name, start, end, cat, args, self._next())
        self._spans.append(s)
        return s

    def gpu_span(self, gid: int, stream: str, name: str, start: float,
                 end: float, args: dict | None = None) -> Span:
        return self.span(self.gpu_pid(gid), STREAM_TIDS[stream], name,
                         start, end, cat=f"stream:{stream}", args=args)

    def grant_span(self, gid: int, name: str, start: float,
                   args: dict | None = None) -> Span:
        """Open-ended device-grant span; the engine sets ``end`` when the
        grant's device time is fully charged (gpu_done)."""
        return self.span(self.gpu_pid(gid), TID_GRANT, name, start, None,
                         cat="grant", args=args)

    def client_span(self, client: int, direction: str, name: str,
                    start: float, end: float,
                    args: dict | None = None) -> Span | None:
        if not self.traces_client(client):
            return None  # unsampled client: schedule unchanged, span dropped
        tid = TID_UP if direction == "up" else TID_DOWN
        return self.span(self.client_pid(client), tid, name, start, end,
                         cat=f"net:{direction}", args=args)

    def gpu_fault_span(self, gid: int, name: str, start: float, end: float,
                       args: dict | None = None) -> Span:
        """A crash window on a device's fault track (chaos runs)."""
        return self.span(self.gpu_pid(gid), TID_FAULT, name, start, end,
                         cat="fault", args=args)

    def client_fault_span(self, client: int, name: str, start: float,
                          end: float, args: dict | None = None) -> Span | None:
        """A link-outage window on a client's fault track (chaos runs)."""
        if not self.traces_client(client):
            return None
        return self.span(self.client_pid(client), TID_CLIENT_FAULT, name,
                         start, end, cat="fault", args=args)

    def counter(self, pid: int, name: str, t: float, values: dict) -> None:
        self._counters.append((self._next(), t, pid, name, values))

    def instant(self, pid: int, tid: int, name: str, t: float,
                args: dict | None = None) -> None:
        self._instants.append((self._next(), t, pid, tid, name, args))

    def gpu_instant(self, gid: int, stream: str, name: str, t: float,
                    args: dict | None = None) -> None:
        self.instant(self.gpu_pid(gid), STREAM_TIDS[stream], name, t, args)

    def flow(self, src: Span, dst: Span, name: str = "delta") -> int:
        """Causal arrow between two spans (e.g. device grant -> downlink
        delta transfer). Materialized at export from the span endpoints, so
        a later schedule edit moves the arrow with the span."""
        self._flow_seq += 1
        self._flows.append((self._flow_seq, src, dst, name))
        return self._flow_seq

    # ---- export ---------------------------------------------------------
    def to_events(self) -> list[dict]:
        events: list[dict] = []
        for pid in sorted(self._procs):
            events.append({"ph": "M", "pid": pid, "tid": 0,
                           "name": "process_name",
                           "args": {"name": self._procs[pid]}})
            events.append({"ph": "M", "pid": pid, "tid": 0,
                           "name": "process_sort_index",
                           "args": {"sort_index": pid}})
        for (pid, tid) in sorted(self._threads):
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": self._threads[(pid, tid)]}})
        timed: list[tuple[int, int, dict]] = []
        for s in self._spans:
            if s.cancelled:
                continue
            end = s.start if s.end is None else s.end
            e = {"ph": "X", "pid": s.pid, "tid": s.tid, "name": s.name,
                 "cat": s.cat, "ts": _us(s.start),
                 "dur": max(_us(end) - _us(s.start), 0)}
            if s.args:
                e["args"] = s.args
            timed.append((e["ts"], s.seq, e))
        for seq, t, pid, name, values in self._counters:
            timed.append((_us(t), seq,
                          {"ph": "C", "pid": pid, "tid": 0, "name": name,
                           "ts": _us(t), "args": values}))
        for seq, t, pid, tid, name, args in self._instants:
            e = {"ph": "i", "s": "t", "pid": pid, "tid": tid, "name": name,
                 "ts": _us(t)}
            if args:
                e["args"] = args
            timed.append((_us(t), seq, e))
        for fid, src, dst, name in self._flows:
            if src.cancelled or dst.cancelled:
                continue
            src_end = src.start if src.end is None else src.end
            timed.append((_us(src_end), src.seq,
                          {"ph": "s", "id": fid, "name": name, "cat": "flow",
                           "pid": src.pid, "tid": src.tid,
                           "ts": _us(src_end)}))
            timed.append((_us(dst.start), dst.seq,
                          {"ph": "f", "bp": "e", "id": fid, "name": name,
                           "cat": "flow", "pid": dst.pid, "tid": dst.tid,
                           "ts": _us(dst.start)}))
        timed.sort(key=lambda x: (x[0], x[1]))
        events.extend(e for _, _, e in timed)
        return events

    def to_json(self) -> str:
        doc = {"traceEvents": self.to_events(),
               "displayTimeUnit": "ms",
               "otherData": dict(self.meta)}
        return json.dumps(doc, sort_keys=True, separators=(",", ":")) + "\n"

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())


# ---------------------------------------------------------------------------
# schema / invariant validation (CI gate for emitted traces)
# ---------------------------------------------------------------------------

REQUIRED_COUNTERS = ("queue_depth", "backlog_frames", "stream_util")


def validate_trace(trace: dict,
                   require_counters=REQUIRED_COUNTERS) -> list[str]:
    """Structural + invariant checks on a parsed Chrome trace. Returns a
    list of problems (empty = valid):

    * every complete span has a non-negative duration;
    * the required counter tracks exist;
    * per device stream, spans never overlap (each stream executes its
      launches serially — preemption truncates, it does not double-book);
    * per client link track (uplink/downlink), spans never overlap — link
      occupancy is serial, so a ``retry`` span may not overlap its link's
      live transfer (the chaos retry path waits for the link);
    * under a ``serialized`` stream model the two streams of one device
      are mutually exclusive, so per-device span concurrency is <= 1
      (<= 2 under ``overlap``);
    * every span tagged with a grant id nests inside that grant's span
      (the fused train/select/encode stages belong to their device grant);
    * fault vocabulary: ``cat="fault"`` spans are named ``outage``/
      ``crash`` and live on a fault track (client/device respectively),
      ``retry`` spans live on a ``net:*`` link track, and ``supersede``
      instants live on client processes.
    """
    problems: list[str] = []
    evs = trace.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["traceEvents missing or empty"]
    gpu_pids = {e["pid"] for e in evs
                if e.get("ph") == "M" and e.get("name") == "process_name"
                and str(e.get("args", {}).get("name", "")).startswith("gpu")}
    client_pids = {e["pid"] for e in evs
                   if e.get("ph") == "M" and e.get("name") == "process_name"
                   and str(e.get("args", {}).get("name", ""))
                   .startswith("client")}
    counters = {e.get("name") for e in evs if e.get("ph") == "C"}
    for name in require_counters:
        if name not in counters:
            problems.append(f"missing counter track {name!r}")
    spans = [e for e in evs if e.get("ph") == "X"]
    for e in spans:
        for fld in ("pid", "tid", "ts", "dur", "name"):
            if fld not in e:
                problems.append(f"span missing {fld!r}: {e}")
        if e.get("dur", 0) < 0:
            problems.append(f"negative duration: {e}")
    # per-stream serial execution; client links are serial too — retries
    # queue behind the link like any transfer (fault tracks are exempt:
    # a client's up and down outage windows may legitimately overlap)
    by_track: dict = {}
    for e in spans:
        by_track.setdefault((e["pid"], e["tid"]), []).append(e)
    for (pid, tid), track in by_track.items():
        if not (pid in gpu_pids
                or (pid in client_pids and tid in (TID_UP, TID_DOWN))):
            continue
        track.sort(key=lambda e: (e["ts"], e["ts"] + e["dur"]))
        for a, b in zip(track, track[1:]):
            if b["ts"] < a["ts"] + a["dur"]:
                problems.append(
                    f"overlapping spans on pid={pid} tid={tid}: "
                    f"{a['name']}@{a['ts']} and {b['name']}@{b['ts']}")
    # cross-stream concurrency per device
    serialized = trace.get("otherData", {}).get("stream_mode") == "serialized"
    limit = 1 if serialized else 2
    for pid in gpu_pids:
        marks = []
        for tid in (TID_LABEL, TID_TRAIN):
            for e in by_track.get((pid, tid), []):
                if e["dur"] > 0:
                    marks.append((e["ts"], 1))
                    marks.append((e["ts"] + e["dur"], -1))
        marks.sort()
        depth = peak = 0
        for _, d in marks:
            depth += d
            peak = max(peak, depth)
        if peak > limit:
            problems.append(
                f"device pid={pid} ran {peak} concurrent stream spans "
                f"(limit {limit} for "
                f"{'serialized' if serialized else 'overlap'} streams)")
    # grant nesting
    grants = {e["args"]["seq"]: e for e in spans
              if e.get("cat") == "grant" and "seq" in e.get("args", {})}
    for e in spans:
        g = e.get("args", {}).get("grant")
        if g is None or g not in grants:
            continue
        ge = grants[g]
        if e["ts"] < ge["ts"] or e["ts"] + e["dur"] > ge["ts"] + ge["dur"]:
            problems.append(
                f"span {e['name']}@{e['ts']} escapes grant {g} "
                f"[{ge['ts']}, {ge['ts'] + ge['dur']}]")
    # fault vocabulary (chaos runs)
    for e in spans:
        if e.get("cat") == "fault":
            if e["name"] == "outage":
                if not (e["pid"] in client_pids
                        and e["tid"] == TID_CLIENT_FAULT):
                    problems.append(
                        f"outage span off a client fault track: {e}")
            elif e["name"] == "crash":
                if not (e["pid"] in gpu_pids and e["tid"] == TID_FAULT):
                    problems.append(
                        f"crash span off a device fault track: {e}")
            else:
                problems.append(
                    f"unknown fault span name {e['name']!r}: {e}")
        elif e.get("name") == "retry":
            if not str(e.get("cat", "")).startswith("net:"):
                problems.append(f"retry span off a network link track: {e}")
    for e in evs:
        if e.get("ph") == "i" and e.get("name") == "supersede":
            if e.get("pid") not in client_pids:
                problems.append(f"supersede instant off a client: {e}")
    return problems


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic-ish accumulator (preemption bookkeeping may decrement)."""

    __slots__ = ("value",)

    def __init__(self, value=0):
        self.value = value

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins value; `set_max` keeps a running maximum."""

    __slots__ = ("value",)

    def __init__(self, value=None):
        self.value = value

    def set(self, v) -> None:
        self.value = v

    def set_max(self, v) -> None:
        if self.value is None or v > self.value:
            self.value = v


class Histogram:
    """Sample accumulator; summary stats match the engine's historical
    ``np.mean``/``np.max`` math exactly (pairwise summation and all)."""

    __slots__ = ("values",)

    def __init__(self):
        self.values: list[float] = []

    def record(self, v: float) -> None:
        self.values.append(v)

    def extend(self, vs) -> None:
        self.values.extend(vs)

    @property
    def count(self) -> int:
        return len(self.values)

    def mean(self) -> float:
        return float(np.mean(self.values)) if self.values else 0.0

    def max(self) -> float:
        return float(np.max(self.values)) if self.values else 0.0

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.mean(), "max": self.max()}


class MetricsRegistry:
    """Named metrics with dotted paths; `as_results` builds the nested
    results dict (``"update_pipeline.update_s_charged"`` lands under
    ``results["update_pipeline"]``). One registry per engine — the single
    source the results dict is assembled from."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get_or_create(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(*args)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str, value=0) -> Counter:
        return self._get_or_create(name, Counter, value)

    def gauge(self, name: str, value=None) -> Gauge:
        return self._get_or_create(name, Gauge, value)

    def histogram(self, name: str) -> Histogram:
        return self._get_or_create(name, Histogram)

    def set(self, name: str, value) -> None:
        self.gauge(name).set(value)

    def __getitem__(self, name: str):
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def as_results(self) -> dict:
        """Nested dict of every counter/gauge value. Histograms are raw
        sample stores for derived stats; callers export the summaries they
        want under explicit gauge names, so histograms are skipped here."""
        out: dict = {}
        for name, m in self._metrics.items():
            if isinstance(m, Histogram):
                continue
            node = out
            parts = name.split(".")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = m.value
        return out


# ---------------------------------------------------------------------------
# modeled-vs-measured cost-model drift audit
# ---------------------------------------------------------------------------


def _modeled_stage_s(cost, stage: str, key: tuple, nbytes: int,
                     calls: int) -> float | None:
    """Modeled device-seconds for ``calls`` invocations of one pipeline
    stage under ``cost``, from the pricing inputs the timing hooks recorded
    in ``key``/``nbytes``. Returns None for stages the model has no price
    for (they still appear in measured totals, just not in the ratio)."""
    if stage == "train_fused":
        b, k = key
        return calls * cost.train_batch_s(b, k)
    if stage == "train_solo":
        (k,) = key
        return calls * k * cost.train_iter_s
    if stage == "select_stacked":
        (b,) = key
        # the stacked selection's share of `update_batch_s`: setup + the
        # primary's select + discounted rider selects
        return calls * (cost.update_setup_s
                        + cost.select_s * (1 + cost.update_discount
                                           * (b - 1)))
    if stage == "select_solo":
        return calls * cost.select_s
    if stage == "encode_stacked":
        (b,) = key
        blend = (1 + cost.update_discount * (b - 1)) / b
        return cost.delta_comp_s(nbytes) * blend
    if stage == "encode_solo":
        return cost.delta_comp_s(nbytes)
    if stage == "sharded_device":
        # one pool slot's lifecycle in a sharded batch
        # (core.batched.train_phases_sharded): the measured window runs
        # from batch start to this device's own train completion, so the
        # price is the stacked selection share plus the fused train launch
        _slot, b, k = key
        return calls * (cost.update_setup_s
                        + cost.select_s * (1 + cost.update_discount
                                           * (b - 1))
                        + cost.train_batch_s(b, k))
    if stage == "train_sharded":
        # whole-batch parallel wall-clock: D uniform lifecycles running
        # concurrently are priced at ONE lifecycle — that the measured
        # ratio approaches this only with real distinct devices is the
        # point of the audit. Non-uniform batches (no (D, B, K) key) are
        # covered by their per-device stages instead.
        if len(key) != 3:
            return None
        _d, b, k = key
        return calls * (cost.update_setup_s
                        + cost.select_s * (1 + cost.update_discount
                                           * (b - 1))
                        + cost.train_batch_s(b, k))
    return None


def drift_report(cost, stats: dict | None = None) -> dict:
    """Per-stage modeled vs measured seconds from `core.timing` stats.

    For each stage: measured steady-state wall-clock, compile (first
    launch) wall-clock, and the cost model's price for the *steady* calls
    (first calls are excluded from both sides of the ratio — the model
    prices execution, not compilation). ``drift_ratio`` > 1 means the real
    math is slower than modeled; None means the model prices the stage at
    zero (itself a finding: the stage costs real time the engine charges
    nothing for).

    Sharded batches (`core.batched.train_phases_sharded`) additionally get
    a *per-device* comparison: the ``sharded_device`` entry carries a
    ``per_device`` dict keyed by pool slot, each with its own measured vs
    modeled steady seconds and drift ratio — the audit that tells a real
    4-device pool from four modeled clocks ticking over one device."""
    from repro.core import timing as _timing

    stats = _timing.snapshot() if stats is None else stats
    out: dict = {}
    for (stage, key), v in sorted(stats.items(),
                                  key=lambda kv: (kv[0][0], str(kv[0][1]))):
        modeled = _modeled_stage_s(cost, stage, key, v["nbytes"], v["calls"])
        if modeled is None:
            continue
        e = out.setdefault(stage, {
            "calls": 0, "steady_calls": 0, "compile_s": 0.0,
            "measured_steady_s": 0.0, "modeled_steady_s": 0.0, "nbytes": 0})
        steady = v["calls"] - v["first_calls"]
        e["calls"] += v["calls"]
        e["steady_calls"] += steady
        e["compile_s"] += v["first_s"]
        e["measured_steady_s"] += v["steady_s"]
        modeled_steady = (modeled * steady / v["calls"] if v["calls"] else 0.0)
        e["modeled_steady_s"] += modeled_steady
        e["nbytes"] += v["nbytes"]
        if stage == "sharded_device":
            d = e.setdefault("per_device", {}).setdefault(int(key[0]), {
                "calls": 0, "steady_calls": 0,
                "measured_steady_s": 0.0, "modeled_steady_s": 0.0})
            d["calls"] += v["calls"]
            d["steady_calls"] += steady
            d["measured_steady_s"] += v["steady_s"]
            d["modeled_steady_s"] += modeled_steady
    for e in out.values():
        for d in (*e.get("per_device", {}).values(), e):
            meas, mod = d["measured_steady_s"], d["modeled_steady_s"]
            d["drift_ratio"] = (meas / mod) if mod > 0 else None
            d["measured_per_call_s"] = (meas / d["steady_calls"]
                                        if d["steady_calls"] else 0.0)
    return out


# ---------------------------------------------------------------------------
# unified introspection
# ---------------------------------------------------------------------------


def debug_snapshot() -> dict:
    """One call answering "what got fused, what compiled, what did it
    cost" — unifies the per-module cache/counter hooks (`core.batched`,
    `core.selection`, `core.delta`) with the stage timing totals and the
    kernel-dispatch decisions (`core.kernel_dispatch`: mode, plus every
    auto race's winner and measured times), so tests and benchmarks stop
    importing five modules to ask."""
    from repro.core import batched, kernel_dispatch, selection, timing
    from repro.core import delta as delta_codec

    return {
        "fused_train_cache": batched.cache_info(),
        "auto_exec_modes": {f"{backend}:{abs(hash(base)) % 10**8:08d}": mode
                            for (backend, base), mode
                            in batched.auto_mode_info().items()},
        "update_pipeline": batched.update_pipeline_info(),
        "sharded": batched.sharded_info(),
        "stacked_select_cache": selection.stacked_cache_info(),
        "stacked_encode_cache": delta_codec.stack_cache_info(),
        "kernel_dispatch": kernel_dispatch.kernel_dispatch_info(),
        "stage_timings": timing.totals(),
    }
