"""Struct-of-arrays stub fleets: one engine, 10⁴–10⁵ modeled clients.

AMS is a many-client system (one server continuously adapting models for a
fleet of edge devices), but a `StubSession` per client tops the engine out
in the dozens: every session is a Python object graph (session + network +
two links + ledger + unbounded per-eval lists) and every event touches it
attribute by attribute. `FleetState` keeps the whole fleet as parallel
numpy arrays instead — sampling rate, φ, staleness (last-update time),
outbox depth, admitted mask, per-direction link rate/occupancy/bytes — and
the engine's fleet path (`engine.ServingEngine` with a `FleetState` in
place of the session list) updates whole *cohorts* of clients per event.

Equivalence contract
--------------------
The fleet path is an optimization, not a different model: driven by the
same config, a `FleetState` reproduces the per-object `StubSession` engine
**bit-for-bit** — the results dict (minus wall-clock fields) and, under a
tracer, the emitted trace bytes. The pieces that make that hold:

* every array is float64/int64 and every update mirrors the per-object
  expression operand-for-operand (same IEEE ops, same order);
* `FleetSessionView` is a flyweight over the arrays exposing the exact
  `SessionBase` duck surface (including a `ClientNetwork`-shaped ``net``),
  so every rare per-client engine path — grants, deltas, chaos retries,
  traced transfers — runs the *same scalar code* against array storage;
* per-eval mIoU samples are stored as (clients, values) cohort chunks and
  re-grouped per client by one stable argsort at read time, so
  ``np.mean`` sees the same values in the same order.

What a fleet deliberately drops: per-transfer `BandwidthLedger.events`
tuples (never read by results) and real frame indices in the outbox (only
counts are ever consumed — the engine's labeling, tracing, and byte math
all use ``len``); the outbox is a depth counter and `take_outbox`
synthesizes ``[0] * depth``.

Telemetry modes
---------------
``telemetry="full"`` (default) keeps every mIoU/latency sample —
bit-identical to `StubSession`, O(total evals) memory. ``"moments"`` keeps
running (count, sum, max) accumulators instead — O(1) memory per client,
which is what lets a 10⁵-client sweep run in bounded RSS; its means are
``sum/count`` rather than ``np.mean`` (pairwise), so it is numerically
equal only to ~1 ulp and is NOT covered by the bit-identity contract.

`StubSession` grows the same knob for per-object fleets; the differential
tests in ``tests/test_fleet.py`` and the ``serving_scale --fleet`` gate
hold the contract.
"""
from __future__ import annotations

import numpy as np

from repro.serving.session import StubDelta

TELEMETRY_MODES = ("full", "moments")


def _arr(x, n: int, dtype) -> np.ndarray:
    """Broadcast a scalar or per-client sequence to an (n,) array."""
    a = np.asarray(x, dtype=dtype)
    if a.ndim == 0:
        return np.full(n, a, dtype=dtype)
    if a.shape != (n,):
        raise ValueError(f"per-client field has shape {a.shape}, "
                         f"expected ({n},)")
    return a.copy()


class _LinkView:
    """One direction of one client's pipe, as a view over the fleet arrays.
    Mirrors `network.Link` field-for-field (`transfer` is the same math
    against array cells), so the engine's scalar paths — chaos retries,
    traced transfers, rate-trace replay — run unchanged."""

    __slots__ = ("f", "i", "_rate", "_busy", "_bytes", "_count", "_traces",
                 "_dir")

    def __init__(self, fleet: "FleetState", idx: int, direction: str):
        self.f = fleet
        self.i = idx
        self._dir = direction
        if direction == "up":
            self._rate = fleet.up_kbps
            self._busy = fleet.up_busy
            self._bytes = fleet.up_bytes
            self._count = fleet.up_transfers
            self._traces = fleet._up_traces
        else:
            self._rate = fleet.down_kbps
            self._busy = fleet.down_busy
            self._bytes = fleet.down_bytes
            self._count = fleet.down_transfers
            self._traces = fleet._down_traces

    @property
    def rate_kbps(self) -> float:
        return float(self._rate[self.i])

    @property
    def prop_delay_s(self) -> float:
        return float(self.f.prop_delay_s[self.i])

    @property
    def busy_until(self) -> float:
        return float(self._busy[self.i])

    @busy_until.setter
    def busy_until(self, v: float) -> None:
        self._busy[self.i] = v

    @property
    def bytes_carried(self) -> int:
        return int(self._bytes[self.i])

    @property
    def transfers(self) -> int:
        return int(self._count[self.i])

    @property
    def trace(self):
        return self._traces[self.i]

    @trace.setter
    def trace(self, value) -> None:
        old = self._traces[self.i]
        self._traces[self.i] = value
        # O(1) "any link customized?" check for the engine's fast lane
        self.f._n_traced += (value is not None) - (old is not None)

    def tx_seconds(self, nbytes: int) -> float:
        rate = self._rate[self.i]
        if rate <= 0:  # unmodeled link: instantaneous
            return 0.0
        return nbytes * 8.0 / (rate * 1e3)

    def transfer(self, t_now: float, nbytes: int) -> float:
        i = self.i
        start = max(t_now, self._busy[i])
        tr = self._traces[i]
        if tr is not None:
            self._busy[i] = tr.finish_time(start, nbytes * 8.0)
        else:
            self._busy[i] = start + self.tx_seconds(nbytes)
        self._bytes[i] += int(nbytes)
        self._count[i] += 1
        return float(self._busy[i] + self.f.prop_delay_s[i])


class FleetNet:
    """`ClientNetwork`-shaped view for one fleet client: same send/kbps
    surface, same traced-transfer span emission, ledger bytes held in the
    fleet arrays (per-transfer ledger *events* are not kept — nothing in
    the engine's results reads them)."""

    __slots__ = ("f", "client", "tracer", "last_span", "up", "down")

    def __init__(self, fleet: "FleetState", idx: int):
        self.f = fleet
        self.client = idx
        self.tracer = None
        self.last_span = None
        self.up = _LinkView(fleet, idx, "up")
        self.down = _LinkView(fleet, idx, "down")

    def _traced_transfer(self, link: _LinkView, direction: str, t_now: float,
                         nbytes: int, what: str) -> float:
        if self.tracer is None:
            return link.transfer(t_now, nbytes)
        start = max(t_now, link.busy_until)
        arrival = link.transfer(t_now, nbytes)
        self.last_span = self.tracer.client_span(
            self.client, direction, what, start, link.busy_until,
            {"bytes": int(nbytes)})
        return arrival

    def send_up(self, t_now: float, nbytes: int, what: str = "frames") -> float:
        # ledger bytes and Link.bytes_carried receive identical increments
        # in the per-object path; here one array serves both, filled by
        # _LinkView.transfer below.
        return self._traced_transfer(self.up, "up", t_now, nbytes, what)

    def send_down(self, t_now: float, nbytes: int, what: str = "delta") -> float:
        return self._traced_transfer(self.down, "down", t_now, nbytes, what)

    def send_ctrl(self, t_now: float, nbytes: int) -> float:
        return self.send_down(t_now, nbytes, what="asr-rate")

    def kbps(self, duration_s: float) -> tuple[float, float]:
        if duration_s <= 0:
            return 0.0, 0.0
        i = self.client
        return (int(self.f.up_bytes[i]) * 8 / duration_s / 1e3,
                int(self.f.down_bytes[i]) * 8 / duration_s / 1e3)


class FleetSessionView:
    """Flyweight `StubSession` over one fleet row — the `SessionBase` duck
    surface, every scalar produced as a plain Python int/float/bool/list so
    results dicts stay JSON-safe and bit-comparable to per-object runs."""

    __slots__ = ("f", "idx", "_net", "ams_session")

    def __init__(self, fleet: "FleetState", idx: int):
        self.f = fleet
        self.idx = idx
        self._net = None
        self.ams_session = None  # stubs never enter the fused real math

    # ---- identity / config ---------------------------------------------
    @property
    def net(self) -> FleetNet:
        n = self._net
        if n is None:
            n = self._net = FleetNet(self.f, self.idx)
        return n

    @property
    def sampling_rate(self) -> float:
        return float(self.f.sampling_rate[self.idx])

    @property
    def phi_signal(self) -> float:
        return float(self.f.phi[self.idx])

    @property
    def dynamics(self) -> float:
        return float(self.f.dynamics[self.idx])

    @property
    def fps(self) -> float:
        return float(self.f.fps[self.idx])

    @property
    def eval_interval_s(self) -> float:
        return float(self.f.eval_interval_s[self.idx])

    @property
    def t_update(self) -> float:
        return float(self.f.t_update[self.idx])

    @property
    def k_iters(self) -> int:
        return int(self.f.k_iters[self.idx])

    @property
    def state_bytes(self) -> int:
        return int(self.f.state_bytes[self.idx])

    @property
    def delta_bytes_hint(self) -> int:
        return int(self.f.delta_bytes[self.idx])

    @property
    def admitted(self) -> bool:
        return bool(self.f.admitted[self.idx])

    @admitted.setter
    def admitted(self, v: bool) -> None:
        self.f.admitted[self.idx] = v

    @property
    def edge_sampling_rate(self) -> float:
        er = self.f.edge_rate[self.idx]
        if np.isnan(er):
            return float(self.f.sampling_rate[self.idx])
        return float(er)

    def apply_rate_ctrl(self, rate: float) -> None:
        self.f.edge_rate[self.idx] = rate

    # ---- edge side ------------------------------------------------------
    def capture(self, t: float) -> None:
        self.f.outbox_depth[self.idx] += 1

    def take_outbox(self) -> list[int]:
        d = int(self.f.outbox_depth[self.idx])
        self.f.outbox_depth[self.idx] = 0
        return [0] * d  # frame identities are never consumed, only counts

    def upload_bytes(self, n_frames: int) -> int:
        return 256 + n_frames * int(self.f.frame_bytes[self.idx])

    def evaluate(self, t: float) -> None:
        f = self.f
        staleness = t - float(f.last_update_t[self.idx])
        v = max(0.2, 0.9 - float(f.dynamics[self.idx]) * staleness)
        f.record_miou(self.idx, v)

    def apply_delta(self, delta, t_sent: float, t_now: float) -> None:
        f = self.f
        f.last_update_t[self.idx] = t_now
        f.record_latency(self.idx, t_now - t_sent)

    # ---- server side ----------------------------------------------------
    def label_and_ingest(self, idxs: list, t: float) -> None:
        self.f.ingested[self.idx] += len(idxs)

    def train(self, t: float):
        f = self.f
        if f.ingested[self.idx] == 0:
            return None
        f.phases[self.idx] += 1
        return StubDelta(total_bytes=int(f.delta_bytes[self.idx]))

    def note_device(self, gid: int, stream: str = "train") -> None:
        self.f._phase_devices.setdefault(self.idx, []).append(gid)
        self.f._phase_streams.setdefault(self.idx, []).append(stream)

    # ---- telemetry ------------------------------------------------------
    @property
    def phases(self) -> int:
        return int(self.f.phases[self.idx])

    @property
    def mious(self) -> list[float]:
        return self.f.miou_values(self.idx).tolist()

    @property
    def delta_latencies(self) -> list[float]:
        vals = self.f.latency_values_of(self.idx)
        return [] if vals is None else vals

    @property
    def phase_devices(self) -> list[int]:
        return self.f._phase_devices.get(self.idx, [])

    @property
    def phase_streams(self) -> list[str]:
        return self.f._phase_streams.get(self.idx, [])

    def miou_mean(self) -> float:
        return self.f.miou_mean_of(self.idx)

    def latency_values(self):
        return self.f.latency_values_of(self.idx)

    def latency_summary(self) -> tuple[int, float, float]:
        return self.f.latency_summary_of(self.idx)


class _FleetViews:
    """Lazy, cached sequence of per-client views: the engine's
    ``self.sessions``. Views are flyweights, built on first index so a
    10⁵-client run only materializes the ones its scalar paths touch
    (plus one pass at results time)."""

    __slots__ = ("f", "_cache")

    def __init__(self, fleet: "FleetState"):
        self.f = fleet
        self._cache: list = [None] * fleet.n

    def __len__(self) -> int:
        return self.f.n

    def __getitem__(self, i: int) -> FleetSessionView:
        v = self._cache[i]
        if v is None:
            v = self._cache[i] = FleetSessionView(self.f, i)
        return v

    def __iter__(self):
        return (self[i] for i in range(self.f.n))


class FleetState:
    """The whole stub fleet as parallel arrays (one row per client).

    Scalars broadcast; per-client values may be passed as length-``n``
    sequences. Defaults mirror `StubSession` + `LinkSpec` defaults, so
    ``FleetState(n)`` twins ``[StubSession(i) for i in range(n)]``.
    """

    is_fleet = True

    def __init__(self, n: int, *, fps=4.0, t_update=10.0, k_iters=20,
                 rate=1.0, dynamics=0.01, frame_bytes=7000,
                 delta_bytes=20_000, state_bytes=32_000_000, eval_stride=6,
                 up_kbps=1000.0, down_kbps=2000.0, prop_delay_s=0.05,
                 telemetry: str = "full"):
        if n <= 0:
            raise ValueError(f"a fleet needs at least one client, got {n}")
        if telemetry not in TELEMETRY_MODES:
            raise ValueError(f"telemetry must be one of {TELEMETRY_MODES}, "
                             f"got {telemetry!r}")
        self.n = int(n)
        self.telemetry = telemetry
        f64, i64 = np.float64, np.int64
        self.fps = _arr(fps, n, f64)
        self.t_update = _arr(t_update, n, f64)
        self.k_iters = _arr(k_iters, n, i64)
        self.sampling_rate = _arr(rate, n, f64)
        self.phi = self.sampling_rate.copy()  # stubs: configured rate IS φ
        self.dynamics = _arr(dynamics, n, f64)
        self.frame_bytes = _arr(frame_bytes, n, i64)
        self.delta_bytes = _arr(delta_bytes, n, i64)
        self.state_bytes = _arr(state_bytes, n, i64)
        self.eval_interval_s = _arr(eval_stride, n, f64) / self.fps
        self.last_update_t = np.zeros(n, f64)
        self.outbox_depth = np.zeros(n, i64)
        self.ingested = np.zeros(n, i64)
        self.phases = np.zeros(n, i64)
        self.admitted = np.ones(n, dtype=bool)
        self.edge_rate = np.full(n, np.nan, f64)  # nan = no delivered rate
        # link state (ledger bytes and Link.bytes_carried are incremented
        # identically in the per-object path, so one array serves both)
        self.up_kbps = _arr(up_kbps, n, f64)
        self.down_kbps = _arr(down_kbps, n, f64)
        self.prop_delay_s = _arr(prop_delay_s, n, f64)
        self.up_busy = np.zeros(n, f64)
        self.down_busy = np.zeros(n, f64)
        self.up_bytes = np.zeros(n, i64)
        self.down_bytes = np.zeros(n, i64)
        self.up_transfers = np.zeros(n, i64)
        self.down_transfers = np.zeros(n, i64)
        self._up_traces: list = [None] * n  # per-client RateTrace overrides
        self._down_traces: list = [None] * n
        self._n_traced = 0
        # sparse per-client records (only clients that get grants pay)
        self._phase_devices: dict[int, list] = {}
        self._phase_streams: dict[int, list] = {}
        if telemetry == "full":
            # cohort chunks, re-grouped per client by one stable argsort at
            # read time — same values in the same order as per-object lists
            self._miou_c: list[np.ndarray] = []
            self._miou_v: list[np.ndarray] = []
            self._miou_sorted = None
            self._lat: dict[int, list[float]] = {}
        else:
            self._m_n = np.zeros(n, i64)
            self._m_sum = np.zeros(n, f64)
            self._lat_n = np.zeros(n, i64)
            self._lat_sum = np.zeros(n, f64)
            self._lat_max = np.zeros(n, f64)
        self._views = _FleetViews(self)

    # ---- engine surface --------------------------------------------------
    def views(self) -> _FleetViews:
        return self._views

    def effective_rate(self, clients: np.ndarray) -> np.ndarray:
        """Per-client `edge_sampling_rate`: the last *delivered* ASR rate
        where one exists, the server-side rate otherwise."""
        er = self.edge_rate[clients]
        return np.where(np.isnan(er), self.sampling_rate[clients], er)

    @property
    def any_link_traces(self) -> bool:
        return self._n_traced > 0

    # ---- mIoU telemetry --------------------------------------------------
    def record_mious(self, clients: np.ndarray, values: np.ndarray) -> None:
        if self.telemetry == "full":
            self._miou_c.append(np.asarray(clients, np.int64).copy())
            self._miou_v.append(np.asarray(values, np.float64).copy())
            self._miou_sorted = None
        else:
            np.add.at(self._m_n, clients, 1)
            np.add.at(self._m_sum, clients, values)

    def record_miou(self, i: int, v: float) -> None:
        if self.telemetry == "full":
            self._miou_c.append(np.array([i], np.int64))
            self._miou_v.append(np.array([v], np.float64))
            self._miou_sorted = None
        else:
            self._m_n[i] += 1
            self._m_sum[i] += v

    def _mious_by_client(self) -> tuple[np.ndarray, np.ndarray]:
        if self._miou_sorted is None:
            if self._miou_c:
                cc = np.concatenate(self._miou_c)
                vv = np.concatenate(self._miou_v)
                order = np.argsort(cc, kind="stable")  # keeps time order
                self._miou_sorted = (cc[order], vv[order])
            else:
                self._miou_sorted = (np.empty(0, np.int64),
                                     np.empty(0, np.float64))
        return self._miou_sorted

    def miou_values(self, i: int) -> np.ndarray:
        if self.telemetry != "full":
            raise ValueError(
                "per-eval mIoU samples are not kept under telemetry="
                "'moments'; use miou_mean_of or telemetry='full'")
        cc, vv = self._mious_by_client()
        lo, hi = np.searchsorted(cc, [i, i + 1])
        return vv[lo:hi]

    def miou_mean_of(self, i: int) -> float:
        if self.telemetry == "full":
            vals = self.miou_values(i)
            return float(np.mean(vals)) if len(vals) else float("nan")
        n = int(self._m_n[i])
        return float(self._m_sum[i] / n) if n else float("nan")

    # ---- delta-latency telemetry ----------------------------------------
    def record_latency(self, i: int, lat: float) -> None:
        if self.telemetry == "full":
            self._lat.setdefault(i, []).append(lat)
        else:
            self._lat_n[i] += 1
            self._lat_sum[i] += lat
            if lat > self._lat_max[i]:
                self._lat_max[i] = lat

    def latency_values_of(self, i: int):
        if self.telemetry == "full":
            return self._lat.get(i, [])
        return None  # moments mode: samples are folded, not kept

    def latency_summary_of(self, i: int) -> tuple[int, float, float]:
        if self.telemetry == "full":
            vals = self._lat.get(i, [])
            return (len(vals), float(sum(vals)),
                    float(max(vals)) if vals else 0.0)
        return (int(self._lat_n[i]), float(self._lat_sum[i]),
                float(self._lat_max[i]))
