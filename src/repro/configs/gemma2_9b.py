"""Gemma-2 9B [arXiv:2408.00118] — dense, local+global alternating attention,
GeGLU, logit softcaps, post-block norms, GQA kv=8, head_dim=256."""
from repro.models.common import ModelConfig

_BASE = dict(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118",
    pattern=("attn_local", "attn"),
    window_size=4096,
    mlp_act="geglu",
    norm="rms",
    post_norm=True,
    embed_scale=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    rope_theta=10_000.0,
)


def full() -> ModelConfig:
    return ModelConfig(
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256_000,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
        **_BASE,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        **dict(_BASE, window_size=16),
    )
