"""RWKV-6 "Finch" 3B [arXiv:2404.05892] — attention-free, data-dependent
decay time-mix; head_size 64 (40 heads at d_model 2560)."""
from repro.models.common import ModelConfig

_BASE = dict(
    name="rwkv6-3b",
    family="ssm",
    source="arXiv:2404.05892",
    pattern=("rwkv",),
    mlp_act="gelu",  # unused by rwkv blocks; channel-mix has its own form
    norm="layer",
    pos="none",
    ssm_head_dim=64,
)


def full() -> ModelConfig:
    return ModelConfig(
        num_layers=32,
        d_model=2560,
        num_heads=1,
        num_kv_heads=1,
        d_ff=8960,
        vocab_size=65536,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
        **_BASE,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        num_layers=2,
        d_model=128,
        num_heads=1,
        num_kv_heads=1,
        d_ff=256,
        vocab_size=512,
        **dict(_BASE, ssm_head_dim=32),
    )
