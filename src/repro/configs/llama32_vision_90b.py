"""Llama-3.2-Vision 90B [hf:meta-llama/Llama-3.2-11B-Vision, scaled per
assignment] — 100 layers with gated cross-attention image layers every 5th;
vision encoder is a stub (input_specs feeds patch embeddings)."""
from repro.models.common import ModelConfig

_BASE = dict(
    name="llama-3.2-vision-90b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    pattern=("attn", "attn", "attn", "attn", "xattn"),
    mlp_act="swiglu",
    norm="rms",
    rope_theta=500_000.0,
)


def full() -> ModelConfig:
    return ModelConfig(
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128_256,
        num_xattn_tokens=1601,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
        **_BASE,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        num_layers=5,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_xattn_tokens=24,
        **_BASE,
    )
