"""Whisper large-v3 [arXiv:2212.04356] — encoder-decoder; the mel+conv
frontend is a stub (input_specs feeds 1500 frame embeddings). Deviation
(DESIGN.md §8): sinusoidal positions for both stacks instead of a learned
decoder table (a 500k-row learned table is not meaningful)."""
from repro.models.common import ModelConfig

_BASE = dict(
    name="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356",
    pattern=("attn_xattn",),
    mlp_act="gelu",
    norm="layer",
    pos="sinusoidal",
)


def full() -> ModelConfig:
    return ModelConfig(
        num_layers=32,
        encoder_layers=32,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        num_xattn_tokens=1500,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
        **_BASE,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        num_layers=2,
        encoder_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_xattn_tokens=24,
        **_BASE,
    )
