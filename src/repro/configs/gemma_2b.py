"""Gemma 2B [arXiv:2403.08295] — dense, GeGLU, head_dim=256, MQA (kv=1)."""
from repro.models.common import ModelConfig

_BASE = dict(
    name="gemma-2b",
    family="dense",
    source="arXiv:2403.08295",
    pattern=("attn",),
    mlp_act="geglu",
    norm="rms",
    embed_scale=True,
    rope_theta=10_000.0,
)


def full() -> ModelConfig:
    return ModelConfig(
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256_000,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
        **_BASE,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        **_BASE,
    )
