"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B] — fine-grained MoE
(DeepSeek-style): 64 routed experts top-6 + 2 shared experts, expert
d_ff=1408."""
from repro.models.common import ModelConfig

_BASE = dict(
    name="moonshot-v1-16b-a3b",
    family="dense",  # per assignment table label; structurally MoE
    source="hf:moonshotai/Moonlight-16B-A3B",
    pattern=("moe",),
    mlp_act="swiglu",
    norm="rms",
    rope_theta=50_000.0,
)


def full() -> ModelConfig:
    return ModelConfig(
        num_layers=48,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=163_840,
        num_experts=64,
        experts_per_token=6,
        expert_d_ff=1408,
        num_shared_experts=2,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
        **_BASE,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=64,
        vocab_size=512,
        num_experts=4,
        experts_per_token=2,
        expert_d_ff=64,
        num_shared_experts=1,
        **_BASE,
    )
