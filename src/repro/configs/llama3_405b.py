"""Llama 3.1 405B [arXiv:2407.21783] — dense, GQA kv=8, 126 layers."""
from repro.models.common import ModelConfig

_BASE = dict(
    name="llama3-405b",
    family="dense",
    source="arXiv:2407.21783",
    pattern=("attn",),
    mlp_act="swiglu",
    norm="rms",
    rope_theta=500_000.0,
)


def full() -> ModelConfig:
    return ModelConfig(
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        head_dim=128,
        d_ff=53248,
        vocab_size=128_256,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
        **_BASE,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        **_BASE,
    )
