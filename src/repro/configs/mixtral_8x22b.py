"""Mixtral 8x22B [arXiv:2401.04088] — 8 experts top-2, sliding-window
attention (per assignment), GQA kv=8."""
from repro.models.common import ModelConfig

_BASE = dict(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088",
    pattern=("moe_local",),
    window_size=4096,
    mlp_act="swiglu",
    norm="rms",
    rope_theta=1_000_000.0,
)


def full() -> ModelConfig:
    return ModelConfig(
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32768,
        num_experts=8,
        experts_per_token=2,
        expert_d_ff=16384,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
        **_BASE,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        num_experts=4,
        experts_per_token=2,
        expert_d_ff=128,
        **dict(_BASE, window_size=16),
    )
