"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family] —
alternating dense/MoE layers, 128 routed experts top-1 + shared expert."""
from repro.models.common import ModelConfig

_BASE = dict(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    pattern=("attn", "moe"),
    mlp_act="swiglu",
    norm="rms",
    rope_theta=500_000.0,
)


def full() -> ModelConfig:
    return ModelConfig(
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        num_experts=128,
        experts_per_token=1,
        expert_d_ff=8192,
        num_shared_experts=1,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
        **_BASE,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        num_experts=4,
        experts_per_token=1,
        expert_d_ff=128,
        num_shared_experts=1,
        **_BASE,
    )
