"""Zamba2-7B [arXiv:2411.15242] — hybrid: Mamba2 trunk + shared attention
block applied periodically (weights reused; here: at the start of each
3-mamba-layer scan group, 27 applications over 81 layers)."""
from repro.models.common import ModelConfig

_BASE = dict(
    name="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    pattern=("mamba", "mamba", "mamba"),
    shared_attn=True,
    mlp_act="swiglu",
    norm="rms",
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
)


def full() -> ModelConfig:
    return ModelConfig(
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        d_ff=14336,
        vocab_size=32_000,
        ssm_state=64,
        ssm_chunk=128,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat=True,
        **_BASE,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        num_layers=3,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        ssm_state=16,
        ssm_chunk=8,
        **dict(_BASE, ssm_head_dim=32),
    )
