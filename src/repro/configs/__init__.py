"""Architecture config registry.

Every assigned architecture has a module exporting ``full()`` (the exact
published config) and ``smoke()`` (a reduced same-family variant: <=2 pattern
repeats, d_model<=512, <=4 experts) used by CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.models.common import ModelConfig

ARCH_IDS = (
    "gemma2_9b",
    "zamba2_7b",
    "llama32_vision_90b",
    "whisper_large_v3",
    "gemma_2b",
    "moonshot_v1_16b_a3b",
    "rwkv6_3b",
    "mixtral_8x22b",
    "llama3_405b",
    "llama4_maverick_400b_a17b",
)

# external spelling (--arch flag) -> module name
ALIASES = {
    "gemma2-9b": "gemma2_9b",
    "zamba2-7b": "zamba2_7b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "whisper-large-v3": "whisper_large_v3",
    "gemma-2b": "gemma_2b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "rwkv6-3b": "rwkv6_3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama3-405b": "llama3_405b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "ams-seg": "ams_seg",
}


def _module(name: str):
    name = ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).full()
    return cfg.replace(**overrides) if overrides else cfg


def get_smoke(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).smoke()
    return cfg.replace(**overrides) if overrides else cfg
