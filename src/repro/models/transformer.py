"""Generic decoder stack: block dispatch + scan-over-groups assembly.

Every architecture is a repeated `pattern` of block kinds:

    attn        full causal self-attention + MLP
    attn_local  sliding-window causal self-attention + MLP
    attn_nc     non-causal self-attention + MLP (whisper encoder)
    xattn       cross-attention (onto stub frontend memory) + MLP
    attn_xattn  self-attn + cross-attn + MLP in one block (whisper decoder)
    moe         full causal self-attention + MoE
    moe_local   sliding-window self-attention + MoE (mixtral)
    mamba       Mamba2 SSD block (no separate MLP)
    rwkv        RWKV6 time-mix + channel-mix

Parameters for each pattern position are stacked over groups and the stack is
consumed by one `lax.scan` (optionally remat'd), keeping HLO size independent
of depth — essential for 126-layer models compiled on a 512-device mesh.

`cfg.shared_attn` (zamba2): one *shared* attention block (weights outside the
scan) is applied at the start of every group; its KV caches are per-group.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.common import ModelConfig, ParamMeta, stack_group
from repro.models.layers import (
    apply_norm,
    embed_apply,
    embed_metas,
    mlp_apply,
    mlp_metas,
    norm_meta,
    unembed_apply,
)
from repro.models.ssm import mamba2, rwkv6

ATTN_KINDS = ("attn", "attn_local", "attn_nc", "moe", "moe_local")


# ---------------------------------------------------------------------------
# Block metas
# ---------------------------------------------------------------------------


def block_metas(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    if kind in ("attn", "attn_local", "attn_nc"):
        m = {"ln1": norm_meta(d), "attn": attn.attn_metas(cfg), "ln2": norm_meta(d),
             "mlp": mlp_metas(cfg)}
        if cfg.post_norm:
            m["ln1_post"] = norm_meta(d)
            m["ln2_post"] = norm_meta(d)
        return m
    if kind in ("moe", "moe_local"):
        m = {"ln1": norm_meta(d), "attn": attn.attn_metas(cfg), "ln2": norm_meta(d),
             "moe": moe_mod.moe_metas(cfg)}
        if cfg.post_norm:
            m["ln1_post"] = norm_meta(d)
            m["ln2_post"] = norm_meta(d)
        return m
    if kind == "xattn":
        return {"ln1": norm_meta(d), "xattn": attn.attn_metas(cfg),
                "ln2": norm_meta(d), "mlp": mlp_metas(cfg),
                "gate": ParamMeta((1,), ("unsharded",), init="zeros")}
    if kind == "attn_xattn":
        return {"ln1": norm_meta(d), "attn": attn.attn_metas(cfg),
                "lnx": norm_meta(d), "xattn": attn.attn_metas(cfg),
                "ln2": norm_meta(d), "mlp": mlp_metas(cfg)}
    if kind == "mamba":
        return {"ln1": norm_meta(d), "mamba": mamba2.mamba2_metas(cfg)}
    if kind == "rwkv":
        return {"ln1": norm_meta(d), "ln2": norm_meta(d), "rwkv": rwkv6.rwkv6_metas(cfg)}
    raise ValueError(f"unknown block kind {kind}")


def model_metas(cfg: ModelConfig) -> dict:
    groups = {
        f"b{i}": stack_group(block_metas(cfg, kind), cfg.num_groups)
        for i, kind in enumerate(cfg.pattern)
    }
    m = {"embed": embed_metas(cfg), "groups": groups, "final_norm": norm_meta(cfg.d_model)}
    if cfg.shared_attn:
        m["shared_attn"] = {
            "ln1": norm_meta(cfg.d_model),
            "attn": attn.attn_metas(cfg),
            "ln2": norm_meta(cfg.d_model),
            "mlp": mlp_metas(cfg),
        }
    if cfg.encoder_layers:
        m["encoder"] = {
            "groups": {
                "b0": stack_group(block_metas(cfg, "attn_nc"), cfg.encoder_layers)
            },
            "final_norm": norm_meta(cfg.d_model),
        }
    return m


# ---------------------------------------------------------------------------
# Block apply (full sequence / training / prefill)
# ---------------------------------------------------------------------------


def _window_for(cfg: ModelConfig, kind: str) -> int:
    return cfg.window_size if kind in ("attn_local", "moe_local") else 0


def _attn_sub(cfg, p, x, kind, positions, want_cache):
    h = apply_norm(cfg, x, p["ln1"])
    causal = kind != "attn_nc"
    out, kv = attn.self_attention(
        cfg, p["attn"], h, window=_window_for(cfg, kind), positions=positions, causal=causal
    )
    if cfg.post_norm:
        out = apply_norm(cfg, out, p["ln1_post"])
    cache = {"k": kv[0], "v": kv[1]} if want_cache else None
    return x + out, cache


def block_apply(cfg: ModelConfig, kind: str, p: dict, x, *, positions, memory=None,
                want_cache: bool = False):
    """Returns (x, aux_loss, cache_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_local", "attn_nc"):
        x, cache = _attn_sub(cfg, p, x, kind, positions, want_cache)
        h = apply_norm(cfg, x, p["ln2"])
        out = mlp_apply(cfg, p["mlp"], h)
        if cfg.post_norm:
            out = apply_norm(cfg, out, p["ln2_post"])
        return x + out, aux, cache
    if kind in ("moe", "moe_local"):
        x, cache = _attn_sub(cfg, p, x, kind, positions, want_cache)
        h = apply_norm(cfg, x, p["ln2"])
        out, aux = moe_mod.moe_apply(cfg, p["moe"], h)
        if cfg.post_norm:
            out = apply_norm(cfg, out, p["ln2_post"])
        return x + out, aux, cache
    if kind == "xattn":
        h = apply_norm(cfg, x, p["ln1"])
        out = attn.cross_attention(cfg, p["xattn"], h, memory)
        x = x + jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * out
        h = apply_norm(cfg, x, p["ln2"])
        x = x + mlp_apply(cfg, p["mlp"], h)
        cache = None
        if want_cache:
            xc = attn.precompute_cross_cache(cfg, p["xattn"], memory)
            cache = {"xk": xc["k"], "xv": xc["v"]}
        return x, aux, cache
    if kind == "attn_xattn":
        h = apply_norm(cfg, x, p["ln1"])
        out, kv = attn.self_attention(cfg, p["attn"], h, window=0, positions=positions)
        x = x + out
        h = apply_norm(cfg, x, p["lnx"])
        x = x + attn.cross_attention(cfg, p["xattn"], h, memory)
        h = apply_norm(cfg, x, p["ln2"])
        x = x + mlp_apply(cfg, p["mlp"], h)
        cache = None
        if want_cache:
            xc = attn.precompute_cross_cache(cfg, p["xattn"], memory)
            cache = {"k": kv[0], "v": kv[1], "xk": xc["k"], "xv": xc["v"]}
        return x, aux, cache
    if kind == "mamba":
        h = apply_norm(cfg, x, p["ln1"])
        if want_cache:
            out, st = mamba2.mamba2_apply(cfg, p["mamba"], h, want_state=True)
            return x + out, aux, st
        return x + mamba2.mamba2_apply(cfg, p["mamba"], h), aux, None
    if kind == "rwkv":
        h1 = apply_norm(cfg, x, p["ln1"])
        if want_cache:
            out, wkv = rwkv6.rwkv6_time_mix(cfg, p["rwkv"]["tm"], h1, want_state=True)
        else:
            out, wkv = rwkv6.rwkv6_time_mix(cfg, p["rwkv"]["tm"], h1), None
        x = x + out
        h2 = apply_norm(cfg, x, p["ln2"])
        x = x + rwkv6.rwkv6_channel_mix(cfg, p["rwkv"]["cm"], h2)
        cache = (
            {"wkv": wkv, "tm_last": h1[:, -1], "cm_last": h2[:, -1]} if want_cache else None
        )
        return x, aux, cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full forward / loss
# ---------------------------------------------------------------------------


def _constrain(cfg: ModelConfig, x):
    """Pin activation sharding (batch over act_sharding axes) if configured."""
    if cfg.act_sharding is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = P(tuple(cfg.act_sharding) or None, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def _shared_attn_apply(cfg, sp, x, positions):
    h = apply_norm(cfg, x, sp["ln1"])
    out, _ = attn.self_attention(cfg, sp["attn"], h, window=cfg.window_size, positions=positions)
    x = x + out
    h = apply_norm(cfg, x, sp["ln2"])
    return x + mlp_apply(cfg, sp["mlp"], h)


def _stack_forward(cfg: ModelConfig, params: dict, x, positions, memory=None):
    """Decoder trunk (no embed/unembed). Returns (x, total_aux)."""
    shared = params.get("shared_attn")

    def group_body(carry, gp):
        h = _constrain(cfg, carry)
        aux = jnp.zeros((), jnp.float32)
        if shared is not None:
            h = _shared_attn_apply(cfg, shared, h, positions)
        for i, kind in enumerate(cfg.pattern):
            h, a, _ = block_apply(cfg, kind, gp[f"b{i}"], h, positions=positions, memory=memory)
            h = _constrain(cfg, h)
            aux = aux + a
        return h, aux

    body = jax.checkpoint(group_body) if cfg.remat else group_body
    x, auxs = jax.lax.scan(body, x, params["groups"], unroll=cfg.scan_unroll)
    return x, auxs.sum()


def encode(cfg: ModelConfig, params: dict, frames):
    """Whisper-style encoder over stubbed frame embeddings (B, Sf, d)."""
    enc = params["encoder"]
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])

    def body(h, gp):
        h, _, _ = block_apply(cfg, "attn_nc", gp["b0"], h, positions=positions)
        return h, None

    b = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(b, frames.astype(cfg.cdtype), enc["groups"], unroll=cfg.scan_unroll)
    return apply_norm(cfg, x, enc["final_norm"])


def forward(cfg: ModelConfig, params: dict, tokens, memory=None):
    """tokens: (B,S) int32; memory: (B,Sm,d) stub embeddings (vlm/audio).
    Returns (logits (B,S,V), aux)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.encoder_layers and memory is not None:
        memory = encode(cfg, params, memory)
    elif memory is not None:
        memory = memory.astype(cfg.cdtype)
    x = embed_apply(cfg, params["embed"], tokens, positions)
    x, aux = _stack_forward(cfg, params, x, positions, memory)
    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed_apply(cfg, params["embed"], x)
    return logits, aux


def distill_loss(cfg: ModelConfig, params: dict, batch: dict):
    """Token-level knowledge-distillation loss: CE of the student against the
    teacher's hard labels (the paper trains on teacher argmax labels) plus the
    MoE load-balance aux. batch: {tokens, labels[, memory]}."""
    logits, aux = forward(cfg, params, batch["tokens"], batch.get("memory"))
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, batch["labels"][..., None], axis=-1)[..., 0]
    ce = (lse - lab).mean()
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def ring_len(cfg: ModelConfig, kind: str, seq: int) -> int:
    """Self-attention cache length for a block: the full seq, or the ring
    size min(seq, window) under the §Perf ring-cache optimization."""
    if not cfg.decode_window_slicing:
        return seq
    if kind in ("attn_local", "moe_local") and cfg.window_size:
        w = cfg.window_size
    elif kind == "shared":
        w = cfg.window_size or cfg.attn_window_override
    else:
        w = cfg.attn_window_override
    return min(seq, w) if w else seq


def cache_metas(cfg: ModelConfig, batch: int, seq: int, mem_len: int = 0) -> dict:
    """ParamMeta tree describing the decode cache (shapes + logical axes);
    materialize with zeros, or make abstract for the dry-run."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    G = cfg.num_groups

    def kv_meta(length, seq_ax="cache_seq"):
        # cross-attn memory caches use "mem_seq" (odd lengths: 1601/1500 —
        # never sharded); self-attn caches use "cache_seq".
        return ParamMeta((G, batch, length, kv, hd),
                         ("layers", "batch", seq_ax, "kv_heads", "unsharded"))

    d_inner, H, Pm, N = (cfg.ssm_expand * cfg.d_model,
                         (cfg.ssm_expand * cfg.d_model) // max(cfg.ssm_head_dim, 1),
                         cfg.ssm_head_dim, cfg.ssm_state)
    caches: dict = {}
    for i, kind in enumerate(cfg.pattern):
        key = f"b{i}"
        if kind in ATTN_KINDS:
            r = ring_len(cfg, kind, seq)
            caches[key] = {"k": kv_meta(r), "v": kv_meta(r)}
        elif kind == "xattn":
            caches[key] = {"xk": kv_meta(mem_len, "mem_seq"), "xv": kv_meta(mem_len, "mem_seq")}
        elif kind == "attn_xattn":
            r = ring_len(cfg, kind, seq)
            caches[key] = {"k": kv_meta(r), "v": kv_meta(r),
                           "xk": kv_meta(mem_len, "mem_seq"),
                           "xv": kv_meta(mem_len, "mem_seq")}
        elif kind == "mamba":
            caches[key] = {
                "ssm": ParamMeta((G, batch, H, Pm, N),
                                 ("layers", "batch", "unsharded", "unsharded", "unsharded")),
                "conv_x": ParamMeta((G, batch, cfg.ssm_conv - 1, d_inner),
                                    ("layers", "batch", "unsharded", "ff")),
                "conv_bc": ParamMeta((G, batch, cfg.ssm_conv - 1, 2 * N),
                                     ("layers", "batch", "unsharded", "unsharded")),
            }
        elif kind == "rwkv":
            P_ = cfg.ssm_head_dim
            H_ = cfg.d_model // P_
            caches[key] = {
                "wkv": ParamMeta((G, batch, H_, P_, P_),
                                 ("layers", "batch", "unsharded", "unsharded", "unsharded")),
                "tm_last": ParamMeta((G, batch, cfg.d_model), ("layers", "batch", "embed")),
                "cm_last": ParamMeta((G, batch, cfg.d_model), ("layers", "batch", "embed")),
            }
    if cfg.shared_attn:
        r = ring_len(cfg, "shared", seq)
        caches["shared"] = {"k": kv_meta(r), "v": kv_meta(r)}
    return caches


def cache_dtype(path_key: str, default_dtype):
    """SSM/wkv recurrent states stay fp32; K/V and conv taps use model dtype."""
    return jnp.float32 if path_key in ("ssm", "wkv") else default_dtype


def init_cache(cfg: ModelConfig, batch: int, seq: int, mem_len: int = 0, dtype=None):
    dtype = dtype or cfg.cdtype
    metas = cache_metas(cfg, batch, seq, mem_len)
    return jax.tree_util.tree_map_with_path(
        lambda path, m: jnp.zeros(m.shape, cache_dtype(path[-1].key, dtype)),
        metas, is_leaf=lambda v: isinstance(v, ParamMeta),
    )


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def block_decode(cfg: ModelConfig, kind: str, p: dict, x, cache, pos):
    """One-token decode for a single block. Returns (x, new_cache)."""
    if kind in ("attn", "attn_local", "moe", "moe_local"):
        h = apply_norm(cfg, x, p["ln1"])
        out, new_kv = attn.decode_self_attention(
            cfg, p["attn"], h, cache, pos, window=_window_for(cfg, kind)
        )
        if cfg.post_norm:
            out = apply_norm(cfg, out, p["ln1_post"])
        x = x + out
        h = apply_norm(cfg, x, p["ln2"])
        if kind in ("moe", "moe_local"):
            out, _ = moe_mod.moe_apply(cfg, p["moe"], h)
        else:
            out = mlp_apply(cfg, p["mlp"], h)
        if cfg.post_norm:
            out = apply_norm(cfg, out, p["ln2_post"])
        return x + out, new_kv
    if kind == "xattn":
        h = apply_norm(cfg, x, p["ln1"])
        out = attn.decode_cross_attention(cfg, p["xattn"], h, {"k": cache["xk"], "v": cache["xv"]})
        x = x + jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * out
        h = apply_norm(cfg, x, p["ln2"])
        return x + mlp_apply(cfg, p["mlp"], h), cache
    if kind == "attn_xattn":
        h = apply_norm(cfg, x, p["ln1"])
        out, new_kv = attn.decode_self_attention(
            cfg, p["attn"], h, {"k": cache["k"], "v": cache["v"]}, pos, window=0
        )
        x = x + out
        h = apply_norm(cfg, x, p["lnx"])
        x = x + attn.decode_cross_attention(cfg, p["xattn"], h,
                                            {"k": cache["xk"], "v": cache["xv"]})
        h = apply_norm(cfg, x, p["ln2"])
        x = x + mlp_apply(cfg, p["mlp"], h)
        return x, dict(cache, k=new_kv["k"], v=new_kv["v"])
    if kind == "mamba":
        h = apply_norm(cfg, x, p["ln1"])
        out, new_cache = mamba2.mamba2_decode(cfg, p["mamba"], h, cache)
        return x + out, new_cache
    if kind == "rwkv":
        h = apply_norm(cfg, x, p["ln1"])
        out, new_cache = rwkv6.rwkv6_decode(cfg, p["rwkv"], h, dict(cache))
        x = x + out
        h = apply_norm(cfg, x, p["ln2"])
        out = rwkv6.rwkv6_channel_mix(cfg, p["rwkv"]["cm"], h, last=cache["cm_last"])
        new_cache = dict(new_cache, cm_last=h[:, 0])
        return x + out, new_cache
    raise ValueError(kind)


def decode_step(cfg: ModelConfig, params: dict, caches: dict, tokens, pos):
    """serve_step: one new token against a cache of `seq` positions.
    tokens: (B,1) int32; pos: scalar int32. Returns (logits (B,1,V), caches')."""
    B = tokens.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    x = embed_apply(cfg, params["embed"], tokens, positions)
    shared = params.get("shared_attn")
    shared_cache = caches.get("shared")

    group_caches = {k: v for k, v in caches.items() if k != "shared"}
    xs = (params["groups"], group_caches)
    if shared is not None:
        xs = (params["groups"], group_caches, shared_cache)

    def group_body(carry, gxs):
        h = carry
        if shared is not None:
            gp, gcache, scache = gxs
            h2 = apply_norm(cfg, h, shared["ln1"])
            out, new_sc = attn.decode_self_attention(
                cfg, shared["attn"], h2, scache, pos, window=cfg.window_size
            )
            h = h + out
            h2 = apply_norm(cfg, h, shared["ln2"])
            h = h + mlp_apply(cfg, shared["mlp"], h2)
        else:
            gp, gcache = gxs
            new_sc = None
        new_gcache = {}
        for i, kind in enumerate(cfg.pattern):
            h, new_gcache[f"b{i}"] = block_decode(cfg, kind, gp[f"b{i}"], h, gcache[f"b{i}"], pos)
        ys = (new_gcache, new_sc) if shared is not None else new_gcache
        return h, ys

    x, ys = jax.lax.scan(group_body, x, xs, unroll=cfg.scan_unroll)
    if shared is not None:
        new_caches, new_shared = ys
        new_caches = dict(new_caches, shared=new_shared)
    else:
        new_caches = dict(ys)
    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed_apply(cfg, params["embed"], x)
    return logits, new_caches


def prefill(cfg: ModelConfig, params: dict, tokens, cache_len: int, memory=None):
    """Run the full prompt, returning (logits of last position, caches sized
    cache_len). Attention caches are filled with the prompt K/V; SSM states
    are produced by the chunked scans' final states via a replay pass."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.encoder_layers and memory is not None:
        memory = encode(cfg, params, memory)
    elif memory is not None:
        memory = memory.astype(cfg.cdtype)
    x = embed_apply(cfg, params["embed"], tokens, positions)
    shared = params.get("shared_attn")

    def group_body(carry, gp):
        h = carry
        caches = {}
        if shared is not None:
            h2 = apply_norm(cfg, h, shared["ln1"])
            out, kv = attn.self_attention(cfg, shared["attn"], h2,
                                          window=cfg.window_size, positions=positions)
            h = h + out
            h2 = apply_norm(cfg, h, shared["ln2"])
            h = h + mlp_apply(cfg, shared["mlp"], h2)
            caches["shared"] = {"k": kv[0], "v": kv[1]}
        for i, kind in enumerate(cfg.pattern):
            h, _, c = block_apply(cfg, kind, gp[f"b{i}"], h, positions=positions,
                                  memory=memory, want_cache=True)
            if c is not None:
                caches[f"b{i}"] = c
        return h, caches

    body = jax.checkpoint(group_body) if cfg.remat else group_body
    x, caches = jax.lax.scan(body, x, params["groups"], unroll=cfg.scan_unroll)
    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed_apply(cfg, params["embed"], x[:, -1:])

    # Lay self-attention K/V caches out for decode: either padded to
    # cache_len, or — under the ring-cache optimization — the last R
    # positions rolled into their `p mod R` slots (cross "xk"/"xv" and SSM
    # states keep their natural shapes).
    def to_ring(c, kind):
        Sp = c.shape[2]
        R = ring_len(cfg, kind, cache_len)
        if Sp <= R:  # slots p % R == p: plain end-padding
            return jnp.pad(c, ((0, 0), (0, 0), (0, R - Sp), (0, 0), (0, 0)))
        return jnp.roll(c[:, :, Sp - R :], Sp % R, axis=2)

    kind_of = {f"b{i}": kind for i, kind in enumerate(cfg.pattern)}
    kind_of["shared"] = "shared"
    caches = {
        k: {kk: (to_ring(vv, kind_of[k]) if kk in ("k", "v") else vv)
            for kk, vv in v.items()}
        for k, v in caches.items()
    }
    return logits, caches
