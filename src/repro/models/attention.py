"""Attention: GQA/MQA/MHA with full / sliding-window / cross variants.

Projection weights keep heads factored as (kv_heads, q_per_group) so that
either factor can be tensor-parallel sharded depending on the arch/mesh
(see launch/shardings.py):

    wq: (d, KV, G, hd)   q = einsum('bsd,dkgh->bskgh')
    wk: (d, KV, hd)      k = einsum('bsd,dkh->bskh')
    wv: (d, KV, hd)
    wo: (KV, G, hd, d)

The full-sequence path is a chunked flash attention (online softmax, memory
O(q_chunk * kv_chunk)) written in pure jnp — the TPU production path swaps in
the Pallas kernel (kernels/flash_attention) behind cfg.use_pallas.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamMeta
from repro.models.layers import apply_rope, softcap

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Metas
# ---------------------------------------------------------------------------


def attn_metas(cfg: ModelConfig) -> dict:
    d, kv, hd = cfg.d_model, cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // kv
    return {
        "wq": ParamMeta((d, kv, g, hd), ("attn_embed", "kv_heads", "qgroups", "unsharded")),
        "wk": ParamMeta((d, kv, hd), ("attn_embed", "kv_heads", "unsharded")),
        "wv": ParamMeta((d, kv, hd), ("attn_embed", "kv_heads", "unsharded")),
        "wo": ParamMeta((kv, g, hd, d), ("kv_heads", "qgroups", "unsharded", "attn_embed")),
    }


# ---------------------------------------------------------------------------
# Chunked flash attention (jnp reference/production-CPU path)
# ---------------------------------------------------------------------------


def _pick_chunk(n: int, target: int) -> int:
    c = min(target, n)
    while n % c:
        c -= 1
    return c


def flash_attention(
    q,  # (B, Sq, KV, G, hd)
    k,  # (B, Skv, KV, hd)
    v,  # (B, Skv, KV, hd)
    *,
    causal: bool = True,
    window: int = 0,
    logit_softcap: float = 0.0,
    q_offset: int = 0,  # absolute position of q[0] (for decode-style calls)
    q_chunk: int = 512,
    kv_chunk: int = 512,
    unroll: bool = False,
):
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    scale = hd**-0.5
    qc = _pick_chunk(Sq, q_chunk)
    kc = _pick_chunk(Skv, kv_chunk)
    nq, nk = Sq // qc, Skv // kc

    qr = q.reshape(B, nq, qc, KV, G, hd)
    kr = k.reshape(B, nk, kc, KV, hd)
    vr = v.reshape(B, nk, kc, KV, hd)

    q_pos = q_offset + jnp.arange(Sq).reshape(nq, qc)
    k_pos = jnp.arange(Skv).reshape(nk, kc)

    def q_chunk_body(_, qin):
        qi, qp = qin  # (B,qc,KV,G,hd), (qc,)

        def kv_step(carry, kin):
            m, l, acc = carry
            ki, vi, kp = kin  # (B,kc,KV,hd), (B,kc,KV,hd), (kc,)
            s = jnp.einsum(
                "bqkgh,bskh->bkgqs", qi, ki, preferred_element_type=jnp.float32
            ) * scale  # (B,KV,G,qc,kc)
            if logit_softcap:
                s = logit_softcap * jnp.tanh(s / logit_softcap)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window:
                mask &= qp[:, None] - kp[None, :] < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bqkgh", p, vi.astype(jnp.float32))
            acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, qc, KV, G, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4), k_pos),
            unroll=unroll,
        )
        l = jnp.maximum(l, 1e-30)
        out = acc / l.transpose(0, 3, 1, 2)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(
        q_chunk_body, None, (qr.transpose(1, 0, 2, 3, 4, 5), q_pos), unroll=unroll
    )
    # outs: (nq, B, qc, KV, G, hd)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, hd)


def attention_ref(q, k, v, *, causal=True, window=0, logit_softcap=0.0, q_offset=0):
    """Naive O(S^2)-memory oracle used by tests."""
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)
    s = s * (hd**-0.5)
    if logit_softcap:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    qp = q_offset + jnp.arange(Sq)
    kp = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window:
        mask &= qp[:, None] - kp[None, :] < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Block-level apply
# ---------------------------------------------------------------------------


def _proj_qkv(cfg: ModelConfig, p: dict, x, x_kv=None, positions=None, rope: bool = True):
    x_kv = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dkgh->bskgh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x_kv, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x_kv, p["wv"])
    if rope and cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def self_attention(cfg: ModelConfig, p: dict, x, *, window: int, positions, causal=True):
    """Full-sequence self attention. Returns (out, (k, v)) — k/v feed the
    prefill KV cache."""
    q, k, v = _proj_qkv(cfg, p, x, positions=positions)
    eff_window = window
    if cfg.attn_window_override and not window:
        eff_window = cfg.attn_window_override  # long-context SWA variant
    o = flash_attention(
        q, k, v, causal=causal, window=eff_window, logit_softcap=cfg.attn_logit_softcap,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk, unroll=cfg.scan_unroll,
    )
    out = jnp.einsum("bskgh,kghd->bsd", o, p["wo"])
    return out, (k, v)


def cross_attention(cfg: ModelConfig, p: dict, x, memory):
    """Cross attention onto stubbed frontend embeddings (B, Sm, d).
    No causal mask, no rope (memory has its own implicit positions)."""
    q, k, v = _proj_qkv(cfg, p, x, x_kv=memory, rope=False)
    o = flash_attention(q, k, v, causal=False, window=0, logit_softcap=cfg.attn_logit_softcap,
                        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
                        unroll=cfg.scan_unroll)
    return jnp.einsum("bskgh,kghd->bsd", o, p["wo"])


def decode_self_attention(cfg: ModelConfig, p: dict, x, cache, pos, *, window: int):
    """One-token decode. x: (B,1,d); cache: dict(k,v) each (B,R,KV,hd);
    pos: scalar int32 — current position (same for the whole batch).

    §Perf hillclimb A (ring cache): when cfg.decode_window_slicing is on and
    the block is windowed, R == min(seq, window) and the cache is a ring
    buffer — slot j holds absolute position pos - ((pos - j) mod R). Reads
    are O(window) and *static* (no dynamic_slice across a sharded dim, which
    GSPMD would implement as a full-cache gather — measured and refuted in
    EXPERIMENTS.md §Perf A.1). Writes stay a single-slot DUS.
    Returns (out, new_cache)."""
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k_new, v_new = _proj_qkv(cfg, p, x, positions=positions)
    R = cache["k"].shape[1]
    eff_window = window or cfg.attn_window_override
    ring = bool(cfg.decode_window_slicing and eff_window)
    slot = pos % R if ring else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    j = jnp.arange(R)
    if ring:
        kp = pos - jnp.mod(pos - j, R)  # absolute position held by slot j
    else:
        kp = j
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)
    s = s * (cfg.resolved_head_dim**-0.5)
    if cfg.attn_logit_softcap:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    mask = (kp <= pos) & (kp >= 0)
    if eff_window:
        mask &= kp > pos - eff_window
    s = jnp.where(mask, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", pr, v.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bskgh,kghd->bsd", o, p["wo"])
    return out, {"k": k, "v": v}


def decode_cross_attention(cfg: ModelConfig, p: dict, x, mem_cache):
    """Decode-time cross attention; memory K/V precomputed at prefill.
    mem_cache: dict(k,v) each (B,Sm,KV,hd)."""
    q = jnp.einsum("bsd,dkgh->bskgh", x, p["wq"])
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, mem_cache["k"], preferred_element_type=jnp.float32)
    s = s * (cfg.resolved_head_dim**-0.5)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", pr, mem_cache["v"].astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bskgh,kghd->bsd", o, p["wo"])


def precompute_cross_cache(cfg: ModelConfig, p: dict, memory):
    k = jnp.einsum("bsd,dkh->bskh", memory, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", memory, p["wv"])
    return {"k": k, "v": v}
