"""Mixture-of-Experts layer: top-k router + capacity-based scatter dispatch.

Dispatch avoids the classic GShard (tokens, experts, capacity) one-hot —
instead tokens are scattered into an (E, C, d) buffer via cumulative position
assignment (O(T*E) ints, no T*E*C tensor). Experts shard over the "model"
mesh axis (expert parallelism); XLA inserts the token all-to-all at the
scatter/gather boundaries.

`moe_ref` is the dense oracle (every expert on every token) used by tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamMeta, dense_meta
from repro.models.layers import mlp_apply, mlp_metas


def moe_metas(cfg: ModelConfig) -> dict:
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    # expert weights get their own embed logical axis: under the §Perf
    # "moe_shard" lever it is detached from the FSDP data axis so the expert
    # matmuls contract an unsharded d (no capacity-buffer-sized all-reduces).
    m = {
        "router": ParamMeta((d, E), ("embed", "unsharded")),
        "wg": ParamMeta((E, d, ff), ("experts", "expert_embed", "expert_ff")),
        "wu": ParamMeta((E, d, ff), ("experts", "expert_embed", "expert_ff")),
        "wd": ParamMeta((E, ff, d), ("experts", "expert_ff", "expert_embed")),
    }
    if cfg.num_shared_experts:
        m["shared"] = mlp_metas(cfg, d_ff=cfg.num_shared_experts * ff)
    return m


def _act(cfg, g):
    return jax.nn.silu(g) if cfg.mlp_act == "swiglu" else jax.nn.gelu(g, approximate=True)


def _route(cfg: ModelConfig, p: dict, x_flat):
    """Returns (weights (T,k), idx (T,k), aux_loss)."""
    logits = (x_flat @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch/GShard load-balance auxiliary loss.
    E = cfg.num_experts
    one_hot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)  # primary assignment
    frac_tokens = one_hot.mean(axis=0)
    mean_probs = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * mean_probs)
    return weights.astype(x_flat.dtype), idx, aux


def moe_apply(cfg: ModelConfig, p: dict, x):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    k, E = cfg.experts_per_token, cfg.num_experts
    x_flat = x.reshape(B * S, d)
    T = B * S
    weights, idx, aux = _route(cfg, p, x_flat)

    cap = max(int(cfg.capacity_factor * T * k / E), 1)
    cap = min(cap, T)

    idx_f = idx.reshape(T * k)  # expert id per slot
    w_f = weights.reshape(T * k)
    # position of each slot within its expert, via cumulative count
    one_hot = (idx_f[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)  # (T*k, E)
    pos_f = (jnp.cumsum(one_hot, axis=0) * one_hot).sum(axis=-1) - 1  # (T*k,)
    keep = pos_f < cap

    tok_idx = jnp.repeat(jnp.arange(T), k)
    contrib = x_flat[tok_idx] * keep[:, None].astype(x_flat.dtype)
    buffer = jnp.zeros((E, cap, d), x_flat.dtype)
    buffer = buffer.at[idx_f, jnp.where(keep, pos_f, cap)].add(contrib, mode="drop")

    def _ep_constrain(t):
        # §Perf "moe_shard": pin expert-parallel layout (experts over the EP
        # axis, capacity over the data axes) so GSPMD routes tokens with an
        # all-to-all instead of reducing capacity-buffer partial sums.
        if cfg.moe_ep_axis is None and cfg.moe_cap_axes is None:
            return t
        from jax.sharding import PartitionSpec as P

        spec = P(cfg.moe_ep_axis, tuple(cfg.moe_cap_axes) if cfg.moe_cap_axes else None,
                 None)
        return jax.lax.with_sharding_constraint(t, spec)

    buffer = _ep_constrain(buffer)
    h = _act(cfg, jnp.einsum("ecd,edf->ecf", buffer, p["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", buffer, p["wu"])
    h_out = _ep_constrain(jnp.einsum("ecf,efd->ecd", h, p["wd"]))

    gathered = h_out[idx_f, jnp.where(keep, pos_f, 0)]  # (T*k, d)
    gathered = gathered * (w_f * keep.astype(w_f.dtype))[:, None]
    out = gathered.reshape(T, k, d).sum(axis=1)

    if cfg.num_shared_experts:
        out = out + mlp_apply(cfg, p["shared"], x_flat)
    return out.reshape(B, S, d), aux


def moe_ref(cfg: ModelConfig, p: dict, x):
    """Dense oracle: run every expert on every token (no capacity drops)."""
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    weights, idx, aux = _route(cfg, p, x_flat)
    h = _act(cfg, jnp.einsum("td,edf->tef", x_flat, p["wg"]))
    h = h * jnp.einsum("td,edf->tef", x_flat, p["wu"])
    all_out = jnp.einsum("tef,efd->ted", h, p["wd"])  # (T, E, d)
    sel = jnp.take_along_axis(all_out, idx[..., None], axis=1)  # (T, k, d)
    out = (sel * weights[..., None]).sum(axis=1)
    if cfg.num_shared_experts:
        out = out + mlp_apply(cfg, p["shared"], x_flat)
    return out.reshape(B, S, d), aux
