"""Model configuration and parameter-metadata machinery.

A single ``ParamMeta`` tree is the source of truth for every architecture:
  * ``init_params``      materializes real weights (smoke tests, repro world)
  * ``abstract_params``  returns ShapeDtypeStructs (dry-run, no allocation)
  * ``partition_specs``  derives jax.sharding.PartitionSpec per leaf from the
                         logical axis names + a rules table.

Logical axis vocabulary (see DESIGN.md §4):
  "layers"   scan dimension over repeated block groups  (never sharded)
  "embed"    d_model                                    (FSDP -> "data")
  "heads"    fused attention head dim (H*hd)            (TP   -> "model")
  "ff"       mlp hidden                                 (TP   -> "model")
  "vocab"    vocabulary                                 (TP   -> "model")
  "experts"  MoE expert dim                             (EP   -> "model")
  "unsharded" anything replicated (norm scales, biases, conv taps, ...)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """One config type for every architecture family (see configs/)."""

    name: str = "model"
    family: str = "dense"  # dense|moe|ssm|hybrid|vlm|audio|seg
    source: str = ""  # citation (arXiv id / hf model card)

    # trunk
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # block pattern, repeated num_layers/len(pattern) times (scan groups).
    # Block kinds: attn | attn_local | xattn | attn_xattn | moe | moe_local
    #              | mamba | rwkv
    pattern: tuple = ("attn",)

    # attention details
    window_size: int = 0  # for *_local blocks
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    post_norm: bool = False  # gemma2-style post-block norms
    rope_theta: float = 10_000.0
    pos: str = "rope"  # rope|learned|none
    max_position: int = 1 << 20  # for learned positions only

    # mlp
    mlp_act: str = "swiglu"  # swiglu|geglu|gelu
    norm: str = "rms"  # rms|layer
    norm_plus_one: bool = False  # gemma-style (1 + w) RMSNorm scale
    embed_scale: bool = False  # gemma sqrt(d_model) embedding scale
    tie_embeddings: bool = True

    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    expert_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (zamba2): a single *shared* attention block applied at the start
    # of every scan group (weights reused across groups).
    shared_attn: bool = False

    # cross-attention inputs (vlm patches / audio frames)
    num_xattn_tokens: int = 0

    # encoder (whisper)
    encoder_layers: int = 0

    # numerics / runtime
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    use_pallas: bool = False
    # unroll the layer scan (cost-counting dry-run variants; HLO cost
    # analysis counts while-loop bodies once — see roofline/analytic.py)
    scan_unroll: bool = False
    # chunked-flash tile sizes for the jnp attention path
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 512
    # mesh axes carrying the batch dim of activations; when set, the stack
    # pins x to P(act_sharding, None, None) at block boundaries (keeps GSPMD
    # from inventing pathological activation shardings)
    act_sharding: tuple | None = None
    # §Perf hillclimb A: windowed decode uses a ring cache of size
    # min(seq, window). Off by default = the naive baseline measured in
    # EXPERIMENTS.md.
    decode_window_slicing: bool = False
    # §Perf hillclimb B ("moe_shard"): explicit expert-parallel layout for
    # the MoE dispatch buffers (experts over ep_axis, capacity over data).
    moe_ep_axis: str | None = None
    moe_cap_axes: tuple | None = None
    # runtime sliding-window override applied to *full* attention blocks
    # (long_500k policy for dense archs, DESIGN.md §6); 0 = no override.
    attn_window_override: int = 0

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def num_groups(self) -> int:
        assert self.num_layers % len(self.pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern length {len(self.pattern)}"
        )
        return self.num_layers // len(self.pattern)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Param metadata
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamMeta:
    shape: tuple
    axes: tuple  # logical axis names, len == len(shape)
    init: str = "fan_in"  # fan_in|zeros|ones|normal|embed
    init_scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_init(meta: ParamMeta, key, dtype):
    if meta.init == "zeros":
        return jnp.zeros(meta.shape, dtype)
    if meta.init == "ones":
        return jnp.ones(meta.shape, dtype)
    if meta.init == "normal":
        return (meta.init_scale * jax.random.normal(key, meta.shape)).astype(dtype)
    if meta.init == "embed":
        return (jax.random.normal(key, meta.shape)).astype(dtype)
    if meta.init == "fan_in":
        # fan-in is the second-to-last axis by convention (matmul lhs dim);
        # for 1-D params fall back to the only axis.
        fan_in = meta.shape[-2] if len(meta.shape) >= 2 else meta.shape[0]
        scale = meta.init_scale / math.sqrt(max(fan_in, 1))
        return (scale * jax.random.normal(key, meta.shape)).astype(dtype)
    raise ValueError(f"unknown init {meta.init}")


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def tree_map_meta(fn: Callable[[ParamMeta], Any], metas):
    return jax.tree.map(fn, metas, is_leaf=is_meta)


def init_params(metas, rng, dtype) -> Any:
    """Materialize real parameters from a ParamMeta tree."""
    leaves, treedef = jax.tree.flatten(metas, is_leaf=is_meta)
    keys = jax.random.split(rng, len(leaves))
    vals = [_leaf_init(m, k, dtype) for m, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(metas, dtype) -> Any:
    """ShapeDtypeStruct stand-ins: dry-run path, zero allocation."""
    return tree_map_meta(lambda m: jax.ShapeDtypeStruct(m.shape, dtype), metas)


# Default tensor-parallel rules; fsdp=True additionally shards the embed
# (d_model) axis of weight matrices over the data axis (ZeRO-3 semantics --
# XLA inserts per-layer all-gathers inside the scan).
def sharding_rules(*, fsdp: bool, data_axis="data", model_axis="model") -> dict:
    return {
        "layers": None,
        "embed": data_axis if fsdp else None,
        "heads": model_axis,
        "kv_heads": model_axis,
        "qgroups": None,
        "ff": model_axis,
        "vocab": model_axis,
        "experts": model_axis,
        "expert_embed": data_axis if fsdp else None,
        "expert_ff": model_axis,
        "unsharded": None,
        # activation/cache logical axes (used by launch/shardings.py)
        "batch": data_axis,
        "seq": None,
        "cache_seq": None,
    }


def meta_pspec(meta: ParamMeta, rules: dict) -> P:
    """Map logical axes -> mesh axes; a mesh axis may appear only once, the
    first logical axis wins (e.g. MoE (experts, embed, ff): experts->model,
    then ff must stay unsharded). Tuple rules keep their non-conflicting
    components (partial FSDP+TP sharding)."""
    used = set()
    out = []
    for ax in meta.axes:
        mesh_ax = rules.get(ax)
        parts = (
            () if mesh_ax is None else (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
        )
        parts = tuple(a for a in parts if a not in used)
        if not parts:
            out.append(None)
        else:
            used.update(parts)
            out.append(parts[0] if len(parts) == 1 else parts)
    return P(*out)


def partition_specs(metas, rules: dict):
    return tree_map_meta(lambda m: meta_pspec(m, rules), metas)


def param_count(metas) -> int:
    leaves = jax.tree.leaves(metas, is_leaf=is_meta)
    return sum(math.prod(m.shape) for m in leaves)


def param_bytes(metas, dtype) -> int:
    return param_count(metas) * jnp.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# Shared building blocks for meta trees
# ---------------------------------------------------------------------------


def norm_meta(d: int) -> ParamMeta:
    return ParamMeta((d,), ("unsharded",), init="zeros")  # rms (1+w) style uses zeros
    # NOTE: plain rms/layer norm reads this as scale offset; see layers.apply_norm


def dense_meta(d_in: int, d_out: int, ax_in: str, ax_out: str, scale=1.0) -> ParamMeta:
    return ParamMeta((d_in, d_out), (ax_in, ax_out), init="fan_in", init_scale=scale)


def stack_group(metas, n_groups: int):
    """Prepend a scanned 'layers' axis to every leaf of a block meta tree."""
    return tree_map_meta(
        lambda m: ParamMeta((n_groups, *m.shape), ("layers", *m.axes), m.init, m.init_scale),
        metas,
    )
