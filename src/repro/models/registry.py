"""Model facade: bundles config + param machinery + step functions."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.common import (
    ModelConfig,
    ParamMeta,
    abstract_params,
    init_params,
    param_count,
    partition_specs,
)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # --- params ---
    def metas(self) -> dict:
        return tfm.model_metas(self.cfg)

    def init(self, rng):
        return init_params(self.metas(), rng, self.cfg.pdtype)

    def abstract(self):
        return abstract_params(self.metas(), self.cfg.pdtype)

    def pspecs(self, rules: dict):
        return partition_specs(self.metas(), rules)

    def num_params(self) -> int:
        return param_count(self.metas())

    # --- steps ---
    def forward(self, params, tokens, memory=None):
        return tfm.forward(self.cfg, params, tokens, memory)

    def loss(self, params, batch):
        return tfm.distill_loss(self.cfg, params, batch)

    def prefill(self, params, tokens, cache_len, memory=None):
        return tfm.prefill(self.cfg, params, tokens, cache_len, memory)

    def decode_step(self, params, caches, tokens, pos):
        return tfm.decode_step(self.cfg, params, caches, tokens, pos)

    # --- caches ---
    def cache_metas(self, batch, seq, mem_len=0):
        return tfm.cache_metas(self.cfg, batch, seq, mem_len)

    def init_cache(self, batch, seq, mem_len=0, dtype=None):
        return tfm.init_cache(self.cfg, batch, seq, mem_len, dtype)

    def abstract_cache(self, batch, seq, mem_len=0, dtype=None):
        dtype = dtype or self.cfg.cdtype
        return jax.tree_util.tree_map_with_path(
            lambda path, m: jax.ShapeDtypeStruct(m.shape, tfm.cache_dtype(path[-1].key, dtype)),
            self.cache_metas(batch, seq, mem_len),
            is_leaf=lambda v: isinstance(v, ParamMeta),
        )

    def cache_pspecs(self, batch, seq, rules, mem_len=0):
        return partition_specs(self.cache_metas(batch, seq, mem_len), rules)


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
