"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Core v6 signature kept: the per-channel decay w_t is a *function of the
input* (LoRA-style bottleneck on the token-shifted mix), the wkv state is a
per-head (P x P) matrix updated as

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)

Simplification vs the reference (DESIGN.md §8): token-shift interpolation
coefficients are static learned vectors (v5-style) rather than themselves
data-dependent; the data-dependent *decay* — the part that matters for
long-context selectivity — is faithful.

Full-sequence path scans over time chunks: within a chunk the contribution of
in-chunk keys is computed with causal matmuls (decay products), the carried
state applies via one matmul — same chunking idea as SSD, keeps the MXU busy.
`rwkv6_scan_ref` is the per-step oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamMeta
from repro.models.layers import rms_norm

LORA_R = 32


def _dims(cfg: ModelConfig):
    P = cfg.ssm_head_dim
    H = cfg.d_model // P
    return H, P


def rwkv6_metas(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, P = _dims(cfg)
    tm = {
        # static token-shift mixes
        "mu_r": ParamMeta((d,), ("unsharded",), init="zeros"),
        "mu_k": ParamMeta((d,), ("unsharded",), init="zeros"),
        "mu_v": ParamMeta((d,), ("unsharded",), init="zeros"),
        "mu_w": ParamMeta((d,), ("unsharded",), init="zeros"),
        "mu_g": ParamMeta((d,), ("unsharded",), init="zeros"),
        "w_r": ParamMeta((d, d), ("embed", "unsharded")),
        "w_k": ParamMeta((d, d), ("embed", "unsharded")),
        "w_v": ParamMeta((d, d), ("embed", "unsharded")),
        "w_g": ParamMeta((d, d), ("embed", "unsharded")),
        "w_o": ParamMeta((d, d), ("unsharded", "embed")),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x @ a) @ b))
        "decay_w0": ParamMeta((d,), ("unsharded",), init="zeros"),
        "decay_a": ParamMeta((d, LORA_R), ("embed", "unsharded")),
        "decay_b": ParamMeta((LORA_R, d), ("unsharded", "unsharded")),
        "bonus_u": ParamMeta((d,), ("unsharded",), init="zeros"),
        "ln_x": ParamMeta((d,), ("unsharded",), init="zeros"),
    }
    cm = {
        "mu_k": ParamMeta((d,), ("unsharded",), init="zeros"),
        "w_in": ParamMeta((d, cfg.d_ff), ("embed", "ff")),
        "w_out": ParamMeta((cfg.d_ff, d), ("ff", "embed")),
    }
    return {"tm": tm, "cm": cm}


def _shift(x, last=None):
    """Previous-token view. x: (B,S,d); last: (B,d) decode carry or None."""
    if last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = last[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _time_mix_inputs(cfg, p, x, last=None):
    H, P = _dims(cfg)
    B, S, d = x.shape
    xx = _shift(x, last)

    def mix(mu):
        return x + (xx - x) * jax.nn.sigmoid(mu)

    r = (mix(p["mu_r"]) @ p["w_r"]).reshape(B, S, H, P)
    k = (mix(p["mu_k"]) @ p["w_k"]).reshape(B, S, H, P)
    v = (mix(p["mu_v"]) @ p["w_v"]).reshape(B, S, H, P)
    g = jax.nn.silu(mix(p["mu_g"]) @ p["w_g"])
    xw = mix(p["mu_w"])
    logw = -jnp.exp(
        p["decay_w0"].astype(jnp.float32)
        + jnp.tanh(xw.astype(jnp.float32) @ p["decay_a"].astype(jnp.float32))
        @ p["decay_b"].astype(jnp.float32)
    )  # (B,S,d) log-decay <= 0, data-dependent
    # clamp: a saturated decay (logw -> -inf) makes cum-sum differences in
    # the chunked path inf - inf = NaN; e^-20 is already an exact-zero decay
    logw = jnp.clip(logw, -20.0, -1e-6)
    w = logw.reshape(B, S, H, P)
    u = p["bonus_u"].reshape(H, P)
    return r, k, v, g, w, u


def _wkv_chunked(r, k, v, logw, u, chunk: int, unroll: bool = False):
    """Chunked wkv. r,k,v,logw: (B,S,H,P) fp32; u: (H,P).
    Returns y (B,S,H,P) and final state (B,H,P,P)."""
    B, S, H, P = r.shape
    Lc = min(chunk, S)
    while S % Lc:
        Lc -= 1
    nc = S // Lc
    # scan axis first; all intra-chunk quadratic work stays inside the body.
    rc = r.reshape(B, nc, Lc, H, P).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nc, Lc, H, P).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, Lc, H, P).transpose(1, 0, 2, 3, 4)
    wc = logw.reshape(B, nc, Lc, H, P).transpose(1, 0, 2, 3, 4)
    strict = jnp.tril(jnp.ones((Lc, Lc), bool), k=-1)

    def chunk_step(S_prev, inp):
        ri, ki, vi, wi = inp  # (B,Lc,H,P) each
        cum_w = jnp.cumsum(wi, axis=1)  # inclusive log decay
        # intra: y_i += sum_{j<i} r_i * exp(cum_w_{i-1} - cum_w_j) k_j * v_j
        #        + r_i * diag(u) k_i v_i  (bonus, j == i)
        seg = cum_w[:, :, None] - cum_w[:, None, :]  # (B,i,j,H,P)
        dec = jnp.where(
            strict[None, :, :, None, None], jnp.exp(seg - wi[:, :, None]), 0.0
        )
        att = jnp.einsum("bihp,bijhp,bjhp->bijh", ri, dec, ki)
        y_intra = jnp.einsum("bijh,bjhp->bihp", att, vi)
        bonus = jnp.einsum("bihp,hp,bihp->bih", ri, u, ki)
        y_intra = y_intra + bonus[..., None] * vi
        # inter: carried state, decayed from chunk start to i-1
        y_inter = jnp.einsum(
            "bihp,bhpq->bihq", ri * jnp.exp(cum_w - wi), S_prev
        )
        # state update
        decay_to_end = jnp.exp(cum_w[:, -1:] - cum_w)
        S_chunk = jnp.einsum("bjhp,bjhq->bhpq", ki * decay_to_end, vi)
        S_new = S_prev * jnp.exp(cum_w[:, -1])[..., None] + S_chunk
        return S_new, y_intra + y_inter

    S0 = jnp.zeros((B, H, P, P), jnp.float32)
    S_fin, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc), unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, S_fin


def _finish(cfg, p, y, g):
    B, S = y.shape[:2]
    y = y.reshape(B, S, cfg.d_model)
    y = rms_norm(y, p["ln_x"]) * g
    return y @ p["w_o"]


def rwkv6_time_mix(cfg: ModelConfig, p: dict, x, chunk: int = 64, want_state: bool = False):
    r, k, v, g, w, u = _time_mix_inputs(cfg, p, x)
    y, S_fin = _wkv_chunked(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), w, u, chunk,
        unroll=cfg.scan_unroll,
    )
    out = _finish(cfg, p, y.astype(x.dtype), g)
    return (out, S_fin) if want_state else out


def rwkv6_time_mix_ref(cfg: ModelConfig, p: dict, x):
    """Per-step oracle."""
    H, P = _dims(cfg)
    B, S, d = x.shape
    r, k, v, g, w, u = _time_mix_inputs(cfg, p, x)

    def step(S_prev, inp):
        rt, kt, vt, wt = inp  # (B,H,P) each
        y = jnp.einsum("bhp,bhpq->bhq", rt, S_prev) + jnp.einsum(
            "bhp,hp,bhp,bhq->bhq", rt, u, kt, vt
        )
        S_new = S_prev * jnp.exp(wt)[..., None] + jnp.einsum("bhp,bhq->bhpq", kt, vt)
        return S_new, y

    S0 = jnp.zeros((B, H, P, P), jnp.float32)
    args = [
        a.astype(jnp.float32).transpose(1, 0, 2, 3) for a in (r, k, v, w)
    ]
    _, ys = jax.lax.scan(step, S0, tuple(args))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, H, P)
    return _finish(cfg, p, y.astype(x.dtype), g)


def rwkv6_channel_mix(cfg: ModelConfig, p: dict, x, last=None):
    xx = _shift(x, last)
    xm = x + (xx - x) * jax.nn.sigmoid(p["mu_k"])
    h = jnp.square(jax.nn.relu(xm @ p["w_in"]))
    return h @ p["w_out"]


def rwkv6_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    H, P = _dims(cfg)
    return {
        "wkv": jnp.zeros((batch, H, P, P), jnp.float32),
        "tm_last": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_last": jnp.zeros((batch, cfg.d_model), dtype),
    }


def rwkv6_decode(cfg: ModelConfig, p: dict, x, cache):
    """One-token decode of a full rwkv layer (time-mix + channel-mix handled
    by the caller; this does time-mix only). x: (B,1,d)."""
    H, P = _dims(cfg)
    r, k, v, g, w, u = _time_mix_inputs(cfg, p["tm"], x, last=cache["tm_last"])
    rt, kt, vt, wt = (a[:, 0].astype(jnp.float32) for a in (r, k, v, w))
    S_prev = cache["wkv"]
    y = jnp.einsum("bhp,bhpq->bhq", rt, S_prev) + jnp.einsum(
        "bhp,hp,bhp,bhq->bhq", rt, u, kt, vt
    )
    S_new = S_prev * jnp.exp(wt)[..., None] + jnp.einsum("bhp,bhq->bhpq", kt, vt)
    out = _finish(cfg, p["tm"], y[:, None].astype(x.dtype), g)
    new_cache = dict(cache, wkv=S_new, tm_last=x[:, 0])
    return out, new_cache
