"""Mamba2 (SSD) block — chunked, matmul-dominant formulation (TPU-native).

The recurrence per head h (state S in R^{P x N}, P=head_dim, N=d_state):

    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * x_t (outer) B_t
    y_t = C_t . S_t + D_h * x_t

is evaluated chunk-wise (chunk length Lc): an intra-chunk causal matmul part
plus an inter-chunk state scan — the standard SSD decomposition, which turns
the sequential scan into MXU-aligned einsums. `mamba2_scan_ref` is the
step-by-step oracle used by tests.

Deviation from the reference CUDA impl (noted in DESIGN.md): the short causal
conv is applied to x and (B,C) via two separate per-channel convs rather than
one fused conv over the concatenated xBC block — identical math, cleaner
tensor-parallel sharding (x channels shard over "model", B/C stay replicated).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamMeta
from repro.models.layers import rms_norm


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    return d_inner, heads, cfg.ssm_head_dim, cfg.ssm_state


def mamba2_metas(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, H, P, N = _dims(cfg)
    k = cfg.ssm_conv
    return {
        "w_x": ParamMeta((d, d_inner), ("embed", "ff")),
        "w_z": ParamMeta((d, d_inner), ("embed", "ff")),
        "w_bc": ParamMeta((d, 2 * N), ("embed", "unsharded")),
        "w_dt": ParamMeta((d, H), ("embed", "unsharded")),
        "conv_x": ParamMeta((k, d_inner), ("unsharded", "ff"), init="normal", init_scale=0.1),
        "conv_bc": ParamMeta((k, 2 * N), ("unsharded", "unsharded"), init="normal", init_scale=0.1),
        "a_log": ParamMeta((H,), ("unsharded",), init="zeros"),
        "dt_bias": ParamMeta((H,), ("unsharded",), init="zeros"),
        "d_skip": ParamMeta((H,), ("unsharded",), init="ones"),
        "norm": ParamMeta((d_inner,), ("unsharded",), init="zeros"),
        "w_out": ParamMeta((d_inner, d), ("ff", "embed")),
    }


def _causal_conv(x, w, state=None):
    """Per-channel causal conv. x: (B,S,C); w: (k,C). If `state` is given
    ((B,k-1,C), decode path) it is prepended and the new state returned."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1) :] if k > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(out), new_state


def _inputs(cfg: ModelConfig, p: dict, x, conv_states=None):
    """Shared projection/conv front half. x: (B,S,d)."""
    d_inner, H, P, N = _dims(cfg)
    B, S, _ = x.shape
    z = jax.nn.silu(x @ p["w_z"])
    xs = x @ p["w_x"]
    bc = x @ p["w_bc"]
    cs_x = cs_bc = None
    if conv_states is not None:
        cs_x, cs_bc = conv_states["x"], conv_states["bc"]
    xs, new_cs_x = _causal_conv(xs, p["conv_x"], cs_x)
    bc, new_cs_bc = _causal_conv(bc, p["conv_bc"], cs_bc)
    Bm, Cm = jnp.split(bc, 2, axis=-1)  # (B,S,N) each
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,) negative
    xh = xs.reshape(B, S, H, P)
    return z, xh, Bm, Cm, dt, A, {"x": new_cs_x, "bc": new_cs_bc}


def _finish(cfg, p, y, z):
    B, S = y.shape[:2]
    y = y.reshape(B, S, -1)
    y = rms_norm(y * z, p["norm"])
    return y @ p["w_out"]


def mamba2_apply(cfg: ModelConfig, p: dict, x, chunk: int | None = None,
                 want_state: bool = False):
    """Full-sequence chunked SSD. x: (B,S,d) -> (B,S,d) or
    ((B,S,d), decode-ready cache) when want_state."""
    d_inner, H, P, N = _dims(cfg)
    B, S, _ = x.shape
    Lc = min(chunk or cfg.ssm_chunk, S)
    while S % Lc:
        Lc -= 1
    nc = S // Lc
    z, xh, Bm, Cm, dt, A, conv_states = _inputs(cfg, p, x)

    # chunked views, scan axis first (all intra-chunk work lives inside the
    # scan body so peak memory is O(B * Lc^2 * H), not O(B * S * Lc * H)).
    xc = xh.reshape(B, nc, Lc, H, P).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Lc, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Lc, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Lc, H).transpose(1, 0, 2, 3)
    causal = jnp.tril(jnp.ones((Lc, Lc), bool))

    def chunk_step(S_prev, inp):
        xi, bi, ci, dti = inp  # (B,Lc,H,P), (B,Lc,N), (B,Lc,N), (B,Lc,H)
        a = dti * A  # (B,Lc,H) log-decay per step
        cum_a = jnp.cumsum(a, axis=1)  # inclusive
        xdt = xi * dti[..., None]
        # intra-chunk: L[i,j] = exp(cum_a_i - cum_a_j) for i >= j (incl. diag)
        seg = cum_a[:, :, None, :] - cum_a[:, None, :, :]  # (B,i,j,H)
        L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        G = jnp.einsum("bin,bjn->bij", ci, bi)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", G, L, xdt)
        # carried state applies with decay from chunk start
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", ci, S_prev, jnp.exp(cum_a))
        # state update
        decay_to_end = jnp.exp(cum_a[:, -1:, :] - cum_a)  # (B,Lc,H)
        S_chunk = jnp.einsum("bjh,bjhp,bjn->bhpn", decay_to_end, xdt, bi)
        S_new = S_prev * jnp.exp(cum_a[:, -1, :])[..., None, None] + S_chunk
        return S_new, (y_intra + y_inter + p["d_skip"][:, None] * xi)

    S0 = jnp.zeros((B, H, P, N), jnp.float32)
    S_fin, ys = jax.lax.scan(chunk_step, S0, (xc, Bc, Cc, dtc), unroll=cfg.scan_unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P).astype(x.dtype)
    out = _finish(cfg, p, y, z)
    if want_state:
        return out, {"ssm": S_fin, "conv_x": conv_states["x"], "conv_bc": conv_states["bc"]}
    return out


def mamba2_scan_ref(cfg: ModelConfig, p: dict, x):
    """Step-by-step recurrence oracle (tests)."""
    d_inner, H, P, N = _dims(cfg)
    B, S, _ = x.shape
    z, xh, Bm, Cm, dt, A, _ = _inputs(cfg, p, x)

    def step(S_prev, inp):
        xt, bt, ct, dtt = inp  # (B,H,P), (B,N), (B,N), (B,H)
        decay = jnp.exp(dtt * A)  # (B,H)
        S_new = S_prev * decay[..., None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", xt.astype(jnp.float32), bt.astype(jnp.float32), dtt
        )
        y = jnp.einsum("bhpn,bn->bhp", S_new, ct.astype(jnp.float32))
        return S_new, y

    S0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(
        step,
        S0,
        (
            xh.transpose(1, 0, 2, 3),
            Bm.transpose(1, 0, 2),
            Cm.transpose(1, 0, 2),
            dt.transpose(1, 0, 2),
        ),
    )
    y = ys.transpose(1, 0, 2, 3) + p["d_skip"][:, None] * xh.astype(jnp.float32)
    return _finish(cfg, p, y.astype(x.dtype), z)


def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    d_inner, H, P, N = _dims(cfg)
    k = cfg.ssm_conv
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv_x": jnp.zeros((batch, k - 1, d_inner), dtype),
        "conv_bc": jnp.zeros((batch, k - 1, 2 * cfg.ssm_state), dtype),
    }


def mamba2_decode(cfg: ModelConfig, p: dict, x, cache):
    """One-token decode. x: (B,1,d); cache from mamba2_init_cache."""
    z, xh, Bm, Cm, dt, A, new_conv = _inputs(
        cfg, p, x, conv_states={"x": cache["conv_x"], "bc": cache["conv_bc"]}
    )
    decay = jnp.exp(dt[:, 0] * A)  # (B,H)
    S_new = cache["ssm"] * decay[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn",
        xh[:, 0].astype(jnp.float32),
        Bm[:, 0].astype(jnp.float32),
        dt[:, 0],
    )
    y = jnp.einsum("bhpn,bn->bhp", S_new, Cm[:, 0].astype(jnp.float32))
    y = y + p["d_skip"][:, None] * xh[:, 0].astype(jnp.float32)
    out = _finish(cfg, p, y[:, None].astype(x.dtype), z)
    return out, {"ssm": S_new, "conv_x": new_conv["x"], "conv_bc": new_conv["bc"]}
