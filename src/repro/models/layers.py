"""Primitive layers: norms, rotary embeddings, MLPs, embedding/unembedding.

Convention: every norm stores its scale as an *offset* w with effective scale
(1 + w) (zeros-init). This matches Gemma's (1+w) RMSNorm exactly and is
numerically identical to ones-init scale for the others.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamMeta, dense_meta, norm_meta


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x, w, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(dt)


def apply_norm(cfg: ModelConfig, x, w):
    return rms_norm(x, w) if cfg.norm == "rms" else layer_norm(x, w)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (B, seq, *head_dims, head_dim); positions: (B, seq).
    Broadcasts over any number of intermediate head dims (no reshape — keeps
    GSPMD shardings intact)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, seq, hd/2)
    expand = (slice(None), slice(None)) + (None,) * (x.ndim - 3) + (slice(None),)
    cos = jnp.cos(ang)[expand]  # (B, seq, 1..., hd/2)
    sin = jnp.sin(ang)[expand]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Softcap
# ---------------------------------------------------------------------------


def softcap(x, cap: float):
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_metas(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, (d_ff or cfg.d_ff)
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {
            "wg": dense_meta(d, ff, "embed", "ff"),
            "wu": dense_meta(d, ff, "embed", "ff"),
            "wd": dense_meta(ff, d, "ff", "embed"),
        }
    return {  # plain gelu (whisper)
        "wu": dense_meta(d, ff, "embed", "ff"),
        "wd": dense_meta(ff, d, "ff", "embed"),
    }


def mlp_apply(cfg: ModelConfig, p: dict, x):
    if cfg.mlp_act in ("swiglu", "geglu"):
        g = x @ p["wg"]
        act = jax.nn.silu(g) if cfg.mlp_act == "swiglu" else jax.nn.gelu(g, approximate=True)
        return (act * (x @ p["wu"])) @ p["wd"]
    return jax.nn.gelu(x @ p["wu"], approximate=True) @ p["wd"]


# ---------------------------------------------------------------------------
# Embedding / unembedding (tied)
# ---------------------------------------------------------------------------


def sinusoidal_pos(positions, d_model: int, dtype=jnp.float32):
    """Classic transformer sinusoidal encoding; positions: (..., seq)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def embed_metas(cfg: ModelConfig) -> dict:
    m = {"tok": ParamMeta((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="embed")}
    if cfg.pos == "learned":
        m["pos"] = ParamMeta((cfg.max_position, cfg.d_model), ("unsharded", "embed"), init="embed")
    return m


def embed_apply(cfg: ModelConfig, p: dict, tokens, positions=None):
    x = jnp.take(p["tok"], tokens, axis=0).astype(cfg.cdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.cdtype)
    if cfg.pos == "learned":
        assert positions is not None
        x = x + jnp.take(p["pos"], positions, axis=0).astype(cfg.cdtype)
    elif cfg.pos == "sinusoidal":
        assert positions is not None
        x = x + sinusoidal_pos(positions, cfg.d_model, cfg.cdtype)
    return x


def unembed_apply(cfg: ModelConfig, p: dict, x):
    logits = x @ p["tok"].T.astype(cfg.cdtype)
    return softcap(logits, cfg.final_logit_softcap)
