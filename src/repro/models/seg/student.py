"""MobileNetV2-style separable-conv segmentation student (pure JAX).

Same family as the paper's DeeplabV3+MobileNetV2 edge model (inverted
residual blocks + a lite ASPP head + bilinear upsample), scaled by `width`
to CPU-experiment size (DESIGN.md §8.4). `width=1.0` is ~70k params; the
paper's 2M-param operating point is `width~=4`.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import ParamMeta, abstract_params, init_params, param_count


@dataclass(frozen=True)
class SegConfig:
    name: str = "seg-student"
    in_channels: int = 3
    n_classes: int = 5
    width: float = 1.0
    # (expansion, out_ch, stride) per inverted-residual block
    blocks: tuple = ((3, 24, 2), (3, 24, 1), (3, 32, 2), (3, 32, 1))
    stem: int = 16
    head: int = 64

    def ch(self, c: int) -> int:
        return max(8, int(round(c * self.width)))


def _conv_meta(kh, kw, cin, cout):
    return ParamMeta((kh, kw, cin, cout), ("unsharded", "unsharded", "embed", "ff"))


def _dw_meta(kh, kw, c):
    return ParamMeta((kh, kw, 1, c), ("unsharded", "unsharded", "unsharded", "ff"))


def _bn_meta(c):  # folded scale/offset pair
    return {
        "scale": ParamMeta((c,), ("unsharded",), init="zeros"),
        "bias": ParamMeta((c,), ("unsharded",), init="zeros"),
    }


def seg_metas(cfg: SegConfig) -> dict:
    m: dict = {}
    c_in = cfg.in_channels
    stem = cfg.ch(cfg.stem)
    m["stem"] = {"w": _conv_meta(3, 3, c_in, stem), "bn": _bn_meta(stem)}
    c_prev = stem
    blocks = {}
    for i, (exp, out, stride) in enumerate(cfg.blocks):
        hidden, c_out = c_prev * exp, cfg.ch(out)
        blocks[f"b{i}"] = {
            "expand": {"w": _conv_meta(1, 1, c_prev, hidden), "bn": _bn_meta(hidden)},
            "dw": {"w": _dw_meta(3, 3, hidden), "bn": _bn_meta(hidden)},
            "project": {"w": _conv_meta(1, 1, hidden, c_out), "bn": _bn_meta(c_out)},
        }
        c_prev = c_out
    m["blocks"] = blocks
    head = cfg.ch(cfg.head)
    m["aspp"] = {
        "local": {"w": _conv_meta(1, 1, c_prev, head), "bn": _bn_meta(head)},
        "ctx": {"w": _conv_meta(1, 1, c_prev, head), "bn": _bn_meta(head)},
    }
    m["classifier"] = {"w": _conv_meta(1, 1, head, cfg.n_classes),
                       "b": ParamMeta((cfg.n_classes,), ("unsharded",), init="zeros")}
    return m


def _conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=groups,
    )


def _bn_act(x, bn, act=True):
    # folded-norm affine (no running stats: online setting, tiny batches)
    mu = x.mean(axis=(0, 1, 2), keepdims=True)
    var = x.var(axis=(0, 1, 2), keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + 1e-5)
    x = x * (1.0 + bn["scale"]) + bn["bias"]
    return jnp.clip(x, 0.0, 6.0) if act else x


def seg_forward(cfg: SegConfig, params: dict, img):
    """img: (B,H,W,3) float -> logits (B,H,W,n_classes)."""
    x = img
    H, W = x.shape[1:3]
    x = _bn_act(_conv(x, params["stem"]["w"], stride=2), params["stem"]["bn"])
    c_prev = x.shape[-1]
    for i, (exp, out, stride) in enumerate(cfg.blocks):
        p = params["blocks"][f"b{i}"]
        h = _bn_act(_conv(x, p["expand"]["w"]), p["expand"]["bn"])
        h = _bn_act(_conv(h, p["dw"]["w"], stride=stride, groups=h.shape[-1]), p["dw"]["bn"])
        h = _bn_act(_conv(h, p["project"]["w"]), p["project"]["bn"], act=False)
        x = x + h if (stride == 1 and h.shape == x.shape) else h
    # lite-ASPP head: local 1x1 + global context
    loc = _bn_act(_conv(x, params["aspp"]["local"]["w"]), params["aspp"]["local"]["bn"])
    ctx = x.mean(axis=(1, 2), keepdims=True)
    ctx = _bn_act(_conv(ctx, params["aspp"]["ctx"]["w"]), params["aspp"]["ctx"]["bn"])
    h = loc + ctx
    logits = _conv(h, params["classifier"]["w"]) + params["classifier"]["b"]
    return jax.image.resize(logits, (logits.shape[0], H, W, cfg.n_classes), "bilinear")


def seg_loss(cfg: SegConfig, params: dict, img, labels):
    """Pixel cross-entropy distillation loss against teacher hard labels."""
    logits = seg_forward(cfg, params, img).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return (lse - lab).mean()


def seg_predict(cfg: SegConfig, params: dict, img):
    return jnp.argmax(seg_forward(cfg, params, img), axis=-1)


def make_student(cfg: SegConfig, rng):
    metas = seg_metas(cfg)
    params = init_params(metas, rng, jnp.float32)
    return params


def seg_param_count(cfg: SegConfig) -> int:
    return param_count(seg_metas(cfg))
