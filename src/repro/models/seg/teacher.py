"""Learned teacher: a wider same-family convnet trained on ground truth.

Used by the teacher-fidelity ablation (benchmarks/ablation_teacher.py): AMS's
measured quantity is student-vs-teacher mIoU, so swapping the oracle teacher
(DESIGN.md §5) for a *learned* model must not change the §Repro conclusions.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.core.masked_adam import adam_update, init_state
from repro.data.video import SyntheticVideo
from repro.models.seg.student import SegConfig, make_student, seg_loss, seg_predict


def teacher_config(n_classes: int) -> SegConfig:
    return SegConfig(name="seg-teacher", n_classes=n_classes, width=3.0,
                     blocks=((3, 24, 2), (3, 24, 1), (3, 32, 2), (3, 32, 1),
                             (3, 48, 1)))


@dataclass
class ModelTeacher:
    """Same interface as OracleTeacher: label(frame_index) -> (H, W) int."""

    video: SyntheticVideo
    cfg: SegConfig
    params: object

    def __post_init__(self):
        cfg = self.cfg

        @jax.jit
        def predict(params, frames):
            return seg_predict(cfg, params, frames)

        self._predict = predict
        self._cache: dict = {}

    def label(self, idx: int) -> np.ndarray:
        if idx not in self._cache:
            img, _ = self.video.frame(idx)
            self._cache[idx] = np.asarray(self._predict(self.params, img[None])[0])
            if len(self._cache) > 512:
                self._cache.pop(next(iter(self._cache)))
        return self._cache[idx]


def train_teacher(video: SyntheticVideo, n_classes: int, steps: int = 400,
                  batch: int = 8, lr: float = 2e-3, seed: int = 7) -> ModelTeacher:
    """Fit the wide teacher on the video's ground truth (the stand-in for the
    paper's Cityscapes-pretrained Xception65)."""
    cfg = teacher_config(n_classes)
    params = make_student(cfg, jax.random.PRNGKey(seed))
    opt = init_state(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, opt, frames, labels):
        loss, grads = jax.value_and_grad(lambda p: seg_loss(cfg, p, frames, labels))(params)
        params, opt, _ = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    for _ in range(steps):
        idxs = rng.integers(0, video.cfg.n_frames, size=batch)
        frames = np.stack([video.frame(int(i))[0] for i in idxs])
        labels = np.stack([video.frame(int(i))[1] for i in idxs])
        params, opt, loss = step(params, opt, frames, labels)
    return ModelTeacher(video=video, cfg=cfg, params=params)
