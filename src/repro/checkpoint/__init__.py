"""Minimal npz pytree checkpointing (flat path keys, dtype-preserving)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_paths(tree):
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}/{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}/{i}", v)
        else:
            arr = np.asarray(node)
            if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
                arr = arr.astype(np.float32)  # bf16 -> fp32 on disk
            flat[prefix] = arr

    rec("", tree)
    return flat


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten_paths(tree))


def load(path: str, like):
    """Restore into the structure of `like` (shapes/dtypes preserved)."""
    data = np.load(path)
    flat = dict(data)

    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [rec(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            return type(node)(vals)
        arr = flat[prefix]
        return jnp.asarray(arr, dtype=node.dtype)  # restore original dtype

    return rec("", like)


def exists(path: str) -> bool:
    return os.path.exists(path)
