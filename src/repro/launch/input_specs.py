"""ShapeDtypeStruct stand-ins for every model input — the dry-run path.

Weak-type-correct, shardable, zero device allocation."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig

INPUT_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode_long", seq_len=524288, global_batch=1),
}


def batch_pspec(rules) -> P:
    return P(rules.get("batch"))


def train_inputs(cfg: ModelConfig, batch: int, seq: int):
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.num_xattn_tokens:
        specs["memory"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_xattn_tokens, cfg.d_model), cfg.cdtype
        )
    return specs


def train_input_pspecs(cfg: ModelConfig, rules) -> dict:
    b = rules.get("batch")
    out = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.num_xattn_tokens:
        out["memory"] = P(b, None, None)
    return out


def decode_inputs(cfg: ModelConfig, batch: int):
    return {
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def decode_input_pspecs(cfg: ModelConfig, rules) -> dict:
    return {"tokens": P(rules.get("batch"), None), "pos": P()}
