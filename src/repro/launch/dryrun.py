import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes — 16x16 single-pod and 2x16x16 two-pod — and extract
memory/cost/collective numbers for EXPERIMENTS.md §Dry-run and §Roofline.

The two lines above MUST precede any other import (jax locks the device
count at first init). Do not set that flag anywhere else in the repo.

Per pair this runs up to three compiles:
  1. full depth, scanned               -> lowering proof + memory_analysis
  2. depth-1 and depth-2, fully        -> collective bytes (and HLO flop
     unrolled ("count compiles")          cross-check), linearly extrapolated
                                          in depth (analysis.extrapolate_depth)
FLOPs/HBM bytes for the roofline come from roofline/analytic.py (XLA's cost
analysis counts scan bodies once — see that module's docstring).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.jsonl]
  PYTHONPATH=src python -m repro.launch.dryrun --all --proof-only   # skip count compiles
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.core.masked_adam import MaskedAdamState
from repro.launch import input_specs as ispec
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import rules_for
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.registry import build
from repro.roofline import analysis
from repro.roofline.analytic import ShapeSpec, analytic_cost


def _abstract_opt_state(params, m_dtype=jnp.float32):
    # paper-faithful baseline: fp32 Adam moments; hillclimb C trades the
    # first moment to bf16 ("m_bf16" opt).
    return MaskedAdamState(
        m=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, m_dtype), params),
        v=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
        count=jax.ShapeDtypeStruct((), jnp.int32),
    )


def _mask_like(params):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bool_), params)


def resolve_cfg(arch: str, shape_name: str, mesh=None):
    shp = ispec.INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    variant = "native"
    if shp["global_batch"] > 1 and mesh is not None:
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        cfg = cfg.replace(act_sharding=batch_axes)
    if shp["kind"] == "decode_long":
        # long_500k policy (DESIGN.md §6): sub-quadratic required; dense
        # full-attention archs run the sliding-window variant (window=8192).
        if not any(k in ("mamba", "rwkv") for k in cfg.pattern) and not cfg.window_size:
            cfg = cfg.replace(attn_window_override=8192)
            variant = "swa_500k"
    return cfg, variant


def _compile_step(cfg, mesh, shape_name: str, opts: frozenset = frozenset()):
    """Lower + compile one step function for cfg on mesh. Returns compiled.
    opts: §Perf levers — "grad_shard" | "m_bf16" (window_slice lives on cfg)."""
    shp = ispec.INPUT_SHAPES[shape_name]
    kind = shp["kind"]
    model = build(cfg)
    rules = rules_for(cfg, mesh, shape_kind=kind,
                      attn_dp="attn_dp" in opts and kind in ("train", "prefill"),
                      moe_shard="moe_shard" in opts and kind in ("train", "prefill"),
                      decode_ep="decode_ep" in opts)
    if ("moe_shard" in opts and cfg.num_experts and rules.get("experts") is None
            and kind in ("train", "prefill")):
        # Only when experts can't shard over "model" (e.g. mixtral's E=8 on a
        # 16-way axis): pin the capacity buffer over the data axes so GSPMD
        # stops emitting capacity-sized partial-sum all-reduces. When experts
        # DO shard (moonshot/llama4), XLA's inferred layout is already better
        # — measured in EXPERIMENTS.md §Perf B.3/B.4.
        cfg = cfg.replace(
            moe_cap_axes=tuple(a for a in ("pod", "data") if a in mesh.axis_names),
        )
        model = build(cfg)
    pspecs = model.pspecs(rules)
    params = model.abstract()

    if kind == "train":
        step = make_train_step(model, grad_pspecs=pspecs if "grad_shard" in opts else None)
        opt = _abstract_opt_state(params,
                                  m_dtype=jnp.bfloat16 if "m_bf16" in opts else jnp.float32)
        opt_specs = MaskedAdamState(m=pspecs, v=pspecs, count=P())
        batch = ispec.train_inputs(cfg, shp["global_batch"], shp["seq_len"])
        bspecs = ispec.train_input_pspecs(cfg, rules)
        jitted = jax.jit(
            step,
            in_shardings=(pspecs, opt_specs, pspecs, bspecs),
            out_shardings=(pspecs, opt_specs, pspecs, P()),
        )
        lowered = jitted.lower(params, opt, _mask_like(params), batch)
    elif kind == "prefill":
        step = make_prefill_step(model, cache_len=shp["seq_len"])
        batch = ispec.train_inputs(cfg, shp["global_batch"], shp["seq_len"])
        batch.pop("labels")
        bspecs = ispec.train_input_pspecs(cfg, rules)
        bspecs.pop("labels")
        cache_specs = model.cache_pspecs(
            shp["global_batch"], shp["seq_len"], rules, mem_len=cfg.num_xattn_tokens
        )
        logit_spec = P(rules.get("batch"), None, rules.get("vocab"))
        jitted = jax.jit(step, in_shardings=(pspecs, bspecs),
                         out_shardings=(logit_spec, cache_specs))
        lowered = jitted.lower(params, batch)
    else:  # decode / decode_long
        step = make_serve_step(model)
        caches = model.abstract_cache(
            shp["global_batch"], shp["seq_len"], mem_len=cfg.num_xattn_tokens
        )
        cache_specs = model.cache_pspecs(
            shp["global_batch"], shp["seq_len"], rules, mem_len=cfg.num_xattn_tokens
        )
        batch = ispec.decode_inputs(cfg, shp["global_batch"])
        bspecs = ispec.decode_input_pspecs(cfg, rules)
        jitted = jax.jit(step, in_shardings=(pspecs, cache_specs, bspecs),
                         out_shardings=(P(rules.get("batch"), None), cache_specs))
        lowered = jitted.lower(params, caches, batch)
    return lowered.compile()


def _count_cfg(cfg, depth: int, seq_len: int):
    """Depth-reduced, fully-unrolled variant for cost counting."""
    G = cfg.num_groups
    kw = dict(
        num_layers=len(cfg.pattern) * depth,
        scan_unroll=True,
        attn_q_chunk=seq_len,
        attn_kv_chunk=seq_len,
    )
    if cfg.encoder_layers:
        kw["encoder_layers"] = max(1, cfg.encoder_layers // G) * depth
    return cfg.replace(**kw)


def lower_pair(arch: str, shape_name: str, mesh, *, verbose: bool = True,
               proof_only: bool = False, cfg_override=None,
               opts: frozenset = frozenset()) -> dict:
    shp = ispec.INPUT_SHAPES[shape_name]
    cfg, variant = resolve_cfg(arch, shape_name, mesh)
    if cfg_override is not None:
        cfg = cfg_override
    if "window_slice" in opts:
        cfg = cfg.replace(decode_window_slicing=True)
    jax.set_mesh(mesh)
    chips = int(jnp.prod(jnp.array(mesh.devices.shape)))

    t0 = time.time()
    compiled = _compile_step(cfg, mesh, shape_name, opts=opts)
    full = analysis.hlo_facts(compiled)
    t_full = time.time() - t0

    spec = ShapeSpec(kind=shp["kind"], seq_len=shp["seq_len"],
                     global_batch=shp["global_batch"])
    ana = analytic_cost(cfg, spec)

    facts = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "opts": sorted(opts),
        "mesh": "x".join(map(str, mesh.devices.shape)), "chips": chips,
        "compile_s": round(t_full, 1),
        "flops": ana["flops"], "bytes": ana["bytes"],
        "model_flops": ana["model_flops"],
        "hlo_flops_scan_once": full["hlo_flops"],
        "device_temp_bytes": full["device_temp_bytes"],
        "device_arg_bytes": full["device_arg_bytes"],
        # scan-aware: while-body collectives x trip count (analysis.py)
        "collective_bytes": float(full["collective"]["sum"]),
        "collective_counts": full["collective"]["counts"],
        "collective_totals": full["collective"]["totals"],
    }

    facts.update(analysis.roofline_terms(
        facts["flops"], facts["bytes"], facts["collective_bytes"], chips))
    facts["useful_flops_ratio"] = (
        facts["model_flops"] / facts["flops"] if facts["flops"] else 0.0
    )

    if verbose:
        print(f"[{arch} | {shape_name} | mesh {facts['mesh']} | {variant}] "
              f"compile {facts['compile_s']}s bottleneck={facts['bottleneck']}")
        print(f"  flops={facts['flops']:.3e} bytes={facts['bytes']:.3e} "
              f"coll={facts['collective_bytes']:.3e} "
              f"t=(c {facts['t_compute_s']*1e3:.2f} | m {facts['t_memory_s']*1e3:.2f} "
              f"| n {facts['t_collective_s']*1e3:.2f}) ms "
              f"useful={facts['useful_flops_ratio']:.2f}")
        print(f"  per-device: args {facts['device_arg_bytes']/2**30:.2f} GiB, "
              f"temps {facts['device_temp_bytes']/2**30:.2f} GiB")
    return facts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(ispec.INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--proof-only", action="store_true",
                    help="skip the count compiles (lowering proof + memory only)")
    ap.add_argument("--json", default=None, help="append results (json-lines)")
    ap.add_argument("--opt", action="append", default=[],
                    choices=["grad_shard", "m_bf16", "window_slice", "attn_dp",
                             "moe_shard", "decode_ep"],
                    help="§Perf levers (repeatable)")
    ap.add_argument("--optimized", action="store_true",
                    help="enable the validated §Perf levers")
    args = ap.parse_args(argv)
    opts = frozenset(args.opt) if not args.optimized else frozenset(
        ["m_bf16", "window_slice", "moe_shard", "decode_ep"])

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    if args.all:
        pairs = [(a, s) for a in ARCH_IDS for s in ispec.INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    ok, fail = 0, []
    for arch, shape in pairs:
        try:
            facts = lower_pair(arch, shape, mesh, proof_only=args.proof_only, opts=opts)
            ok += 1
            if args.json:
                with open(args.json, "a") as f:
                    f.write(json.dumps(facts) + "\n")
        except Exception as e:  # noqa: BLE001
            fail.append((arch, shape, repr(e)[:300]))
            print(f"[{arch} | {shape}] FAILED: {e}", file=sys.stderr)
    print(f"\ndry-run: {ok} ok, {len(fail)} failed")
    for f in fail:
        print("  FAIL", *f)
    return 1 if fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
