"""End-to-end AMS distillation trainer for the model zoo (CPU-runnable).

The server continually adapts a *student* LM to a drifting token stream by
distilling a *teacher* — here the teacher is a larger same-family model
briefly fitted to the stream (or the stream's own labels with --oracle).
Model updates are streamed as gradient-guided sparse deltas, exactly
Algorithm 1/2, on transformer pytrees instead of convnets.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --steps 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import selection
from repro.core.delta import encode_delta
from repro.core.masked_adam import init_state, masked_adam_update
from repro.data.tokens import StreamConfig, TokenStream
from repro.models.registry import build


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--phase-len", type=int, default=10, help="K iterations per phase")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--clip", type=float, default=1.0, help="global grad-norm clip")
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    model = build(cfg)
    rng = jax.random.PRNGKey(0)
    nprng = np.random.default_rng(0)
    params = model.init(rng)
    opt = init_state(params)
    stream = TokenStream(StreamConfig(vocab_size=cfg.vocab_size, seed=1))

    memory = None
    if cfg.num_xattn_tokens:
        memory = 0.1 * jnp.ones((args.batch, cfg.num_xattn_tokens, cfg.d_model))

    @jax.jit
    def step(params, opt, mask, tokens, labels):
        batch = {"tokens": tokens, "labels": labels}
        if memory is not None:
            batch["memory"] = memory
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        if args.clip:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, args.clip / jnp.maximum(gn, 1e-9))
            # non-finite gradient guard: a single inf/nan grad would poison
            # the Adam moments of EVERY coordinate (they track all params).
            # NB: must be where(), not multiply-by-zero (0 * nan == nan).
            ok = jnp.isfinite(gn)
            grads = jax.tree.map(
                lambda g: jnp.where(ok & jnp.isfinite(g),
                                    g.astype(jnp.float32) * scale, 0.0).astype(g.dtype),
                grads)
        params, opt, u = masked_adam_update(params, grads, opt, mask, lr=args.lr)
        return params, opt, u, loss

    u_prev = None
    total_down = 0
    t0 = time.time()

    for it in range(args.steps):
        t_stream = it * 2.0  # stream time advances -> distribution drifts
        if it % args.phase_len == 0:  # new phase: select I_n (Algorithm 2 line 1)
            if u_prev is None:
                rng, k = jax.random.split(rng)
                mask = selection.random_mask(k, params, args.gamma)
            else:
                mask = selection.gradient_guided_mask(u_prev, args.gamma)
        data = stream.sample(nprng, args.batch, args.seq, t_stream)
        tokens, labels = jnp.asarray(data[:, :-1]), jnp.asarray(data[:, 1:])
        params, opt, u_prev, loss = step(params, opt, mask, tokens, labels)
        if (it + 1) % args.phase_len == 0:  # end of phase: stream the delta
            delta = encode_delta(params, mask)
            total_down += delta.total_bytes
        if it % args.log_every == 0:
            print(f"step {it:5d} loss {float(loss):.4f} "
                  f"downlink {total_down/1e3:.1f} KB  ({time.time()-t0:.1f}s)")
    print(f"done: final loss {float(loss):.4f}, total downlink {total_down/1e3:.1f} KB, "
          f"{args.steps} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
