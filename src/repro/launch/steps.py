"""The jit-able step functions that the launcher/dry-run lower.

train_step IS the paper's technique: one masked-Adam (Algorithm 2) inner
iteration of online distillation against teacher hard labels. serve_step is
one-token decode against a KV/state cache (edge inference path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.masked_adam import MaskedAdamState, masked_adam_update
from repro.models.registry import Model


def make_train_step(model: Model, lr: float = 1e-3, grad_pspecs=None):
    """grad_pspecs (§Perf hillclimb B/C): constrain gradients to the weight
    shardings at the reduction point so GSPMD emits reduce-scatters into the
    FSDP shards instead of full all-reduces."""

    def train_step(params, opt_state: MaskedAdamState, mask, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        if grad_pspecs is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_pspecs)
        params, opt_state, u = masked_adam_update(params, grads, opt_state, mask, lr=lr)
        return params, opt_state, u, loss

    return train_step


def make_prefill_step(model: Model, cache_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch["tokens"], cache_len, batch.get("memory"))

    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, caches, batch):
        logits, caches = model.decode_step(params, caches, batch["tokens"], batch["pos"])
        # greedy next token (argmax over the sharded vocab)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    return serve_step
