"""Batched serving driver: prefill a prompt batch, then decode tokens with
the KV/state cache (the edge-inference path, CPU-runnable on smoke configs).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models.registry import build


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    model = build(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    cache_len = args.prompt_len + args.tokens
    prompt = jax.random.randint(rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    memory = None
    if cfg.num_xattn_tokens:
        memory = 0.1 * jnp.ones((args.batch, cfg.num_xattn_tokens, cfg.d_model))

    decode = jax.jit(model.decode_step)
    t0 = time.time()
    logits, caches = model.prefill(params, prompt, cache_len, memory)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [tok]
    t1 = time.time()
    for i in range(args.tokens - 1):
        logits, caches = decode(params, caches, tok, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t1
    seq = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} batch={args.batch} prefill={t_prefill*1e3:.1f}ms "
          f"decode={dt/max(args.tokens-1,1)*1e3:.2f}ms/tok "
          f"({args.batch*(args.tokens-1)/dt:.1f} tok/s)")
    print("sample:", seq[0, :16].tolist())


if __name__ == "__main__":
    main()
