"""Per-(arch, mesh, input-shape) sharding rules.

Strategy (DESIGN.md §4):
  * tensor-parallel over "model": attention kv-heads (or q-groups when kv
    doesn't divide), mlp/expert ff, vocab, MoE experts — each applied only
    when the dimension divides the mesh axis;
  * FSDP over "data" on the embed (d_model) axis of every weight, so Adam
    moments shard 16x256-way on the big archs;
  * attention weights whose head dims can't shard fall back to
    ("data","model") FSDP on their embed axis (meta_pspec keeps the
    non-conflicting components);
  * batch over ("pod","data"); decode caches shard seq over "model" when kv
    heads can't (and over data too for batch=1 long-context).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.common import ModelConfig


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def rules_for(cfg: ModelConfig, mesh, *, shape_kind: str = "train", fsdp: bool = True,
              attn_dp: bool = False, moe_shard: bool = False,
              decode_ep: bool = False) -> dict:
    """shape_kind: train | prefill | decode | decode_long (affects batch and
    cache-seq rules only).

    attn_dp (§Perf hillclimb B): when attention heads can't shard over
    "model", the default fallback shards attention weights over
    ("data","model") — the model-sharded contraction then all-reduces
    *activation*-sized partial sums every layer (huge at 1M-token train
    batches). attn_dp instead keeps attention weights ("data",)-sharded and
    replicated over "model": per-layer traffic becomes weight-sized
    all-gathers, orders of magnitude smaller for train shapes."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = axes.get("model", 1)
    data_parts = tuple(a for a in ("pod", "data") if a in axes)

    kv_ok = _div(cfg.num_kv_heads, model_n)
    g_ok = _div(cfg.num_heads // max(cfg.num_kv_heads, 1), model_n)

    rules: dict = {
        "layers": None,
        "embed": ("data",) if (fsdp and "data" in axes and _div(cfg.d_model, axes["data"])) else None,
        "heads": "model" if _div(cfg.num_heads, model_n) else None,
        "kv_heads": "model" if kv_ok else None,
        "qgroups": "model" if (not kv_ok and g_ok) else None,
        # attention embed: FSDP always; adds "model" when heads don't shard
        "attn_embed": None,
        "ff": "model" if _div(cfg.d_ff, model_n) else None,
        "vocab": "model" if _div(cfg.vocab_size, model_n) else None,
        "experts": "model" if _div(cfg.num_experts, model_n) else None,
        "unsharded": None,
    }
    # Expert weights keep FSDP embed sharding (measured: detaching them from
    # the data axis replicates tens-of-GB of moments — refuted in §Perf B.3).
    # The "moe_shard" lever instead pins the *capacity buffer* layout
    # (experts x capacity sharded over model x data) via cfg.moe_cap_axes.
    rules["expert_embed"] = rules["embed"]
    rules["expert_ff"] = "model" if _div(cfg.expert_d_ff, model_n) else None
    if (moe_shard and cfg.num_experts and _div(cfg.num_experts, model_n)
            and cfg.experts_per_token <= 2):
        # coarse-routed EPxTP (llama4: top-1, big experts): experts over
        # model, expert ff over data, embed local -> expert matmuls contract
        # an unsharded d (no capacity-sized partial sums); weights+moments
        # stay 256-way sharded. Fine-grained MoE (moonshot top-6) measured
        # WORSE under this layout (§Perf B.5) and keeps the default.
        if fsdp and _div(cfg.expert_d_ff, axes.get("data", 1)):
            rules["expert_embed"] = None
            rules["expert_ff"] = ("data",)
    # §Perf "decode_ep" (MoE decode, experts divisible): weight-stationary
    # layout — no weight dims on "data", so no per-token weight all-gathers;
    # expert ff shards over data instead (storage stays 256-way), and the
    # B~1 activation partial-sums are negligible. Infeasible for dense
    # 405B-class archs (weights would not fit without the data axis).
    if (decode_ep and cfg.num_experts and _div(cfg.num_experts, model_n)
            and shape_kind in ("decode", "decode_long")
            and fsdp and _div(cfg.expert_d_ff, axes.get("data", 1))):
        rules["embed"] = None
        rules["expert_embed"] = None
        rules["expert_ff"] = ("data",)
        attn_parts = []
        if not (kv_ok or g_ok):
            attn_parts.append("model")
        d_total = 1
        for a in attn_parts:
            d_total *= axes.get(a, 1)
        rules["attn_embed"] = (
            tuple(attn_parts) if attn_parts and _div(cfg.d_model, d_total) else None
        )
        rules["batch"] = None if shape_kind == "decode_long" else (
            data_parts if len(data_parts) > 1 else data_parts[0])
        rules["cache_seq"] = ("model" if cfg.decode_window_slicing
                              or not kv_ok else None)
        if shape_kind == "decode_long" and not cfg.decode_window_slicing:
            rules["cache_seq"] = tuple(list(data_parts) + (["model"] if not kv_ok else []))
        rules["seq"] = None
        return rules

    attn_parts = list(data_parts[-1:]) if fsdp else []  # ("data",)
    if not (kv_ok or g_ok) and not attn_dp:
        attn_parts.append("model")
    d_total = 1
    for a in attn_parts:
        d_total *= axes.get(a, 1)
    rules["attn_embed"] = tuple(attn_parts) if attn_parts and _div(cfg.d_model, d_total) else (
        ("data",) if fsdp and _div(cfg.d_model, axes.get("data", 1)) else None
    )
    if rules["ff"] is None and fsdp:
        rules["ff"] = None  # embed FSDP already covers these weights

    # activation / cache axes
    if shape_kind == "decode_long":  # global_batch == 1
        rules["batch"] = None
        if cfg.decode_window_slicing and (cfg.window_size or cfg.attn_window_override):
            # ring caches are window-sized: a 256-way sharding leaves ~16
            # slots/shard and GSPMD degenerates to gathers (§Perf A.4);
            # shard over "model" only.
            rules["cache_seq"] = "model"
        else:
            seq_parts = list(data_parts)
            if not kv_ok:
                seq_parts.append("model")
            rules["cache_seq"] = tuple(seq_parts)
    elif shape_kind == "decode":
        rules["batch"] = data_parts if len(data_parts) > 1 else data_parts[0]
        rules["cache_seq"] = "model" if not kv_ok else None
    else:
        rules["batch"] = data_parts if len(data_parts) > 1 else data_parts[0]
        rules["cache_seq"] = None
    rules["seq"] = None
    return rules
