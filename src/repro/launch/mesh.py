"""Production meshes (TPU v5e target) and the serving session mesh.

A function, not a module-level constant — importing this module must never
touch jax device state.

Two mesh families live here. The training meshes (`make_production_mesh`,
`make_local_mesh`) are 2-D/3-D ("data", "model") grids for the student
archs in `launch.shardings`. The serving mesh (`make_session_mesh`) is
1-D over a "session" axis: fused grant lifecycles (`core.batched`) stack
co-resident sessions on the leading axis, and sharding *that* axis across
an N-device host-platform mesh (`launch.host_mesh`, forced with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) is what turns the
GPU pool's modeled per-device clocks into real parallel launches."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh for CPU tests (degenerate axes)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_session_mesh(n: int | None = None):
    """1-D mesh over the fused-serving "session" axis.

    ``n`` defaults to every live device (forced host devices included);
    pass an explicit count to pin the pool width. See `launch.host_mesh`
    for the env plumbing that makes n > 1 real on a CPU host."""
    if n is None:
        n = len(jax.devices())
    if n < 1 or n > len(jax.devices()):
        raise ValueError(
            f"session mesh wants {n} devices, have {len(jax.devices())}")
    return jax.make_mesh((n,), ("session",))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
