"""Production meshes (TPU v5e target).

A function, not a module-level constant — importing this module must never
touch jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh for CPU tests (degenerate axes)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
