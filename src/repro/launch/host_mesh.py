"""Host-platform device meshes: N real JAX devices on one CPU host.

XLA will split a single host into N independent `CpuDevice`s when
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` is set *before the
backend initializes* (the trick the exemplar JAX training repos use in
their run.sh, and what `scripts/env.sh` exports for CI). Each forced
device owns its own executable cache and buffer space, so work placed on
different devices genuinely dispatches as separate launches — which is
exactly what `serving.resources.GPUPool(device_backend="jax")` and
`core.batched.train_phases_sharded` need to turn modeled per-device
clocks into measured ones.

Like `launch.mesh`, everything here is a function: importing this module
never touches jax device state. The only environment-mutating helper,
`ensure_host_devices`, edits ``XLA_FLAGS`` and is honest about whether
the edit can still take effect (it cannot once the backend is up — flags
are read exactly once).
"""
from __future__ import annotations

import os
import re

_FLAG = "--xla_force_host_platform_device_count"


def host_device_count_flag(n: int) -> str:
    """The XLA_FLAGS fragment that forces ``n`` host-platform devices."""
    if n < 1:
        raise ValueError(f"need at least one device, got {n}")
    return f"{_FLAG}={n}"


def forced_host_device_count(env: str | None = None) -> int | None:
    """Parse the forced device count out of ``XLA_FLAGS`` (None if unset).

    `env` overrides ``os.environ['XLA_FLAGS']`` for tests.
    """
    flags = os.environ.get("XLA_FLAGS", "") if env is None else env
    m = None
    for m in re.finditer(rf"{_FLAG}=(\d+)", flags):
        pass  # last occurrence wins, matching XLA's own flag parsing
    return int(m.group(1)) if m else None


def _backend_initialized() -> bool:
    """True once jax has built a backend (flags are frozen from then on)."""
    try:
        from jax._src import xla_bridge

        return bool(xla_bridge._backends)
    except Exception:  # pragma: no cover - private-API drift
        # Can't tell; assume the worst so callers re-check live devices.
        return True


def ensure_host_devices(n: int) -> bool:
    """Ask for ``n`` forced host devices via ``XLA_FLAGS``.

    Returns True when the flag is in place *and* can still take effect
    (jax backend not yet initialized, or already initialized with >= n
    devices). Returns False when the backend is already up with fewer
    devices — the process-level flag window has closed, and callers
    should degrade to the devices that actually exist (or re-exec under
    `scripts/env.sh`, which exports the flag before python starts).
    """
    if n < 1:
        raise ValueError(f"need at least one device, got {n}")
    current = forced_host_device_count()
    if current is None or current < n:
        flags = os.environ.get("XLA_FLAGS", "")
        # strip any stale occurrences so the surviving value is unambiguous
        flags = re.sub(rf"\s*{_FLAG}=\d+", "", flags).strip()
        os.environ["XLA_FLAGS"] = (flags + " " if flags else "") + \
            host_device_count_flag(n)
    if not _backend_initialized():
        return True
    import jax

    return len(jax.devices()) >= n


def host_devices(n: int | None = None) -> list:
    """The live device list, optionally truncated to the first ``n``.

    Raises with a pointer at `ensure_host_devices` / `scripts/env.sh`
    when fewer than ``n`` devices materialized, so a silently-serial
    "sharded" run can't masquerade as a measured parallel one.
    """
    import jax

    devs = list(jax.devices())
    if n is None:
        return devs
    if len(devs) < n:
        raise RuntimeError(
            f"asked for {n} host devices but only {len(devs)} exist; "
            f"export XLA_FLAGS={host_device_count_flag(n)} before jax "
            f"initializes (source scripts/env.sh, or call "
            f"launch.host_mesh.ensure_host_devices({n}) at process start)")
    return devs[:n]


def make_host_mesh(n: int | None = None):
    """A 1-D mesh over the ``session`` axis on ``n`` host devices.

    This is the serving counterpart of `launch.mesh.make_local_mesh`:
    fused grant lifecycles stack sessions on the leading axis, so the
    mesh is one-dimensional and the only thing sharded is that axis.
    """
    from repro.launch.mesh import make_session_mesh

    return make_session_mesh(n)


def session_sharding(mesh):
    """NamedSharding placing a stacked tree's leading (session) axis."""
    import jax

    return jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("session"))


def replicated_sharding(mesh):
    """NamedSharding replicating a leaf across the session mesh."""
    import jax

    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
