"""Byte-cost models for the uplink video path (§3.2 "Compression").

This container has no x264 binary; deployments plug a real encoder in here.
The constants are calibrated to the paper's reported operating points:

  * H.264 two-pass over a T_update buffer of sampled frames targets 200 Kbps
    (paper: "a target bitrate of 200 Kbps"), with efficiency degrading when
    fewer frames share the buffer (intra-coded only).
  * A good-quality JPEG at 1024x512 is ~87.5 KB (paper footnote 2:
    ~700 Kbps at 1 fps), used by Remote+Tracking which cannot buffer.

Costs scale linearly in pixel count relative to the reference resolution.
"""
from __future__ import annotations

REF_PIXELS = 1024 * 512
JPEG_BYTES_REF = 87_500  # ~700 Kbps at 1 fps (paper footnote 2)
H264_TARGET_BPS = 200_000  # two-pass target bitrate (paper §4.1)
H264_MIN_FRAME_FRACTION = 0.25  # intra floor: a lone frame still costs >= this of JPEG


def jpeg_bytes(n_pixels: int, quality_scale: float = 1.0) -> int:
    return int(JPEG_BYTES_REF * (n_pixels / REF_PIXELS) * quality_scale)


def h264_buffer_bytes(n_frames: int, n_pixels: int, t_update: float) -> int:
    """Encoding a buffer of n_frames sampled over t_update seconds."""
    if n_frames <= 0:
        return 0
    rate_bytes = int(H264_TARGET_BPS * t_update / 8 * (n_pixels / REF_PIXELS))
    floor = int(n_frames * jpeg_bytes(n_pixels) * H264_MIN_FRAME_FRACTION)
    return min(max(rate_bytes, 1), max(floor, 1)) if n_frames == 1 else min(
        rate_bytes, n_frames * jpeg_bytes(n_pixels)
    )
