"""Drifting synthetic token streams — the LLM-world analogue of the video
generator: a Markov source whose transition structure rotates slowly over
time, so a one-time-adapted student decays and a continually-adapted one
tracks (same phenomenology the paper exploits for video)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class StreamConfig:
    vocab_size: int = 512
    order_states: int = 64  # latent Markov states
    drift_period: float = 600.0  # seconds for a full structure rotation
    tokens_per_second: float = 64.0
    temperature: float = 0.7
    seed: int = 0


class TokenStream:
    def __init__(self, cfg: StreamConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        k, v = cfg.order_states, cfg.vocab_size
        self.state_emit_a = rng.normal(size=(k, v)).astype(np.float32)
        self.state_emit_b = rng.normal(size=(k, v)).astype(np.float32)
        self.trans = rng.dirichlet(0.3 * np.ones(k), size=k).astype(np.float32)
        self.tok2state = rng.integers(0, k, size=v)

    def _emit_logits(self, state: np.ndarray, t: float) -> np.ndarray:
        # structure drifts by interpolating between two emission tables
        phase = 0.5 * (1 + np.sin(2 * np.pi * t / self.cfg.drift_period))
        return (1 - phase) * self.state_emit_a[state] + phase * self.state_emit_b[state]

    def sample(self, rng: np.random.Generator, batch: int, seq: int, t: float):
        """Returns (tokens (B,S+1) int32): context + next-token labels are
        tokens[:, :-1] / tokens[:, 1:]."""
        cfg = self.cfg
        state = rng.integers(0, cfg.order_states, size=batch)
        out = np.empty((batch, seq + 1), np.int64)
        out[:, 0] = rng.integers(0, cfg.vocab_size, size=batch)
        for i in range(1, seq + 1):
            logits = self._emit_logits(state, t) / cfg.temperature
            logits -= logits.max(axis=-1, keepdims=True)
            p = np.exp(logits)
            p /= p.sum(axis=-1, keepdims=True)
            cum = np.cumsum(p, axis=-1)
            r = rng.random((batch, 1))
            out[:, i] = (r < cum).argmax(axis=-1)
            state = self.tok2state[out[:, i]]
        return out.astype(np.int32)
