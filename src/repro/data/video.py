"""Procedural video streams with exact ground-truth segmentation.

Replaces the paper's YouTube/Cityscapes footage (unavailable offline) with a
controllable generator (DESIGN.md §5): moving shapes over a drifting textured
background. Two properties matter for reproducing the paper's phenomena:

  * **temporal coherence** — objects move smoothly, so a student trained on
    the recent horizon generalizes to the near future;
  * **distribution drift** — the color palette and background slowly rotate,
    so a model customized once (One-Time) degrades, while continual
    adaptation (AMS) tracks; the drift rate is the scene-dynamics knob.

`motion_schedule` modulates object speed over time (e.g. a stop/go profile
reproduces the Fig. 3 traffic-light ASR behaviour).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import numpy as np


@dataclass(frozen=True)
class VideoConfig:
    height: int = 64
    width: int = 64
    fps: float = 10.0
    duration: float = 300.0  # seconds
    n_classes: int = 5  # incl. background = class 0
    n_objects: int = 7
    base_speed: float = 10.0  # px/sec
    drift_period: float = 240.0  # seconds for a full palette rotation
    cut_period: float = 0.0  # >0: palette jumps (scene cuts) every P seconds
    texture_scale: float = 8.0
    seed: int = 0
    motion_schedule: Callable[[float], float] | None = None  # t -> speed mult

    @property
    def n_frames(self) -> int:
        return int(self.duration * self.fps)


class SyntheticVideo:
    """Deterministic function of (config, frame index)."""

    def __init__(self, cfg: VideoConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        n = cfg.n_objects
        self.cls = rng.integers(1, cfg.n_classes, size=n)
        self.cx0 = rng.uniform(0, cfg.width, size=n)
        self.cy0 = rng.uniform(0, cfg.height, size=n)
        self.phase = rng.uniform(0, 2 * math.pi, size=n)
        self.omega = rng.uniform(0.2, 1.0, size=n)  # direction wobble
        self.radius = rng.uniform(0.09, 0.22, size=n) * min(cfg.height, cfg.width)
        self.shape = rng.integers(0, 2, size=n)  # 0=disk, 1=square
        self.tex_phase = rng.uniform(0, 2 * math.pi, size=4)
        yy, xx = np.mgrid[0 : cfg.height, 0 : cfg.width]
        self.yy, self.xx = yy.astype(np.float32), xx.astype(np.float32)
        # per-class base hue anchors (palette drifts around these)
        self.class_hue = np.linspace(0.0, 1.0, cfg.n_classes, endpoint=False)

    # -- motion ----------------------------------------------------------
    def _speed_mult(self, t: float) -> float:
        ms = self.cfg.motion_schedule
        return float(ms(t)) if ms is not None else 1.0

    def _integrated_motion(self, t: float) -> float:
        """∫ speed_mult dt, evaluated cheaply (piecewise-constant per 0.5s)."""
        if self.cfg.motion_schedule is None:
            return t
        steps = int(t / 0.5)
        acc = sum(self._speed_mult(i * 0.5) for i in range(steps)) * 0.5
        return acc + self._speed_mult(steps * 0.5) * (t - steps * 0.5)

    def _positions(self, t: float):
        """Bounded orbits: position change rate is proportional to the
        *instantaneous* speed (a frozen schedule freezes the scene exactly —
        no lever-arm growth with accumulated path length)."""
        cfg = self.cfg
        s = self.cfg.base_speed * self._integrated_motion(t)
        r_orbit = 0.45 * min(cfg.height, cfg.width)
        ang = self.phase + self.omega * (s / r_orbit) * 4.0
        cx = (self.cx0 + r_orbit * np.cos(ang)) % cfg.width
        cy = (self.cy0 + r_orbit * np.sin(ang)) % cfg.height
        return cx, cy

    # -- appearance --------------------------------------------------------
    def _cut_phase(self, t: float) -> float:
        if self.cfg.cut_period <= 0:
            return 0.0
        return 0.35 * (int(t / self.cfg.cut_period) % 2)  # A/B palette jumps

    def _palette(self, t: float) -> np.ndarray:
        """(n_classes, 3) RGB; hue rotates with the drift period (plus scene
        cuts when cut_period > 0 — the fast-scene-change regime)."""
        drift = (t / self.cfg.drift_period + self._cut_phase(t)) % 1.0
        hues = (self.class_hue + drift) % 1.0
        # cheap HSV->RGB at s=0.75, v=0.9
        h6 = hues * 6.0
        i = np.floor(h6).astype(int) % 6
        f = h6 - np.floor(h6)
        v, s = 0.9, 0.75
        p, q, u = v * (1 - s), v * (1 - s * f), v * (1 - s * (1 - f))
        table = np.stack(
            [
                np.stack([np.full_like(f, v), u, np.full_like(f, p)], -1),
                np.stack([q, np.full_like(f, v), np.full_like(f, p)], -1),
                np.stack([np.full_like(f, p), np.full_like(f, v), u], -1),
                np.stack([np.full_like(f, p), q, np.full_like(f, v)], -1),
                np.stack([u, np.full_like(f, p), np.full_like(f, v)], -1),
                np.stack([np.full_like(f, v), np.full_like(f, p), q], -1),
            ],
            0,
        )
        return table[i, np.arange(len(hues))]

    def _background(self, t: float) -> np.ndarray:
        cfg = self.cfg
        drift = 2 * math.pi * (t / cfg.drift_period + self._cut_phase(t))
        k = 2 * math.pi / cfg.texture_scale
        tex = (
            np.sin(k * self.xx + self.tex_phase[0] + drift)
            + np.sin(k * self.yy + self.tex_phase[1] - 0.7 * drift)
            + 0.5 * np.sin(k * (self.xx + self.yy) / 1.4 + self.tex_phase[2] + 0.3 * drift)
        ) / 2.5
        base = self._palette(t)[0]
        img = base[None, None, :] * (0.6 + 0.4 * tex[..., None])
        return img.astype(np.float32)

    # -- frame -------------------------------------------------------------
    def frame(self, idx: int):
        """Returns (img (H,W,3) float32 in [0,1], mask (H,W) int32)."""
        cfg = self.cfg
        t = idx / cfg.fps
        img = self._background(t)
        mask = np.zeros((cfg.height, cfg.width), np.int32)
        pal = self._palette(t)
        cx, cy = self._positions(t)
        order = np.argsort(self.radius)  # big shapes first, small on top
        for j in order[::-1]:
            if self.shape[j] == 0:
                inside = (self.xx - cx[j]) ** 2 + (self.yy - cy[j]) ** 2 <= self.radius[j] ** 2
            else:
                inside = (np.abs(self.xx - cx[j]) <= self.radius[j]) & (
                    np.abs(self.yy - cy[j]) <= self.radius[j]
                )
            c = int(self.cls[j])
            shade = 0.75 + 0.25 * math.sin(0.13 * t + j)
            img[inside] = pal[c] * shade
            mask[inside] = c
        noise = np.random.default_rng(cfg.seed * 100003 + idx).normal(
            0.0, 0.02, size=img.shape
        )
        return np.clip(img + noise, 0.0, 1.0).astype(np.float32), mask

    def frames(self, start: int = 0, stop: int | None = None, stride: int = 1):
        stop = stop if stop is not None else self.cfg.n_frames
        for i in range(start, stop, stride):
            yield i, *self.frame(i)


def stop_and_go(stop_at: float, go_at: float) -> Callable[[float], float]:
    """Fig.-3-style motion schedule: full speed, halt, resume."""

    def sched(t: float) -> float:
        return 0.02 if stop_at <= t < go_at else 1.0

    return sched


class OracleTeacher:
    """Stochastic oracle standing in for the paper's DeeplabV3-Xception65
    teacher (DESIGN.md §5): ground truth + controlled, temporally-consistent
    corruption (boundary erosion + patch flips) at a target error rate."""

    def __init__(self, video: SyntheticVideo, error_rate: float = 0.04, seed: int = 1):
        self.video = video
        self.error_rate = error_rate
        self.seed = seed

    def label(self, idx: int) -> np.ndarray:
        _, mask = self.video.frame(idx)
        rng = np.random.default_rng(self.seed * 7919 + idx // 8)  # consistent over ~8 frames
        out = mask.copy()
        h, w = mask.shape
        n_patches = int(self.error_rate * h * w / 25)
        for _ in range(n_patches):
            y, x = rng.integers(0, h - 5), rng.integers(0, w - 5)
            out[y : y + 5, x : x + 5] = rng.integers(0, self.video.cfg.n_classes)
        return out
