"""Pallas kernels for the serving hot path.

Three kernel families live here, each as ``<name>.py`` (the pallas_call) +
``ops.py`` (jit'd shape-polymorphic wrapper) + ``ref.py`` (pure-jnp
oracle):

* ``masked_adam`` — the fused Algorithm-2 inner update. Beyond the
  original per-leaf 2-D kernel, `ops.masked_adam_stacked` runs a whole
  fused grant's optimizer step as ONE launch: every session's pytree is
  flattened and concatenated into per-dtype ``(B, rows, 128)`` buffers
  (`repro.kernels.stacking` caches the offsets per shape struct, so the
  unstack is bit-exact) and the vmapped session axis becomes a grid
  dimension. p/g/m/v/mask move HBM→VMEM exactly once per iteration.
* ``topk_mask`` — the bit-pattern top-k threshold behind gradient-guided
  selection: 32 counting passes over the f32 bit space collapse into one
  kernel that reads each session's |u| bits ONCE in VMEM — byte-identical
  masks to `core.selection`'s exact sort-path threshold.
* ``flash_attention`` / ``rmsnorm`` — model-side kernels (pre-serving).

Dispatch: the serving executables do NOT call these directly — they go
through `core.batched.set_kernel_mode` (``"xla"`` default |
``"pallas"`` | ``"auto"``, which races both per (backend, compile key)
and caches the measured winner). See ROADMAP item 5 for how the kernels'
achieved-fraction-of-roofline lands in ``BENCH_serving.json``.

Interpret mode: kernels default to ``interpret=None`` → resolved by
`interpret_default()`: interpret only when the default jax backend is CPU
(override with the ``REPRO_PALLAS_INTERPRET`` env var or the kwarg), so
accelerator hosts stop silently running kernels in the interpreter. On
this CPU container interpret mode measures CORRECTNESS (byte-identical
outputs, CI-gated via ``scripts/ci.sh`` → ``kernels_bench --kernels``),
not speed — the roofline fractions it reports are the analytic story,
the wall-clock one needs a real accelerator.
"""
from __future__ import annotations

import os


def interpret_default() -> bool:
    """Whether Pallas kernels should run in interpret mode when the caller
    passed ``interpret=None``: yes only on a CPU default backend (there is
    no Mosaic there), overridable via ``REPRO_PALLAS_INTERPRET=0/1``.
    Resolved at trace time — the backend does not change mid-process."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "")
    import jax

    return jax.default_backend() == "cpu"


def resolve_interpret(interpret) -> bool:
    """``interpret=None`` → backend-aware default; booleans pass through."""
    return interpret_default() if interpret is None else bool(interpret)
