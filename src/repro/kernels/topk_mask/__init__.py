from repro.kernels.topk_mask.ops import (pallas_topk_supported,
                                         stacked_topk_masks)
from repro.kernels.topk_mask.topk_mask import (PALLAS_TOPK_MAX_PER_SESSION,
                                               topk_threshold_bits_3d)

__all__ = ["stacked_topk_masks", "pallas_topk_supported",
           "topk_threshold_bits_3d", "PALLAS_TOPK_MAX_PER_SESSION"]
