"""jit'd wrapper: stacked bit-pattern top-k masks from one kernel launch.

The serving entry point is `core.selection.stacked_gradient_guided_masks`
with ``kernel_mode("pallas")`` — it calls `stacked_topk_masks` here, which
flattens a B-stacked |u| tree into one lane-aligned uint32 bit buffer
(`repro.kernels.stacking` plan, cached per struct), launches the per-session
threshold kernel, and materializes the masks with the same ``|u| >= thr``
jnp comparison the XLA path uses — byte-identical masks, one HBM read of
the bit buffer instead of 32."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret, stacking
from repro.kernels.topk_mask.topk_mask import (PALLAS_TOPK_MAX_PER_SESSION,
                                               topk_threshold_bits_3d)


def _abs_bits(l):
    return jax.lax.bitcast_convert_type(
        jnp.abs(l.astype(jnp.float32)).reshape(l.shape[0], -1), jnp.uint32)


@functools.partial(jax.jit, static_argnames=("frac", "interpret"))
def stacked_topk_masks(u_stacked, *, frac: float, interpret=None):
    """Per-session gradient-guided masks for a B-stacked update tree.

    Matches ``vmap(core.selection._bitwise_topk_body)`` byte-for-byte:
    same exact threshold (the kernel reproduces the 32-pass counting
    search bit-for-bit, zero padding never counts), same mask comparison
    (float ``>=`` on the original leaves, so NaN/denormal/zero semantics
    are untouched). ``frac`` static per executable — one γ per fused
    group. Returns the stacked bool mask tree."""
    interpret = resolve_interpret(interpret)
    leaves = jax.tree.leaves(u_stacked)
    plan = stacking.stack_plan(
        jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                     u_stacked))
    b = plan.b
    n = sum(g.n for g in plan.groups)
    k = max(int(frac * n), 1)
    # |u| bits for every leaf, concatenated across ALL groups in plan
    # order (the source tree may mix dtypes; bits are uniformly uint32)
    parts = []
    for group in plan.groups:
        for i in group.indices:
            parts.append(_abs_bits(leaves[i]))
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    pad = (-n) % stacking.LANES
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    bits = flat.reshape(b, -1, stacking.LANES)
    thr_bits = topk_threshold_bits_3d(bits, k, interpret=interpret)
    thr = jax.lax.bitcast_convert_type(thr_bits.reshape(b), jnp.float32)

    def leaf_mask(l):
        t = thr.reshape((b,) + (1,) * (l.ndim - 1))
        return jnp.abs(l.astype(jnp.float32)) >= t

    return jax.tree.map(leaf_mask, u_stacked)


def pallas_topk_supported(per_session: int) -> bool:
    """Whether one session's coordinates fit the single-block kernel's
    VMEM budget (the dispatch layer's fallback test)."""
    return per_session <= PALLAS_TOPK_MAX_PER_SESSION
