"""Pure-jnp oracle for the bit-pattern top-k kernel: the same 32 unrolled
counting passes, as XLA ops (this is exactly the implementation
`core.selection._bitwise_topk_body` derives its threshold from — kept here
so the kernel's test oracle does not depend on the serving stack)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def topk_threshold_bits_ref(u_leaves, k: int) -> jax.Array:
    """Threshold bits for ONE session: the bit pattern of the exact value
    ``sort(|u|)[N-k]`` over the concatenated leaves."""
    bits = [jax.lax.bitcast_convert_type(
        jnp.abs(l.astype(jnp.float32)).reshape(-1), jnp.uint32)
        for l in u_leaves]
    thr = jnp.uint32(0)
    for bit in range(31, -1, -1):
        cand = thr | jnp.uint32(1 << bit)
        cnt = sum(jnp.sum(b >= cand) for b in bits)
        thr = jnp.where(cnt >= k, cand, thr)
    return thr


def topk_threshold_sort_ref(u_leaves, k: int) -> float:
    """The sort-path ground truth the bit search must reproduce."""
    flat = np.concatenate([np.abs(np.asarray(l, np.float32)).reshape(-1)
                           for l in u_leaves])
    return float(np.sort(flat)[flat.size - k])
