"""Pallas kernel: bit-pattern top-k threshold search, one session per grid
step.

Gradient-guided selection needs the exact k-th largest |u| per session.
Non-negative float32s order exactly as their unsigned bit patterns, so the
threshold is found by binary search over the 32-bit space — the same 32
counting passes `core.selection._bitwise_topk_body` unrolls in XLA. The
XLA lowering re-reads the |u| buffer from HBM on every pass (32 x 4N
bytes); this kernel keeps each session's bit buffer resident in VMEM and
runs all 32 passes on-chip — ONE HBM read of 4N bytes per session, which
is the analytic roofline bound `roofline.analysis.topk_hbm_bytes` states.

The kernel emits only the per-session threshold BITS (B, 1); the caller
bitcasts to float and materializes the ``|u| >= thr`` masks with the same
jnp comparison the XLA path uses, so the masks are byte-identical by
construction (including NaN semantics, which a bits-space ``>=`` would
get wrong).

VMEM bound: one session's buffer must fit on-chip (~16 MB/core → ~4M
f32 coordinates). The dispatch layer (`core.selection`) falls back to the
XLA path above `PALLAS_TOPK_MAX_PER_SESSION`; serving students are ~0.5M
parameters, comfortably inside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128

# per-session coordinate budget for the single-block kernel (f32 bits +
# compare scratch well under the ~16 MB VMEM/core)
PALLAS_TOPK_MAX_PER_SESSION = 4_000_000


def _kernel(bits_ref, thr_ref, *, k: int):
    bits = bits_ref[...]  # (1, R, LANES) uint32 — |u| bit patterns, 0-padded
    thr = jnp.uint32(0)
    # 32 counting passes, all in VMEM: zero padding never counts (cand >= 1)
    for bit in range(31, -1, -1):
        cand = thr | jnp.uint32(1 << bit)
        cnt = jnp.sum((bits >= cand).astype(jnp.int32))
        thr = jnp.where(cnt >= k, cand, thr)
    thr_ref[...] = thr.reshape(1, 1)


def topk_threshold_bits_3d(bits, k: int, *, interpret: bool = True):
    """Per-session exact top-k threshold bits.

    ``bits``: (B, R, 128) uint32 — each session's |u| float32 bit patterns,
    flattened/concatenated and zero-padded (`repro.kernels.stacking`).
    ``k``: static per-session selection count (same for every session in a
    stack — one γ per fused group by compile-key construction). Returns
    (B, 1) uint32: the bit pattern of ``sort(|u|)[N-k]``, exactly."""
    B, R, _ = bits.shape
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, R, LANES), lambda s: (s, 0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.uint32),
        interpret=interpret,
    )(bits)
