"""Pure-jnp oracle for the fused masked-Adam kernel (identical math to
core/masked_adam.py's per-leaf update)."""
from __future__ import annotations

import jax.numpy as jnp


def masked_adam_ref(p, g, m, v, b, bc, *, b1: float, b2: float, eps: float):
    g32 = g.astype(jnp.float32)
    m_new = b1 * m.astype(jnp.float32) + (1.0 - b1) * g32
    v_new = b2 * v.astype(jnp.float32) + (1.0 - b2) * jnp.square(g32)
    u = bc.reshape(()) * m_new / jnp.sqrt(v_new + eps)
    p_new = (p.astype(jnp.float32) - u * b.astype(jnp.float32)).astype(p.dtype)
    return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype), u
