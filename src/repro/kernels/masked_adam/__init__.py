from repro.kernels.masked_adam.ops import masked_adam_leaf  # noqa: F401
