"""Pallas TPU kernel: fused masked-Adam inner update (Algorithm 2, lines 8-13).

On the server this op touches every parameter 4x per iteration (p, m, v plus
the emitted update u) — at 0 FLOP/byte it is purely HBM-bandwidth bound, so
the win is one HBM->VMEM pass with all arithmetic fused, instead of the
~10 separate elementwise HLO ops XLA emits for the unfused tree_map version.

Tiling: parameters are flattened and reshaped to (rows, 128) lanes; each grid
step processes a (BLOCK_ROWS, 128) tile resident in VMEM (6 input + 4 output
tiles ~= 2.6 MB at BLOCK_ROWS=512 — comfortably under the ~16 MB VMEM/core).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 512


def _kernel(p_ref, g_ref, m_ref, v_ref, b_ref, s_ref,
            po_ref, mo_ref, vo_ref, uo_ref, *, b1, b2, eps):
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...].astype(jnp.float32) + (1.0 - b1) * g
    v = b2 * v_ref[...].astype(jnp.float32) + (1.0 - b2) * g * g
    bc = s_ref[0, 0]  # lr * sqrt(1-b2^i)/(1-b1^i), precomputed on host
    u = bc * m / jnp.sqrt(v + eps)
    p = p_ref[...].astype(jnp.float32) - u * b_ref[...].astype(jnp.float32)
    po_ref[...] = p.astype(po_ref.dtype)
    mo_ref[...] = m.astype(mo_ref.dtype)
    vo_ref[...] = v.astype(vo_ref.dtype)
    uo_ref[...] = u.astype(uo_ref.dtype)


def masked_adam_2d(p, g, m, v, b, bc, *, b1: float, b2: float, eps: float,
                   block_rows: int = BLOCK_ROWS, interpret: bool = True):
    """Core 2-D tiled call. All tensors (R, 128); bc: (1,1) f32."""
    R = p.shape[0]
    br = min(block_rows, R)
    while R % br:
        br -= 1
    grid = (R // br,)
    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    scal = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out_shapes = (
        jax.ShapeDtypeStruct(p.shape, p.dtype),
        jax.ShapeDtypeStruct(m.shape, m.dtype),
        jax.ShapeDtypeStruct(v.shape, v.dtype),
        jax.ShapeDtypeStruct(p.shape, jnp.float32),
    )
    return pl.pallas_call(
        functools.partial(_kernel, b1=b1, b2=b2, eps=eps),
        grid=grid,
        in_specs=[tile, tile, tile, tile, tile, scal],
        out_specs=(tile, tile, tile, tile),
        out_shape=out_shapes,
        interpret=interpret,
    )(p, g, m, v, b, bc)


def masked_adam_stacked_3d(p, g, m, v, b, bc, *, b1: float, b2: float,
                           eps: float, block_rows: int = BLOCK_ROWS,
                           interpret: bool = True):
    """Stacked-layout call for a fused grant: all tensors (B, R, 128) with
    the vmapped session axis as the leading GRID dimension, bc (B, 1) f32
    per-session bias correction (sessions in one stack can sit at different
    Adam step counts). One ``pallas_call`` covers the whole group: grid
    (B, R/br), each step streaming a (1, br, 128) tile of p/g/m/v/mask
    through VMEM exactly once — the same single-HBM-pass math as
    `masked_adam_2d`, without a per-session dispatch loop."""
    B, R, _ = p.shape
    br = min(block_rows, R)
    while R % br:
        br -= 1
    grid = (B, R // br)
    tile = pl.BlockSpec((1, br, LANES), lambda s, i: (s, i, 0))
    scal = pl.BlockSpec((1, 1), lambda s, i: (s, 0))
    out_shapes = (
        jax.ShapeDtypeStruct(p.shape, p.dtype),
        jax.ShapeDtypeStruct(m.shape, m.dtype),
        jax.ShapeDtypeStruct(v.shape, v.dtype),
        jax.ShapeDtypeStruct(p.shape, jnp.float32),
    )
    return pl.pallas_call(
        functools.partial(_kernel, b1=b1, b2=b2, eps=eps),
        grid=grid,
        in_specs=[tile, tile, tile, tile, tile, scal],
        out_specs=(tile, tile, tile, tile),
        out_shape=out_shapes,
        interpret=interpret,
    )(p, g, m, v, b, bc)
