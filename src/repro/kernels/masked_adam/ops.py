"""jit'd wrappers for the fused masked-Adam kernel.

`masked_adam_leaf` applies the 2-D kernel to one leaf of any shape/dtype
(pad + reshape to lane-aligned 2-D, undo afterwards). `masked_adam_stacked`
is the serving hot path: a fused grant's whole ``(params, opt_state, mask)``
stack — every leaf carrying a leading session axis B — runs as one
``pallas_call`` per distinct param dtype over flattened-and-concatenated
``(B, rows, 128)`` buffers (`repro.kernels.stacking` caches the offsets per
shape struct, so the unstack is bit-exact). The arithmetic is the same
float32 expression tree as `core.masked_adam.masked_adam_update`; outputs
agree with the XLA tree_map path to float32 rounding — XLA:CPU's
context-dependent FMA contraction moves single ULPs between compilation
contexts (it makes even the XLA path differ jit-vs-nojit), so byte
identity is asserted downstream where it actually holds: selection masks
and packed wire masks (tests/test_kernel_dispatch.py, ``ci.sh --kernels``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret, stacking
from repro.kernels.masked_adam.masked_adam import (LANES, masked_adam_2d,
                                                   masked_adam_stacked_3d)


def _to_2d(x, n_pad):
    flat = x.reshape(-1)
    if n_pad:
        flat = jnp.pad(flat, (0, n_pad))
    return flat.reshape(-1, LANES)


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "interpret"))
def masked_adam_leaf(p, g, m, v, b, bc, *, b1=0.9, b2=0.999, eps=1e-8,
                     interpret=None):
    """Fused Algorithm-2 inner update for a single parameter leaf.
    bc is the scalar lr * sqrt(1-b2^i)/(1-b1^i). Returns (p', m', v', u).
    ``interpret=None`` resolves backend-aware (interpret only on CPU)."""
    interpret = resolve_interpret(interpret)
    shape = p.shape
    n = p.size
    n_pad = (-n) % LANES
    args = [_to_2d(a, n_pad) for a in (p, g, m, v)]
    bmask = _to_2d(b.astype(jnp.float32), n_pad)
    bc2 = jnp.asarray(bc, jnp.float32).reshape(1, 1)
    po, mo, vo, uo = masked_adam_2d(*args, bmask, bc2, b1=b1, b2=b2, eps=eps,
                                    interpret=interpret)

    def _back(x, dtype=None):
        flat = x.reshape(-1)[:n]
        return flat.reshape(shape) if dtype is None else flat.reshape(shape).astype(dtype)

    return _back(po), _back(mo), _back(vo), _back(uo)


def masked_adam_stacked(params, grads, state, mask, *, lr=1e-3, b1=0.9,
                        b2=0.999, eps=1e-8, interpret=None):
    """One masked-Adam inner iteration for a B-stacked session group, as
    Pallas launches over concatenated leaf buffers.

    Drop-in for ``vmap(masked_adam_update)`` on stacked trees: ``params``
    / ``grads`` / ``mask`` and ``state``'s moment trees all carry a
    leading session axis; ``state.count`` is (B,) so sessions at different
    Adam step counts get their own bias correction (fed to the kernel as a
    per-session grid scalar). Returns ``(params', state', u)`` with ``u``
    float32 like the tree_map path. Designed to be traced inside the
    cached phase executables (`core.batched`) — under jit the per-struct
    `stacking.stack_plan` keeps retracing flat.
    """
    interpret = resolve_interpret(interpret)
    i = state.count + 1
    i32 = i.astype(jnp.float32)
    bc = lr * jnp.sqrt(1.0 - b2 ** i32) / (1.0 - b1 ** i32)
    plan = stacking.stack_plan(params)
    b_sessions = plan.b
    bc2 = bc.reshape(b_sessions, 1)

    leaves_p = jax.tree.leaves(params)
    leaves_g = jax.tree.leaves(grads)
    leaves_m = jax.tree.leaves(state.m)
    leaves_v = jax.tree.leaves(state.v)
    leaves_b = jax.tree.leaves(mask)
    n_leaves = len(leaves_p)
    out_p: list = [None] * n_leaves
    out_m: list = [None] * n_leaves
    out_v: list = [None] * n_leaves
    out_u: list = [None] * n_leaves
    for group in plan.groups:
        pb = stacking.flatten_group(leaves_p, group, b_sessions)
        gb = stacking.flatten_group(leaves_g, group, b_sessions)
        mb = stacking.flatten_group(leaves_m, group, b_sessions)
        vb = stacking.flatten_group(leaves_v, group, b_sessions)
        bb = stacking.flatten_group(leaves_b, group, b_sessions,
                                    transform=lambda l: l.astype(jnp.float32))
        po, mo, vo, uo = masked_adam_stacked_3d(
            pb, gb, mb, vb, bb, bc2, b1=b1, b2=b2, eps=eps,
            interpret=interpret)
        stacking.unflatten_group(po, group, b_sessions, plan.shapes, out=out_p)
        stacking.unflatten_group(mo, group, b_sessions, plan.shapes, out=out_m)
        stacking.unflatten_group(vo, group, b_sessions, plan.shapes, out=out_v)
        stacking.unflatten_group(uo, group, b_sessions, plan.shapes, out=out_u)
    treedef = plan.treedef
    params_new = jax.tree.unflatten(treedef, out_p)
    m_new = jax.tree.unflatten(treedef, out_m)
    v_new = jax.tree.unflatten(treedef, out_v)
    u = jax.tree.unflatten(treedef, out_u)
    return params_new, type(state)(m_new, v_new, i), u
