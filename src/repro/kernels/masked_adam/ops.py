"""jit'd wrapper: apply the fused masked-Adam kernel to one leaf of any
shape/dtype (pad + reshape to lane-aligned 2-D, undo afterwards)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.masked_adam.masked_adam import LANES, masked_adam_2d


def _to_2d(x, n_pad):
    flat = x.reshape(-1)
    if n_pad:
        flat = jnp.pad(flat, (0, n_pad))
    return flat.reshape(-1, LANES)


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "interpret"))
def masked_adam_leaf(p, g, m, v, b, bc, *, b1=0.9, b2=0.999, eps=1e-8,
                     interpret=True):
    """Fused Algorithm-2 inner update for a single parameter leaf.
    bc is the scalar lr * sqrt(1-b2^i)/(1-b1^i). Returns (p', m', v', u)."""
    shape = p.shape
    n = p.size
    n_pad = (-n) % LANES
    args = [_to_2d(a, n_pad) for a in (p, g, m, v)]
    bmask = _to_2d(b.astype(jnp.float32), n_pad)
    bc2 = jnp.asarray(bc, jnp.float32).reshape(1, 1)
    po, mo, vo, uo = masked_adam_2d(*args, bmask, bc2, b1=b1, b2=b2, eps=eps,
                                    interpret=interpret)

    def _back(x, dtype=None):
        flat = x.reshape(-1)[:n]
        return flat.reshape(shape) if dtype is None else flat.reshape(shape).astype(dtype)

    return _back(po), _back(mo), _back(vo), _back(uo)
