"""Flatten-and-concatenate plans for stacked (B, ...) pytrees.

The stacked Pallas kernels (masked-Adam, bit-pattern top-k) want each
session's parameters as ONE lane-aligned buffer — ``(B, rows, 128)`` — so a
whole fused group moves through HBM in a single grid sweep instead of one
dispatch per leaf. The flatten is reshape + concat + zero-pad and the
unflatten is slice + reshape: all bit-exact re-layouts, so a kernel output
unstacks to exactly the per-leaf arrays the tree_map path would have
produced.

A `StackPlan` caches the host-side bookkeeping per shape/dtype struct —
leaf order grouped by dtype, per-leaf sizes and offsets, pad amount, row
count — so repeated launches for the same compile key re-derive nothing.
(The device-side ops are traced into the surrounding jit either way; the
plan keeps Python trace time flat at fleet scale, mirroring
`core.batched`'s executable cache.)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

LANES = 128


class DtypeGroup(NamedTuple):
    dtype: str            # param dtype name of every leaf in the group
    indices: tuple        # leaf positions (flatten order) in the source tree
    sizes: tuple          # per-session flat size of each leaf
    offsets: tuple        # start of each leaf inside the concat buffer
    n: int                # per-session valid elements (sum of sizes)
    rows: int             # ceil(n / LANES) — buffer is (B, rows, LANES)


class StackPlan(NamedTuple):
    b: int                # session-axis length
    groups: tuple         # DtypeGroup per distinct leaf dtype
    shapes: tuple         # per-leaf full shapes (B first), flatten order
    treedef: object


_PLANS: dict = {}
_PLAN_HITS = 0
_PLAN_MISSES = 0


def plan_cache_info() -> dict:
    return {"size": len(_PLANS), "hits": _PLAN_HITS, "misses": _PLAN_MISSES}


def plan_cache_clear() -> None:
    global _PLAN_HITS, _PLAN_MISSES
    _PLANS.clear()
    _PLAN_HITS = _PLAN_MISSES = 0


def stack_plan(tree) -> StackPlan:
    """The (cached) flatten/concat plan for a stacked pytree whose every
    leaf carries a leading session axis B. Leaves are grouped by dtype —
    one ``(B, rows, 128)`` kernel buffer per distinct dtype — and within a
    group keep tree-flatten order, so offsets are deterministic."""
    global _PLAN_HITS, _PLAN_MISSES
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("stack_plan needs at least one leaf")
    key = (treedef,
           tuple((tuple(l.shape), l.dtype.name) for l in leaves))
    plan = _PLANS.get(key)
    if plan is not None:
        _PLAN_HITS += 1
        return plan
    _PLAN_MISSES += 1
    b = int(leaves[0].shape[0])
    for l in leaves:
        if l.shape[0] != b:
            raise ValueError(
                f"inconsistent session axis: {l.shape[0]} vs {b}")
    by_dtype: dict[str, list[int]] = {}
    for i, l in enumerate(leaves):
        by_dtype.setdefault(l.dtype.name, []).append(i)
    groups = []
    for dt in sorted(by_dtype):
        idx = tuple(by_dtype[dt])
        sizes = tuple(int(np.prod(leaves[i].shape[1:])) for i in idx)
        offsets, off = [], 0
        for s in sizes:
            offsets.append(off)
            off += s
        rows = -(-off // LANES)  # ceil
        groups.append(DtypeGroup(dt, idx, sizes, tuple(offsets), off, rows))
    plan = StackPlan(b, tuple(groups),
                     tuple(tuple(l.shape) for l in leaves), treedef)
    _PLANS[key] = plan
    return plan


def flatten_group(leaves, group: DtypeGroup, b: int, transform=None):
    """Concat a dtype group's leaves into the kernel buffer
    ``(B, rows, LANES)``, zero-padded past ``group.n``. ``transform`` maps
    each leaf before flattening (e.g. abs-bit-pattern for top-k); padding
    zeros are appended AFTER the transform, so a transform need only be
    elementwise."""
    parts = []
    for i in group.indices:
        l = leaves[i]
        if transform is not None:
            l = transform(l)
        parts.append(l.reshape(b, -1))
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    pad = group.rows * LANES - group.n
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat.reshape(b, group.rows, LANES)


def unflatten_group(buf, group: DtypeGroup, b: int, shapes, out=None,
                    dtype=None):
    """Inverse of `flatten_group`: slice each leaf back out of the
    ``(B, rows, LANES)`` buffer into ``out`` (a list indexed like the
    source tree's flat leaves). Padding is discarded; the round trip is
    bit-exact. ``dtype`` optionally casts every leaf (top-k thresholds
    aside, kernels emit leaves in their source dtype already)."""
    flat = buf.reshape(b, group.rows * LANES)
    out = [None] * (max(group.indices) + 1) if out is None else out
    for i, size, off in zip(group.indices, group.sizes, group.offsets):
        leaf = flat[:, off:off + size].reshape(shapes[i])
        if dtype is not None:
            leaf = leaf.astype(dtype)
        out[i] = leaf
    return out
