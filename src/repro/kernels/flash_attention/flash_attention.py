"""Pallas TPU kernel: blocked causal/sliding-window GQA flash attention.

The student forward (and the pod-side teacher labeling pass) is dominated by
attention; this is the TPU-native analogue of the jnp chunked path in
models/attention.py.

Design (MXU/VMEM-aware, DESIGN.md §5):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the kv dimension is the
    innermost, sequential ("arbitrary") axis — the online-softmax state
    (m, l, acc) lives in VMEM scratch across kv steps.
  * GQA without materializing repeated K/V: the K/V BlockSpec index_map
    folds the q-head -> kv-head mapping (h // group), so each kv head's
    tile is fetched once per group directly from HBM.
  * block sizes default to 128/128: MXU-aligned (128x128 systolic array),
    q/k/v/o tiles + scratch ~= 0.4 MB in VMEM at head_dim 128.
  * fully-masked (q_block, kv_block) pairs are skipped with @pl.when —
    causal wedges and sliding windows skip ~half / ~all-but-W/S of steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, block_q, block_k, nk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # static-shape positions; masks built per tile
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # skip tiles that are entirely masked out
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window:
        live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_4d(q, k, v, *, causal=True, window=0, softcap=0.0,
                       block_q=128, block_k=128, interpret=True):
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd) with H % KV == 0.
    Returns (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    group = H // KV
    bq = min(block_q, Sq)
    while Sq % bq:
        bq -= 1
    bk = min(block_k, Skv)
    while Skv % bk:
        bk -= 1
    nq, nk = Sq // bq, Skv // bk
    grid = (B, H, nq, nk)
    scale = hd**-0.5

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, nk=nk,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
