"""Pure-jnp oracle for the flash-attention kernel (naive O(S^2) softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (B,H,Sq,hd); k,v: (B,KV,Skv,hd). Returns (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    group = H // KV
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (hd**-0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= qp - kp < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
