"""jit'd wrapper around the Pallas flash-attention kernel, in the model's
native (B, S, KV, G, hd) layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention_4d


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(q, k, v, *, causal=True, window=0, softcap=0.0,
                           block_q=128, block_k=128, interpret=None):
    """q: (B,Sq,KV,G,hd); k,v: (B,Skv,KV,hd) — same layout as
    models/attention.flash_attention. Returns (B,Sq,KV,G,hd).
    ``interpret=None`` resolves per backend
    (`repro.kernels.interpret_default`)."""
    interpret = resolve_interpret(interpret)
    B, Sq, KV, G, hd = q.shape
    q4 = q.transpose(0, 2, 3, 1, 4).reshape(B, KV * G, Sq, hd)
    k4 = k.transpose(0, 2, 1, 3)
    v4 = v.transpose(0, 2, 1, 3)
    o = flash_attention_4d(q4, k4, v4, causal=causal, window=window, softcap=softcap,
                           block_q=block_q, block_k=block_k, interpret=interpret)
    return o.reshape(B, KV, G, Sq, hd).transpose(0, 3, 1, 2, 4)
