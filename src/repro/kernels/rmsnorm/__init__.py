from repro.kernels.rmsnorm.ops import rms_norm_pallas  # noqa: F401
