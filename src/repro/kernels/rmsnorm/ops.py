"""jit'd wrapper: RMSNorm kernel over arbitrary leading dims."""
from __future__ import annotations

import functools

import jax

from repro.kernels import resolve_interpret
from repro.kernels.rmsnorm.rmsnorm import rms_norm_2d


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rms_norm_pallas(x, w, *, eps: float = 1e-6, interpret: bool | None = None):
    """x: (..., d); w: (d,). ``interpret=None`` resolves per backend
    (`repro.kernels.interpret_default`: interpret on CPU, compiled on TPU,
    env-overridable)."""
    interpret = resolve_interpret(interpret)
    shape = x.shape
    out = rms_norm_2d(x.reshape(-1, shape[-1]), w, eps=eps, interpret=interpret)
    return out.reshape(shape)
