"""Pure-jnp oracle (identical to models/layers.rms_norm)."""
from repro.models.layers import rms_norm as rms_norm_ref  # noqa: F401
