"""Pallas TPU kernel: fused RMSNorm (gemma-style (1+w) scale).

Pre-norms run 2x per block x every token; unfused XLA emits square/reduce/
rsqrt/mul chains with an HBM round-trip at the reduction. One VMEM pass:
each grid step loads a (rows, d) tile, reduces, scales, writes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * (1.0 + w)).astype(o_ref.dtype)


def rms_norm_2d(x, w, *, eps: float = 1e-6, block_rows: int = BLOCK_ROWS,
                interpret: bool = True):
    """x: (R, d); w: (d,). Returns (R, d)."""
    R, d = x.shape
    br = min(block_rows, R)
    while R % br:
        br -= 1
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, w)
