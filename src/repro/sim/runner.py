"""End-to-end streaming simulation of all five schemes (§4.1).

Timeline granularity = one video frame. Every scheme shares the same eval
loop (client inference vs teacher labels, per-frame mIoU — exactly the
paper's metric) and the same bandwidth ledger; they differ in what moves
over the network and when the student trains.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.bandwidth import BandwidthLedger
from repro.core.delta import apply_delta, encode_delta, full_model_bytes
from repro.core.masked_adam import (
    init_momentum,
    init_state,
    adam_update,
    masked_adam_update,
    momentum_update,
)
from repro.core import selection
from repro.core.server import AMSConfig, AMSSession, Task
from repro.data import codec
from repro.metrics.miou import miou
from repro.sim.seg_world import SegWorld, phi_pixel_loss


@dataclass(frozen=True)
class SimConfig:
    eval_stride: int = 3  # evaluate every k-th frame
    one_time_window: float = 60.0
    one_time_iters: int = 200
    remote_rate: float = 1.0  # fps, Remote+Tracking label rate
    # Just-In-Time baseline, following the paper's methodology (§4.1): it
    # samples continuously (every frame) and its accuracy threshold is tuned
    # so JIT matches AMS accuracy — bandwidth is then compared at equal mIoU.
    jit_threshold: float = 0.60
    jit_max_iters: int = 4
    jit_sample_rate: float = 4.0


@dataclass
class Result:
    scheme: str
    miou_per_frame: list = field(default_factory=list)
    eval_times: list = field(default_factory=list)
    ledger: BandwidthLedger = field(default_factory=BandwidthLedger)
    updates: int = 0
    extras: dict = field(default_factory=dict)

    @property
    def mean_miou(self) -> float:
        return float(np.mean(self.miou_per_frame)) if self.miou_per_frame else 0.0

    def bandwidth_kbps(self, duration: float) -> tuple[float, float]:
        return self.ledger.kbps(duration)


def _label_bytes(label: np.ndarray) -> int:
    import gzip

    return len(gzip.compress(label.astype(np.uint8).tobytes(), 6))


def _global_shift(prev: np.ndarray, cur: np.ndarray) -> tuple[int, int]:
    """Phase-correlation global motion estimate (optical-flow proxy for
    Remote+Tracking)."""
    a = prev.mean(axis=-1)
    b = cur.mean(axis=-1)
    fa, fb = np.fft.rfft2(a), np.fft.rfft2(b)
    cross = fa * np.conj(fb)
    cross /= np.maximum(np.abs(cross), 1e-9)
    corr = np.fft.irfft2(cross, s=a.shape)
    dy, dx = np.unravel_index(np.argmax(corr), corr.shape)
    h, w = a.shape
    if dy > h // 2:
        dy -= h
    if dx > w // 2:
        dx -= w
    return int(dy), int(dx)


def run_scheme(
    scheme: str,
    world: SegWorld,
    pretrained,
    ams_cfg: AMSConfig | None = None,
    sim: SimConfig | None = None,
    seed: int = 0,
) -> Result:
    ams_cfg = ams_cfg or AMSConfig()
    sim = sim or SimConfig()
    video, teacher = world.video, world.teacher
    fps = video.cfg.fps
    n_frames = video.cfg.n_frames
    n_pixels = video.cfg.height * video.cfg.width
    res = Result(scheme=scheme)
    client_params = jax.tree.map(lambda x: x, pretrained)
    rng = np.random.default_rng(seed)

    # ---- scheme state ----------------------------------------------------
    session = None
    if scheme in ("ams", "jit_like"):
        task = Task(loss_and_grad=world.loss_and_grad, teacher=None, phi_loss=phi_pixel_loss)
        session = AMSSession(task, ams_cfg, jax.tree.map(lambda x: x, pretrained), seed=seed)
    pending: list = []  # frames sampled at the edge, waiting for upload
    next_sample_t = 0.0
    next_upload_t = ams_cfg.t_update
    # one-time
    ot_frames: list = []
    ot_done = False
    # remote tracking
    rt_label = None
    rt_prev_frame = None
    next_rt_t = 0.0
    # jit (Just-In-Time baseline)
    jit_opt = init_momentum(pretrained) if scheme == "jit" else None
    jit_params = jax.tree.map(lambda x: x, pretrained) if scheme == "jit" else None
    jit_u_prev = None
    next_jit_t = 0.0

    for idx in range(n_frames):
        t = idx / fps
        img, _ = video.frame(idx)
        tlabel = teacher.label(idx)

        # ---------------- evaluation (paper metric) -----------------------
        if idx % sim.eval_stride == 0:
            if scheme == "remote_tracking":
                pred = rt_label if rt_label is not None else np.zeros_like(tlabel)
            else:
                pred = np.asarray(world.predict(client_params, img[None])[0])
            res.miou_per_frame.append(miou(pred, tlabel, video.cfg.n_classes))
            res.eval_times.append(t)

        # ---------------- scheme mechanics --------------------------------
        if scheme == "no_custom":
            continue

        if scheme == "one_time":
            if t < sim.one_time_window:
                if t >= next_sample_t:
                    ot_frames.append((img, tlabel))
                    next_sample_t += 1.0
            elif not ot_done:
                ot_done = True
                res.ledger.uplink(
                    codec.h264_buffer_bytes(len(ot_frames), n_pixels, sim.one_time_window), t
                )
                params, opt = jax.tree.map(lambda x: x, pretrained), init_state(pretrained)
                fr = np.stack([f for f, _ in ot_frames])
                lb = np.stack([l for _, l in ot_frames])
                for _ in range(sim.one_time_iters):
                    pick = rng.integers(0, len(ot_frames), size=ams_cfg.batch_size)
                    _, grads = world.loss_and_grad(params, fr[pick], lb[pick])
                    params, opt, _ = adam_update(params, grads, opt, lr=ams_cfg.lr)
                client_params = params
                res.ledger.downlink(full_model_bytes(params), t, "full-model")
                res.updates += 1
            continue

        if scheme == "remote_tracking":
            # warp held label by estimated global motion every frame
            if rt_label is not None and rt_prev_frame is not None:
                dy, dx = _global_shift(rt_prev_frame, img)
                rt_label = np.roll(np.roll(rt_label, dy, axis=0), dx, axis=1)
            rt_prev_frame = img
            if t >= next_rt_t:
                # full-quality JPEG up (buffering would make labels stale)
                res.ledger.uplink(codec.jpeg_bytes(n_pixels), t, "jpeg")
                rt_label = tlabel
                res.ledger.downlink(_label_bytes(tlabel), t, "label")
                next_rt_t += 1.0 / sim.remote_rate
            continue

        if scheme == "jit":
            # sample at fixed 1 fps, upload full-quality frames immediately
            if t >= next_jit_t:
                next_jit_t += 1.0 / sim.jit_sample_rate
                res.ledger.uplink(codec.jpeg_bytes(n_pixels), t, "jpeg")
                fr, lb = img[None], tlabel[None]
                it = 0
                while (
                    float(world.accuracy(jit_params, fr, lb)) < sim.jit_threshold
                    and it < sim.jit_max_iters
                ):
                    _, grads = world.loss_and_grad(jit_params, fr, lb)
                    if jit_u_prev is None:
                        mask = selection.random_mask(
                            jax.random.PRNGKey(seed + idx), jit_params, ams_cfg.gamma
                        )
                    else:
                        mask = selection.gradient_guided_mask(jit_u_prev, ams_cfg.gamma)
                    jit_params, jit_opt, jit_u_prev = momentum_update(
                        jit_params, grads, jit_opt, mask, lr=ams_cfg.lr,
                        momentum=ams_cfg.momentum,
                    )
                    it += 1
                if it > 0:  # a model update is shipped
                    delta = encode_delta(jit_params, mask, ams_cfg.value_dtype)
                    res.ledger.downlink(delta.total_bytes, t)
                    client_params = apply_delta(client_params, delta)
                    res.updates += 1
            continue

        if scheme == "ams":
            # --- edge sampling at the server-set rate (ASR) ---
            if t >= next_sample_t:
                pending.append((img, tlabel))
                next_sample_t = t + 1.0 / max(session.sampling_rate, 1e-6)
            # --- buffered upload + train phase every T_update ---
            if t >= next_upload_t:
                if pending:
                    res.ledger.uplink(
                        codec.h264_buffer_bytes(len(pending), n_pixels, session.t_update), t
                    )
                    session.receive_labeled(
                        np.stack([f for f, _ in pending]),
                        np.stack([l for _, l in pending]),
                        t,
                    )
                    pending.clear()
                delta = session.train_phase(t)
                if delta is not None:
                    res.ledger.downlink(delta.total_bytes, t)
                    client_params = apply_delta(client_params, delta)
                    res.updates += 1
                next_upload_t = t + session.t_update
            continue

        raise ValueError(scheme)

    if session is not None:
        res.extras["history"] = session.history
    return res


SCHEMES = ("no_custom", "one_time", "remote_tracking", "jit", "ams")
