"""Multiple edge devices sharing one server GPU (Appendix E, Fig. 6/10).

Each client streams its own video; the server round-robins labeling +
training phases. When the GPU saturates, phases are deferred — effective
T_update grows and dynamic videos lose accuracy. ATR (Appendix D) frees
cycles on stationary feeds, raising the supported-client count.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.core.scheduler import GPUCostModel, RoundRobinScheduler
from repro.core.server import AMSConfig, AMSSession, Task
from repro.data.video import SyntheticVideo, VideoConfig, stop_and_go
from repro.metrics.miou import miou
from repro.sim.seg_world import SegWorld, phi_pixel_loss


@dataclass
class ClientState:
    world: SegWorld
    session: AMSSession
    params: object  # client-side model
    pending: list
    next_sample_t: float = 0.0
    next_upload_t: float = 10.0
    mious: list = None

    def __post_init__(self):
        if self.mious is None:
            self.mious = []


def run_multiclient(
    n_clients: int,
    pretrained,
    seg_cfg,
    ams_cfg: AMSConfig,
    *,
    duration: float = 120.0,
    video_kw: dict | None = None,
    cost: GPUCostModel | None = None,
    eval_stride: int = 6,
    stationary_frac: float = 0.3,
    seed: int = 0,
) -> dict:
    """Returns mean mIoU across clients + scheduler telemetry."""
    video_kw = dict(video_kw or {})
    video_kw.setdefault("duration", duration)
    fps = video_kw.get("fps", 4.0)
    video_kw["fps"] = fps

    clients = []
    for i in range(n_clients):
        kw = dict(video_kw, seed=seed * 1000 + i)
        if i < int(stationary_frac * n_clients):
            kw["motion_schedule"] = stop_and_go(0.0, duration)  # near-static feed
        world = SegWorld.make(VideoConfig(**kw), seg_cfg)
        task = Task(loss_and_grad=world.loss_and_grad, teacher=None, phi_loss=phi_pixel_loss)
        session = AMSSession(task, ams_cfg, jax.tree.map(lambda x: x, pretrained), seed=i)
        clients.append(ClientState(world=world, session=session,
                                   params=jax.tree.map(lambda x: x, pretrained),
                                   pending=[], next_upload_t=ams_cfg.t_update))

    sched = RoundRobinScheduler(cost=cost or GPUCostModel())
    n_frames = int(duration * fps)

    for idx in range(n_frames):
        t = idx / fps
        for ci, c in enumerate(clients):
            img, _ = c.world.video.frame(idx)
            tlabel = c.world.teacher.label(idx)
            if idx % eval_stride == 0:
                pred = np.asarray(c.world.predict(c.params, img[None])[0])
                c.mious.append(miou(pred, tlabel, c.world.video.cfg.n_classes))
            # edge sampling
            if t >= c.next_sample_t:
                c.pending.append((img, tlabel))
                c.next_sample_t = t + 1.0 / max(c.session.sampling_rate, 1e-6)
            # server turn (round-robin: one session per scheduler grant)
            if t >= c.next_upload_t:
                if sched.try_acquire(t, len(c.pending), c.session.cfg.k_iters):
                    if c.pending:
                        c.session.receive_labeled(
                            np.stack([f for f, _ in c.pending]),
                            np.stack([l for _, l in c.pending]), t)
                        c.pending.clear()
                    delta = c.session.train_phase(t)
                    if delta is not None:
                        c.params = jax.tree.map(lambda x: x, c.session.params)
                    c.next_upload_t = t + c.session.t_update
                # else: deferred — retried next frame tick

    per_client = [float(np.mean(c.mious)) for c in clients]
    return {
        "n_clients": n_clients,
        "miou_per_client": per_client,
        "mean_miou": float(np.mean(per_client)),
        "gpu_utilization": sched.utilization(duration),
        "phases_served": sched.served,
        "phases_deferred": sched.deferred,
    }
