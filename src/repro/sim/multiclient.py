"""Multiple edge devices sharing a server GPU pool (Appendix E, Fig. 6/10).

Compatibility shim: `run_multiclient` keeps its seed-era signature and
result-dict keys but now builds sessions for the event-driven runtime in
`repro.serving` — so phases queue behind a modeled GPU pool, frame batches
and deltas occupy rate-limited links (deltas arrive *stale*, never
teleported), and the GPU policy is pluggable (``policy="fair" | "edf" |
"gain" | "affinity"``). ``n_gpus`` sizes the pool and ``affinity=True``
selects residency-aware (session, gpu) placement; the defaults
(``n_gpus=1``, blind) reproduce the PR-1 single-GPU runs bit-for-bit.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core.scheduler import GPUCostModel
from repro.core.server import AMSConfig, AMSSession, Task
from repro.data.video import VideoConfig, stop_and_go
from repro.serving import (
    ClientNetwork,
    LinkSpec,
    SegServingSession,
    ServingConfig,
    ServingEngine,
    StreamModel,
)
from repro.sim.seg_world import SegWorld, phi_pixel_loss


def build_sessions(
    n_clients: int,
    pretrained,
    seg_cfg,
    ams_cfg: AMSConfig,
    *,
    duration: float = 120.0,
    video_kw: dict | None = None,
    eval_stride: int = 6,
    stationary_frac: float = 0.3,
    seed: int = 0,
    link: LinkSpec | None = None,
) -> list[SegServingSession]:
    """N seg worlds -> serving sessions; the first ``stationary_frac`` of
    clients watch near-static feeds (the ATR/gain-aware reclamation target)."""
    video_kw = dict(video_kw or {})
    video_kw.setdefault("duration", duration)
    video_kw.setdefault("fps", 4.0)
    link = link or LinkSpec()

    sessions = []
    for i in range(n_clients):
        kw = dict(video_kw, seed=seed * 1000 + i)
        if i < int(stationary_frac * n_clients):
            kw["motion_schedule"] = stop_and_go(0.0, duration)  # near-static feed
        world = SegWorld.make(VideoConfig(**kw), seg_cfg)
        task = Task(loss_and_grad=world.loss_and_grad, teacher=None,
                    phi_loss=phi_pixel_loss)
        ams = AMSSession(task, ams_cfg, jax.tree.map(lambda x: x, pretrained),
                         seed=i)
        sessions.append(SegServingSession(
            i, world, ams, pretrained, net=ClientNetwork(link),
            eval_stride=eval_stride))
    return sessions


def run_multiclient(
    n_clients: int,
    pretrained,
    seg_cfg,
    ams_cfg: AMSConfig,
    *,
    duration: float = 120.0,
    video_kw: dict | None = None,
    cost: GPUCostModel | None = None,
    eval_stride: int = 6,
    stationary_frac: float = 0.3,
    seed: int = 0,
    policy: str = "fair",
    n_gpus: int | None = None,
    affinity: bool = False,
    fuse_train: int | None = None,
    streams: StreamModel | None = None,
    link: LinkSpec | None = None,
    serving_cfg: ServingConfig | None = None,
    tracer=None,
    faults=None,
) -> dict:
    """Returns mean mIoU across clients + scheduler/network telemetry.

    Seed-era keys (``n_clients``, ``miou_per_client``, ``mean_miou``,
    ``gpu_utilization``, ``phases_served``, ``phases_deferred``) are
    preserved; the engine adds per-client Kbps, delta latency, deferral-rate,
    per-GPU utilization/migration and events/sec fields on top.

    ``n_gpus`` sizes the server's GPU pool (sessions then compete for
    (session, gpu) assignments instead of one busy flag), ``affinity=True``
    swaps in the residency-aware `AffinityAware` policy, and
    ``fuse_train=B`` lets a granted device co-train up to B sessions whose
    staging is free or beaten by the fused-stack discount as one stacked
    scan/vmap launch (`core.batched`) priced by the sublinear
    `GPUCostModel.train_batch_s`, and ``streams`` selects the per-device
    dual-stream model (`serving.StreamModel`: overlap teacher labeling with
    training, optionally preempting labeling launches at frame-batch
    boundaries) — the defaults (one GPU, unfused, serialized streams, no
    preemption) keep PR-1/PR-2/PR-3 results bit-identical.

    ``tracer`` attaches a `repro.serving.Tracer` flight recorder: every
    grant/labeling/train/transfer lands as a span in simulated time; dump
    with ``tracer.dump("out.json")`` and open in Perfetto. ``tracer=None``
    (the default) records nothing and changes nothing.

    ``faults`` attaches a seeded `repro.serving.FaultPlan` chaos schedule
    (link loss/outages, rate-trace replay, device crashes/slowdowns);
    ``faults=None`` (the default) keeps the run bit-identical to the
    pre-chaos engine.

    The ``duration`` kwarg governs the run: it sizes the videos AND the
    engine horizon. A ``serving_cfg`` supplies the other engine knobs
    (queue cap, admission, batching, migration model, its own ``n_gpus``);
    its ``duration`` is overridden so clients can never be scored past the
    end of their streams, and an explicit ``n_gpus`` kwarg (even 1) wins
    over the config's."""
    sessions = build_sessions(
        n_clients, pretrained, seg_cfg, ams_cfg, duration=duration,
        video_kw=video_kw, eval_stride=eval_stride,
        stationary_frac=stationary_frac, seed=seed, link=link)
    if affinity:
        if not (isinstance(policy, str) and policy in ("fair", "gain",
                                                       "affinity")):
            raise ValueError(
                f"affinity=True swaps in the gain-based AffinityAware "
                f"policy; it cannot be combined with policy={policy!r}")
        policy = "affinity"
    if serving_cfg is None:
        fkw = {} if faults is None else {"faults": faults}
        cfg = ServingConfig(duration=duration, n_gpus=n_gpus or 1,
                            fuse_train=fuse_train or 1,
                            streams=streams or StreamModel(), **fkw)
    else:
        cfg = dataclasses.replace(
            serving_cfg, duration=duration,
            n_gpus=serving_cfg.n_gpus if n_gpus is None else n_gpus,
            fuse_train=(serving_cfg.fuse_train if fuse_train is None
                        else fuse_train),
            streams=(serving_cfg.streams if streams is None else streams),
            faults=(serving_cfg.faults if faults is None else faults))
    engine = ServingEngine(sessions, policy=policy, cost=cost, cfg=cfg,
                           tracer=tracer)
    return engine.run()
