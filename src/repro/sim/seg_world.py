"""Binds the AMS core to the segmentation world (student + oracle teacher)."""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.video import OracleTeacher, SyntheticVideo, VideoConfig
from repro.models.seg.student import (
    SegConfig,
    make_student,
    seg_forward,
    seg_loss,
    seg_predict,
)


def phi_pixel_loss(label_now: np.ndarray, label_prev: np.ndarray) -> float:
    """Task loss between consecutive teacher labels (0-1 pixel loss) — the
    φ-score signal for segmentation."""
    return float(np.mean(label_now != label_prev))


@functools.lru_cache(maxsize=None)
def _compiled_fns(cfg: SegConfig):
    """One jitted (loss_and_grad, predict, accuracy) triple per SegConfig.

    Module-level on purpose: N worlds with the same config share the SAME
    callables, so N sessions cost one compile instead of N — and
    `core.batched` can group their phases into one fused launch (its compile
    key includes the loss callable's identity)."""

    @jax.jit
    def loss_and_grad(params, frames, labels):
        return jax.value_and_grad(lambda p: seg_loss(cfg, p, frames, labels))(params)

    @jax.jit
    def predict(params, frames):
        return seg_predict(cfg, params, frames)

    @jax.jit
    def accuracy(params, frames, labels):
        pred = seg_predict(cfg, params, frames)
        return (pred == labels).mean()

    return loss_and_grad, predict, accuracy


@dataclass
class SegWorld:
    video: SyntheticVideo
    teacher: OracleTeacher
    seg_cfg: SegConfig

    def __post_init__(self):
        self.loss_and_grad, self.predict, self.accuracy = _compiled_fns(self.seg_cfg)

    @classmethod
    def make(cls, video_cfg: VideoConfig, seg_cfg: SegConfig | None = None,
             teacher_error: float = 0.04):
        video = SyntheticVideo(video_cfg)
        seg_cfg = seg_cfg or SegConfig(n_classes=video_cfg.n_classes)
        return cls(video=video, teacher=OracleTeacher(video, error_rate=teacher_error),
                   seg_cfg=seg_cfg)


def pretrain_student(seg_cfg: SegConfig, n_videos: int = 6, steps: int = 200,
                     batch: int = 8, lr: float = 2e-3, seed: int = 42,
                     video_kw: dict | None = None):
    """The "No Customization" checkpoint: train on a generic mixture of
    videos (different seeds/drifts) — analogous to the paper's
    Cityscapes/VOC-pretrained student."""
    from repro.core.masked_adam import adam_update, init_state

    video_kw = video_kw or {}
    videos = [
        SyntheticVideo(VideoConfig(seed=1000 + i, drift_period=120 + 60 * i, **video_kw))
        for i in range(n_videos)
    ]
    teachers = [OracleTeacher(v, error_rate=0.04) for v in videos]
    rng = np.random.default_rng(seed)
    params = make_student(seg_cfg, jax.random.PRNGKey(seed))
    opt = init_state(params)

    @jax.jit
    def step(params, opt, frames, labels):
        loss, grads = jax.value_and_grad(lambda p: seg_loss(seg_cfg, p, frames, labels))(params)
        params, opt, _ = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    for it in range(steps):
        vi = rng.integers(0, n_videos)
        idxs = rng.integers(0, videos[vi].cfg.n_frames, size=batch)
        frames = np.stack([videos[vi].frame(int(i))[0] for i in idxs])
        labels = np.stack([teachers[vi].label(int(i)) for i in idxs])
        params, opt, loss = step(params, opt, frames, labels)
    return params
