"""Mean Intersection-over-Union, computed exactly as the paper (§4.1):
per-class IoU = TP / (TP + FP + FN), averaged over classes; scores measured
*relative to the teacher's labels*."""
from __future__ import annotations

import numpy as np


def confusion(pred: np.ndarray, target: np.ndarray, n_classes: int) -> np.ndarray:
    idx = (target.reshape(-1).astype(np.int64) * n_classes + pred.reshape(-1)).astype(np.int64)
    return np.bincount(idx, minlength=n_classes * n_classes).reshape(n_classes, n_classes)


def miou_from_confusion(cm: np.ndarray) -> float:
    tp = np.diag(cm).astype(np.float64)
    fp = cm.sum(axis=0) - tp
    fn = cm.sum(axis=1) - tp
    union = tp + fp + fn
    present = union > 0
    if not present.any():
        return 1.0
    return float((tp[present] / union[present]).mean())


def miou(pred: np.ndarray, target: np.ndarray, n_classes: int) -> float:
    return miou_from_confusion(confusion(pred, target, n_classes))
