"""Roofline terms from the dry-run (EXPERIMENTS.md §Roofline).

    compute    = FLOPs / (chips * peak_FLOP/s)        [analytic model]
    memory     = HBM bytes / (chips * HBM_bw)         [analytic model]
    collective = collective_bytes / (chips * link_bw) [compiled HLO,
                  depth-1/2 unrolled compiles, linear depth extrapolation]

compiled.cost_analysis() is also recorded ("hlo_*", scan bodies counted once)
— see roofline/analytic.py for why it cannot be used directly for scanned
models, and tests/test_roofline.py for the analytic-vs-HLO validation.
"""
from __future__ import annotations

import math
import re

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dt: str, dims: str) -> int:
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _line_collective(stripped: str):
    """(kind, bytes) for a collective-op HLO line, else None."""
    for kind in _COLLECTIVES:
        if f" {kind}(" in stripped or f" {kind}-start(" in stripped:
            eq = stripped.find("=")
            if eq < 0:
                return None
            rhs = stripped[eq + 1 :]
            shapes = _SHAPE_RE.findall(rhs.split(kind)[0])
            return kind, sum(_shape_bytes(dt, dims) for dt, dims in shapes)
    return None


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _parse_computations(hlo_text: str) -> dict:
    """comp name -> list of body lines."""
    comps: dict = {}
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Collective result bytes, scan-aware: collectives inside a while-loop
    body count once per iteration (trip count = the loop-condition constant).
    HLO cost analysis can't do this (it visits loop bodies once); GSPMD keeps
    our FSDP all-gathers inside the layer scan, so the multiplier matters."""
    comps = _parse_computations(hlo_text)
    own = {}
    whiles = {}  # comp -> list[(cond, body)]
    for name, lines in comps.items():
        totals = {k: 0 for k in _COLLECTIVES}
        counts = {k: 0 for k in _COLLECTIVES}
        wl = []
        for ln in lines:
            got = _line_collective(ln)
            if got:
                totals[got[0]] += got[1]
                counts[got[0]] += 1
            m = _WHILE_RE.search(ln)
            if m:
                wl.append((m.group(1), m.group(2)))
        own[name] = (totals, counts)
        whiles[name] = wl

    def trip_count(cond: str) -> int:
        consts = [int(c) for ln in comps.get(cond, []) for c in _CONST_RE.findall(ln)]
        return max(consts) if consts else 1

    import functools

    @functools.lru_cache(maxsize=None)
    def total(name: str):
        t = dict(own[name][0])
        c = dict(own[name][1])
        for cond, body in whiles.get(name, []):
            n = trip_count(cond)
            bt, bc = total(body)
            for k in _COLLECTIVES:
                t[k] += n * bt[k]
                c[k] += n * bc[k]
        return t, c

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line.strip()[len("ENTRY "):].strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back: flat count
        entry = max(comps, key=lambda n: len(comps[n])) if comps else None
    if entry is None:
        z = {k: 0 for k in _COLLECTIVES}
        return {"totals": z, "counts": z, "sum": 0}
    totals, counts = total(entry)
    return {"totals": totals, "counts": counts, "sum": int(sum(totals.values()))}


def hlo_facts(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes_from_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "hlo_flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "collective": coll,
        "device_arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "device_out_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "device_temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
    }


def extrapolate_depth(c1: float, c2: float, n_groups: int) -> float:
    """Linear in depth: total(G) = c1 + (G-1)*(c2-c1)."""
    return c1 + (n_groups - 1) * (c2 - c1)


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   chips: int) -> dict:
    t_compute = flops / (chips * PEAK_FLOPS_BF16)
    t_memory = hbm_bytes / (chips * HBM_BW)
    t_collective = collective_bytes / (chips * ICI_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bottleneck": max(terms, key=terms.get),
        "step_time_lb_s": max(terms.values()),
    }


# ---------------------------------------------------------------------------
# serving hot-path kernel bounds (Pallas masked-Adam + bit-pattern top-k)
# ---------------------------------------------------------------------------


def adam_step_hbm_bytes(n_params: int, *, param_bytes: int = 4) -> int:
    """Analytic minimum HBM traffic of ONE masked-Adam step over
    ``n_params`` coordinates: read p/g/m/v + bool mask, write p/m/v/u —
    every buffer touched exactly once (what the fused Pallas kernel
    streams; 33 B/param for f32 params). Multiply by B sessions and K
    iterations for a fused phase's optimizer-update term."""
    return int(n_params) * (25 + 2 * param_bytes)


def topk_hbm_bytes(n_coords: int, *, passes: int = 1) -> int:
    """Analytic HBM traffic of one session's bit-pattern top-k selection:
    ``passes`` reads of the 4-byte |u| buffer plus the 1-byte mask write.
    The fused Pallas kernel keeps the bits in VMEM across all 32 counting
    passes (``passes=1``); the XLA lowering re-reads per pass
    (``passes=32``)."""
    return int(n_coords) * (4 * passes + 1)


def kernel_roofline_fraction(nbytes: int, measured_s: float,
                             *, chips: int = 1) -> float | None:
    """Achieved fraction of the HBM roofline: the analytic memory-bound
    time for ``nbytes`` of traffic over the measured wall-clock. 1.0 means
    the launch ran at memory-bandwidth speed; the gap is launch overhead,
    compute, or wasted re-reads."""
    if not measured_s or measured_s <= 0:
        return None
    return (nbytes / (chips * HBM_BW)) / measured_s


def serving_stage_report(drift: dict) -> dict:
    """Roofline-style summary of the serving pipeline's *measured* stage
    timings, consuming a `repro.serving.obs.drift_report` dict.

    Where `roofline_terms` ranks analytic lower bounds, this ranks the
    stages the fused serving path actually ran (steady-state wall-clock,
    compile excluded) and reports each stage's model efficiency — modeled
    seconds / measured seconds, the fraction of `GPUCostModel`'s price the
    real stacked executables achieve. ``bottleneck`` is the stage eating
    the most measured steady time; a low ``model_efficiency`` there is
    where re-pricing (or a faster kernel) pays first.

    Stages whose timing hooks recorded analytic byte traffic (``nbytes`` —
    the masked-Adam and top-k bounds above) additionally report
    ``roofline_fraction``: measured steady wall-clock against the
    memory-bound time for those bytes (`kernel_roofline_fraction`)."""
    stages = {}
    for stage, e in sorted(drift.items()):
        meas, mod = e["measured_steady_s"], e["modeled_steady_s"]
        nbytes = int(e.get("nbytes", 0))
        stages[stage] = {
            "measured_s": meas,
            "modeled_s": mod,
            "compile_s": e["compile_s"],
            "calls": e["calls"],
            "model_efficiency": (mod / meas) if meas > 0 else None,
            "nbytes": nbytes,
            "roofline_fraction": (kernel_roofline_fraction(nbytes, meas)
                                  if nbytes else None),
        }
    measured = {k: v["measured_s"] for k, v in stages.items()}
    return {
        "stages": stages,
        "bottleneck": (max(measured, key=measured.get) if measured else None),
        "measured_total_s": sum(measured.values()),
    }
