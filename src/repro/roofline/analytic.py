"""Analytic FLOP/byte model per (architecture x input shape).

Why analytic: XLA's HloCostAnalysis counts while-loop bodies ONCE, so any
scanned model (layer scan, attention chunk scan, SSD chunk scan) is
undercounted by the trip count. The dry-run therefore takes
  * FLOPs / HBM bytes from this model (validated against fully-unrolled
    small compiles in tests/test_roofline.py),
  * collective bytes from depth-1/2 unrolled compiles (collectives never sit
    inside the inner chunk scans), linearly extrapolated in depth,
  * per-device memory from the full-depth compiled memory_analysis().

FLOPs are "as computed by the current implementation": the jnp chunked-flash
path evaluates every (q,kv) block and masks, so causal/SWA attention counts
the full S^2 term (the Pallas kernel's block skipping is an optimization
tracked separately in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.common import ModelConfig, param_count
from repro.models.transformer import model_metas


def _glu_flops(d, ff):
    return 6 * d * ff  # wg + wu + wd matmuls, 2mnk each


def _mlp_flops(cfg: ModelConfig, d, ff):
    return _glu_flops(d, ff) if cfg.mlp_act in ("swiglu", "geglu") else 4 * d * ff


def _attn_proj_flops(cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    return 2 * d * H * hd + 2 * 2 * d * KV * hd + 2 * H * hd * d


def _attn_score_flops(cfg: ModelConfig, ctx: int):
    """Per query token against `ctx` keys (qk^T + pv)."""
    return 2 * 2 * cfg.num_heads * cfg.resolved_head_dim * ctx


def _moe_flops(cfg: ModelConfig):
    d = cfg.d_model
    routed = _glu_flops(d, cfg.expert_d_ff) * cfg.experts_per_token * cfg.capacity_factor
    shared = _glu_flops(d, cfg.num_shared_experts * cfg.expert_d_ff) if cfg.num_shared_experts else 0
    return 2 * d * cfg.num_experts + routed + shared


def _mamba_flops(cfg: ModelConfig):
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    H = d_inner // cfg.ssm_head_dim
    P, N, Lc = cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_chunk
    proj = 2 * d * (2 * d_inner + 2 * N + H) + 2 * d_inner * d
    conv = 2 * cfg.ssm_conv * (d_inner + 2 * N)
    # per token: intra-chunk (G-matrix, y_intra) + state path
    intra = 2 * Lc * N + 2 * Lc * H * P * 2
    state = 3 * 2 * H * P * N
    return proj + conv + intra + state


def _rwkv_flops(cfg: ModelConfig):
    d = cfg.d_model
    P = cfg.ssm_head_dim
    H = d // P
    Lc = 64
    proj = 5 * 2 * d * d + 2 * 2 * d * 32  # r,k,v,g,o + decay lora
    wkv = 2 * Lc * H * P * 3 + 2 * 2 * H * P * P
    cmix = 2 * 2 * d * cfg.d_ff
    return proj + wkv + cmix


def _decode_ctx(cfg: ModelConfig, kind: str, S: int) -> int:
    """Effective attended context per decode step for a block kind (reflects
    the window-slicing optimization when enabled)."""
    if not cfg.decode_window_slicing:
        return S
    if kind in ("attn_local", "moe_local") and cfg.window_size:
        return min(S, cfg.window_size)
    if cfg.attn_window_override:
        return min(S, cfg.attn_window_override)
    return S


def _block_flops(cfg: ModelConfig, kind: str, ctx: int, mem_len: int):
    d = cfg.d_model
    if kind in ("attn", "attn_local", "attn_nc"):
        return _attn_proj_flops(cfg) + _attn_score_flops(cfg, ctx) + _mlp_flops(cfg, d, cfg.d_ff)
    if kind in ("moe", "moe_local"):
        return _attn_proj_flops(cfg) + _attn_score_flops(cfg, ctx) + _moe_flops(cfg)
    if kind == "xattn":
        return _attn_proj_flops(cfg) + _attn_score_flops(cfg, mem_len) + _mlp_flops(cfg, d, cfg.d_ff)
    if kind == "attn_xattn":
        return (2 * _attn_proj_flops(cfg) + _attn_score_flops(cfg, ctx)
                + _attn_score_flops(cfg, mem_len) + _mlp_flops(cfg, d, cfg.d_ff))
    if kind == "mamba":
        return _mamba_flops(cfg)
    if kind == "rwkv":
        return _rwkv_flops(cfg)
    raise ValueError(kind)


@dataclass(frozen=True)
class ShapeSpec:
    kind: str  # train | prefill | decode | decode_long
    seq_len: int
    global_batch: int


def analytic_cost(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    """Global (all-chip) FLOPs and HBM bytes for one step."""
    B, S = spec.global_batch, spec.seq_len
    decode = spec.kind in ("decode", "decode_long")
    n_q = B * (1 if decode else S)  # query tokens this step
    mem = cfg.num_xattn_tokens

    def ctx_for(kind):
        # the jnp path computes the full (masked) context except where the
        # decode window-slicing optimization is enabled
        return _decode_ctx(cfg, kind, S) if decode else S

    per_tok = sum(_block_flops(cfg, k, ctx_for(k), mem) for k in cfg.pattern) * cfg.num_groups
    if cfg.shared_attn:
        shared_ctx = ctx_for("attn_local" if cfg.window_size else "attn")
        per_tok += (_attn_proj_flops(cfg) + _attn_score_flops(cfg, shared_ctx)
                    + _mlp_flops(cfg, cfg.d_model, cfg.d_ff)) * cfg.num_groups
    head = 2 * cfg.d_model * cfg.vocab_size  # unembed per evaluated position

    # encoder (whisper): runs over mem tokens, full self-attention
    enc = 0.0
    if cfg.encoder_layers and mem:
        enc_tok = (_attn_proj_flops(cfg) + _attn_score_flops(cfg, mem)
                   + _mlp_flops(cfg, cfg.d_model, cfg.d_ff)) * cfg.encoder_layers
        enc = enc_tok * B * mem

    pc = param_count(model_metas(cfg))
    pbytes = pc * cfg.pdtype.itemsize

    if spec.kind == "train":
        fwd = per_tok * n_q + head * n_q + enc
        mult = 4.0 if cfg.remat else 3.0  # fwd + (recompute) + bwd(2x)
        flops = fwd * mult + 10.0 * pc  # + optimizer elementwise
        act_bytes = cfg.num_layers * n_q * cfg.d_model * 2 * 12  # ~12 tensors r/w per layer
        opt_bytes = pc * (2 + 2 + 4 + 4 + 4 + 4 + 4 + 1)  # p rw bf16, m rw? v rw fp32, u w, g r, mask
        wbytes = pbytes * 3  # fwd read + bwd re-read + grad write
        byt = wbytes + opt_bytes + act_bytes
        useful = 6.0 * _active_params(cfg) * n_q
    elif spec.kind == "prefill":
        flops = per_tok * n_q + head * B + enc
        kv_bytes = _cache_bytes(cfg, B, S, mem)
        byt = pbytes + kv_bytes + cfg.num_layers * n_q * cfg.d_model * 2 * 8
        useful = 2.0 * _active_params(cfg) * n_q
    else:  # decode
        flops = per_tok * n_q + head * n_q + (enc if False else 0.0)
        touched = _decode_touched_params(cfg, B) * cfg.pdtype.itemsize
        byt = (touched + _cache_read_bytes(cfg, B, S, mem)
               + n_q * cfg.d_model * 2 * 8 * cfg.num_layers)
        useful = 2.0 * _active_params(cfg) * n_q
    return {"flops": float(flops), "bytes": float(byt), "model_flops": float(useful)}


def _active_params(cfg: ModelConfig) -> float:
    """Per-token active parameters (MoE: only routed top-k + shared)."""
    pc = param_count(model_metas(cfg))
    if not cfg.num_experts:
        return pc
    from repro.models.moe import moe_metas

    moe_pc = param_count(moe_metas(cfg))
    n_moe_layers = sum(1 for k in cfg.pattern if k.startswith("moe")) * cfg.num_groups
    d, eff = cfg.d_model, cfg.expert_d_ff
    expert_pc = 3 * d * eff * cfg.num_experts  # routed experts only
    active_expert = 3 * d * eff * cfg.experts_per_token
    return pc - n_moe_layers * expert_pc + n_moe_layers * active_expert


def _decode_touched_params(cfg: ModelConfig, batch: int) -> float:
    pc = param_count(model_metas(cfg))
    if not cfg.num_experts:
        return pc
    d, eff, E = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    n_moe_layers = sum(1 for k in cfg.pattern if k.startswith("moe")) * cfg.num_groups
    expert_pc = 3 * d * eff * E
    frac = min(1.0, batch * cfg.experts_per_token / E)
    return pc - n_moe_layers * expert_pc * (1 - frac)


def _cache_read_bytes(cfg: ModelConfig, B: int, S: int, mem: int) -> float:
    """Per-decode-step cache traffic: reads of the attended window (plus the
    one-slot write, negligible). Honors decode window slicing."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    total = 0.0
    for k in cfg.pattern:
        if k in ("attn", "attn_local", "attn_nc", "moe", "moe_local"):
            total += 2 * B * _decode_ctx(cfg, k, S) * kv * hd * 2
        elif k == "xattn":
            total += 2 * B * mem * kv * hd * 2
        elif k == "attn_xattn":
            total += 2 * B * (_decode_ctx(cfg, k, S) + mem) * kv * hd * 2
        elif k == "mamba":
            d_inner = cfg.ssm_expand * cfg.d_model
            H = d_inner // cfg.ssm_head_dim
            total += 2 * B * H * cfg.ssm_head_dim * cfg.ssm_state * 4
        elif k == "rwkv":
            H = cfg.d_model // cfg.ssm_head_dim
            total += 2 * B * H * cfg.ssm_head_dim**2 * 4
    total *= cfg.num_groups
    if cfg.shared_attn:
        ctx = _decode_ctx(cfg, "attn_local" if cfg.window_size else "attn", S)
        total += cfg.num_groups * 2 * B * ctx * kv * hd * 2
    return total


def _cache_bytes(cfg: ModelConfig, B: int, S: int, mem: int) -> float:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    total = 0.0
    for k in cfg.pattern:
        if k in ("attn", "attn_local", "attn_nc", "moe", "moe_local"):
            total += 2 * B * S * kv * hd * 2
        elif k == "xattn":
            total += 2 * B * mem * kv * hd * 2
        elif k == "attn_xattn":
            total += 2 * B * (S + mem) * kv * hd * 2
        elif k == "mamba":
            d_inner = cfg.ssm_expand * cfg.d_model
            H = d_inner // cfg.ssm_head_dim
            total += B * H * cfg.ssm_head_dim * cfg.ssm_state * 4
        elif k == "rwkv":
            H = cfg.d_model // cfg.ssm_head_dim
            total += B * H * cfg.ssm_head_dim**2 * 4
    total *= cfg.num_groups
    if cfg.shared_attn:
        total += cfg.num_groups * 2 * B * S * kv * hd * 2
    return total
