"""Multiple edge devices sharing one server GPU (Appendix E).

Run:  PYTHONPATH=src python examples/multi_client.py --clients 4
"""
import argparse

import jax

from repro.core.server import AMSConfig
from repro.sim.multiclient import run_multiclient
from repro.sim.seg_world import pretrain_student
from repro.models.seg.student import SegConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--duration", type=float, default=90.0)
    ap.add_argument("--atr", action="store_true")
    args = ap.parse_args()

    seg_cfg = SegConfig(n_classes=5)
    pre = pretrain_student(seg_cfg, n_videos=3, steps=120,
                           video_kw=dict(height=48, width=48, fps=4.0, duration=60.0))
    ams = AMSConfig(t_update=10.0, t_horizon=60.0, k_iters=12, batch_size=6,
                    gamma=0.05, lr=2e-3, phi_target=0.15, asr_eta=1.0, atr_enabled=args.atr)
    out = run_multiclient(args.clients, pre, seg_cfg, ams, duration=args.duration,
                          video_kw=dict(height=48, width=48, fps=4.0))
    print(f"clients={out['n_clients']} mean mIoU={out['mean_miou']:.3f} "
          f"gpu_util={out['gpu_utilization']:.2f} served={out['phases_served']} "
          f"deferred={out['phases_deferred']}")
    for i, m in enumerate(out["miou_per_client"]):
        print(f"  client {i}: mIoU {m:.3f}")


if __name__ == "__main__":
    main()
