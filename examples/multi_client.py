"""Multiple edge devices sharing one server GPU (Appendix E), on the
event-driven serving runtime: pick a GPU policy and a link profile and watch
per-client accuracy, bandwidth, and delta staleness.

Run:  PYTHONPATH=src python examples/multi_client.py --clients 4 --policy gain

Flight recorder: add ``--trace out.json`` to record every grant, labeling
launch, train phase and client transfer as spans in simulated time, then
open the file at https://ui.perfetto.dev ("Open trace file") to see the
schedule — one track per GPU stream, one per client link, counter tracks
for queue depth / backlog / stream utilization.
"""
import argparse

from repro.core.server import AMSConfig
from repro.models.seg.student import SegConfig
from repro.serving import LinkSpec, StreamModel, Tracer
from repro.sim.multiclient import run_multiclient
from repro.sim.seg_world import pretrain_student


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--duration", type=float, default=90.0)
    ap.add_argument("--atr", action="store_true")
    ap.add_argument("--policy", default="fair",
                    choices=("fair", "edf", "gain", "affinity"))
    ap.add_argument("--gpus", type=int, default=1,
                    help="server GPU pool size")
    ap.add_argument("--affinity", action="store_true",
                    help="residency-aware (session, gpu) placement")
    ap.add_argument("--fuse-train", type=int, default=1,
                    help="max co-resident sessions per fused train launch")
    ap.add_argument("--overlap", action="store_true",
                    help="dual-stream devices: teacher labeling overlaps "
                         "training instead of serializing on one clock")
    ap.add_argument("--slowdown", type=float, default=1.1,
                    help="stream contention stretch while both streams are "
                         "busy (with --overlap; 1.0 = full overlap)")
    ap.add_argument("--preempt", action="store_true",
                    help="labeling launches preemptible at frame-batch "
                         "boundaries (works with or without --overlap)")
    ap.add_argument("--up-kbps", type=float, default=1000.0)
    ap.add_argument("--down-kbps", type=float, default=2000.0)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome trace-event JSON of the run "
                         "(open at https://ui.perfetto.dev)")
    args = ap.parse_args()

    seg_cfg = SegConfig(n_classes=5)
    pre = pretrain_student(seg_cfg, n_videos=3, steps=120,
                           video_kw=dict(height=48, width=48, fps=4.0, duration=60.0))
    ams = AMSConfig(t_update=10.0, t_horizon=60.0, k_iters=12, batch_size=6,
                    gamma=0.05, lr=2e-3, phi_target=0.15, asr_eta=1.0, atr_enabled=args.atr)
    streams = None
    if args.overlap or args.preempt:
        streams = StreamModel(
            mode="overlap" if args.overlap else "serialized",
            slowdown=args.slowdown if args.overlap else 1.0,
            preempt=args.preempt, preempt_cost_s=0.02)
    tracer = Tracer() if args.trace else None
    out = run_multiclient(args.clients, pre, seg_cfg, ams, duration=args.duration,
                          video_kw=dict(height=48, width=48, fps=4.0),
                          policy=args.policy, n_gpus=args.gpus,
                          affinity=args.affinity, fuse_train=args.fuse_train,
                          streams=streams,
                          link=LinkSpec(up_kbps=args.up_kbps, down_kbps=args.down_kbps),
                          tracer=tracer)
    if tracer is not None:
        tracer.dump(args.trace)
        print(f"trace: {args.trace} — open at https://ui.perfetto.dev "
              f"('Open trace file')")
    print(f"clients={out['n_clients']} policy={out['scheduler']} "
          f"gpus={out['n_gpus']} "
          f"mean mIoU={out['mean_miou']:.3f} gpu_util={out['gpu_utilization']:.2f} "
          f"served={out['phases_served']} deferred={out['phases_deferred']} "
          f"dropped={out['dropped_requests']}")
    print(f"delta latency: mean={out['delta_latency_mean_s']*1e3:.0f} ms "
          f"max={out['delta_latency_max_s']*1e3:.0f} ms; "
          f"events={out['events_processed']} ({out['events_per_sec']:.0f}/s)")
    if out["n_gpus"] > 1:
        utils = "/".join(f"{u:.2f}" for u in out["per_gpu_utilization"])
        print(f"pool: per-gpu util {utils}; migrations={out['migrations']} "
              f"({out['migration_s_total']:.1f} s); "
              f"evictions={out['residency_evictions']}")
    if out["fused_launches"]:
        print(f"fused training: {out['fused_launches']} stacked launches "
              f"covering {out['fused_sessions']} sessions "
              f"({out['rider_grants']} riders)")
        up = out["update_pipeline"]
        print(f"update pipeline: {up['stacked_select_launches']} stacked "
              f"selection launches ({up['stacked_select_sessions']} "
              f"sessions), {up['stacked_encode_launches']} batched encodes "
              f"({up['stacked_encode_sessions']} deltas)")
    if out["stream_mode"] != "serialized" or out["preemptions"]:
        su = out["per_gpu_stream_utilization"]
        print(f"streams [{out['stream_mode']}]: label util "
              f"{su['label'][0]:.2f} train util {su['train'][0]:.2f}; "
              f"overlap {out['overlap_s']:.1f} s; "
              f"{out['preemptions']} preemptions "
              f"({out['preempted_frames']} frames requeued)")
    for i, (m, (up, down), ph, dev) in enumerate(zip(out["miou_per_client"],
                                                     out["per_client_kbps"],
                                                     out["phases_per_client"],
                                                     out["devices_per_client"])):
        gpus = ",".join(map(str, dev)) or "-"
        print(f"  client {i}: mIoU {m:.3f}  up {up:.0f} Kbps  down {down:.0f} Kbps  "
              f"phases {ph}  gpus [{gpus}]")


if __name__ == "__main__":
    main()
