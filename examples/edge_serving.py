"""End-to-end driver: live video segmentation on an edge device with AMS.

Streams a synthetic video; the edge client runs the lightweight student at
frame rate while the server continually distills and streams sparse updates
(Algorithm 1). Prints a timeline of mIoU, sampling rate (ASR), and bandwidth.

Run:  PYTHONPATH=src python examples/edge_serving.py [--duration 120]
"""
import argparse

import numpy as np

from repro.core.server import AMSConfig
from repro.data.video import VideoConfig, stop_and_go
from repro.sim.runner import SimConfig, run_scheme
from repro.sim.seg_world import SegWorld, pretrain_student


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--size", type=int, default=48)
    ap.add_argument("--fps", type=float, default=4.0)
    ap.add_argument("--scheme", default="ams",
                    choices=["ams", "no_custom", "one_time", "remote_tracking", "jit"])
    args = ap.parse_args()

    vcfg = VideoConfig(height=args.size, width=args.size, fps=args.fps,
                       duration=args.duration, seed=11, drift_period=90.0,
                       motion_schedule=stop_and_go(args.duration * 0.4,
                                                   args.duration * 0.6))
    world = SegWorld.make(vcfg)
    print("pretraining generic student checkpoint ...")
    pre = pretrain_student(world.seg_cfg, n_videos=4, steps=150,
                           video_kw=dict(height=args.size, width=args.size,
                                         fps=args.fps, duration=60.0))

    ams = AMSConfig(t_update=10.0, t_horizon=90.0, k_iters=12, batch_size=6,
                    gamma=0.05, lr=2e-3, phi_target=0.15, asr_eta=1.0, atr_enabled=True)
    res = run_scheme(args.scheme, world, pre, ams, SimConfig(eval_stride=4))
    up, down = res.bandwidth_kbps(args.duration)
    print(f"\nscheme={args.scheme}  mean mIoU {res.mean_miou:.3f}  "
          f"uplink {up:.1f} Kbps  downlink {down:.1f} Kbps  "
          f"model updates {res.updates}")
    hist = res.extras.get("history", [])
    for h in hist:
        print(f"  t={h['t']:6.1f}s loss={h['loss']:.3f} rate={h['rate']:.2f}fps "
              f"T_update={h['t_update']:.0f}s delta={h['bytes']/1e3:.1f}KB")


if __name__ == "__main__":
    main()
