"""Quickstart: the AMS core in 60 lines.

A toy regression "student" adapts online to a drifting target function via
Algorithm 2 (gradient-guided masked Adam) while streaming only 5% of its
parameters per phase. Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selection
from repro.core.delta import apply_delta, encode_delta, full_model_bytes
from repro.core.masked_adam import init_state, masked_adam_update

rng = np.random.default_rng(0)


def model(params, x):  # tiny MLP
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def target(x, t):  # drifting ground truth (the "video")
    return jnp.sin(3 * x + 0.8 * t) + 0.3 * jnp.cos(7 * x - t)


params = {
    "w1": jnp.asarray(rng.normal(size=(1, 64)) * 0.5, jnp.float32),
    "b1": jnp.zeros(64), "w2": jnp.asarray(rng.normal(size=(64, 1)) * 0.5, jnp.float32),
    "b2": jnp.zeros(1),
}
edge_params = jax.tree.map(lambda x: x, params)  # client copy
opt = init_state(params)
GAMMA, K = 0.05, 20


@jax.jit
def loss_and_grad(p, x, y):
    return jax.value_and_grad(lambda q: jnp.mean((model(q, x) - y) ** 2))(p)


u_prev, total_bytes = None, 0
for phase in range(30):
    t = phase * 0.5
    # select I_n from the previous phase's Adam updates (Alg. 2 line 1)
    if u_prev is None:
        mask = selection.random_mask(jax.random.PRNGKey(phase), params, GAMMA)
    else:
        mask = selection.gradient_guided_mask(u_prev, GAMMA)
    for _ in range(K):  # K masked-Adam iterations on the recent horizon
        x = jnp.asarray(rng.uniform(-1, 1, size=(64, 1)), jnp.float32)
        y = target(x, t)
        loss, g = loss_and_grad(params, x, y)
        params, opt, u_prev = masked_adam_update(params, g, opt, mask, lr=3e-3)
    # stream the sparse delta to the edge
    delta = encode_delta(params, mask)
    edge_params = apply_delta(edge_params, delta)
    total_bytes += delta.total_bytes
    if phase % 5 == 0:
        xs = jnp.linspace(-1, 1, 256)[:, None]
        edge_err = float(jnp.mean((model(edge_params, xs) - target(xs, t)) ** 2))
        print(f"phase {phase:2d}  t={t:4.1f}  loss={float(loss):.4f} "
              f"edge_mse={edge_err:.4f}  delta={delta.total_bytes}B")

full = full_model_bytes(params)
print(f"\nstreamed {total_bytes} bytes over 30 phases; "
      f"full-model streaming would be {30 * full} bytes "
      f"({30 * full / total_bytes:.1f}x more)")
