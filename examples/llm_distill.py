"""AMS beyond the paper: continual distillation of a transformer student.

A drifting token stream stands in for the live video; the student (any
model-zoo architecture, reduced size) is adapted with gradient-guided masked
Adam and its sparse deltas are streamed — demonstrating that the AMS core is
architecture-agnostic (DESIGN.md §6).

Run:  PYTHONPATH=src python examples/llm_distill.py --arch rwkv6-3b
"""
import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()
    train.main(["--arch", args.arch, "--steps", str(args.steps),
                "--phase-len", "10", "--log-every", "20"])


if __name__ == "__main__":
    main()
