"""Flight recorder: trace determinism + invariants, metrics registry,
modeled-vs-measured drift audit, unified debug snapshot.

The recorder must be an *observer*: with tracing off the engine's schedule
and results are bit-identical to the untraced run, and with tracing on the
emitted Chrome trace is deterministic (byte-identical across identical
runs) and structurally valid — spans never overlap on a stream, serialized
devices never run two streams at once, and a fused grant's
train/select/encode stages nest inside its device-grant span.
"""
import json

from _hyp import given, settings, st

from repro.core import timing
from repro.core.scheduler import GPUCostModel
from repro.roofline.analysis import serving_stage_report
from repro.serving import (
    ClientNetwork,
    FaultPlan,
    LinkSpec,
    MetricsRegistry,
    ServingConfig,
    ServingEngine,
    StreamModel,
    StubSession,
    Tracer,
    debug_snapshot,
    drift_report,
    validate_trace,
)

PRICED = dict(select_s=0.15, delta_comp_s_per_mb=5.0)


def _fleet(n, link=None, rate_head=0.15):
    link = link or LinkSpec(up_kbps=500.0, down_kbps=2000.0)
    return [StubSession(i, rate=rate_head if i < 2 else 1.0,
                        dynamics=0.0005 if i < 2 else 0.004,
                        net=ClientNetwork(link))
            for i in range(n)]


def _run(n=6, *, n_gpus=2, fuse=4, streams=None, cost=None, duration=90.0,
         fuse_updates=True, policy="fair", tracer=None, rate_head=0.15,
         faults=None):
    fkw = {} if faults is None else {"faults": faults}
    eng = ServingEngine(
        _fleet(n, rate_head=rate_head), policy=policy,
        cost=cost or GPUCostModel(),
        cfg=ServingConfig(duration=duration, n_gpus=n_gpus, fuse_train=fuse,
                          fuse_updates=fuse_updates,
                          streams=streams or StreamModel(), **fkw),
        tracer=tracer)
    return eng.run()


def _traced(n=6, **kw):
    tracer = Tracer()
    r = _run(n, tracer=tracer, **kw)
    return r, tracer


_WALL_KEYS = ("wall_s", "events_per_sec", "events_per_sec_steady",
              "observability")


def _stable(r):
    return {k: v for k, v in r.items() if k not in _WALL_KEYS}


# ---------------- trace determinism ----------------


def test_trace_byte_identical_across_runs():
    _, t1 = _traced(8, cost=GPUCostModel(**PRICED))
    _, t2 = _traced(8, cost=GPUCostModel(**PRICED))
    assert t1.to_json() == t2.to_json()


def test_tracing_does_not_perturb_the_schedule():
    plain = _run(8, cost=GPUCostModel(**PRICED))
    traced, _ = _traced(8, cost=GPUCostModel(**PRICED))
    assert _stable(plain) == _stable(traced)
    assert plain["observability"]["tracing"] is False
    assert traced["observability"]["tracing"] is True


def test_trace_has_layout_and_counters():
    r, tracer = _traced(6, n_gpus=2)
    trace = json.loads(tracer.to_json())
    evs = trace["traceEvents"]
    procs = {e["args"]["name"] for e in evs
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert "serving-engine" in procs
    assert {"gpu0", "gpu1"} <= procs
    assert {f"client{i}" for i in range(6)} <= procs
    threads = {e["args"]["name"] for e in evs
               if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert {"stream:label", "stream:train", "grants",
            "uplink", "downlink"} <= threads
    counters = {e["name"] for e in evs if e.get("ph") == "C"}
    assert {"queue_depth", "backlog_frames", "stream_util"} <= counters
    assert trace["otherData"]["n_gpus"] == 2
    # a grant -> downlink-delta causal arrow exists
    assert any(e.get("ph") == "s" for e in evs)
    assert any(e.get("ph") == "f" for e in evs)


# ---------------- client sampling past the fleet cap ----------------


def test_tracer_refuses_big_fleets_and_points_at_sampling():
    tracer = Tracer(max_clients=4)
    try:
        _run(6, tracer=tracer)
    except ValueError as e:
        assert "sample_clients=k" in str(e)
    else:
        raise AssertionError("expected the big-fleet refusal")
    try:
        Tracer(sample_clients=0)
    except ValueError as e:
        assert "sample_clients" in str(e)
    else:
        raise AssertionError("expected sample_clients >= 1 validation")


def test_tracer_sampling_is_deterministic_and_evenly_spaced():
    t1 = Tracer(max_clients=4, sample_clients=3)
    t2 = Tracer(max_clients=4, sample_clients=3)
    r1 = _run(9, tracer=t1)
    r2 = _run(9, tracer=t2)
    assert t1._sampled == t2._sampled  # same fleet -> same subset
    assert t1._sampled == frozenset({0, 3, 6})  # ids[(j*n)//k], spans range
    assert t1.meta["sampled_clients"] == 3
    assert all(t1.traces_client(c) == (c in {0, 3, 6}) for c in range(9))
    assert t1.client_span(1, "up", "x", 0.0, 1.0) is None  # span dropped
    assert t1.to_json() == t2.to_json()
    # sampling drops spans, never events: the schedule is untouched
    assert _stable(r1) == _stable(r2)
    assert _stable(r1) == _stable(_run(9))
    # sampled-client tracks exist; unsampled ones don't
    trace = json.loads(t1.to_json())
    procs = {e["args"]["name"] for e in trace["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert {"client0", "client3", "client6"} <= procs
    assert not {f"client{i}" for i in (1, 2, 4, 5, 7, 8)} & procs


def test_tracer_sampling_inactive_under_the_cap():
    tracer = Tracer(sample_clients=3)  # default cap 1000 >> fleet
    _run(6, tracer=tracer)
    assert tracer._sampled is None  # every client traced
    assert "sampled_clients" not in tracer.meta
    assert all(tracer.traces_client(c) for c in range(6))


# ---------------- trace invariants (property-style) ----------------


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=3, max_value=10),
       gpus=st.integers(min_value=1, max_value=3),
       overlap=st.booleans(), preempt=st.booleans(),
       fuse=st.sampled_from([1, 4]))
def test_trace_invariants_property(n, gpus, overlap, preempt, fuse):
    """Across stream models, pool sizes and fusing: non-negative durations,
    per-stream serial execution, cross-stream concurrency <= 1 (serialized)
    / <= 2 (overlap), and grant-tagged spans nested in their grant."""
    streams = StreamModel(mode="overlap" if overlap else "serialized",
                          slowdown=1.1 if overlap else 1.0,
                          preempt=preempt, preempt_cost_s=0.02)
    _, tracer = _traced(n, n_gpus=gpus, fuse=fuse, streams=streams,
                        cost=GPUCostModel(**PRICED), duration=60.0)
    trace = json.loads(tracer.to_json())
    assert validate_trace(trace) == []


def test_fused_grant_nests_train_select_encode():
    r, tracer = _traced(8, n_gpus=1, fuse=4, cost=GPUCostModel(**PRICED),
                        duration=120.0)
    trace = json.loads(tracer.to_json())
    assert validate_trace(trace) == []
    spans = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    fused = [e for e in spans if e.get("cat") == "grant"
             and e["args"]["riders"] > 0]
    assert fused, "run produced no fused grants"
    by_grant: dict = {}
    for e in spans:
        g = e.get("args", {}).get("grant")
        if g is not None:
            by_grant.setdefault(g, set()).add(e["name"])
    for g in fused:
        names = by_grant.get(g["args"]["seq"], set())
        assert {"train", "select", "encode"} <= names, (
            f"fused grant {g['args']['seq']} has stages {sorted(names)}")


def test_preemption_is_a_schedule_edit_in_the_trace():
    # the known preemption-triggering shape from test_streams: 8 dynamic
    # clients on one serialized-era GPU with overlap+preempt streams
    streams = StreamModel("overlap", slowdown=1.1, preempt=True,
                          preempt_cost_s=0.02)
    tracer = Tracer()
    eng = ServingEngine(
        _fleet(8, rate_head=1.0), policy="fair",
        cfg=ServingConfig(duration=180.0, max_queue=64, streams=streams),
        tracer=tracer)
    r = eng.run()
    assert r["preemptions"] > 0
    trace = json.loads(tracer.to_json())
    assert validate_trace(trace) == []
    names = {e["name"] for e in trace["traceEvents"]}
    assert "preempt" in names  # the cut instant
    assert "preempt_cost" in names  # the modeled preemption charge


def test_validate_trace_rejects_tampering():
    _, tracer = _traced(6)
    good = json.loads(tracer.to_json())
    assert validate_trace(good) == []
    bad = json.loads(tracer.to_json())
    next(e for e in bad["traceEvents"] if e.get("ph") == "X")["dur"] = -5
    assert any("negative" in p for p in validate_trace(bad))
    gutted = dict(good, traceEvents=[e for e in good["traceEvents"]
                                     if e.get("name") != "queue_depth"])
    assert any("queue_depth" in p for p in validate_trace(gutted))


# ---------------- chaos traces ----------------


def _chaos_traced(n=10, duration=120.0, n_gpus=2):
    tracer = Tracer()
    r = _run(n, n_gpus=n_gpus, duration=duration, policy="gain",
             tracer=tracer,
             faults=FaultPlan.reference(duration, n_gpus=n_gpus))
    return r, tracer


def test_chaos_trace_validates_with_fault_vocabulary():
    r, tracer = _chaos_traced()
    trace = json.loads(tracer.to_json())
    assert validate_trace(trace) == []
    evs = trace["traceEvents"]
    names = {e.get("name") for e in evs}
    assert "outage" in names  # link-outage windows on client fault tracks
    assert "crash" in names  # the crash window on the device fault track
    assert "retry" in names  # retransmits occupy the link like transfers
    # the fault threads exist only because chaos is on
    fault_threads = [e for e in evs if e.get("ph") == "M"
                     and e.get("name") == "thread_name"
                     and e["args"]["name"] == "faults"]
    assert fault_threads
    assert r["chaos"]["uploads_lost"] > 0


def test_chaos_trace_byte_identical_across_runs():
    _, t1 = _chaos_traced()
    _, t2 = _chaos_traced()
    assert t1.to_json() == t2.to_json()


def test_validate_trace_rejects_retry_overlapping_live_transfer():
    _, tracer = _chaos_traced()
    trace = json.loads(tracer.to_json())
    assert validate_trace(trace) == []
    # forge a retry that double-books a client uplink while a real transfer
    # occupies it — link occupancy is serial, the validator must object
    up = next(e for e in trace["traceEvents"]
              if e.get("ph") == "X" and e.get("cat") == "net:up"
              and e["dur"] > 0)
    forged = dict(up, name="retry", ts=up["ts"] + up["dur"] // 2)
    trace["traceEvents"].append(forged)
    assert any("overlapping" in p for p in validate_trace(trace))


def test_validate_trace_rejects_misplaced_fault_events():
    _, tracer = _chaos_traced()
    base = tracer.to_json()
    # a crash span on a client's fault track is vocabulary abuse
    trace = json.loads(base)
    crash = next(e for e in trace["traceEvents"]
                 if e.get("ph") == "X" and e.get("cat") == "fault"
                 and e["name"] == "crash")
    outage = next(e for e in trace["traceEvents"]
                  if e.get("ph") == "X" and e.get("cat") == "fault"
                  and e["name"] == "outage")
    crash["pid"], crash["tid"] = outage["pid"], outage["tid"]
    assert any("crash span off a device fault track" in p
               for p in validate_trace(trace))
    # a supersede instant belongs to a client process, not the server
    trace2 = json.loads(base)
    sup = [e for e in trace2["traceEvents"]
           if e.get("ph") == "i" and e.get("name") == "supersede"]
    if sup:  # the reference plan produces these; guard stays for tuning
        sup[0]["pid"] = 1  # PID_SERVER
        assert any("supersede instant off a client" in p
                   for p in validate_trace(trace2))
    # an unknown fault-span name is rejected outright
    trace3 = json.loads(base)
    next(e for e in trace3["traceEvents"]
         if e.get("ph") == "X" and e.get("cat") == "fault")["name"] = "gremlin"
    assert any("unknown fault span" in p for p in validate_trace(trace3))


# ---------------- metrics registry ----------------


def test_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    c = m.counter("a.b")
    c.inc()
    c.inc(2)
    m.gauge("a.g", 0).set_max(5)
    m.gauge("a.g").set_max(3)  # lower: keeps the max
    m.set("top", "x")
    h = m.histogram("lat")
    h.extend([1.0, 3.0])
    assert h.count == 2 and h.mean() == 2.0 and h.max() == 3.0
    out = m.as_results()
    assert out == {"a": {"b": 3, "g": 5}, "top": "x"}  # histograms skipped
    assert "lat" in m and m["lat"] is h


def test_registry_type_mismatch_raises():
    m = MetricsRegistry()
    m.counter("x")
    try:
        m.gauge("x")
    except TypeError:
        pass
    else:
        raise AssertionError("gauge('x') over a Counter should raise")


def test_results_assembled_from_registry():
    eng = ServingEngine(_fleet(5), policy="fair",
                        cfg=ServingConfig(duration=60.0))
    r = eng.run()
    # the counters the run accumulated are the values the dict reports
    assert r["phases_served"] == eng.served.value
    assert r["label_batches"] == eng.label_batches.value
    assert r["max_backlog"] == eng.max_backlog.value
    assert r["update_pipeline"]["batched_launches"] == \
        eng.update_batched_launches.value
    assert r == eng.metrics.as_results()


def test_events_per_sec_steady_present():
    r = _run(5, n_gpus=1, fuse=1)
    # stub fleets compile nothing, so steady == raw up to the clamp; with
    # compile attributed it can only be >= raw
    assert r["events_per_sec_steady"] >= r["events_per_sec"] > 0.0
    obs = r["observability"]
    assert obs["compile_s"] == 0.0 and obs["drift"] == {}


# ---------------- timing shim + drift audit ----------------


def test_timing_shim_first_vs_steady():
    snap = timing.snapshot()
    timing.record("train_fused", 0.5, first=True, key=(4, 20))
    timing.record("train_fused", 0.1, key=(4, 20))
    timing.record("train_fused", 0.1, key=(4, 20))
    stats = timing.delta(snap)
    e = stats[("train_fused", (4, 20))]
    assert e["calls"] == 3 and e["first_calls"] == 1
    assert abs(e["first_s"] - 0.5) < 1e-12
    assert abs(e["steady_s"] - 0.2) < 1e-12
    assert abs(timing.compile_s(stats) - 0.5) < 1e-12
    tot = timing.totals(stats)
    assert tot["train_fused"]["calls"] == 3


def test_timing_disabled_records_nothing():
    snap = timing.snapshot()
    timing.set_enabled(False)
    try:
        timing.record("train_fused", 1.0, key=(2, 5))
    finally:
        timing.set_enabled(True)
    assert timing.delta(snap) == {}


def test_drift_report_against_known_cost_model():
    cost = GPUCostModel(**PRICED)
    stats = {
        ("train_fused", (4, 20)): {"calls": 3, "first_calls": 1,
                                   "first_s": 2.0, "steady_s": 1.0,
                                   "nbytes": 0},
        ("select_stacked", (4,)): {"calls": 2, "first_calls": 0,
                                   "first_s": 0.0, "steady_s": 0.3,
                                   "nbytes": 0},
        ("encode_solo", ()): {"calls": 2, "first_calls": 0, "first_s": 0.0,
                              "steady_s": 0.1, "nbytes": 2_000_000},
    }
    d = drift_report(cost, stats)
    tf = d["train_fused"]
    # modeled steady = 3 * train_batch_s(4,20) scaled by 2/3 steady calls
    want = 3 * cost.train_batch_s(4, 20) * 2 / 3
    assert abs(tf["modeled_steady_s"] - want) < 1e-9
    assert tf["compile_s"] == 2.0 and tf["steady_calls"] == 2
    assert abs(tf["drift_ratio"] - 1.0 / want) < 1e-9
    sel = d["select_stacked"]
    want_sel = 2 * (cost.update_setup_s
                    + cost.select_s * (1 + cost.update_discount * 3))
    assert abs(sel["modeled_steady_s"] - want_sel) < 1e-9
    enc = d["encode_solo"]
    assert abs(enc["modeled_steady_s"] - cost.delta_comp_s(2_000_000)) < 1e-9
    assert abs(enc["measured_per_call_s"] - 0.05) < 1e-12


def test_serving_stage_report_ranks_bottleneck():
    cost = GPUCostModel(**PRICED)
    stats = {
        ("train_fused", (4, 20)): {"calls": 2, "first_calls": 1,
                                   "first_s": 5.0, "steady_s": 0.4,
                                   "nbytes": 0},
        ("select_stacked", (4,)): {"calls": 2, "first_calls": 1,
                                   "first_s": 1.0, "steady_s": 0.1,
                                   "nbytes": 0},
    }
    rep = serving_stage_report(drift_report(cost, stats))
    assert rep["bottleneck"] == "train_fused"
    assert abs(rep["measured_total_s"] - 0.5) < 1e-12
    tf = rep["stages"]["train_fused"]
    assert tf["measured_s"] == 0.4 and tf["compile_s"] == 5.0
    assert tf["model_efficiency"] is not None


def test_debug_snapshot_unifies_hooks():
    snap = debug_snapshot()
    assert set(snap) == {"fused_train_cache", "auto_exec_modes",
                         "update_pipeline", "sharded",
                         "stacked_select_cache",
                         "stacked_encode_cache", "kernel_dispatch",
                         "stage_timings"}
    assert {"size", "hits", "misses"} <= set(snap["fused_train_cache"])
    assert {"batches", "groups", "sessions", "dispatch_launches",
            "spmd_launches", "distinct_devices"} <= set(snap["sharded"])
    assert {"stacked_select_launches",
            "stacked_encode_launches"} <= set(snap["update_pipeline"])
    assert {"mode", "auto_races"} <= set(snap["kernel_dispatch"])
    assert snap["kernel_dispatch"]["mode"] in ("auto", "pallas", "xla")
