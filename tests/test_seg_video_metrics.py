"""Segmentation world: student model, synthetic video, mIoU metric."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a fallback when absent

from repro.data.video import OracleTeacher, SyntheticVideo, VideoConfig, stop_and_go
from repro.metrics.miou import confusion, miou
from repro.models.seg.student import (
    SegConfig,
    make_student,
    seg_forward,
    seg_loss,
    seg_param_count,
    seg_predict,
)


def test_student_shapes_and_grads():
    cfg = SegConfig(n_classes=5)
    params = make_student(cfg, jax.random.PRNGKey(0))
    img = jnp.zeros((2, 32, 32, 3))
    logits = seg_forward(cfg, params, img)
    assert logits.shape == (2, 32, 32, 5)
    labels = jnp.zeros((2, 32, 32), jnp.int32)
    loss, grads = jax.value_and_grad(lambda p: seg_loss(cfg, p, img, labels))(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
    assert seg_param_count(cfg) > 10_000


def test_student_overfits_single_frame():
    """Capacity sanity: a few Adam steps fit one frame (distillation works)."""
    from repro.core.masked_adam import adam_update, init_state

    cfg = SegConfig(n_classes=3)
    v = SyntheticVideo(VideoConfig(height=32, width=32, n_classes=3, seed=1))
    img, mask = v.frame(0)
    params = make_student(cfg, jax.random.PRNGKey(0))
    opt = init_state(params)

    @jax.jit
    def step(p, o):
        l, g = jax.value_and_grad(lambda q: seg_loss(cfg, q, img[None], mask[None]))(p)
        p, o, _ = adam_update(p, g, o, lr=5e-3)
        return p, o, l

    losses = []
    for _ in range(60):
        params, opt, l = step(params, opt)
        losses.append(float(l))
    assert losses[-1] < 0.5 * losses[0]
    pred = np.asarray(seg_predict(cfg, params, img[None])[0])
    assert miou(pred, mask, 3) > 0.4


def test_video_deterministic_and_drifts():
    v = SyntheticVideo(VideoConfig(seed=5))
    f1a, m1a = v.frame(10)
    f1b, m1b = v.frame(10)
    np.testing.assert_array_equal(f1a, f1b)
    np.testing.assert_array_equal(m1a, m1b)
    # palette drift: same scene positions much later look different
    f2, _ = v.frame(10 + int(v.cfg.fps * v.cfg.drift_period / 2))
    assert np.abs(f1a - f2).mean() > 0.05


def test_motion_schedule_freezes_scene():
    v = SyntheticVideo(VideoConfig(seed=2, motion_schedule=stop_and_go(1.0, 100.0)))
    fps = v.cfg.fps
    m_before = v.frame(int(3 * fps))[1]
    m_after = v.frame(int(5 * fps))[1]
    moved = (m_before != m_after).mean()
    v2 = SyntheticVideo(VideoConfig(seed=2))
    n_before = v2.frame(int(3 * fps))[1]
    n_after = v2.frame(int(5 * fps))[1]
    assert moved < (n_before != n_after).mean()


def test_oracle_teacher_error_rate():
    v = SyntheticVideo(VideoConfig(seed=3))
    t = OracleTeacher(v, error_rate=0.05)
    _, gt = v.frame(7)
    lab = t.label(7)
    err = (lab != gt).mean()
    assert 0.0 < err < 0.15


def test_miou_hand_case():
    pred = np.array([[0, 0], [1, 1]])
    target = np.array([[0, 1], [1, 1]])
    # class0: tp=1 fp=1 fn=0 -> 1/2 ; class1: tp=2 fp=0 fn=1 -> 2/3
    assert miou(pred, target, 2) == pytest.approx((0.5 + 2 / 3) / 2)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 999), n=st.integers(2, 6))
def test_property_miou_bounds(seed, n):
    r = np.random.default_rng(seed)
    a = r.integers(0, n, size=(8, 8))
    b = r.integers(0, n, size=(8, 8))
    m = miou(a, b, n)
    assert 0.0 <= m <= 1.0
    assert miou(a, a, n) == 1.0


def test_confusion_totals():
    r = np.random.default_rng(1)
    a = r.integers(0, 4, size=(16, 16))
    b = r.integers(0, 4, size=(16, 16))
    cm = confusion(a, b, 4)
    assert cm.sum() == a.size
