"""Algorithm 2 (gradient-guided coordinate descent for Adam) unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masked_adam import (
    adam_update,
    init_momentum,
    init_state,
    masked_adam_update,
    momentum_update,
)


def _tree(rng, shapes):
    return {k: jnp.asarray(rng.normal(size=s), jnp.float32) for k, s in shapes.items()}


SHAPES = {"a": (64, 32), "b": (128,), "c": (4, 4, 4)}


def test_full_mask_equals_reference_adam(rng):
    """With mask == 1 the update must equal the paper's Eq (lines 8-12)."""
    p = _tree(rng, SHAPES)
    st = init_state(p)
    lr, b1, b2, eps = 1e-3, 0.9, 0.999, 1e-8
    m = {k: np.zeros(s) for k, s in SHAPES.items()}
    v = {k: np.zeros(s) for k, s in SHAPES.items()}
    cur = {k: np.asarray(x) for k, x in p.items()}
    for i in range(1, 4):
        g = _tree(rng, SHAPES)
        ones = jax.tree.map(lambda x: jnp.ones(x.shape, bool), p)
        p, st, u = masked_adam_update(p, g, st, ones, lr=lr, b1=b1, b2=b2, eps=eps)
        for k in SHAPES:
            gk = np.asarray(g[k])
            m[k] = b1 * m[k] + (1 - b1) * gk
            v[k] = b2 * v[k] + (1 - b2) * gk**2
            uk = lr * np.sqrt(1 - b2**i) / (1 - b1**i) * m[k] / np.sqrt(v[k] + eps)
            cur[k] = cur[k] - uk
            np.testing.assert_allclose(np.asarray(p[k]), cur[k], rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(u[k]), uk, rtol=1e-5, atol=1e-7)


def test_moments_track_all_coordinates(rng):
    """m, v update for EVERY coordinate even when masked out (the paper's key
    requirement for consistent Adam state, §3.1.2)."""
    p = _tree(rng, SHAPES)
    g = _tree(rng, SHAPES)
    st = init_state(p)
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, bool), p)
    p2, st2, u = masked_adam_update(p, g, st, zeros)
    for k in SHAPES:
        np.testing.assert_array_equal(np.asarray(p2[k]), np.asarray(p[k]))  # frozen
        assert float(jnp.abs(st2.m[k]).sum()) > 0  # moments moved
        assert float(jnp.abs(st2.v[k]).sum()) > 0
        assert float(jnp.abs(u[k]).sum()) > 0  # u computed for all


def test_partial_mask_moves_only_selected(rng):
    p = _tree(rng, SHAPES)
    g = _tree(rng, SHAPES)
    st = init_state(p)
    mask = jax.tree.map(lambda x: jnp.asarray(rng.integers(0, 2, x.shape), bool), p)
    p2, _, _ = masked_adam_update(p, g, st, mask)
    for k in SHAPES:
        moved = np.asarray(p2[k]) != np.asarray(p[k])
        assert not np.any(moved & ~np.asarray(mask[k]))


def test_mask_independence_of_moments(rng):
    """Moments after K steps are identical regardless of the mask — the state
    depends only on the gradients at the visited points (here: same grads)."""
    p = _tree(rng, SHAPES)
    gs = [_tree(rng, SHAPES) for _ in range(3)]
    m1 = jax.tree.map(lambda x: jnp.ones(x.shape, bool), p)
    m2 = jax.tree.map(lambda x: jnp.zeros(x.shape, bool), p)
    # NOTE: with mask=0 params stay put so grads would differ in real training;
    # here we feed identical grads to isolate the moment arithmetic.
    stA, stB = init_state(p), init_state(p)
    pA, pB = p, p
    for g in gs:
        _, stA, _ = masked_adam_update(pA, g, stA, m2)
        _, stB, _ = masked_adam_update(pB, g, stB, m2)
    for k in SHAPES:
        np.testing.assert_allclose(np.asarray(stA.m[k]), np.asarray(stB.m[k]))


def test_momentum_baseline(rng):
    p = _tree(rng, SHAPES)
    g = _tree(rng, SHAPES)
    st = init_momentum(p)
    p2, st2, u = momentum_update(p, g, st, lr=0.1, momentum=0.9)
    for k in SHAPES:
        np.testing.assert_allclose(
            np.asarray(p2[k]), np.asarray(p[k]) - 0.1 * np.asarray(g[k]), rtol=1e-6
        )
