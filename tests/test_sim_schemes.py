"""Integration: every scheme runs end-to-end on a tiny stream and produces
sane metrics/bandwidth accounting."""
import jax
import numpy as np
import pytest

from repro.core.server import AMSConfig
from repro.data.video import VideoConfig
from repro.models.seg.student import SegConfig, make_student
from repro.sim.runner import SCHEMES, SimConfig, run_scheme
from repro.sim.seg_world import SegWorld


@pytest.fixture(scope="module")
def setup():
    vcfg = VideoConfig(height=32, width=32, fps=2.0, duration=40.0, seed=5,
                       drift_period=30.0)
    world = SegWorld.make(vcfg)
    pre = make_student(world.seg_cfg, jax.random.PRNGKey(0))
    ams = AMSConfig(t_update=5.0, t_horizon=20.0, k_iters=3, batch_size=3,
                    gamma=0.05, phi_target=0.04)
    return world, pre, ams


@pytest.mark.parametrize("scheme", SCHEMES)
def test_scheme_runs(setup, scheme):
    world, pre, ams = setup
    r = run_scheme(scheme, world, pre, ams, SimConfig(eval_stride=5, jit_max_iters=3))
    assert 0.0 <= r.mean_miou <= 1.0
    assert len(r.miou_per_frame) > 5
    up, down = r.bandwidth_kbps(40.0)
    if scheme == "no_custom":
        assert up == 0 and down == 0
    if scheme == "ams":
        assert r.updates > 0
        assert down > 0
        hist = r.extras["history"]
        assert all(0.1 <= h["rate"] <= 1.0 for h in hist)


def test_ams_downlink_less_than_jit(setup):
    world, pre, ams = setup
    r_ams = run_scheme("ams", world, pre, ams, SimConfig(eval_stride=5))
    r_jit = run_scheme("jit", world, pre, ams, SimConfig(eval_stride=5, jit_max_iters=3))
    _, d_ams = r_ams.bandwidth_kbps(40.0)
    _, d_jit = r_jit.bandwidth_kbps(40.0)
    assert d_ams < d_jit  # the paper's central bandwidth claim


def test_multiclient_runs():
    from repro.core.server import AMSConfig
    from repro.sim.multiclient import run_multiclient

    seg_cfg = SegConfig(n_classes=5)
    pre = make_student(seg_cfg, jax.random.PRNGKey(1))
    ams = AMSConfig(t_update=5.0, t_horizon=20.0, k_iters=3, batch_size=3, gamma=0.05)
    out = run_multiclient(2, pre, seg_cfg, ams, duration=20.0,
                          video_kw=dict(height=32, width=32, fps=2.0), eval_stride=5)
    assert out["n_clients"] == 2
    assert len(out["miou_per_client"]) == 2
    assert out["phases_served"] > 0
