"""Fused post-train update pipeline: stacked gradient-guided selection,
batched delta encode (byte-identical wire format), the amortized
`GPUCostModel.update_batch_s` pricing, and the engine's batched-update
charging + telemetry."""
import gzip

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a fallback when absent

from repro.core import batched, delta as delta_mod, selection
from repro.core.delta import encode_delta, encode_delta_stack
from repro.core.scheduler import GPUCostModel
from repro.serving import (
    ClientNetwork,
    LinkSpec,
    ServingConfig,
    ServingEngine,
    StubSession,
)


def _tree(rng, sizes=((40, 8), (77,), (3, 5, 7))):
    return {f"l{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
            for i, s in enumerate(sizes)}


def _stub_fleet(n):
    link = LinkSpec(up_kbps=500.0, down_kbps=1000.0)
    return [StubSession(i, rate=0.15 if i < 2 else 1.0,
                        dynamics=0.0005 if i < 2 else 0.004,
                        net=ClientNetwork(link))
            for i in range(n)]


# ---------------- stacked selection ----------------


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 5), frac=st.floats(0.02, 0.6),
       seed=st.integers(0, 1 << 16))
def test_stacked_selection_matches_per_session(b, frac, seed):
    """Session j's slice of the stacked launch equals
    ``gradient_guided_mask(u_j, frac)`` within float32 tolerance: any
    disagreeing coordinate sits within float32 noise of the γ-threshold."""
    rng = np.random.default_rng(seed)
    trees = [_tree(rng) for _ in range(b)]
    stacked = selection.stacked_gradient_guided_masks(
        batched.stack_trees(trees), frac)
    for j, tree in enumerate(trees):
        solo = selection.gradient_guided_mask(tree, frac)
        thr = np.sort(np.abs(np.concatenate(
            [np.ravel(l) for l in jax.tree.leaves(tree)])))
        thr = thr[thr.size - max(int(frac * thr.size), 1)]
        for (k, s_leaf), u_leaf in zip(
                ((k, np.asarray(l[j])) for k, l in stacked.items()),
                jax.tree.leaves(tree)):
            solo_leaf = np.asarray(solo[k])
            diff = s_leaf != solo_leaf
            if diff.any():
                near = np.abs(np.asarray(u_leaf))[diff]
                assert np.all(np.abs(near - thr) < 1e-5 * (1.0 + thr))


def test_stacked_selection_bisection_path(monkeypatch):
    """Trees past the _SMALL cutoff take the vmapped bisection; per-session
    thresholds match the solo bisection path's masks."""
    monkeypatch.setattr(selection, "_SMALL", 100)
    selection.stacked_cache_clear()
    rng = np.random.default_rng(7)
    trees = [_tree(rng, sizes=((300,), (150,))) for _ in range(3)]
    stacked = selection.stacked_gradient_guided_masks(
        batched.stack_trees(trees), 0.1)
    for j, tree in enumerate(trees):
        solo = selection.gradient_guided_mask(tree, 0.1)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(stacked[k][j]),
                                          np.asarray(solo[k]))


def test_stacked_selection_cache_shared_across_calls():
    selection.stacked_cache_clear()
    rng = np.random.default_rng(0)
    stack = batched.stack_trees([_tree(rng) for _ in range(4)])
    selection.stacked_gradient_guided_masks(stack, 0.05)
    assert selection.stacked_cache_info() == {"size": 1, "hits": 0,
                                              "misses": 1}
    selection.stacked_gradient_guided_masks(stack, 0.05)
    assert selection.stacked_cache_info() == {"size": 1, "hits": 1,
                                              "misses": 1}
    # a different γ (or shape) is a different executable
    selection.stacked_gradient_guided_masks(stack, 0.2)
    assert selection.stacked_cache_info()["size"] == 2


def test_stacked_selection_fraction_per_session():
    rng = np.random.default_rng(3)
    stack = batched.stack_trees([_tree(rng) for _ in range(3)])
    masks = selection.stacked_gradient_guided_masks(stack, 0.1)
    for j in range(3):
        sel = sum(int(np.asarray(l[j]).sum()) for l in jax.tree.leaves(masks))
        n = sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(masks))
        assert sel / n == pytest.approx(0.1, rel=0.15, abs=0.02)


# ---------------- batched delta encode ----------------


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 6), frac=st.floats(0.0, 1.0),
       seed=st.integers(0, 1 << 16))
def test_encode_delta_stack_byte_identical(b, frac, seed):
    """The golden: every delta out of the batched device->host encode must
    match the per-session `encode_delta` byte for byte — values, the gzip'd
    bit-vector, and all wire accounting."""
    rng = np.random.default_rng(seed)
    params = [_tree(rng) for _ in range(b)]
    masks = [jax.tree.map(
        lambda x: jnp.asarray(rng.uniform(size=x.shape) < frac), p)
        for p in params]
    stacked = encode_delta_stack(batched.stack_trees(params),
                                 batched.stack_trees(masks), b)
    for p, m, got in zip(params, masks, stacked):
        ref = encode_delta(p, m)
        np.testing.assert_array_equal(got.values, ref.values)
        assert got.values.dtype == ref.values.dtype
        assert got.packed_mask == ref.packed_mask
        assert got.n_total == ref.n_total
        assert got.value_bytes == ref.value_bytes
        assert got.mask_bytes == ref.mask_bytes
        assert got.total_bytes == ref.total_bytes


def test_encode_delta_stack_cache_and_fp32():
    delta_mod.stack_cache_clear()
    rng = np.random.default_rng(1)
    params = [_tree(rng) for _ in range(3)]
    masks = [jax.tree.map(
        lambda x: jnp.asarray(rng.uniform(size=x.shape) < 0.3), p)
        for p in params]
    ps, ms = batched.stack_trees(params), batched.stack_trees(masks)
    encode_delta_stack(ps, ms, 3)
    assert delta_mod.stack_cache_info() == {"size": 1, "hits": 0,
                                            "misses": 1}
    encode_delta_stack(ps, ms, 3)
    assert delta_mod.stack_cache_info()["hits"] == 1
    # a float32 wire format is a different executable and still byte-exact
    got = encode_delta_stack(ps, ms, 3, value_dtype="float32")
    assert delta_mod.stack_cache_info()["size"] == 2
    for p, m, g in zip(params, masks, got):
        ref = encode_delta(p, m, value_dtype="float32")
        np.testing.assert_array_equal(g.values, ref.values)
        assert g.packed_mask == ref.packed_mask


def test_mask_scratch_keyed_by_dtype_interleaved():
    """Regression for the scratch keying: two same-sized trees encoded at
    different wire dtypes, interleaved, must each round-trip their own
    values — the (n_total, value_dtype) key keeps their scratch buffers
    (and any future value scratch) from aliasing."""
    rng = np.random.default_rng(9)
    a, b = _tree(rng, sizes=((64,),)), _tree(rng, sizes=((64,),))
    ma = {"l0": jnp.asarray(np.arange(64) % 3 == 0)}
    mb = {"l0": jnp.asarray(np.arange(64) % 2 == 0)}
    d16a = encode_delta(a, ma, value_dtype="float16")
    d32b = encode_delta(b, mb, value_dtype="float32")
    d16a2 = encode_delta(a, ma, value_dtype="float16")  # interleaved re-run
    assert d16a.packed_mask == d16a2.packed_mask
    np.testing.assert_array_equal(d16a.values, d16a2.values)
    np.testing.assert_array_equal(
        d32b.values, np.asarray(b["l0"])[np.asarray(mb["l0"])])
    bits = np.unpackbits(np.frombuffer(
        gzip.decompress(d32b.packed_mask), np.uint8))[:64]
    np.testing.assert_array_equal(bits.astype(bool), np.asarray(mb["l0"]))
    assert (64, "float16") in delta_mod._MASK_SCRATCH
    assert (64, "float32") in delta_mod._MASK_SCRATCH


# ---------------- fused pipeline through train_phases_fused ----------------


def _seg_sessions(n, k_iters=2):
    from repro.core.server import AMSConfig, AMSSession, Task
    from repro.data.video import VideoConfig
    from repro.models.seg.student import SegConfig, make_student
    from repro.sim.seg_world import SegWorld, phi_pixel_loss

    seg = SegConfig(n_classes=5)
    ams = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=k_iters,
                    batch_size=2, gamma=0.05, lr=2e-3, phi_target=0.15)
    pre = make_student(seg, jax.random.PRNGKey(0))
    out = []
    for i in range(n):
        world = SegWorld.make(
            VideoConfig(seed=100 + i, height=24, width=24, fps=2.0,
                        duration=20.0), seg)
        task = Task(loss_and_grad=world.loss_and_grad, teacher=None,
                    phi_loss=phi_pixel_loss)
        s = AMSSession(task, ams, jax.tree.map(lambda x: x, pre), seed=i)
        frames = np.stack([world.video.frame(j)[0] for j in range(6)])
        labels = np.stack([world.teacher.label(j) for j in range(6)])
        s.receive_labeled(frames, labels, 5.0)
        out.append(s)
    return out


def test_train_phases_fused_batches_select_and_encode():
    sessions = _seg_sessions(3)
    batched.update_pipeline_reset()
    # phase 1: no u_prev yet -> random masks, but the encode still batches
    d1 = batched.train_phases_fused(sessions, 6.0, force_stack=True)
    assert all(d is not None for d in d1)
    info = batched.update_pipeline_info()
    assert info["stacked_select_launches"] == 0  # first phase: random masks
    assert info["stacked_encode_launches"] == 1
    assert info["stacked_encode_sessions"] == 3
    # phase 2: every member defers its gradient-guided selection into ONE
    # stacked launch
    d2 = batched.train_phases_fused(sessions, 14.0, force_stack=True)
    assert all(d is not None for d in d2)
    info = batched.update_pipeline_info()
    assert info["stacked_select_launches"] == 1
    assert info["stacked_select_sessions"] == 3
    assert info["stacked_encode_launches"] == 2
    assert all(s.phase == 2 for s in sessions)
    # deltas carry the right wire dtype and decode cleanly
    assert all(d.value_dtype == "float16" for d in d2)


def test_fused_singleton_still_bitwise_with_deferred_selection():
    """The deferred-selection refactor must not perturb the B=1 sequential
    path: two identical sessions, one trained solo and one through the
    fused entry point, stay bit-identical across TWO phases (the second
    exercises the deferred gradient-guided materialization)."""
    a, = _seg_sessions(1)
    b, = _seg_sessions(1)
    for t in (6.0, 14.0):
        da = a.train_phase(t)
        [db] = batched.train_phases_fused([b], t)
        assert np.array_equal(da.values, db.values)
        assert da.packed_mask == db.packed_mask
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ---------------- cost model ----------------


def test_update_batch_s_solo_exact_sublinear_and_free_when_unpriced():
    c = GPUCostModel(select_s=0.1, delta_comp_s_per_mb=5.0)
    nb = 200_000  # 0.2 MB -> 1.0 s comp + 0.1 s select
    assert c.update_solo_s(nb) == pytest.approx(1.1)
    # B=1 is EXACTLY the solo cost (unfused engines bit-identical)
    assert c.update_batch_s([nb]) == c.update_solo_s(nb)
    for b in range(2, 9):
        fused = c.update_batch_s([nb] * b)
        assert fused < b * c.update_solo_s(nb)  # sublinear in B
        assert fused > c.update_batch_s([nb] * (b - 1))  # but monotone
    # an unpriced pipeline stays free: no setup charge materializes
    free = GPUCostModel()
    assert free.update_batch_s([nb] * 4) == 0.0
    assert free.update_batch_s([]) == 0.0
    assert c.update_batch_s([]) == 0.0


# ---------------- engine integration ----------------


def _run_engine(n, *, fuse_train, fuse_updates, cost, duration=120.0):
    eng = ServingEngine(
        _stub_fleet(n), policy="fair", cost=cost,
        cfg=ServingConfig(duration=duration, max_queue=32,
                          fuse_train=fuse_train, fuse_updates=fuse_updates))
    return eng.run()


def test_engine_prices_fused_updates_amortized():
    cost = GPUCostModel(select_s=0.15, delta_comp_s_per_mb=5.0)
    seq = _run_engine(10, fuse_train=4, fuse_updates=False, cost=cost)
    bat = _run_engine(10, fuse_train=4, fuse_updates=True, cost=cost)
    up_seq, up_bat = seq["update_pipeline"], bat["update_pipeline"]
    assert up_seq["batched_launches"] == 0
    assert up_seq["update_s_saved"] == 0.0
    assert up_seq["update_s_charged"] > 0.0
    assert bat["fused_launches"] > 0
    assert up_bat["batched_launches"] > 0
    assert up_bat["batched_sessions"] > up_bat["batched_launches"]
    assert up_bat["update_s_saved"] > 0.0
    assert (up_bat["update_s_charged"]
            < up_bat["update_s_sequential"])
    # the reclaimed device time turns into served phases or freshness
    assert (bat["phases_served"], bat["mean_miou"]) >= (
        seq["phases_served"], seq["mean_miou"])


def test_engine_update_pipeline_free_by_default():
    """Default cost model: the update path is unpriced, so the batched
    pricing is a structural no-op (goldens elsewhere prove bit-identity;
    this pins the telemetry contract)."""
    r = _run_engine(6, fuse_train=4, fuse_updates=True, cost=GPUCostModel())
    up = r["update_pipeline"]
    assert up["update_s_charged"] == 0.0 and up["update_s_saved"] == 0.0
    assert up["stacked_select_launches"] == 0  # stub fleet: no real math
    r1 = _run_engine(6, fuse_train=1, fuse_updates=True, cost=GPUCostModel())
    assert r1["update_pipeline"]["batched_launches"] == 0
