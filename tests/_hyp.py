"""Optional-import shim for ``hypothesis``.

Property tests import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly. When hypothesis is installed the real library is
re-exported unchanged; when it is missing (this container does not ship it and
cannot pip-install), a minimal fallback runs each property a handful of times
with deterministic pseudo-random examples drawn from the declared strategies —
enough to keep the invariants exercised and the suite collectable everywhere.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5  # examples per property when hypothesis is absent

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rnd: random.Random):
            return self._draw(rnd)

    class _St:
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rnd: opts[rnd.randrange(len(opts))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rnd):
                n = rnd.randint(min_size, max_size)
                return [elements.example(rnd) for _ in range(n)]

            return _Strategy(draw)

    st = _St()

    def settings(**_kw):  # accepts and ignores max_examples/deadline/...
        def deco(fn):
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # nullary wrapper; deliberately no functools.wraps — __wrapped__
            # would make pytest read fn's params as fixture requests
            def wrapper():
                # deterministic per-test examples: seed from the test name
                rnd = random.Random(fn.__name__)
                for _ in range(_FALLBACK_EXAMPLES):
                    args = [s.example(rnd) for s in arg_strategies]
                    kwargs = {k: s.example(rnd) for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
