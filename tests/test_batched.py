"""Fused cross-session training (`core.batched`): stack/unstack round-trips,
fused-vs-sequential phase equivalence (B=1 bitwise, B>1 to tolerance), the
module-level executable cache, and the run_multiclient default-path golden."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a fallback when absent

from repro.core import batched
from repro.core.server import AMSConfig, AMSSession, Task
from repro.data.video import VideoConfig
from repro.models.seg.student import SegConfig, make_student
from repro.sim.seg_world import SegWorld, phi_pixel_loss

SEG = SegConfig(n_classes=5)
AMS = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=3, batch_size=2,
                gamma=0.05, lr=2e-3, phi_target=0.15)


def _pretrained():
    return make_student(SEG, jax.random.PRNGKey(0))


def _session(i, pre, ams=AMS, n_feed=6):
    """A deterministic, fully-fed AMS session: same i -> identical state."""
    world = SegWorld.make(
        VideoConfig(seed=100 + i, height=24, width=24, fps=2.0,
                    duration=20.0), SEG)
    task = Task(loss_and_grad=world.loss_and_grad, teacher=None,
                phi_loss=phi_pixel_loss)
    s = AMSSession(task, ams, jax.tree.map(lambda x: x, pre), seed=i)
    if n_feed:
        frames = np.stack([world.video.frame(j)[0] for j in range(n_feed)])
        labels = np.stack([world.teacher.label(j) for j in range(n_feed)])
        s.receive_labeled(frames, labels, 5.0)
    return s


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _max_leaf_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------- stack / unstack ----------------


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 5), n=st.integers(1, 8), m=st.integers(1, 4),
       seed=st.integers(0, 1 << 16))
def test_stack_unstack_roundtrip(b, n, m, seed):
    """unstack(stack(trees)) returns the original trees, leaf for leaf."""
    rng = np.random.default_rng(seed)
    trees = [{"w": jnp.asarray(rng.normal(size=(n, m)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(m,)), jnp.float32),
              "nest": {"c": jnp.asarray(rng.integers(0, 9, size=(n,)),
                                        jnp.int32)}}
             for _ in range(b)]
    stacked = batched.stack_trees(trees)
    assert all(l.shape[0] == b for l in jax.tree.leaves(stacked))
    back = batched.unstack_tree(stacked, b)
    assert len(back) == b
    for orig, got in zip(trees, back):
        assert _leaves_equal(orig, got)


def test_stack_trees_empty_raises():
    with pytest.raises(ValueError):
        batched.stack_trees([])


def test_tree_struct_discriminates():
    a = {"w": jnp.zeros((3, 2))}
    assert batched.tree_struct(a) == batched.tree_struct(
        {"w": jnp.ones((3, 2))})
    assert batched.tree_struct(a) != batched.tree_struct(
        {"w": jnp.zeros((2, 3))})
    assert batched.tree_struct(a) != batched.tree_struct(
        {"w": jnp.zeros((3, 2), jnp.float16)})
    assert batched.tree_struct(a) != batched.tree_struct(
        {"v": jnp.zeros((3, 2))})


# ---------------- fused vs sequential equivalence ----------------


def test_fused_b1_bitwise_equals_sequential():
    """A singleton fused phase IS the sequential phase: params, optimizer
    state, u, and the encoded delta must match bit for bit."""
    pre = _pretrained()
    a, b = _session(0, pre), _session(0, pre)
    da = a.train_phase(6.0)
    [db] = batched.train_phases_fused([b], 6.0)
    assert _leaves_equal(a.params, b.params)
    assert _leaves_equal(a.opt_state.m, b.opt_state.m)
    assert _leaves_equal(a.opt_state.v, b.opt_state.v)
    assert int(a.opt_state.count) == int(b.opt_state.count)
    assert _leaves_equal(a.u_prev, b.u_prev)
    assert np.array_equal(da.values, db.values)
    assert da.packed_mask == db.packed_mask
    assert da.total_bytes == db.total_bytes
    assert a.history == b.history


def test_fused_b4_matches_sequential_to_tolerance():
    """Four sessions stacked into one scan/vmap launch reproduce each
    session's sequential phase to float32 tolerance (vmap batches the convs
    differently, so bitwise is not expected — closeness is)."""
    pre = _pretrained()
    seqs = [_session(i, pre) for i in range(4)]
    fused = [_session(i, pre) for i in range(4)]
    for s in seqs:
        s.train_phase(6.0)
    deltas = batched.train_phases_fused(fused, 6.0, force_stack=True)
    assert all(d is not None for d in deltas)
    for s, f in zip(seqs, fused):
        assert _max_leaf_diff(s.params, f.params) < 1e-4
        # raw moments accumulate conv-reorder noise at gradient scale
        assert _max_leaf_diff(s.opt_state.m, f.opt_state.m) < 2e-3
        assert _max_leaf_diff(s.u_prev, f.u_prev) < 1e-4
        assert int(s.opt_state.count) == int(f.opt_state.count)
        assert s.phase == f.phase == 1


def test_fused_b1_force_stack_matches_to_tolerance():
    """Even B=1 pushed through the stacked executable (benchmarks do this)
    stays within float32 tolerance of the sequential loop."""
    pre = _pretrained()
    a, b = _session(1, pre), _session(1, pre)
    a.train_phase(6.0)
    [d] = batched.train_phases_fused([b], 6.0, force_stack=True)
    assert d is not None
    assert _max_leaf_diff(a.params, b.params) < 1e-4


def test_fused_empty_buffer_yields_none_slot():
    """A session with nothing to train gets None, exactly like train_phase;
    its neighbors still train."""
    pre = _pretrained()
    full, empty = _session(0, pre), _session(1, pre, n_feed=0)
    # n_feed=0 leaves the replay buffer empty
    assert len(empty.buffer) == 0
    out = batched.train_phases_fused([empty, full], 6.0)
    assert out[0] is None and out[1] is not None
    assert empty.phase == 0 and full.phase == 1


def test_fused_mixed_keys_split_groups():
    """Sessions with different K cannot share an executable — they split
    into separate groups but all still train."""
    pre = _pretrained()
    other = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=2, batch_size=2,
                      gamma=0.05, lr=2e-3, phi_target=0.15)
    ss = [_session(0, pre), _session(1, pre),
          _session(2, pre, ams=other), _session(3, pre, ams=other)]
    out = batched.train_phases_fused(ss, 6.0, force_stack=True)
    assert all(d is not None for d in out)
    assert [s.phase for s in ss] == [1, 1, 1, 1]


def test_exec_modes_agree():
    """The scan-shaped executable (accelerator default) and the step-loop
    shape (CPU default) compute the same phase to float32 tolerance; bad
    modes are rejected."""
    pre = _pretrained()
    small = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=2, batch_size=2,
                      gamma=0.05, lr=2e-3, phi_target=0.15)
    try:
        batched.set_exec_mode("scan")
        a = [_session(i, pre, ams=small) for i in range(2)]
        batched.train_phases_fused(a, 6.0, force_stack=True)
        batched.set_exec_mode("loop")
        b = [_session(i, pre, ams=small) for i in range(2)]
        batched.train_phases_fused(b, 6.0, force_stack=True)
    finally:
        batched.set_exec_mode("auto")
    for x, y in zip(a, b):
        assert _max_leaf_diff(x.params, y.params) < 1e-5
    with pytest.raises(ValueError):
        batched.set_exec_mode("unrolled")


def test_auto_mode_races_once_and_caches_winner():
    """``auto`` settles scan-vs-loop by a one-shot timed race on the first
    real stacked batch (not the backend name): the measured winner is
    recorded per compile key, its executable cached, and every later call
    is a plain cache hit."""
    batched.cache_clear()
    pre = _pretrained()
    small = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=2, batch_size=2,
                      gamma=0.05, lr=2e-3, phi_target=0.15)
    assert batched.auto_mode_info() == {}
    batched.train_phases_fused([_session(i, pre, ams=small) for i in range(2)],
                               6.0, force_stack=True)
    decisions = batched.auto_mode_info()
    assert len(decisions) == 1
    ((backend, _), winner), = decisions.items()
    assert winner in ("scan", "loop")
    import jax as _jax
    assert backend == _jax.default_backend()
    # the race is one miss; the losing executable is not cached
    assert batched.cache_info() == {"size": 1, "hits": 0, "misses": 1}
    # second same-shaped fleet: decided key -> straight cache hit, and the
    # winner matches that mode's executable bit-for-bit
    fleet = [_session(i, pre, ams=small) for i in range(2)]
    batched.train_phases_fused(fleet, 6.0, force_stack=True)
    assert batched.cache_info() == {"size": 1, "hits": 1, "misses": 1}
    assert batched.auto_mode_info() == decisions  # no re-race
    try:
        batched.set_exec_mode(winner)
        forced = [_session(i, pre, ams=small) for i in range(2)]
        batched.train_phases_fused(forced, 6.0, force_stack=True)
    finally:
        batched.set_exec_mode("auto")
    for x, y in zip(fleet, forced):
        assert _leaves_equal(x.params, y.params)


# ---------------- executable cache ----------------


def test_phase_cache_compiles_once_for_same_shapes():
    batched.cache_clear()
    pre = _pretrained()
    batched.train_phases_fused([_session(i, pre) for i in range(3)], 6.0,
                               force_stack=True)
    info = batched.cache_info()
    assert info == {"size": 1, "hits": 0, "misses": 1}
    # a second same-shaped fleet reuses the executable
    batched.train_phases_fused([_session(i + 10, pre) for i in range(3)], 6.0,
                               force_stack=True)
    info = batched.cache_info()
    assert info["misses"] == 1 and info["hits"] == 1 and info["size"] == 1


# ---------------- run_multiclient default-path golden ----------------


def test_run_multiclient_default_kwargs_bit_for_bit():
    """The acceptance gate: with default kwargs (no fusing, 1 GPU) the shim
    reproduces the PR-2 numbers exactly — captured from the tree at the
    PR-2 commit (d38f266) before any of the fused-training changes."""
    from repro.sim.multiclient import run_multiclient

    pre = _pretrained()
    ams = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=2, batch_size=2,
                    gamma=0.05, lr=2e-3, phi_target=0.15)
    r = run_multiclient(2, pre, SEG, ams, duration=25.0,
                        video_kw=dict(height=24, width=24, fps=2.0))
    gold = {
        "mean_miou": 0.07633169618507302,
        "gpu_utilization": 0.22184800000000007,
        "phases_served": 5,
        "phases_deferred": 3,
        "mean_up_kbps": 0.456,
        "mean_down_kbps": 2.84592,
        "delta_latency_mean_s": 0.06422960000000053,
        "events_processed": 90,
        "labels_total": 40,
    }
    for k, v in gold.items():
        assert r[k] == v, (k, r[k], v)
    assert r["miou_per_client"] == [0.09255216388896606, 0.06011122848117999]
    assert r["fused_launches"] == 0 and r["fused_sessions"] == 0


# ---------------- batched teacher labeling ----------------


def test_receive_frames_batches_teacher_calls():
    """One stacked teacher launch instead of one per frame, identical
    buffer/φ outcomes."""
    calls = []

    def teacher(frames):
        calls.append(np.asarray(frames).shape[0])
        return np.asarray(frames).sum(axis=-1) > 0

    pre = _pretrained()
    task = Task(loss_and_grad=None, teacher=teacher,
                phi_loss=lambda a, b: float(np.mean(a != b)))
    s = AMSSession(task, AMS, pre, seed=0)
    frames = np.random.default_rng(0).normal(size=(5, 8, 8, 3))
    s.receive_frames(frames, 1.0)
    assert calls == [5]  # one batched call, not 5 singletons
    assert len(s.buffer) == 5
    assert s.asr.phi_ema >= 0.0  # φ ingest really ran
    s.receive_frames([], 2.0)  # empty batch: no teacher call, no crash
    assert calls == [5]
