"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps
against the pure-jnp ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.masked_adam.ops import masked_adam_leaf
from repro.kernels.masked_adam.ref import masked_adam_ref


@pytest.mark.parametrize("shape", [(128,), (1000,), (64, 37), (3, 5, 7), (1,), (129,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_adam_kernel_sweep(rng, shape, dtype):
    p = jnp.asarray(rng.normal(size=shape), dtype)
    g = jnp.asarray(rng.normal(size=shape), dtype)
    m = jnp.asarray(rng.normal(size=shape), jnp.float32)
    v = jnp.asarray(rng.uniform(0.01, 1, size=shape), jnp.float32)
    b = jnp.asarray(rng.integers(0, 2, size=shape), jnp.float32)
    bc = jnp.float32(1e-3)
    out_k = masked_adam_leaf(p, g, m, v, b, bc)
    out_r = masked_adam_ref(p, g, m, v, b, bc.reshape(1, 1), b1=0.9, b2=0.999, eps=1e-8)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    for a, r in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(r, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,KV,G,hd,causal,window,softcap", [
    (2, 128, 2, 2, 32, True, 0, 0.0),
    (1, 256, 1, 4, 16, True, 64, 0.0),   # MQA + sliding window
    (2, 64, 2, 1, 32, False, 0, 0.0),    # non-causal
    (1, 128, 2, 2, 32, True, 0, 30.0),   # softcap
    (1, 96, 3, 1, 16, True, 0, 0.0),     # non-pow2 blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_sweep(rng, B, S, KV, G, hd, causal, window, softcap, dtype):
    q = jnp.asarray(rng.normal(size=(B, S, KV, G, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    o = flash_attention_pallas(q, k, v, causal=causal, window=window, softcap=softcap,
                               block_q=32, block_k=32)
    q4 = q.transpose(0, 2, 3, 1, 4).reshape(B, KV * G, S, hd)
    ref = flash_attention_ref(q4, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                              causal=causal, window=window, softcap=softcap)
    ref = ref.reshape(B, KV, G, S, hd).transpose(0, 3, 1, 2, 4)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_kernel_matches_model_path(rng):
    """Pallas kernel == the model's jnp chunked-flash (swap-in equivalence)."""
    from repro.models.attention import flash_attention as flash_jnp

    q = jnp.asarray(rng.normal(size=(2, 128, 2, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.float32)
    a = flash_attention_pallas(q, k, v, block_q=64, block_k=64)
    b = flash_jnp(q, k, v, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
