"""RMSNorm Pallas kernel vs oracle: shape/dtype sweep."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rmsnorm.ops import rms_norm_pallas
from repro.kernels.rmsnorm.ref import rms_norm_ref


@pytest.mark.parametrize("shape", [(8, 128), (3, 7, 64), (1, 256), (5, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_sweep(rng, shape, dtype):
    x = jnp.asarray(rng.normal(size=shape), dtype)
    w = jnp.asarray(rng.normal(size=shape[-1]) * 0.1, jnp.float32)
    got = rms_norm_pallas(x, w)
    ref = rms_norm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
