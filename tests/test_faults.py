"""Chaos engineering for the serving runtime: seeded `FaultPlan`s, the
deterministic injector (hashed loss draws, backoff jitter, window queries),
`RateTrace` bandwidth replay, and the engine's recovery machinery — upload
retry/abandon, delta supersede, device-crash watchdog requeue, pool-dead
load shedding — all of it bit-reproducible and request-conserving."""
import json
import os

import pytest
from _hyp import given, settings, st  # hypothesis, or a fallback when absent

from repro.serving import (
    ClientNetwork,
    CrashWindow,
    FaultInjector,
    FaultPlan,
    LinkSpec,
    OutageWindow,
    RateTrace,
    ServingConfig,
    ServingEngine,
    SlowdownWindow,
    StubSession,
)
from repro.serving.faults import _u01

_TRACE_FIXTURE = os.path.join(os.path.dirname(__file__), os.pardir,
                              "benchmarks", "traces", "lte_burst.json")

_WALL = ("wall_s", "events_per_sec", "events_per_sec_steady",
         "observability")


def _fleet(n, **kw):
    return [StubSession(i, net=ClientNetwork(LinkSpec(up_kbps=500.0,
                                                      down_kbps=1000.0)), **kw)
            for i in range(n)]


def _core(r):
    return {k: v for k, v in r.items() if k not in _WALL}


def _conserved(r):
    assert r["requests_enqueued"] == (r["requests_granted"]
                                      + r["dropped_requests"]
                                      + r["unserved_backlog"]), r
    return True


# ---------------- plan validation ----------------


def test_plan_rejects_bad_probabilities_and_knobs():
    with pytest.raises(ValueError):
        FaultPlan(up_loss=1.0)
    with pytest.raises(ValueError):
        FaultPlan(down_loss=-0.1)
    with pytest.raises(ValueError):
        FaultPlan(backoff_jitter=1.0)
    with pytest.raises(ValueError):
        FaultPlan(backoff_factor=0.5)
    with pytest.raises(ValueError):
        FaultPlan(watchdog_s=0.0)


def test_plan_rejects_bad_windows():
    with pytest.raises(ValueError):
        OutageWindow(start=5.0, end=1.0)
    with pytest.raises(ValueError):
        OutageWindow(start=0.0, end=1.0, direction="sideways")
    with pytest.raises(ValueError):
        CrashWindow(gid=0, start=3.0, end=3.0)  # empty
    with pytest.raises(ValueError):
        SlowdownWindow(gid=0, start=0.0, end=1.0, factor=0.9)
    with pytest.raises(ValueError):  # disconnect must name a client
        FaultPlan(disconnects=(OutageWindow(start=0.0, end=1.0),))
    with pytest.raises(ValueError):  # overlapping crashes on one device
        FaultPlan(crashes=(CrashWindow(gid=1, start=0.0, end=10.0),
                           CrashWindow(gid=1, start=5.0, end=15.0)))


def test_none_plan_is_default_and_inactive():
    assert FaultPlan.none() == FaultPlan()
    assert not FaultPlan.none().active
    assert FaultPlan(up_loss=0.01).active
    assert FaultPlan(crashes=(CrashWindow(gid=0, start=1.0, end=2.0),)).active
    assert FaultPlan.reference(240.0).active


# ---------------- deterministic draws ----------------


def test_u01_deterministic_and_in_range():
    xs = [_u01(7, 1, c, n) for c in range(4) for n in range(64)]
    assert xs == [_u01(7, 1, c, n) for c in range(4) for n in range(64)]
    assert all(0.0 <= x < 1.0 for x in xs)
    # different key-space tags must decorrelate
    assert _u01(7, 1, 0, 0) != _u01(7, 2, 0, 0)
    assert _u01(7, 1, 0, 0) != _u01(8, 1, 0, 0)


def test_injector_loss_draws_replay_exactly():
    plan = FaultPlan(seed=3, up_loss=0.3, down_loss=0.1)
    a = FaultInjector(plan)
    b = FaultInjector(plan)
    seq_a = [a.transfer_lost("up", 0) for _ in range(200)]
    seq_b = [b.transfer_lost("up", 0) for _ in range(200)]
    assert seq_a == seq_b
    frac = sum(seq_a) / len(seq_a)
    assert 0.15 < frac < 0.45  # roughly the configured probability
    # the per-direction counters are independent lanes
    assert [a.transfer_lost("down", 0) for _ in range(200)] != seq_a


def test_injector_outage_and_slowdown_queries():
    inj = FaultInjector(FaultPlan(
        outages=(OutageWindow(start=10.0, end=20.0, direction="up"),
                 OutageWindow(start=15.0, end=25.0, direction="up"),
                 OutageWindow(start=40.0, end=45.0, direction="down",
                              client=2)),
        slowdowns=(SlowdownWindow(gid=1, start=5.0, end=9.0, factor=2.0),)))
    # adjacent windows merged: up is down over [10, 25)
    assert inj.outage_until("up", 0, 12.0) == 25.0
    assert inj.outage_until("up", 0, 24.9) == 25.0
    assert inj.outage_until("up", 0, 25.0) is None
    assert inj.outage_until("down", 0, 12.0) is None
    # per-client outage hits only that client
    assert inj.outage_until("down", 2, 41.0) == 45.0
    assert inj.outage_until("down", 1, 41.0) is None
    assert inj.slowdown_factor(1, 6.0) == 2.0
    assert inj.slowdown_factor(1, 9.0) == 1.0
    assert inj.slowdown_factor(0, 6.0) == 1.0
    assert inj.link_outage_s(30.0, 3) == pytest.approx(15.0 * 3)


def test_backoff_grows_exponentially_with_bounded_jitter():
    plan = FaultPlan(seed=11, backoff_base_s=0.5, backoff_factor=2.0,
                     backoff_jitter=0.25)
    inj = FaultInjector(plan)
    for c in range(3):
        for k in range(4):
            base = 0.5 * 2.0 ** k
            b = inj.backoff_s(c, k)
            assert base * 0.75 <= b <= base * 1.25
            assert b == inj.backoff_s(c, k)  # pure function, not a draw
    nj = FaultInjector(FaultPlan(backoff_jitter=0.0))
    assert nj.backoff_s(0, 2) == pytest.approx(0.5 * 4.0)


# ---------------- rate traces ----------------


def test_rate_trace_piecewise_finish_time():
    tr = RateTrace(kbps=(1000.0, 500.0), interval_s=1.0)
    # 1.4e6 bits from t=0: 1e6 in the first second, 0.4e6 at 500kbps = 0.8s
    assert tr.finish_time(0.0, 1.4e6) == pytest.approx(1.8)
    # starting mid-slice and wrapping the cyclic trace
    assert tr.rate_at(2.5) == 1000.0  # cycle repeats
    assert tr.finish_time(1.5, 0.25e6) == pytest.approx(2.0)
    assert tr.mean_kbps == pytest.approx(750.0)


def test_rate_trace_survives_zero_slices():
    tr = RateTrace(kbps=(0.0, 1000.0), interval_s=1.0)
    # nothing moves in the dead slice; the transfer completes in the next
    assert tr.finish_time(0.0, 0.5e6) == pytest.approx(1.5)
    with pytest.raises(ValueError):
        RateTrace(kbps=(0.0, 0.0))  # all-dead trace can never finish


def test_linkspec_from_trace_fixture():
    spec = LinkSpec.from_trace(_TRACE_FIXTURE)
    with open(_TRACE_FIXTURE) as f:
        raw = json.load(f)
    assert spec.up_trace is not None and spec.down_trace is not None
    assert spec.up_trace.kbps == tuple(float(x) for x in raw["up_kbps"])
    assert spec.up_trace.interval_s == raw["interval_s"]
    assert spec.prop_delay_s == raw["prop_delay_s"]
    # scalar rates fall back to the trace means (capacity planning reads
    # them), and the built links actually use the trace
    assert spec.up_kbps == pytest.approx(spec.up_trace.mean_kbps)
    net = ClientNetwork(spec)
    assert net.up.trace is spec.up_trace
    # the trace changes the transfer time vs the constant-rate model, and
    # identical links replay it identically
    t0 = net.up.transfer(0.0, 20_000)
    assert t0 == ClientNetwork(spec).up.transfer(0.0, 20_000)
    flat = ClientNetwork(LinkSpec(up_kbps=spec.up_kbps,
                                  down_kbps=spec.down_kbps,
                                  prop_delay_s=spec.prop_delay_s))
    assert t0 != flat.up.transfer(0.0, 20_000)
    # a dict works too
    spec2 = LinkSpec.from_trace(raw)
    assert spec2.up_trace == spec.up_trace


def test_rate_trace_phase_offsets():
    tr = RateTrace(kbps=(1000.0, 500.0), interval_s=1.0)
    # 0-offset keeps object identity: the unphased path is bit-identical
    assert tr.with_phase(0.0) is tr
    assert tr.with_phase(tr.period_s) is tr  # wraps modulo the period
    sh = tr.with_phase(1.0)
    assert sh.rate_at(0.0) == 500.0 and sh.rate_at(1.0) == 1000.0
    # finish_time walks in trace time but returns wall-clock time
    assert sh.finish_time(0.0, 0.5e6) == pytest.approx(1.0)
    assert sh.finish_time(1.0, 1.0e6) == pytest.approx(2.0)
    with pytest.raises(ValueError):
        RateTrace((1000.0,), 1.0, phase_s=-0.5)


def test_rate_trace_for_client_decorrelates_deterministically():
    tr = RateTrace(kbps=(1000.0, 0.0, 250.0, 800.0), interval_s=1.0)
    assert tr.for_client(7) == tr.for_client(7)  # stable across calls
    assert tr.for_client(7).kbps == tr.kbps  # samples untouched, only phase
    phases = {tr.for_client(c).phase_s for c in range(16)}
    assert all(0.0 <= p < tr.period_s for p in phases)
    assert len(phases) >= 14, "client phases collide far too often"
    # a phased replay conserves the cyclic integral: one full period of
    # bits drains in exactly one period wherever the cycle starts (strictly
    # positive rates — a start inside a zero slice legitimately finishes
    # early, at the boundary where the cumulative integral already closes)
    pos = RateTrace(kbps=(1000.0, 125.0, 250.0, 800.0), interval_s=1.0)
    total_bits = sum(r * 1e3 * pos.interval_s for r in pos.kbps)
    for c in (0, 3, 11):
        assert pos.for_client(c).finish_time(0.0, total_bits) == \
            pytest.approx(pos.period_s)


def test_linkspec_from_trace_client_phasing():
    raw = {"interval_s": 1.0, "up_kbps": [1000, 200],
           "down_kbps": [800, 80]}
    base = LinkSpec.from_trace(raw)
    assert base.up_trace.phase_s == 0.0  # default: bit-identical loader
    s7 = LinkSpec.from_trace(raw, client=7)
    assert s7.up_trace == base.up_trace.for_client(7)
    assert s7.down_trace == base.down_trace.for_client(7)
    assert s7.up_kbps == pytest.approx(base.up_kbps)  # mean is phase-free
    # a fixture's own phase_s is honored (and composes with the client's)
    shifted = LinkSpec.from_trace({**raw, "phase_s": 0.25})
    assert shifted.up_trace.phase_s == 0.25


def test_engine_trace_phase_per_client_wireup():
    tr = RateTrace(kbps=(900.0, 90.0), interval_s=1.0)
    plan = FaultPlan(up_rate_trace=tr, down_rate_trace=tr)
    cfg = dict(duration=1.0, max_queue=8, n_gpus=1, faults=plan)
    eng = ServingEngine(_fleet(4), cfg=ServingConfig(**cfg))
    # default: every link replays the SAME trace object (lock-step fleet)
    assert all(s.net.up.trace is tr and s.net.down.trace is tr
               for s in eng.sessions)
    eng = ServingEngine(_fleet(4), cfg=ServingConfig(
        **cfg, trace_phase_per_client=True))
    ups = [s.net.up.trace for s in eng.sessions]
    assert [u.phase_s for u in ups] == \
        [tr.for_client(s.idx).phase_s for s in eng.sessions]
    assert len({u.phase_s for u in ups}) == 4  # decorrelated
    assert all(u.kbps == tr.kbps for u in ups)


# ---------------- engine: fault-free identity ----------------


def test_armed_but_inert_plan_matches_fault_free_service():
    # chaos machinery on (watchdogs armed, counters live) but no fault ever
    # fires inside the horizon -> identical service-level outcome
    inert = FaultPlan(outages=(OutageWindow(start=1e9, end=1e9 + 1.0),))
    assert inert.active

    def run(faults=None):
        kw = {} if faults is None else {"faults": faults}
        return ServingEngine(_fleet(5), policy="gain",
                             cfg=ServingConfig(duration=90.0, **kw)).run()

    base, armed = run(), run(inert)
    for key in ("mean_miou", "miou_per_client", "phases_per_client",
                "phases_served", "dropped_requests", "migrations",
                "requests_enqueued", "requests_granted"):
        assert base[key] == armed[key], key
    assert armed["chaos"]["watchdog_fires"] == 0
    assert armed["chaos"]["grants_killed"] == 0
    assert _conserved(base) and _conserved(armed)


def test_none_plan_runs_are_byte_reproducible():
    def once():
        return _core(ServingEngine(
            _fleet(4), policy="gain",
            cfg=ServingConfig(duration=60.0, faults=FaultPlan.none())).run())

    assert once() == once()


# ---------------- engine: lossy links, retry, abandon ----------------


def test_lossy_uplink_retries_and_books_balance():
    plan = FaultPlan(seed=5, up_loss=0.35)
    r = ServingEngine(_fleet(4), policy="gain",
                      cfg=ServingConfig(duration=90.0, faults=plan)).run()
    ch = r["chaos"]
    assert ch["uploads_lost"] > 0
    assert ch["upload_retries"] > 0
    # with no outages, every lost upload either retried or was abandoned
    assert ch["upload_retries"] + ch["uploads_abandoned"] == ch["uploads_lost"]
    assert ch["upload_bytes_wasted"] > 0
    assert _conserved(r)
    assert all(p > 0 for p in r["phases_per_client"])  # degraded, not dead


def test_uplink_outage_defers_and_retries():
    plan = FaultPlan(outages=(OutageWindow(start=20.0, end=28.0,
                                           direction="up"),))
    r = ServingEngine(_fleet(3), policy="gain",
                      cfg=ServingConfig(duration=80.0, faults=plan)).run()
    ch = r["chaos"]
    assert ch["upload_retries"] > 0  # deferred sends count as retries
    assert ch["uploads_lost"] == 0  # outage defers, it does not burn bytes
    assert _conserved(r)
    assert all(p > 0 for p in r["phases_per_client"])


def test_total_loss_abandons_after_max_retries():
    # a client-specific permanent disconnect: every upload abandoned, the
    # other clients are untouched
    plan = FaultPlan(max_retries=2, disconnects=(
        OutageWindow(start=0.0, end=1e9, client=0),))
    r = ServingEngine(_fleet(3), policy="gain",
                      cfg=ServingConfig(duration=60.0, faults=plan)).run()
    ch = r["chaos"]
    assert ch["uploads_abandoned"] > 0
    assert r["dropped_frame_bytes"] > 0
    assert r["phases_per_client"][0] == 0  # off-air client trains nothing
    assert all(p > 0 for p in r["phases_per_client"][1:])
    assert _conserved(r)


def test_tail_drop_accounts_wasted_upload_bytes():
    # no chaos at all: a saturated queue tail-drops requests whose frames
    # already crossed the uplink — those bytes must land in
    # dropped_frame_bytes (the accounting fix, not a fault path)
    from repro.core.scheduler import GPUCostModel

    fleet = _fleet(12)
    cost = GPUCostModel(teacher_infer_s=0.3, train_iter_s=0.1)
    r = ServingEngine(fleet, policy="fair", cost=cost,
                      cfg=ServingConfig(duration=90.0, n_gpus=1,
                                        max_queue=2)).run()
    assert r["dropped_requests"] > 0
    assert r["dropped_frame_bytes"] > 0
    assert _conserved(r)


# ---------------- engine: supersede semantics ----------------


def test_downlink_outage_supersedes_stale_deltas():
    # outage longer than t_update (10s): by the time a deferred delta could
    # be retransmitted, a fresher one exists -> supersede, never resend
    plan = FaultPlan(outages=(OutageWindow(start=25.0, end=41.0,
                                           direction="down"),))
    r = ServingEngine(_fleet(3), policy="gain",
                      cfg=ServingConfig(duration=90.0, faults=plan)).run()
    ch = r["chaos"]
    assert ch["deltas_superseded"] > 0
    assert ch["superseded_bytes"] > 0
    assert ch["deltas_lost"] == 0  # outage defers; loss is a separate path
    assert _conserved(r)
    assert all(p > 0 for p in r["phases_per_client"])


def test_lossy_downlink_every_loss_resolves():
    plan = FaultPlan(seed=9, down_loss=0.3)
    r = ServingEngine(_fleet(4), policy="gain",
                      cfg=ServingConfig(duration=90.0, faults=plan)).run()
    ch = r["chaos"]
    assert ch["deltas_lost"] > 0
    assert (ch["deltas_retransmitted"] + ch["deltas_superseded"]
            + ch["deltas_abandoned"]) >= ch["deltas_lost"]
    assert _conserved(r)


# ---------------- engine: crash, watchdog, recovery ----------------


def test_crash_recovers_grants_on_survivor():
    from repro.core.scheduler import GPUCostModel

    # uploads land in 10s bursts, so the window starts mid-burst (t=22.5)
    # where a grant is guaranteed in flight on gid 1
    plan = FaultPlan(crashes=(CrashWindow(gid=1, start=22.5, end=48.0),))
    fleet = _fleet(12)
    # slow grants keep both devices busy through the burst
    cost = GPUCostModel(teacher_infer_s=0.05, train_iter_s=0.02)
    r = ServingEngine(fleet, policy="gain", cost=cost,
                      cfg=ServingConfig(duration=120.0, n_gpus=2,
                                        faults=plan)).run()
    ch = r["chaos"]
    assert ch["device_crashes"] == 1
    assert ch["grants_killed"] >= 1  # the pool was loaded when gid 1 died
    assert ch["grants_recovered"] == ch["grants_killed"]
    assert ch["watchdog_fires"] == ch["grants_recovered"]
    assert ch["sessions_recovered"] >= ch["grants_recovered"]
    assert ch["crash_spills"] >= 1  # residency on the dead device is gone
    assert _conserved(r)
    # zero lost sessions: everyone still trains and evaluates
    assert all(p > 0 for p in r["phases_per_client"])
    assert len(r["miou_per_client"]) == len(fleet)


def test_whole_pool_dead_sheds_at_admission():
    plan = FaultPlan(crashes=(CrashWindow(gid=0, start=20.0, end=45.0),))
    r = ServingEngine(_fleet(4), policy="gain",
                      cfg=ServingConfig(duration=90.0, n_gpus=1,
                                        faults=plan)).run()
    ch = r["chaos"]
    assert ch["device_crashes"] == 1
    assert ch["requests_shed"] > 0  # nothing alive to queue behind
    assert r["dropped_requests"] >= ch["requests_shed"]
    assert _conserved(r)
    # the fleet recovers once the device rejoins
    assert all(p > 0 for p in r["phases_per_client"])


def test_crash_runs_are_deterministic():
    plan = FaultPlan.reference(120.0, n_gpus=2)

    def once():
        return _core(ServingEngine(
            _fleet(6), policy="gain",
            cfg=ServingConfig(duration=120.0, n_gpus=2,
                              faults=plan)).run())

    assert once() == once()


# ---------------- property: any plan conserves + reproduces ----------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       up_loss=st.floats(min_value=0.0, max_value=0.4),
       down_loss=st.floats(min_value=0.0, max_value=0.4),
       n_gpus=st.sampled_from((1, 2)),
       n=st.sampled_from((3, 5)),
       with_outage=st.booleans(),
       with_crash=st.booleans())
def test_random_plans_terminate_conserve_and_reproduce(
        seed, up_loss, down_loss, n_gpus, n, with_outage, with_crash):
    plan = FaultPlan(
        seed=seed, up_loss=up_loss, down_loss=down_loss,
        outages=((OutageWindow(start=10.0, end=18.0),) if with_outage
                 else ()),
        crashes=((CrashWindow(gid=n_gpus - 1, start=15.0, end=25.0),)
                 if with_crash else ()))
    def once():
        return _core(ServingEngine(
            _fleet(n), policy="gain",
            cfg=ServingConfig(duration=40.0, n_gpus=n_gpus,
                              faults=plan)).run())

    a, b = once(), once()
    assert a == b  # byte-identical replay of the same seeded plan
    assert _conserved(a)
    ch = a["chaos"]
    assert ch["grants_recovered"] == ch["grants_killed"]
    assert (ch["deltas_retransmitted"] + ch["deltas_superseded"]
            + ch["deltas_abandoned"]) >= ch["deltas_lost"]
    assert len(a["miou_per_client"]) == n
