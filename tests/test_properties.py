"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # hypothesis, or a fallback when absent

from repro.models.layers import apply_rope, rms_norm, softcap


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 999), pos0=st.integers(0, 10_000))
def test_rope_preserves_norm(seed, pos0):
    """Rotary embedding is a rotation: per-head vector norms are invariant."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(1, 4, 2, 8)), jnp.float32)
    pos = jnp.full((1, 4), pos0, jnp.int32) + jnp.arange(4)
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 999), cap=st.floats(1.0, 100.0))
def test_softcap_bounded_and_monotone(seed, cap):
    r = np.random.default_rng(seed)
    x = jnp.asarray(np.sort(r.normal(scale=50, size=64)), jnp.float32)
    y = np.asarray(softcap(x, cap))
    assert np.all(np.abs(y) <= cap + 1e-4)
    assert np.all(np.diff(y) >= -1e-5)  # monotone


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 999))
def test_rmsnorm_scale_invariance(seed):
    """rms_norm(c*x) == rms_norm(x) for any positive scalar c."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(2, 16)), jnp.float32)
    w = jnp.zeros(16)
    a = np.asarray(rms_norm(x, w))
    b = np.asarray(rms_norm(7.3 * x, w))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 200), t=st.floats(0.0, 500.0))
def test_video_mask_classes_in_range(seed, t):
    from repro.data.video import SyntheticVideo, VideoConfig

    v = SyntheticVideo(VideoConfig(height=24, width=24, seed=seed))
    img, mask = v.frame(int(t * v.cfg.fps) % v.cfg.n_frames)
    assert img.min() >= 0.0 and img.max() <= 1.0
    assert mask.min() >= 0 and mask.max() < v.cfg.n_classes


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), frac=st.floats(0.01, 0.5))
def test_masked_adam_invariant_unmasked_frozen(seed, frac):
    """For ANY mask, unmasked coordinates never move (Alg. 2 line 13)."""
    from repro.core.masked_adam import init_state, masked_adam_update

    r = np.random.default_rng(seed)
    p = {"w": jnp.asarray(r.normal(size=200), jnp.float32)}
    g = {"w": jnp.asarray(r.normal(size=200), jnp.float32)}
    mask = {"w": jnp.asarray(r.uniform(size=200) < frac)}
    p2, _, _ = masked_adam_update(p, g, init_state(p), mask)
    frozen = ~np.asarray(mask["w"])
    np.testing.assert_array_equal(np.asarray(p2["w"])[frozen],
                                  np.asarray(p["w"])[frozen])
