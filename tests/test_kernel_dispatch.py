"""Kernel dispatch (`core.kernel_dispatch`) and Pallas-vs-XLA equivalence
for the serving hot-path kernels.

Contract under test (interpret mode, CPU gating set):
  * fused masked-Adam (`kernels.masked_adam.ops.masked_adam_stacked`)
    matches ``vmap(core.masked_adam.masked_adam_update)`` to float32
    rounding across dtypes and non-lane-multiple shapes (byte identity of
    the raw f32 moments is NOT promised: XLA:CPU's context-dependent FMA
    contraction moves single ULPs between compilation contexts — it makes
    even the XLA path differ jit-vs-nojit);
  * bit-pattern top-k (`kernels.topk_mask`) produces BYTE-IDENTICAL masks
    to both the XLA counting search and the solo sort path, including
    negatives, ties, denormals, and all-zero updates;
  * the dispatch layer (`batched.set_kernel_mode`) validates modes, races
    ``auto`` once per (backend, compile key), caches the winner, and
    reports decisions through `serving.obs.debug_snapshot`.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched, kernel_dispatch, selection
from repro.core.masked_adam import init_state, masked_adam_update
from repro.kernels import interpret_default, resolve_interpret
from repro.kernels.masked_adam.ops import masked_adam_stacked
from repro.kernels.topk_mask import stacked_topk_masks
from repro.kernels.topk_mask.ref import (topk_threshold_bits_ref,
                                         topk_threshold_sort_ref)


@pytest.fixture(autouse=True)
def _clean_dispatch():
    kernel_dispatch.reset()  # mode back to "xla", race table cleared
    selection.stacked_cache_clear()
    yield
    kernel_dispatch.reset()
    selection.stacked_cache_clear()


def _assert_close(a, b, tol=2e-6):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float64),
                                   np.asarray(y, np.float64),
                                   rtol=tol, atol=tol)


def _masks_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# fused masked-Adam vs vmapped tree_map reference
# ---------------------------------------------------------------------------

# deliberately awkward shapes: nothing is a multiple of the 128-lane tile
# or the 512-row block, one leaf is smaller than a single lane row
_SHAPES = ((37, 5), (130,), (511,), (3,))


def _adam_fixture(b=3, dtypes=None, seed=0):
    rng = np.random.default_rng(seed)
    dtypes = dtypes or ["float32"] * len(_SHAPES)
    trees, grads, masks = [], [], []
    for _ in range(b):
        t, g, m = {}, {}, {}
        for j, (shape, dt) in enumerate(zip(_SHAPES, dtypes)):
            t[f"l{j}"] = jnp.asarray(rng.normal(size=shape), dt)
            g[f"l{j}"] = jnp.asarray(rng.normal(size=shape), jnp.float32)
            m[f"l{j}"] = jnp.asarray(rng.integers(0, 2, shape), bool)
        trees.append(t)
        grads.append(g)
        masks.append(m)
    return (batched.stack_trees(trees), batched.stack_trees(grads),
            batched.stack_trees([init_state(t) for t in trees]),
            batched.stack_trees(masks))


def _xla_adam(p, g, st, m, **hp):
    return jax.vmap(lambda p_, g_, s_, m_: masked_adam_update(
        p_, g_, s_, m_, **hp))(p, g, st, m)


@pytest.mark.parametrize("seed", [0, 1])
def test_masked_adam_stacked_matches_xla(seed):
    hp = dict(lr=2e-3, b1=0.9, b2=0.999, eps=1e-8)
    p, g, st, m = _adam_fixture(seed=seed)
    px, sx, ux = _xla_adam(p, g, st, m, **hp)
    pp, sp, up = masked_adam_stacked(p, g, st, m, **hp)
    _assert_close(px, pp)
    _assert_close(ux, up)
    _assert_close(sx.m, sp.m)
    _assert_close(sx.v, sp.v)
    assert np.array_equal(np.asarray(sx.count), np.asarray(sp.count))
    # masked coordinates must not move, bit for bit — the mask application
    # is p - u*mask with mask 0.0, which is exact in both engines
    for lp, lx, lm in zip(jax.tree.leaves(pp), jax.tree.leaves(p),
                          jax.tree.leaves(m)):
        frozen = ~np.asarray(lm)
        assert np.array_equal(np.asarray(lp)[frozen],
                              np.asarray(lx)[frozen])


def test_masked_adam_stacked_mixed_dtypes():
    """bf16 + f32 param leaves split into per-dtype kernel launches; the
    bf16 cast after f32 arithmetic tolerates the FMA ULP wobble."""
    hp = dict(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8)
    dtypes = ["bfloat16", "float32", "bfloat16", "float32"]
    p, g, st, m = _adam_fixture(dtypes=dtypes, seed=2)
    px, sx, ux = _xla_adam(p, g, st, m, **hp)
    pp, sp, up = masked_adam_stacked(p, g, st, m, **hp)
    for lx, lp in zip(jax.tree.leaves(px), jax.tree.leaves(pp)):
        assert lx.dtype == lp.dtype
        tol = 1e-2 if lx.dtype == jnp.bfloat16 else 2e-6
        np.testing.assert_allclose(np.asarray(lx, np.float64),
                                   np.asarray(lp, np.float64),
                                   rtol=tol, atol=tol)
    _assert_close(ux, up)  # u is always f32
    _assert_close(sx.v, sp.v)


def test_masked_adam_stacked_per_session_counts():
    """Sessions in one stack at different Adam step counts each get their
    own bias correction (the (B,) count -> per-session grid scalar)."""
    hp = dict(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8)
    p, g, st, m = _adam_fixture(seed=3)
    st = type(st)(st.m, st.v, jnp.asarray([0, 5, 40]))
    px, sx, ux = _xla_adam(p, g, st, m, **hp)
    pp, sp, up = masked_adam_stacked(p, g, st, m, **hp)
    _assert_close(px, pp)
    _assert_close(ux, up)
    assert np.array_equal(np.asarray(sp.count), np.asarray([1, 6, 41]))


def test_masked_adam_stacked_under_jit_and_grad_context():
    """Traceable inside a jitted closure (the phase-executable context)."""
    hp = dict(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8)
    p, g, st, m = _adam_fixture(seed=4)

    @jax.jit
    def step(p, g, st, m):
        return masked_adam_stacked(p, g, st, m, **hp)

    pj, sj, uj = step(p, g, st, m)
    pe, se, ue = masked_adam_stacked(p, g, st, m, **hp)
    _assert_close(pj, pe)
    _assert_close(uj, ue)


# ---------------------------------------------------------------------------
# bit-pattern top-k vs sort-path / counting-search references
# ---------------------------------------------------------------------------


def _u_case(case: str, rng, b=3):
    shapes = ((57, 7), (301,))

    def leaf(shape):
        n = int(np.prod(shape))
        if case == "mixed":
            x = rng.normal(size=n)
        elif case == "negatives":
            x = -np.abs(rng.normal(size=n)) - 0.1
        elif case == "ties":
            x = rng.choice([0.5, -0.5, 2.0, -2.0, 0.0], size=n)
        elif case == "denormals":
            x = rng.normal(size=n) * 1e-41  # subnormal f32 magnitudes
        elif case == "zeros":
            x = np.zeros(n)
        else:
            raise ValueError(case)
        return jnp.asarray(x.reshape(shape), jnp.float32)

    return [{"a": leaf(shapes[0]), "b": leaf(shapes[1])} for _ in range(b)]


@pytest.mark.parametrize("case", ["mixed", "negatives", "ties", "denormals",
                                  "zeros"])
def test_stacked_topk_masks_byte_identical(case):
    rng = np.random.default_rng(5)
    frac = 0.07
    trees = _u_case(case, rng)
    stacked = batched.stack_trees(trees)
    mp = stacked_topk_masks(stacked, frac=frac)
    # vs the XLA counting search the serving path vmaps
    mx = jax.jit(jax.vmap(functools.partial(
        selection._bitwise_topk_body, frac=frac)))(stacked)
    assert _masks_equal(mp, mx), f"pallas mask != XLA counting mask ({case})"
    # vs each session's SOLO sort-path mask (the original per-session API)
    for i, t in enumerate(trees):
        solo = selection.gradient_guided_mask(t, frac)
        mine = jax.tree.map(lambda l: l[i], mp)
        assert _masks_equal(solo, mine), f"session {i} mask drifted ({case})"


def test_topk_threshold_is_exact_sort_value():
    rng = np.random.default_rng(6)
    trees = _u_case("mixed", rng, b=1)
    leaves = jax.tree.leaves(trees[0])
    n = sum(int(np.prod(l.shape)) for l in leaves)
    k = max(int(0.05 * n), 1)
    bits = topk_threshold_bits_ref(leaves, k)
    thr = float(jax.lax.bitcast_convert_type(bits, jnp.float32))
    assert thr == topk_threshold_sort_ref(leaves, k)


# ---------------------------------------------------------------------------
# dispatch layer
# ---------------------------------------------------------------------------


def test_set_kernel_mode_validates():
    with pytest.raises(ValueError):
        batched.set_kernel_mode("cuda")
    batched.set_kernel_mode("pallas")
    assert kernel_dispatch.kernel_mode() == "pallas"


def test_forced_pallas_selection_byte_identical_to_xla():
    rng = np.random.default_rng(7)
    u = {"w": jnp.asarray(rng.normal(size=(2, 2048)), jnp.float32)}
    mx = selection.stacked_gradient_guided_masks(u, 0.1)
    selection.stacked_cache_clear()
    batched.set_kernel_mode("pallas")
    mp = selection.stacked_gradient_guided_masks(u, 0.1)
    assert _masks_equal(mx, mp)


def test_auto_race_runs_once_and_caches_winner():
    rng = np.random.default_rng(8)
    u = {"w": jnp.asarray(rng.normal(size=(2, 2048)), jnp.float32)}
    batched.set_kernel_mode("auto")
    m1 = selection.stacked_gradient_guided_masks(u, 0.1)
    races = kernel_dispatch.auto_info()
    assert len(races) == 1
    (site, backend, _key), entry = next(iter(races.items()))
    assert site == "select_stacked" and backend == jax.default_backend()
    assert entry["winner"] in ("xla", "pallas")
    assert set(entry["times"]) == {"xla", "pallas"}
    assert all(t > 0 for t in entry["times"].values())
    # the race was one miss; the next call is a plain hit on the winner
    info0 = selection.stacked_cache_info()
    assert info0["misses"] == 1 and info0["hits"] == 0
    m2 = selection.stacked_gradient_guided_masks(u, 0.1)
    info1 = selection.stacked_cache_info()
    assert info1["hits"] == 1 and info1["misses"] == 1
    assert len(kernel_dispatch.auto_info()) == 1  # no re-race
    assert _masks_equal(m1, m2)


def test_kernel_dispatch_info_is_json_friendly():
    import json

    kernel_dispatch.record_auto("select_stacked", "cpu", ("k", 1), "pallas",
                                {"xla": 0.2, "pallas": 0.1})
    info = kernel_dispatch.kernel_dispatch_info()
    assert info["mode"] == "xla"
    json.dumps(info)  # must not raise
    (label, entry), = info["auto_races"].items()
    assert label.startswith("select_stacked:cpu:")
    assert entry["winner"] == "pallas"


def test_debug_snapshot_reports_kernel_dispatch():
    from repro.serving import debug_snapshot

    batched.set_kernel_mode("pallas")
    kernel_dispatch.record_auto("train_fused", "cpu", ("k",), "xla",
                                {"xla": 0.1, "pallas": 0.3})
    snap = debug_snapshot()
    assert snap["kernel_dispatch"]["mode"] == "pallas"
    assert len(snap["kernel_dispatch"]["auto_races"]) == 1


# ---------------------------------------------------------------------------
# fused phase executable: pallas kernel inside the compiled phase
# ---------------------------------------------------------------------------


def _toy_loss_and_grad(p, f, l):
    def loss_fn(p):
        pred = f @ p["w"] + p["b"]
        return jnp.mean((pred - l) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(p)
    return loss, grads


@pytest.mark.parametrize("mode", ["loop", "scan"])
def test_build_phase_fn_pallas_matches_xla(mode):
    rng = np.random.default_rng(9)
    b, k, batch, din, dout = 2, 3, 4, 7, 3
    params = batched.stack_trees([
        {"w": jnp.asarray(rng.normal(size=(din, dout)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(dout,)), jnp.float32)}
        for _ in range(b)])
    opt = batched.stack_trees([init_state(
        {"w": jnp.zeros((din, dout)), "b": jnp.zeros((dout,))})
        for _ in range(b)])
    mask = jax.tree.map(lambda x: jnp.asarray(
        rng.integers(0, 2, x.shape), bool), params)
    frames = jnp.asarray(rng.normal(size=(k, b, batch, din)), jnp.float32)
    labels = jnp.asarray(rng.normal(size=(k, b, batch, dout)), jnp.float32)
    hp = dict(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, momentum=0.0)
    outs = {}
    for kern in ("xla", "pallas"):
        fn = batched._build_phase_fn(_toy_loss_and_grad, "adam", hp["lr"],
                                     hp["b1"], hp["b2"], hp["eps"],
                                     hp["momentum"], mode, kern)
        outs[kern] = fn(params, opt, mask, frames, labels)
    px, ox, ux, lx = outs["xla"]
    pp, op, up, lp = outs["pallas"]
    _assert_close(px, pp, tol=5e-6)
    _assert_close(ux, up, tol=5e-6)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp), rtol=5e-6)
    assert np.array_equal(np.asarray(ox.count), np.asarray(op.count))
    # frozen coordinates are bit-frozen through the whole phase
    for l_p, l_x, l_m in zip(jax.tree.leaves(pp), jax.tree.leaves(params),
                             jax.tree.leaves(mask)):
        frozen = ~np.asarray(l_m)
        assert np.array_equal(np.asarray(l_p)[frozen],
                              np.asarray(l_x)[frozen])


# ---------------------------------------------------------------------------
# backend-aware interpret default
# ---------------------------------------------------------------------------


def test_interpret_default_backend_and_env(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert interpret_default() == (jax.default_backend() == "cpu")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert interpret_default() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert interpret_default() is True
    assert resolve_interpret(None) is True
    assert resolve_interpret(False) is False
    assert resolve_interpret(True) is True
