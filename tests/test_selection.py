"""Coordinate-selection strategies (paper §3.1.2 / Table 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a fallback when absent

from repro.core import selection


def _tree(rng, sizes=(1000, 333, 64)):
    return {f"l{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
            for i, s in enumerate(sizes)}


@pytest.mark.parametrize("frac", [0.01, 0.05, 0.2, 0.5])
def test_gradient_guided_fraction(rng, frac):
    tree = _tree(rng)
    mask = selection.gradient_guided_mask(tree, frac)
    assert selection.mask_fraction(mask) == pytest.approx(frac, rel=0.1, abs=0.01)


def test_gradient_guided_picks_largest(rng):
    tree = {"a": jnp.asarray(np.arange(100, dtype=np.float32))}
    mask = selection.gradient_guided_mask(tree, 0.1)
    idx = np.nonzero(np.asarray(mask["a"]))[0]
    assert set(idx) == set(range(90, 100))


def test_bisect_matches_sort_threshold(rng):
    tree = _tree(rng, sizes=(5000, 2000))
    thr = float(selection.global_threshold(tree, 0.07))
    flat = np.abs(np.concatenate([np.ravel(l) for l in jax.tree.leaves(tree)]))
    exact = np.sort(flat)[int((1 - 0.07) * flat.size)]
    assert thr == pytest.approx(exact, rel=0.01)


@pytest.mark.parametrize("strategy", ["random", "first", "last", "first_last"])
def test_ablation_strategies_fraction(rng, strategy):
    tree = _tree(rng)
    mask = selection.make_mask(strategy, params=tree, frac=0.1,
                               rng=jax.random.PRNGKey(0))
    assert selection.mask_fraction(mask) == pytest.approx(0.1, rel=0.15, abs=0.02)


def test_first_vs_last_disjoint_at_small_frac(rng):
    tree = _tree(rng)
    f = selection.first_layers_mask(tree, 0.2)
    l = selection.last_layers_mask(tree, 0.2)
    overlap = sum(int(jnp.sum(a & b)) for a, b in zip(jax.tree.leaves(f),
                                                      jax.tree.leaves(l)))
    assert overlap == 0


@settings(max_examples=20, deadline=None)
@given(frac=st.floats(0.01, 0.9), seed=st.integers(0, 1000))
def test_property_mask_fraction(frac, seed):
    rng = np.random.default_rng(seed)
    tree = _tree(rng, sizes=(700, 411))
    mask = selection.gradient_guided_mask(tree, frac)
    got = selection.mask_fraction(mask)
    assert abs(got - frac) < 0.05 + 0.1 * frac
