"""Checkpointing, token streams, codec cost models, bandwidth ledger."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.core.bandwidth import BandwidthLedger
from repro.data import codec
from repro.data.tokens import StreamConfig, TokenStream


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16), "d": [jnp.zeros(2), jnp.ones(1)]}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        checkpoint.save(path, tree)
        got = checkpoint.load(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_token_stream_drifts():
    s = TokenStream(StreamConfig(vocab_size=64, seed=3, drift_period=100.0))
    r = np.random.default_rng(0)
    a = s.sample(r, batch=4, seq=128, t=0.0)
    assert a.shape == (4, 129)
    assert a.min() >= 0 and a.max() < 64
    # distribution drifts: unigram histograms at opposite drift phases
    # (sin peaks: t = T/4 vs 3T/4) differ
    r1, r2 = np.random.default_rng(1), np.random.default_rng(1)
    h0 = np.bincount(s.sample(r1, 8, 256, 25.0).ravel(), minlength=64)
    h1 = np.bincount(s.sample(r2, 8, 256, 75.0).ravel(), minlength=64)
    h0 = h0 / h0.sum()
    h1 = h1 / h1.sum()
    assert np.abs(h0 - h1).sum() > 0.1


def test_codec_monotonic():
    px = 64 * 64
    assert codec.jpeg_bytes(px) > 0
    one = codec.h264_buffer_bytes(1, px, 10.0)
    many = codec.h264_buffer_bytes(10, px, 10.0)
    assert one <= many or many == codec.h264_buffer_bytes(10, px, 10.0)
    # buffered H.264 beats per-frame JPEG at the same frame count
    assert codec.h264_buffer_bytes(10, px, 10.0) < 10 * codec.jpeg_bytes(px)


def test_bandwidth_ledger():
    led = BandwidthLedger()
    led.uplink(1000, 0.0)
    led.downlink(4000, 1.0)
    up, down = led.kbps(8.0)
    assert up == pytest.approx(1.0)
    assert down == pytest.approx(4.0)
