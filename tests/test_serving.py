"""Event-driven serving runtime: queue ordering, link math, scheduler
fairness, the GPU pool (residency, migration, work conservation), admission
parking, and the `run_multiclient` compatibility shim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a fallback when absent

from repro.core.client import EdgeClient
from repro.core.delta import encode_delta
from repro.core.scheduler import GPUCostModel, RoundRobinScheduler
from repro.serving import (
    ClientNetwork,
    EventQueue,
    GPUPool,
    GPURequest,
    LinkSpec,
    MigrationModel,
    ServingConfig,
    ServingEngine,
    StubSession,
    make_policy,
)
from repro.serving.network import Link


# ---------------- event queue ----------------


def test_event_queue_time_order():
    q = EventQueue()
    q.push(3.0, "c")
    q.push(1.0, "a")
    q.push(2.0, "b")
    assert [q.pop().kind for _ in range(3)] == ["a", "b", "c"]


def test_event_queue_fifo_at_equal_times():
    q = EventQueue()
    for i in range(50):
        q.push(7.0, "k", client=i)
    assert [q.pop().client for _ in range(50)] == list(range(50))


def test_event_queue_interleaved_deterministic():
    def drain(order):
        q = EventQueue()
        for t, c in order:
            q.push(t, "k", client=c)
        return [(e.time, e.client) for e in (q.pop() for _ in range(len(order)))]

    order = [(2.0, 0), (1.0, 1), (2.0, 2), (1.0, 3), (0.5, 4)]
    a = drain(order)
    b = drain(order)
    assert a == b == [(0.5, 4), (1.0, 1), (1.0, 3), (2.0, 0), (2.0, 2)]


# ---------------- network model ----------------


def test_link_occupancy_math():
    # 300 Kbps link: 37500 bytes = 300 Kbit -> exactly 1 s on the wire
    link = Link(rate_kbps=300.0, prop_delay_s=0.05)
    assert link.tx_seconds(37_500) == pytest.approx(1.0)
    assert link.transfer(0.0, 37_500) == pytest.approx(1.05)
    # a second transfer queued behind the first: serialized, not parallel
    assert link.transfer(0.0, 37_500) == pytest.approx(2.05)
    # after the link drains, a later send starts immediately
    assert link.transfer(10.0, 37_500) == pytest.approx(11.05)


def test_client_network_feeds_ledger():
    net = ClientNetwork(LinkSpec(up_kbps=300.0, down_kbps=600.0,
                                 prop_delay_s=0.0))
    net.send_up(0.0, 37_500)
    net.send_down(0.0, 37_500)
    up, down = net.kbps(10.0)
    assert up == pytest.approx(30.0)
    assert down == pytest.approx(30.0)


def test_zero_rate_link_is_instant():
    link = Link(rate_kbps=0.0, prop_delay_s=0.01)
    assert link.transfer(5.0, 10**9) == pytest.approx(5.01)


# ---------------- core round-robin turn order ----------------


def test_round_robin_turn_rotates_despite_poll_order():
    """Client 0 polls first every tick; with turn ordering it must NOT win
    every grant (the seed bug): grants rotate 0,1,2,0,1,2..."""
    s = RoundRobinScheduler(cost=GPUCostModel(teacher_infer_s=0.0,
                                              train_iter_s=0.0))
    grants = []
    for tick in range(9):
        t = float(tick)
        for c in range(3):
            if s.try_acquire(t, 1, 1, client=c):
                grants.append(c)
    assert grants[:6] == [0, 1, 2, 0, 1, 2]
    assert s.served == len(grants)


def test_round_robin_skips_absent_clients():
    s = RoundRobinScheduler(cost=GPUCostModel(teacher_infer_s=0.0,
                                              train_iter_s=0.0), n_clients=4)
    # only clients 1 and 3 ever ask; neither starves, the ring skips 0 and 2
    grants = [c for t in range(8) for c in (1, 3)
              if s.try_acquire(float(t), 1, 1, client=c)]
    assert set(grants) == {1, 3}
    assert abs(grants.count(1) - grants.count(3)) <= 1


def test_round_robin_expires_abandoned_waiters():
    """A client that deferred once and then vanished must not hold the ring
    (grants would otherwise deadlock with an idle GPU)."""
    s = RoundRobinScheduler(cost=GPUCostModel(teacher_infer_s=0.0,
                                              train_iter_s=0.5),
                            waiting_timeout=5.0)
    assert s.try_acquire(0.0, 0, 2, client=0)  # GPU busy until t=1.0
    assert not s.try_acquire(0.5, 0, 2, client=1)  # deferred, then vanishes
    # turn points at 1; while its entry is alive, 0 must wait its turn
    assert not s.try_acquire(2.0, 0, 2, client=0)
    # after waiting_timeout with no re-poll from 1, the ring moves on
    assert s.try_acquire(10.0, 0, 2, client=0)


def test_round_robin_legacy_path_unchanged():
    s = RoundRobinScheduler(cost=GPUCostModel(teacher_infer_s=0.2,
                                              train_iter_s=0.05))
    assert s.try_acquire(0.0, n_frames=4, k_iters=20)
    assert s.gpu_free_at == pytest.approx(1.8)
    assert not s.try_acquire(1.0, 1, 20)
    assert s.deferred == 1


# ---------------- policies ----------------


def _req(client, t_request=0.0, deadline=10.0, phi=1.0, t_update=10.0):
    return GPURequest(client=client, t_request=t_request, n_frames=4,
                      k_iters=20, deadline=deadline, phi=phi,
                      t_update=t_update)


def test_edf_picks_earliest_deadline():
    p = make_policy("edf")
    ready = [_req(0, deadline=30.0), _req(1, deadline=10.0),
             _req(2, deadline=20.0)]
    assert p.pick(0.0, ready).client == 1


def test_gain_prefers_dynamic_but_staleness_backstops():
    p = make_policy("gain")
    dynamic = _req(0, t_request=5.0, phi=1.0)
    static = _req(1, t_request=5.0, phi=0.1)
    assert p.pick(5.0, [dynamic, static]).client == 0
    # after waiting long enough, the near-static session outranks a fresh
    # dynamic request — no starvation
    stale_static = _req(1, t_request=0.0, phi=0.1)
    fresh_dynamic = _req(0, t_request=60.0, phi=1.0)
    assert p.pick(60.0, [fresh_dynamic, stale_static]).client == 1


def test_gain_evicts_lowest_value_not_newest():
    p = make_policy("gain")
    static_queued = _req(1, t_request=10.0, phi=0.05)
    dynamic_queued = _req(0, t_request=10.0, phi=1.5)
    dynamic_arrival = _req(2, t_request=11.0, phi=1.5)
    victim = p.evict(11.0, [dynamic_queued, static_queued, dynamic_arrival])
    assert victim.client == 1
    # default policies tail-drop the newest arrival instead
    assert make_policy("fair").evict(
        11.0, [dynamic_queued, static_queued, dynamic_arrival]).client == 2


def test_fair_policy_rotates():
    p = make_policy("fair")
    ready = [_req(c) for c in range(3)]
    picks = [p.pick(0.0, ready).client for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_unknown_policy_raises():
    with pytest.raises(ValueError):
        make_policy("lifo")


# ---------------- engine on stub sessions ----------------


def _stub_fleet(n, **kw):
    return [StubSession(i, net=ClientNetwork(LinkSpec(up_kbps=500.0,
                                                      down_kbps=1000.0)), **kw)
            for i in range(n)]


def test_engine_fairness_no_client_starves():
    # unsaturated GPU: fair round-robin must serve everyone nearly equally
    fleet = _stub_fleet(6)
    cost = GPUCostModel(teacher_infer_s=0.05, train_iter_s=0.02)
    r = ServingEngine(fleet, policy="fair", cost=cost,
                      cfg=ServingConfig(duration=120.0)).run()
    assert all(p > 0 for p in r["phases_per_client"])
    assert max(r["phases_per_client"]) - min(r["phases_per_client"]) <= 1


def test_engine_gain_no_client_starves_under_saturation():
    fleet = [StubSession(i, rate=0.15 if i < 2 else 1.0,
                         net=ClientNetwork(LinkSpec()))
             for i in range(8)]
    r = ServingEngine(fleet, policy="gain",
                      cfg=ServingConfig(duration=240.0)).run()
    assert all(p > 0 for p in r["phases_per_client"])


def test_engine_deterministic():
    def once():
        r = ServingEngine(_stub_fleet(5), policy="gain",
                          cfg=ServingConfig(duration=90.0)).run()
        return {k: v for k, v in r.items()
                if k not in ("wall_s", "events_per_sec",
                             "events_per_sec_steady")}

    assert once() == once()


def test_engine_nonzero_delta_latency_and_kbps():
    r = ServingEngine(_stub_fleet(3), policy="fair",
                      cfg=ServingConfig(duration=60.0)).run()
    assert r["delta_latency_mean_s"] > 0.0
    assert r["mean_up_kbps"] > 0.0 and r["mean_down_kbps"] > 0.0


def test_engine_admission_control_caps_load():
    fleet = _stub_fleet(8)
    r = ServingEngine(fleet, policy="fair",
                      cfg=ServingConfig(duration=60.0,
                                        admission_util_cap=0.5)).run()
    assert 0 < r["admitted_clients"] < 8
    rejected = [s for s in fleet if not s.admitted]
    assert rejected and all(s.phases == 0 for s in rejected)


def test_engine_saturation_drops_requests():
    fleet = _stub_fleet(12)
    r = ServingEngine(fleet, policy="fair",
                      cfg=ServingConfig(duration=120.0, max_queue=4)).run()
    assert r["dropped_requests"] > 0
    assert r["max_backlog"] <= 4


# ---------------- GPU pool: residency + migration ----------------


def test_pool_double_booking_raises():
    pool = GPUPool(2)
    pool.grant(0, client=0, t=0.0, dur_s=1.0, horizon_s=10.0)
    with pytest.raises(RuntimeError, match="double-booked"):
        pool.grant(0, client=1, t=0.5, dur_s=1.0, horizon_s=10.0)
    pool.grant(1, client=1, t=0.5, dur_s=1.0, horizon_s=10.0)  # other dev ok
    assert pool.free_ids() == []
    pool.release(0)
    assert pool.free_ids() == [0]


def test_pool_migration_first_touch_free_then_charged():
    pool = GPUPool(2, migration=MigrationModel(gbps=1.0, setup_s=0.5))
    nb = 10 ** 9  # 8 Gbit over a 1 Gbps interconnect = 8 s + setup
    assert pool.migration_s(7, 0, nb) == 0.0  # first touch: staged at admit
    pool.grant(0, client=7, t=0.0, dur_s=1.0, horizon_s=100.0)
    assert pool.is_resident(7, 0)
    assert pool.migration_s(7, 0, nb) == 0.0  # warm on home
    assert pool.migration_s(7, 1, nb) == pytest.approx(8.5)  # foreign device
    pool.release(0)
    # moving the grant re-homes the session and counts the migration
    mig = pool.migration_s(7, 1, nb)
    pool.grant(1, client=7, t=2.0, dur_s=1.0, horizon_s=100.0, mig_s=mig)
    assert pool.home_of(7) == 1 and pool.migrations == 1
    assert pool.migration_s_total == pytest.approx(8.5)


def test_pool_residency_cap_spills_lru():
    pool = GPUPool(1, residency_cap=1,
                   migration=MigrationModel(gbps=1.0, setup_s=0.1))
    pool.grant(0, client=0, t=0.0, dur_s=1.0, horizon_s=50.0)
    pool.release(0)
    pool.grant(0, client=1, t=2.0, dur_s=1.0, horizon_s=50.0)
    pool.release(0)
    assert pool.evictions == 1  # client 0 spilled to host
    assert not pool.is_resident(0, 0)
    assert pool.migration_s(0, 0, 10 ** 9) > 0.0  # restage even on old home


def test_pool_busy_accounting_clips_at_horizon():
    pool = GPUPool(1)
    pool.grant(0, client=0, t=9.0, dur_s=5.0, horizon_s=10.0)
    assert pool.device(0).busy_s == pytest.approx(1.0)  # in-window part only


# ---------------- (session, gpu) assignment ----------------


def test_fair_pick_independent_of_queue_arrival_order():
    # two queued requests from the same client: the oldest must win no
    # matter how the queue happens to be ordered (multi-GPU reproducibility)
    old, new = _req(1, t_request=1.0), _req(1, t_request=5.0)
    other = _req(0, t_request=2.0)
    for ready in ([other, old, new], [new, other, old], [old, new, other]):
        p = make_policy("fair")
        p.turn = 1
        assert p.pick(10.0, list(ready)) is old


def test_assign_maps_queue_onto_free_devices():
    pool = GPUPool(4)
    p = make_policy("fair")
    ready = [_req(c) for c in range(3)]
    got = p.assign(0.0, ready, [0, 1, 2, 3], pool)
    assert [(a.req.client, a.gpu) for a in got] == [(0, 0), (1, 1), (2, 2)]
    # more requests than devices: only the free ones are handed out
    p2 = make_policy("fair")
    got = p2.assign(0.0, [_req(c) for c in range(5)], [2, 3], pool)
    assert [(a.req.client, a.gpu) for a in got] == [(0, 2), (1, 3)]


def test_affinity_places_on_resident_device():
    pool = GPUPool(2, migration=MigrationModel(gbps=1.0, setup_s=0.5))
    pool.grant(1, client=3, t=0.0, dur_s=1.0, horizon_s=100.0)
    pool.release(1)
    req = _req(3)
    req.state_bytes = 10 ** 9
    blind = make_policy("gain").assign(5.0, [req], [0, 1], pool)
    aware = make_policy("affinity").assign(5.0, [req], [0, 1], pool)
    assert blind[0].gpu == 0  # affinity-blind: lowest-numbered free device
    assert aware[0].gpu == 1  # resident device: migration avoided


# ---------------- engine on the pool ----------------


def test_engine_n_gpus_1_matches_pr1_engine():
    """The pooled engine with one device must reproduce the PR-1 single
    `gpu_busy`-flag engine bit-for-bit (numbers captured from it)."""
    gold = {
        "fair": {"mean_miou": 0.8730922989000001,
                 "gpu_utilization": 0.9428994666666667,
                 "phases_served": 80, "phases_deferred": 101,
                 "dropped_requests": 17,
                 "mean_up_kbps": 45.615644444444435,
                 "mean_down_kbps": 11.851851851851853,
                 "delta_latency_mean_s": 0.20999999999999908,
                 "labels_total": 706, "label_batches": 34,
                 "max_backlog": 8, "events_processed": 2012},
        "gain": {"mean_miou": 0.8688187919555556,
                 "gpu_utilization": 0.9428994666666667,
                 "phases_served": 71, "phases_deferred": 101,
                 "dropped_requests": 25,
                 "mean_up_kbps": 45.615644444444435,
                 "mean_down_kbps": 10.518518518518519,
                 "delta_latency_mean_s": 0.20999999999999935,
                 "labels_total": 780, "label_batches": 31,
                 "max_backlog": 8, "events_processed": 1994},
    }

    def fleet():
        return [StubSession(i, rate=0.15 if i < 1 else 1.0,
                            dynamics=0.0005 if i < 1 else 0.004,
                            net=ClientNetwork(LinkSpec(up_kbps=500.0,
                                                       down_kbps=1000.0)))
                for i in range(6)]

    for policy, want in gold.items():
        r = ServingEngine(fleet(), policy=policy,
                          cfg=ServingConfig(duration=180.0, max_queue=8)).run()
        for k, v in want.items():
            assert r[k] == pytest.approx(v, rel=0, abs=1e-12), (policy, k)
        assert r["migrations"] == 0 and r["n_gpus"] == 1


@settings(max_examples=8, deadline=None)
@given(n=st.integers(2, 10), n_gpus=st.integers(1, 4),
       policy=st.sampled_from(["fair", "edf", "gain", "affinity"]))
def test_pool_never_double_books_and_busy_bounded(n, n_gpus, policy):
    """Any fleet/pool/policy: `GPUPool.grant` raising on overlap means a
    clean run IS the no-double-booking proof; per-device utilization can
    never exceed the horizon."""
    fleet = _stub_fleet(n)
    eng = ServingEngine(fleet, policy=policy,
                        cfg=ServingConfig(duration=90.0, n_gpus=n_gpus))
    r = eng.run()  # raises RuntimeError on any double-booking
    assert all(d.busy_s <= 90.0 + 1e-9 for d in eng.pool.devices)
    assert sum(r["per_gpu_grants"]) >= r["phases_served"]
    assert sum(r["phases_per_client"]) == r["phases_served"]


@settings(max_examples=8, deadline=None)
@given(n=st.integers(4, 14), n_gpus=st.integers(1, 4),
       comp=st.sampled_from([0.0, 200.0]))
def test_engine_is_work_conserving(n, n_gpus, comp):
    """No *eligible* request may sit queued while a device idles inside the
    horizon (a client already mid-phase elsewhere is not eligible: its
    training state is singular and cannot run on two devices at once).
    Exercised with delta compression both off and on — a compressing device
    must not stall scheduling on the rest of the pool."""
    eng = ServingEngine(_stub_fleet(n), policy="fair",
                        cost=GPUCostModel(delta_comp_s_per_mb=comp),
                        cfg=ServingConfig(duration=60.0, n_gpus=n_gpus))
    eng._init_events()
    while eng.q:
        ev = eng.q.pop()
        eng._dispatch(ev)
        if ev.time < eng.cfg.duration:
            eligible = [b for b in eng._queue
                        if b.req.client not in eng._active]
            assert not (eligible and eng.pool.free_ids()), (
                f"{len(eligible)} eligible requests wait while devices "
                f"{eng.pool.free_ids()} idle at t={ev.time:.2f}")


def test_no_session_trains_on_two_devices_at_once():
    """Saturate a 4-GPU pool with few clients so duplicate same-client
    requests queue up: a client must never be granted a second device while
    its first phase is still running."""
    fleet = _stub_fleet(3)
    eng = ServingEngine(fleet, policy="fair",
                        cfg=ServingConfig(duration=90.0, n_gpus=4))
    eng._init_events()
    running: dict[int, float] = {}  # client -> phase end time
    while eng.q:
        ev = eng.q.pop()
        if ev.kind == "gpu_done":
            running.pop(ev.client, None)
        before = set(eng._active)
        eng._dispatch(ev)
        for c in eng._active - before:
            assert c not in running, (
                f"client {c} granted a second device at t={ev.time:.2f} "
                f"while already mid-phase")
            running[c] = ev.time


def _scale_fleet(n):
    """The serving_scale fleet shape: 30% near-static head, dynamic tail."""
    link = LinkSpec(up_kbps=500.0, down_kbps=2000.0)
    return [StubSession(i, rate=0.15 if i < int(0.3 * n) else 1.0,
                        dynamics=0.0005 if i < int(0.3 * n) else 0.004,
                        net=ClientNetwork(link))
            for i in range(n)]


def test_multi_gpu_scaling_sustains_3x_sessions():
    """Appendix E scale-out: at a fixed mIoU floor, a 4-GPU pool must carry
    >= 3x the sessions of one GPU under the fair policy."""
    target = 0.84

    def sustained(n_gpus, counts):
        best = 0
        for n in counts:
            r = ServingEngine(
                _scale_fleet(n), policy="fair",
                cfg=ServingConfig(duration=240.0, max_queue=32,
                                  n_gpus=n_gpus)).run()
            if r["mean_miou"] >= target:
                best = max(best, n)
        return best

    s1 = sustained(1, (8, 12))
    s4 = sustained(4, (24, 28))
    assert s1 > 0
    assert s4 >= 3 * s1, f"scaled {s1} -> {s4} sessions (< 3x)"


def test_affinity_beats_blind_assignment_at_saturation():
    """Same gain ranking, different placement: residency-aware assignment
    pays less migration tax, so it serves more phases at better freshness."""
    results = {}
    for pol in ("gain", "affinity"):
        results[pol] = ServingEngine(
            _scale_fleet(24), policy=pol,
            cfg=ServingConfig(duration=240.0, max_queue=32, n_gpus=4)).run()
    blind, aware = results["gain"], results["affinity"]
    assert aware["migrations"] < blind["migrations"]
    assert aware["migration_s_total"] < blind["migration_s_total"]
    assert (aware["mean_miou"] > blind["mean_miou"]
            or aware["phases_served"] > blind["phases_served"])
    # every phase ran somewhere in the pool, and the pool was really a pool
    assert set(g for dev in aware["devices_per_client"] for g in dev) > {0}


# ---------------- gain-aware admission: park the lowest phi ----------------


def test_admission_parks_lowest_phi_not_newest():
    """Oversubscribed pool: the near-static sessions are parked, not
    whichever sessions happen to be indexed last (the PR-1 rule would have
    admitted the four static head clients and rejected every dynamic one)."""
    fleet = [StubSession(i, rate=0.15 if i < 4 else 1.0,
                         net=ClientNetwork(LinkSpec()))
             for i in range(8)]
    r = ServingEngine(fleet, policy="fair",
                      cfg=ServingConfig(duration=60.0,
                                        admission_util_cap=0.5)).run()
    admitted = {s.idx for s in fleet if s.admitted}
    assert admitted and admitted <= {4, 5, 6, 7}  # only dynamic feeds fit
    assert r["parked_clients"] == sorted(set(range(8)) - admitted)
    parked = [s for s in fleet if not s.admitted]
    assert all(s.phases == 0 for s in parked)  # inference-only
    assert all(s.mious for s in parked)  # still measured (decay = signal)


# ---------------- modeled ASR rate-control + delta compression ----------------


class _RateShiftSession(StubSession):
    """The server's ASR doubles the rate after the first phase — only a
    delivered rate_ctrl message may move the edge's sampling clock."""

    def train(self, t):
        delta = super().train(t)
        if delta is not None:
            self.sampling_rate = 2.0
        return delta


def test_asr_rate_ctrl_rides_the_downlink():
    def run(ctrl_bytes):
        fleet = [_RateShiftSession(i, rate=1.0, net=ClientNetwork(LinkSpec()))
                 for i in range(3)]
        r = ServingEngine(fleet, policy="fair",
                          cfg=ServingConfig(duration=60.0,
                                            asr_ctrl_bytes=ctrl_bytes)).run()
        return fleet, r

    free_fleet, free = run(0)
    ctrl_fleet, ctrl = run(64)
    assert all(s._edge_rate is None for s in free_fleet)  # PR-1: instant
    # the server-side rate shift really crossed the downlink
    assert all(s.sampling_rate == 2.0 for s in ctrl_fleet)
    assert all(s._edge_rate == 2.0 for s in ctrl_fleet)
    assert ctrl["mean_down_kbps"] > free["mean_down_kbps"]  # bytes charged
    assert ctrl["events_processed"] > free["events_processed"]  # rate_ctrl evs


def test_delta_compression_charges_the_device_clock():
    def run(s_per_mb):
        cost = GPUCostModel(delta_comp_s_per_mb=s_per_mb)
        return ServingEngine(_stub_fleet(4), policy="fair", cost=cost,
                             cfg=ServingConfig(duration=60.0)).run()

    free, comp = run(0.0), run(25.0)  # 20 KB stub delta -> 0.5 s on-device
    assert comp["gpu_utilization"] > free["gpu_utilization"]
    assert comp["mean_down_kbps"] > 0.0  # deltas still delivered, just later
    assert comp["events_processed"] > free["events_processed"]  # gpu_free evs


# ---------------- fused cross-session training ----------------


def test_train_batch_s_solo_exact_and_sublinear():
    c = GPUCostModel()
    assert c.train_batch_s(0, 20) == 0.0
    # B=1 is EXACTLY the sequential phase cost (unfused engines bit-identical)
    assert c.train_batch_s(1, 20) == 20 * c.train_iter_s
    for b in range(2, 9):
        fused = c.train_batch_s(b, 20)
        assert fused < b * c.train_batch_s(1, 20)  # sublinear in B
        assert fused > c.train_batch_s(b - 1, 20)  # but monotone
    # setup amortizes: per-session cost falls as the stack grows
    per = [c.train_batch_s(b, 20) / b for b in (2, 4, 8)]
    assert per[0] > per[1] > per[2]


def _coalesce_pool():
    pool = GPUPool(2, migration=MigrationModel(gbps=1.0, setup_s=0.5))
    for c in (0, 1, 2):  # residents of device 0
        pool.grant(0, client=c, t=0.0, dur_s=0.1, horizon_s=100.0)
        pool.release(0)
    pool.grant(1, client=3, t=0.0, dur_s=0.1, horizon_s=100.0)  # device 1
    pool.release(1)
    return pool


def test_coalesce_takes_coresident_same_k_only():
    from repro.serving import Assignment

    pool = _coalesce_pool()
    p = make_policy("fair")
    granted = Assignment(req=_req(0), gpu=0)
    ready = [_req(1, t_request=2.0), _req(2, t_request=1.0), _req(3)]
    for r in ready:
        r.state_bytes = 10 ** 9
    # client 3 is resident on device 1 -> staging it on 0 costs migration
    riders = p.coalesce(10.0, granted, ready, pool, max_fuse=4)
    assert [r.client for r in riders] == [2, 1]  # oldest first, 3 excluded
    # max_fuse caps the stack (primary + riders)
    assert [r.client for r in p.coalesce(10.0, granted, ready, pool, 2)] == [2]
    # a different iteration count cannot share the executable
    odd = _req(2, t_request=1.0)
    odd.k_iters = 7
    assert p.coalesce(10.0, granted, [odd], pool, 4) == []
    # fusing disabled
    assert p.coalesce(10.0, granted, ready, pool, 1) == []


def test_coalesce_bounded_by_residency_cap():
    """A device whose HBM holds only N session states cannot co-train a
    larger stack — an oversized stack would LRU-evict its own members
    mid-launch (spilling the actively-training primary to host)."""
    from repro.serving import Assignment

    pool = GPUPool(1, residency_cap=2)
    for c in (0, 1, 2):
        pool.grant(0, client=c, t=float(c), dur_s=0.1, horizon_s=100.0)
        pool.release(0)
    granted = Assignment(req=_req(2), gpu=0)
    ready = [_req(1, t_request=1.0), _req(0, t_request=2.0)]
    for policy in ("fair", "gain"):
        riders = make_policy(policy).coalesce(10.0, granted, ready, pool, 4)
        assert len(riders) <= 1  # stack of 2 fits cap=2; 3 would self-evict
    # cap=1: no rider can ever join
    tight = GPUPool(1, residency_cap=1)
    assert make_policy("fair").coalesce(10.0, granted, ready, tight, 4) == []
    # engine end-to-end: every fused stack obeys the cap
    eng = ServingEngine(_stub_fleet(6), policy="fair",
                        cfg=ServingConfig(duration=90.0, fuse_train=4,
                                          residency_cap=2))
    eng._init_events()
    while eng.q:
        ev = eng.q.pop()
        if ev.kind == "gpu_done":
            assert 1 + len(ev.payload[1]) <= 2  # stack never exceeds cap
        eng._dispatch(ev)


def test_coalesce_gain_ranks_riders_by_score():
    from repro.serving import Assignment

    pool = _coalesce_pool()
    p = make_policy("gain")
    granted = Assignment(req=_req(0), gpu=0)
    ready = [_req(1, phi=0.1), _req(2, phi=2.0)]
    riders = p.coalesce(10.0, granted, ready, pool, max_fuse=2)
    assert [r.client for r in riders] == [2]  # highest gain, not oldest


def test_pool_attach_rehomes_rider_without_busy():
    pool = GPUPool(2)
    pool.grant(0, client=0, t=0.0, dur_s=5.0, horizon_s=50.0)
    pool.attach(0, client=4, t=0.0)
    assert pool.home_of(4) == 0 and pool.rider_grants == 1
    assert pool.device(0).busy and not pool.device(1).busy
    assert pool.device(0).grants == 1  # riders are not device grants


def test_engine_fuse_train_coalesces_and_serves_more():
    """A saturated single GPU with fusing on: fused launches happen, riders
    are real, and the sublinear batched cost buys strictly more served
    phases than the sequential engine on the same fleet."""
    def run(fuse):
        return ServingEngine(
            _stub_fleet(8), policy="fair",
            cfg=ServingConfig(duration=120.0, max_queue=32,
                              fuse_train=fuse)).run()

    seq, fused = run(1), run(4)
    assert seq["fused_launches"] == 0 and seq["rider_grants"] == 0
    assert fused["fused_launches"] > 0
    assert fused["fused_sessions"] >= 2 * fused["fused_launches"]
    assert fused["rider_grants"] == (fused["fused_sessions"]
                                     - fused["fused_launches"])
    assert fused["phases_served"] > seq["phases_served"]
    assert fused["mean_miou"] >= seq["mean_miou"]


def test_engine_fused_respects_singular_session_state():
    """Fusing must not break the invariant that a session trains on at most
    one device at a time (riders count as mid-phase too)."""
    fleet = _stub_fleet(4)
    eng = ServingEngine(fleet, policy="fair",
                        cfg=ServingConfig(duration=90.0, n_gpus=2,
                                          fuse_train=3))
    eng._init_events()
    running: dict[int, float] = {}
    while eng.q:
        ev = eng.q.pop()
        if ev.kind == "gpu_done":
            for c in (ev.client, *ev.payload[1]):
                running.pop(c, None)
        before = set(eng._active)
        eng._dispatch(ev)
        for c in eng._active - before:
            assert c not in running, f"client {c} double-granted at {ev.time}"
            running[c] = ev.time


@settings(max_examples=8, deadline=None)
@given(n=st.integers(2, 10), n_gpus=st.integers(1, 3),
       fuse=st.integers(1, 5),
       policy=st.sampled_from(["fair", "edf", "gain", "affinity"]))
def test_engine_fused_pool_invariants(n, n_gpus, fuse, policy):
    """Any fleet/pool/fuse depth: no double-booking (grant raises), busy
    clocks bounded by the horizon, and every session's phases add up."""
    eng = ServingEngine(_stub_fleet(n), policy=policy,
                        cfg=ServingConfig(duration=90.0, n_gpus=n_gpus,
                                          fuse_train=fuse))
    r = eng.run()
    assert all(d.busy_s <= 90.0 + 1e-9 for d in eng.pool.devices)
    assert sum(r["phases_per_client"]) == r["phases_served"]
    assert r["fused_sessions"] - r["fused_launches"] == r["rider_grants"]


def test_run_multiclient_fuse_train_kwarg():
    import jax as _jax

    from repro.core.server import AMSConfig
    from repro.models.seg.student import SegConfig, make_student
    from repro.sim.multiclient import run_multiclient

    seg = SegConfig(n_classes=5)
    pre = make_student(seg, _jax.random.PRNGKey(0))
    ams = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=2, batch_size=2,
                    gamma=0.05, lr=2e-3, phi_target=0.15)
    r = run_multiclient(4, pre, seg, ams, duration=25.0,
                        video_kw=dict(height=24, width=24, fps=2.0),
                        fuse_train=3)
    assert r["fused_launches"] > 0  # real seg sessions fused end-to-end
    assert np.isfinite(r["mean_miou"])


# ---------------- edge client double-buffering ----------------


def test_edge_client_replicas_converge_per_delta():
    params = {"w": jnp.zeros(32), "b": jnp.zeros(4)}
    ec = EdgeClient(lambda p, x: x, params)
    rng = np.random.default_rng(0)
    for step in range(3):
        new = jax.tree.map(lambda x: x + 1.0 + step, params)
        mask = jax.tree.map(
            lambda x: jnp.asarray(rng.uniform(size=x.shape) < 0.3), params)
        delta = encode_delta(new, mask)
        ec.apply_update(delta)
        for a, b in zip(jax.tree.leaves(ec.active), jax.tree.leaves(ec.inactive)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ec.updates_applied == 3


# ---------------- run_multiclient shim regression ----------------


def test_run_multiclient_shim_contract():
    from repro.core.server import AMSConfig
    from repro.models.seg.student import SegConfig, make_student
    from repro.sim.multiclient import run_multiclient

    seg = SegConfig(n_classes=5)
    pre = make_student(seg, jax.random.PRNGKey(0))
    ams = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=2, batch_size=2,
                    gamma=0.05, lr=2e-3, phi_target=0.15)
    r = run_multiclient(2, pre, seg, ams, duration=25.0,
                        video_kw=dict(height=24, width=24, fps=2.0))
    for key in ("n_clients", "miou_per_client", "mean_miou",
                "gpu_utilization", "phases_served", "phases_deferred"):
        assert key in r, key
    assert r["n_clients"] == 2
    assert len(r["miou_per_client"]) == 2
    assert np.isfinite(r["mean_miou"])
    assert 0.0 <= r["mean_miou"] <= 1.0
    # deltas crossed a modeled link: bytes were charged and time passed
    assert r["mean_down_kbps"] > 0.0
    assert r["delta_latency_mean_s"] > 0.0


def test_run_multiclient_gpu_pool_kwargs():
    from repro.core.server import AMSConfig
    from repro.models.seg.student import SegConfig, make_student
    from repro.sim.multiclient import run_multiclient

    seg = SegConfig(n_classes=5)
    pre = make_student(seg, jax.random.PRNGKey(0))
    ams = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=2, batch_size=2,
                    gamma=0.05, lr=2e-3, phi_target=0.15)
    r = run_multiclient(3, pre, seg, ams, duration=25.0,
                        video_kw=dict(height=24, width=24, fps=2.0),
                        n_gpus=2, affinity=True)
    assert r["n_gpus"] == 2 and r["scheduler"] == "affinity"
    assert len(r["per_gpu_utilization"]) == 2
    assert np.isfinite(r["mean_miou"])
    # real sessions report a real (weights+opt+buffer) migration footprint
    assert all(g in (0, 1) for dev in r["devices_per_client"] for g in dev)
