"""Event-driven serving runtime: queue ordering, link math, scheduler
fairness, admission control, and the `run_multiclient` compatibility shim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import EdgeClient
from repro.core.delta import encode_delta
from repro.core.scheduler import GPUCostModel, RoundRobinScheduler
from repro.serving import (
    ClientNetwork,
    EventQueue,
    GPURequest,
    LinkSpec,
    ServingConfig,
    ServingEngine,
    StubSession,
    make_policy,
)
from repro.serving.network import Link


# ---------------- event queue ----------------


def test_event_queue_time_order():
    q = EventQueue()
    q.push(3.0, "c")
    q.push(1.0, "a")
    q.push(2.0, "b")
    assert [q.pop().kind for _ in range(3)] == ["a", "b", "c"]


def test_event_queue_fifo_at_equal_times():
    q = EventQueue()
    for i in range(50):
        q.push(7.0, "k", client=i)
    assert [q.pop().client for _ in range(50)] == list(range(50))


def test_event_queue_interleaved_deterministic():
    def drain(order):
        q = EventQueue()
        for t, c in order:
            q.push(t, "k", client=c)
        return [(e.time, e.client) for e in (q.pop() for _ in range(len(order)))]

    order = [(2.0, 0), (1.0, 1), (2.0, 2), (1.0, 3), (0.5, 4)]
    a = drain(order)
    b = drain(order)
    assert a == b == [(0.5, 4), (1.0, 1), (1.0, 3), (2.0, 0), (2.0, 2)]


# ---------------- network model ----------------


def test_link_occupancy_math():
    # 300 Kbps link: 37500 bytes = 300 Kbit -> exactly 1 s on the wire
    link = Link(rate_kbps=300.0, prop_delay_s=0.05)
    assert link.tx_seconds(37_500) == pytest.approx(1.0)
    assert link.transfer(0.0, 37_500) == pytest.approx(1.05)
    # a second transfer queued behind the first: serialized, not parallel
    assert link.transfer(0.0, 37_500) == pytest.approx(2.05)
    # after the link drains, a later send starts immediately
    assert link.transfer(10.0, 37_500) == pytest.approx(11.05)


def test_client_network_feeds_ledger():
    net = ClientNetwork(LinkSpec(up_kbps=300.0, down_kbps=600.0,
                                 prop_delay_s=0.0))
    net.send_up(0.0, 37_500)
    net.send_down(0.0, 37_500)
    up, down = net.kbps(10.0)
    assert up == pytest.approx(30.0)
    assert down == pytest.approx(30.0)


def test_zero_rate_link_is_instant():
    link = Link(rate_kbps=0.0, prop_delay_s=0.01)
    assert link.transfer(5.0, 10**9) == pytest.approx(5.01)


# ---------------- core round-robin turn order ----------------


def test_round_robin_turn_rotates_despite_poll_order():
    """Client 0 polls first every tick; with turn ordering it must NOT win
    every grant (the seed bug): grants rotate 0,1,2,0,1,2..."""
    s = RoundRobinScheduler(cost=GPUCostModel(teacher_infer_s=0.0,
                                              train_iter_s=0.0))
    grants = []
    for tick in range(9):
        t = float(tick)
        for c in range(3):
            if s.try_acquire(t, 1, 1, client=c):
                grants.append(c)
    assert grants[:6] == [0, 1, 2, 0, 1, 2]
    assert s.served == len(grants)


def test_round_robin_skips_absent_clients():
    s = RoundRobinScheduler(cost=GPUCostModel(teacher_infer_s=0.0,
                                              train_iter_s=0.0), n_clients=4)
    # only clients 1 and 3 ever ask; neither starves, the ring skips 0 and 2
    grants = [c for t in range(8) for c in (1, 3)
              if s.try_acquire(float(t), 1, 1, client=c)]
    assert set(grants) == {1, 3}
    assert abs(grants.count(1) - grants.count(3)) <= 1


def test_round_robin_expires_abandoned_waiters():
    """A client that deferred once and then vanished must not hold the ring
    (grants would otherwise deadlock with an idle GPU)."""
    s = RoundRobinScheduler(cost=GPUCostModel(teacher_infer_s=0.0,
                                              train_iter_s=0.5),
                            waiting_timeout=5.0)
    assert s.try_acquire(0.0, 0, 2, client=0)  # GPU busy until t=1.0
    assert not s.try_acquire(0.5, 0, 2, client=1)  # deferred, then vanishes
    # turn points at 1; while its entry is alive, 0 must wait its turn
    assert not s.try_acquire(2.0, 0, 2, client=0)
    # after waiting_timeout with no re-poll from 1, the ring moves on
    assert s.try_acquire(10.0, 0, 2, client=0)


def test_round_robin_legacy_path_unchanged():
    s = RoundRobinScheduler(cost=GPUCostModel(teacher_infer_s=0.2,
                                              train_iter_s=0.05))
    assert s.try_acquire(0.0, n_frames=4, k_iters=20)
    assert s.gpu_free_at == pytest.approx(1.8)
    assert not s.try_acquire(1.0, 1, 20)
    assert s.deferred == 1


# ---------------- policies ----------------


def _req(client, t_request=0.0, deadline=10.0, phi=1.0, t_update=10.0):
    return GPURequest(client=client, t_request=t_request, n_frames=4,
                      k_iters=20, deadline=deadline, phi=phi,
                      t_update=t_update)


def test_edf_picks_earliest_deadline():
    p = make_policy("edf")
    ready = [_req(0, deadline=30.0), _req(1, deadline=10.0),
             _req(2, deadline=20.0)]
    assert p.pick(0.0, ready).client == 1


def test_gain_prefers_dynamic_but_staleness_backstops():
    p = make_policy("gain")
    dynamic = _req(0, t_request=5.0, phi=1.0)
    static = _req(1, t_request=5.0, phi=0.1)
    assert p.pick(5.0, [dynamic, static]).client == 0
    # after waiting long enough, the near-static session outranks a fresh
    # dynamic request — no starvation
    stale_static = _req(1, t_request=0.0, phi=0.1)
    fresh_dynamic = _req(0, t_request=60.0, phi=1.0)
    assert p.pick(60.0, [fresh_dynamic, stale_static]).client == 1


def test_gain_evicts_lowest_value_not_newest():
    p = make_policy("gain")
    static_queued = _req(1, t_request=10.0, phi=0.05)
    dynamic_queued = _req(0, t_request=10.0, phi=1.5)
    dynamic_arrival = _req(2, t_request=11.0, phi=1.5)
    victim = p.evict(11.0, [dynamic_queued, static_queued, dynamic_arrival])
    assert victim.client == 1
    # default policies tail-drop the newest arrival instead
    assert make_policy("fair").evict(
        11.0, [dynamic_queued, static_queued, dynamic_arrival]).client == 2


def test_fair_policy_rotates():
    p = make_policy("fair")
    ready = [_req(c) for c in range(3)]
    picks = [p.pick(0.0, ready).client for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_unknown_policy_raises():
    with pytest.raises(ValueError):
        make_policy("lifo")


# ---------------- engine on stub sessions ----------------


def _stub_fleet(n, **kw):
    return [StubSession(i, net=ClientNetwork(LinkSpec(up_kbps=500.0,
                                                      down_kbps=1000.0)), **kw)
            for i in range(n)]


def test_engine_fairness_no_client_starves():
    # unsaturated GPU: fair round-robin must serve everyone nearly equally
    fleet = _stub_fleet(6)
    cost = GPUCostModel(teacher_infer_s=0.05, train_iter_s=0.02)
    r = ServingEngine(fleet, policy="fair", cost=cost,
                      cfg=ServingConfig(duration=120.0)).run()
    assert all(p > 0 for p in r["phases_per_client"])
    assert max(r["phases_per_client"]) - min(r["phases_per_client"]) <= 1


def test_engine_gain_no_client_starves_under_saturation():
    fleet = [StubSession(i, rate=0.15 if i < 2 else 1.0,
                         net=ClientNetwork(LinkSpec()))
             for i in range(8)]
    r = ServingEngine(fleet, policy="gain",
                      cfg=ServingConfig(duration=240.0)).run()
    assert all(p > 0 for p in r["phases_per_client"])


def test_engine_deterministic():
    def once():
        r = ServingEngine(_stub_fleet(5), policy="gain",
                          cfg=ServingConfig(duration=90.0)).run()
        return {k: v for k, v in r.items()
                if k not in ("wall_s", "events_per_sec")}

    assert once() == once()


def test_engine_nonzero_delta_latency_and_kbps():
    r = ServingEngine(_stub_fleet(3), policy="fair",
                      cfg=ServingConfig(duration=60.0)).run()
    assert r["delta_latency_mean_s"] > 0.0
    assert r["mean_up_kbps"] > 0.0 and r["mean_down_kbps"] > 0.0


def test_engine_admission_control_caps_load():
    fleet = _stub_fleet(8)
    r = ServingEngine(fleet, policy="fair",
                      cfg=ServingConfig(duration=60.0,
                                        admission_util_cap=0.5)).run()
    assert 0 < r["admitted_clients"] < 8
    rejected = [s for s in fleet if not s.admitted]
    assert rejected and all(s.phases == 0 for s in rejected)


def test_engine_saturation_drops_requests():
    fleet = _stub_fleet(12)
    r = ServingEngine(fleet, policy="fair",
                      cfg=ServingConfig(duration=120.0, max_queue=4)).run()
    assert r["dropped_requests"] > 0
    assert r["max_backlog"] <= 4


# ---------------- edge client double-buffering ----------------


def test_edge_client_replicas_converge_per_delta():
    params = {"w": jnp.zeros(32), "b": jnp.zeros(4)}
    ec = EdgeClient(lambda p, x: x, params)
    rng = np.random.default_rng(0)
    for step in range(3):
        new = jax.tree.map(lambda x: x + 1.0 + step, params)
        mask = jax.tree.map(
            lambda x: jnp.asarray(rng.uniform(size=x.shape) < 0.3), params)
        delta = encode_delta(new, mask)
        ec.apply_update(delta)
        for a, b in zip(jax.tree.leaves(ec.active), jax.tree.leaves(ec.inactive)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ec.updates_applied == 3


# ---------------- run_multiclient shim regression ----------------


def test_run_multiclient_shim_contract():
    from repro.core.server import AMSConfig
    from repro.models.seg.student import SegConfig, make_student
    from repro.sim.multiclient import run_multiclient

    seg = SegConfig(n_classes=5)
    pre = make_student(seg, jax.random.PRNGKey(0))
    ams = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=2, batch_size=2,
                    gamma=0.05, lr=2e-3, phi_target=0.15)
    r = run_multiclient(2, pre, seg, ams, duration=25.0,
                        video_kw=dict(height=24, width=24, fps=2.0))
    for key in ("n_clients", "miou_per_client", "mean_miou",
                "gpu_utilization", "phases_served", "phases_deferred"):
        assert key in r, key
    assert r["n_clients"] == 2
    assert len(r["miou_per_client"]) == 2
    assert np.isfinite(r["mean_miou"])
    assert 0.0 <= r["mean_miou"] <= 1.0
    # deltas crossed a modeled link: bytes were charged and time passed
    assert r["mean_down_kbps"] > 0.0
    assert r["delta_latency_mean_s"] > 0.0
