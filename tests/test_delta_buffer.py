"""Sparse delta codec + replay buffer + controllers."""
import gzip

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a fallback when absent

from repro.core.atr import ATRController
from repro.core.buffer import ReplayBuffer
from repro.core.delta import apply_delta, encode_delta, full_model_bytes
from repro.core.sampler import ASRController


# ---------------- delta codec ----------------


def _tree(rng, sizes=((16, 8), (33,), (2, 3, 5))):
    return {f"l{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
            for i, s in enumerate(sizes)}


def test_delta_roundtrip(rng):
    old = _tree(rng)
    new = jax.tree.map(lambda x: x + 1.0, old)
    mask = jax.tree.map(lambda x: jnp.asarray(rng.integers(0, 2, x.shape), bool), old)
    delta = encode_delta(new, mask)
    got = apply_delta(old, delta)
    for k in old:
        m = np.asarray(mask[k])
        np.testing.assert_allclose(np.asarray(got[k])[m], np.asarray(new[k])[m],
                                   atol=2e-3)  # fp16 wire format
        np.testing.assert_array_equal(np.asarray(got[k])[~m], np.asarray(old[k])[~m])


def test_delta_bytes_accounting(rng):
    tree = _tree(rng, sizes=((1000,),))
    mask = {"l0": jnp.asarray(np.arange(1000) < 50)}
    d = encode_delta(tree, mask)
    assert d.value_bytes == 50 * 2
    assert d.mask_bytes < 1000 / 8 + 64
    assert d.total_bytes < full_model_bytes(tree)


def test_encode_delta_matches_two_pass_reference(rng):
    """Golden regression for the single-pass/reused-buffer encoder: values,
    unpacked mask bits, and every byte count must match the original
    two-pass flatten/concat algorithm exactly (the raw gzip bytes differ
    only in the pinned MTIME header field, so compare decompressed)."""

    def reference(params_new, mask, value_dtype="float16"):
        def _flat(t):
            leaves = [np.asarray(l).reshape(-1) for l in jax.tree.leaves(t)]
            return np.concatenate(leaves) if leaves else np.zeros((0,))

        flat_p = _flat(params_new)
        flat_m = _flat(mask).astype(bool)
        values = flat_p[flat_m].astype(value_dtype)
        packed = gzip.compress(np.packbits(flat_m).tobytes(), compresslevel=6)
        return values, packed, flat_p.size

    for sizes in (((16, 8), (33,), (2, 3, 5)), ((1,),), ((257,), (4, 4))):
        tree = _tree(rng, sizes=sizes)
        mask = jax.tree.map(
            lambda x: jnp.asarray(rng.uniform(size=x.shape) < 0.25), tree)
        d = encode_delta(tree, mask)
        ref_v, ref_packed, ref_n = reference(tree, mask)
        np.testing.assert_array_equal(d.values, ref_v)
        assert d.values.dtype == ref_v.dtype
        assert gzip.decompress(d.packed_mask) == gzip.decompress(ref_packed)
        assert d.mask_bytes == len(ref_packed)
        assert d.n_total == ref_n
        assert d.total_bytes == ref_v.nbytes + len(ref_packed)
        # the new encoding is additionally a pure function of its inputs
        assert encode_delta(tree, mask).packed_mask == d.packed_mask


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), frac=st.floats(0, 1))
def test_property_delta_roundtrip(seed, frac):
    r = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(r.normal(size=(r.integers(1, 200),)), jnp.float32)}
    mask = {"a": jnp.asarray(r.uniform(size=tree["a"].shape) < frac)}
    new = jax.tree.map(lambda x: x * 2 + 1, tree)
    got = apply_delta(tree, encode_delta(new, mask))
    m = np.asarray(mask["a"])
    np.testing.assert_allclose(np.asarray(got["a"])[m], np.asarray(new["a"])[m],
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(got["a"])[~m], np.asarray(tree["a"])[~m])


# ---------------- replay buffer ----------------


def test_buffer_horizon_window():
    buf = ReplayBuffer(horizon=10.0, slack=0.0)
    for t in range(20):
        buf.add(np.full((2, 2), t), np.full((2, 2), t), float(t))
    idx = buf.window_indices(19.0)
    stamps = np.asarray(buf.stamps)[idx]
    assert stamps.min() >= 9.0
    r = np.random.default_rng(0)
    frames, labels = buf.sample(r, 64, 19.0)
    assert frames.min() >= 9.0  # only window frames sampled
    assert frames.shape == (64, 2, 2)


def test_buffer_eviction():
    buf = ReplayBuffer(horizon=5.0, slack=1.0)
    for t in range(100):
        buf.add(np.zeros(1), np.zeros(1), float(t))
    assert len(buf) < 100
    assert min(buf.stamps) >= 99 - 5 - 1 - 1


# ---------------- ASR (Eq. 1) ----------------


def test_asr_increases_on_change_decreases_on_static():
    asr = ASRController(phi_target=0.1, eta=1.0, r_min=0.1, r_max=1.0, delta_t=1.0)
    asr.rate = 0.5
    asr.observe(0.5)  # big scene change
    assert asr.maybe_update(1.0) > 0.5
    asr2 = ASRController(phi_target=0.1, eta=1.0, r_min=0.1, r_max=1.0, delta_t=1.0)
    asr2.rate = 0.5
    asr2.observe(0.0)
    assert asr2.maybe_update(1.0) < 0.5


@settings(max_examples=30, deadline=None)
@given(phis=st.lists(st.floats(0, 1), min_size=1, max_size=50))
def test_property_asr_bounded(phis):
    asr = ASRController(phi_target=0.2, eta=2.0, r_min=0.1, r_max=1.0, delta_t=0.0)
    for i, p in enumerate(phis):
        asr.observe(p)
        r = asr.maybe_update(float(i + 1))
        assert 0.1 <= r <= 1.0


# ---------------- ATR (Eq. 2) ----------------


def test_atr_slowdown_cycle():
    atr = ATRController(tau_min=10.0, delta=2.0, gamma0=0.25, gamma1=0.35)
    assert atr.update(0.5) == 10.0  # fast scene: stay at tau_min
    assert atr.update(0.2) == 12.0  # enter slowdown, stretch
    assert atr.update(0.2) == 14.0
    assert atr.update(0.3) == 16.0  # hysteresis: still below gamma1
    assert atr.update(0.4) == 10.0  # exit: snap back to tau_min
