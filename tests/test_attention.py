"""Chunked-flash attention vs naive oracle; decode-vs-full consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attention_ref, flash_attention


def _qkv(rng, B, S, KV, G, hd, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(B, S, KV, G, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("S,KV,G,window,softcap,causal", [
    (64, 2, 2, 0, 0.0, True),
    (96, 1, 4, 0, 0.0, True),      # MQA
    (64, 2, 1, 16, 0.0, True),     # sliding window
    (64, 2, 2, 0, 30.0, True),     # softcap (gemma2)
    (48, 2, 2, 0, 0.0, False),     # non-causal (encoder/cross)
    (100, 2, 2, 0, 0.0, True),     # non-divisible chunking
])
def test_flash_matches_ref(rng, S, KV, G, window, softcap, causal):
    q, k, v = _qkv(rng, 2, S, KV, G, 16)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          logit_softcap=softcap, q_chunk=32, kv_chunk=16)
    ref = attention_ref(q, k, v, causal=causal, window=window, logit_softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_chunk_invariance(rng):
    q, k, v = _qkv(rng, 1, 64, 2, 2, 8)
    outs = [flash_attention(q, k, v, q_chunk=c, kv_chunk=c2)
            for c, c2 in [(8, 8), (64, 64), (16, 32)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), rtol=1e-5, atol=1e-5)


def test_bf16_path(rng):
    q, k, v = _qkv(rng, 1, 32, 1, 2, 16, jnp.bfloat16)
    out = flash_attention(q, k, v, q_chunk=16, kv_chunk=16)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               rtol=0.05, atol=0.05)
