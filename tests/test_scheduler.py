"""Round-robin GPU scheduler (Appendix E) accounting."""
import pytest

from repro.core.scheduler import GPUCostModel, RoundRobinScheduler


def test_gpu_busy_accounting():
    s = RoundRobinScheduler(cost=GPUCostModel(teacher_infer_s=0.2, train_iter_s=0.05))
    assert s.try_acquire(0.0, n_frames=4, k_iters=20)  # 0.8 + 1.0 = 1.8s
    assert s.gpu_free_at == pytest.approx(1.8)
    assert not s.try_acquire(1.0, 1, 20)  # still busy -> deferred
    assert s.deferred == 1
    assert s.try_acquire(2.0, 1, 20)
    assert s.served == 2
    assert 0 < s.utilization(3.0) <= 1.5


def test_saturation_grows_deferrals():
    s = RoundRobinScheduler(cost=GPUCostModel(teacher_infer_s=0.25, train_iter_s=0.05))
    granted = 0
    for step in range(100):  # 10 clients asking every second
        t = step / 10
        if s.try_acquire(t, 2, 20):
            granted += 1
    assert granted < 100  # GPU can't serve all
    assert s.deferred > 0
