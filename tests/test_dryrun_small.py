"""Miniature dry-run on the CPU's own devices: the launch plumbing (rules,
pspecs, lower, compile) works end-to-end without the 512-device flag."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke
from repro.core.masked_adam import MaskedAdamState
from repro.launch.shardings import rules_for
from repro.launch.steps import make_serve_step, make_train_step
from repro.models.registry import build


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ["gemma2_9b", "mixtral_8x22b", "zamba2_7b",
                                  "whisper_large_v3", "rwkv6_3b"])
def test_train_step_lowers_and_compiles(arch, mesh):
    cfg = get_smoke(arch)
    model = build(cfg)
    rules = rules_for(cfg, mesh, shape_kind="train")
    pspecs = model.pspecs(rules)
    params = model.abstract()
    opt = MaskedAdamState(
        m=params,
        v=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
        count=jax.ShapeDtypeStruct((), jnp.int32),
    )
    mask = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bool_), params)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    if cfg.num_xattn_tokens:
        batch["memory"] = jax.ShapeDtypeStruct((2, cfg.num_xattn_tokens, cfg.d_model),
                                               cfg.cdtype)
    jax.set_mesh(mesh)
    step = make_train_step(model)
    jitted = jax.jit(step, in_shardings=(pspecs, MaskedAdamState(pspecs, pspecs, P()),
                                         pspecs, None))
    compiled = jitted.lower(params, opt, mask, batch).compile()
    assert compiled.cost_analysis() is not None
    assert compiled.memory_analysis() is not None


@pytest.mark.parametrize("arch", ["gemma_2b", "zamba2_7b"])
def test_serve_step_lowers_and_compiles(arch, mesh):
    cfg = get_smoke(arch)
    model = build(cfg)
    rules = rules_for(cfg, mesh, shape_kind="decode")
    pspecs = model.pspecs(rules)
    params = model.abstract()
    caches = model.abstract_cache(2, 32, mem_len=cfg.num_xattn_tokens)
    cache_specs = model.cache_pspecs(2, 32, rules, mem_len=cfg.num_xattn_tokens)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 1), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    jax.set_mesh(mesh)
    step = make_serve_step(model)
    jitted = jax.jit(step, in_shardings=(pspecs, cache_specs, None))
    compiled = jitted.lower(params, caches, batch).compile()
    assert compiled.memory_analysis() is not None
