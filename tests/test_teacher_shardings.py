"""Learned teacher + the decode_ep/moe_shard sharding rule variants."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, get_smoke
from repro.data.video import SyntheticVideo, VideoConfig
from repro.launch.mesh import make_local_mesh
from repro.launch.shardings import rules_for
from repro.metrics.miou import miou
from repro.models.seg.teacher import train_teacher


def test_learned_teacher_beats_chance():
    v = SyntheticVideo(VideoConfig(height=32, width=32, fps=2.0, duration=30.0,
                                   seed=9, n_classes=4))
    teacher = train_teacher(v, 4, steps=120, batch=6)
    scores = [miou(teacher.label(i), v.frame(i)[1], 4) for i in range(0, 50, 10)]
    assert np.mean(scores) > 0.45  # far above the ~0.1 chance level


class _FakeMesh:
    axis_names = ("data", "model")
    class devices:  # noqa: D106 - shape-only stand-in
        shape = (16, 16)


@pytest.mark.parametrize("arch", ["llama4_maverick_400b_a17b", "moonshot_v1_16b_a3b"])
def test_decode_ep_rules_drop_data_from_weights(arch):
    cfg = get_config(arch)
    rules = rules_for(cfg, _FakeMesh(), shape_kind="decode_long", decode_ep=True)
    assert rules["embed"] is None
    assert rules["expert_embed"] is None
    assert rules["expert_ff"] == ("data",)
    # baseline keeps FSDP
    base = rules_for(cfg, _FakeMesh(), shape_kind="decode_long")
    assert base["embed"] == ("data",)


def test_decode_ep_not_applied_when_experts_indivisible():
    cfg = get_config("mixtral_8x22b")  # E=8 on a 16-way model axis
    rules = rules_for(cfg, _FakeMesh(), shape_kind="decode_long", decode_ep=True)
    assert rules["embed"] == ("data",)  # fell through to the default path


def test_moe_shard_ep_tp_gated_on_topk():
    coarse = get_config("llama4_maverick_400b_a17b")  # top-1
    fine = get_config("moonshot_v1_16b_a3b")  # top-6
    rc = rules_for(coarse, _FakeMesh(), shape_kind="train", moe_shard=True)
    rf = rules_for(fine, _FakeMesh(), shape_kind="train", moe_shard=True)
    assert rc["expert_ff"] == ("data",) and rc["expert_embed"] is None
    assert rf["expert_embed"] == rf["embed"]  # fine-grained keeps the default
