"""Ring-cache decode (§Perf hillclimb A) must be bit-for-bit* equivalent to
full-cache masked decode (*within fp tolerance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.registry import build


@pytest.mark.parametrize("arch,override", [
    ("gemma2_9b", 0),       # native local windows (window_size=16 in smoke)
    ("mixtral_8x22b", 0),   # SWA MoE
    ("gemma_2b", 8),        # dense + SWA-variant override (long_500k policy)
    ("zamba2_7b", 8),       # shared attn + override
])
def test_ring_decode_matches_full(arch, override):
    base_cfg = get_smoke(arch)
    if base_cfg.num_experts:
        base_cfg = base_cfg.replace(capacity_factor=float(base_cfg.num_experts))
    if override:
        base_cfg = base_cfg.replace(attn_window_override=override)
    ring_cfg = base_cfg.replace(decode_window_slicing=True)

    B, S, steps = 2, 24, 8
    rng = jax.random.PRNGKey(0)
    params = build(base_cfg).init(rng)
    tokens = jax.random.randint(rng, (B, S + steps), 0, base_cfg.vocab_size)
    memory = None
    if base_cfg.num_xattn_tokens:
        memory = 0.3 * jax.random.normal(rng, (B, base_cfg.num_xattn_tokens,
                                               base_cfg.d_model))

    outs = {}
    for name, cfg in (("full", base_cfg), ("ring", ring_cfg)):
        model = build(cfg)
        logits, caches = model.prefill(params, tokens[:, :S], S + steps, memory)
        seq = [np.asarray(logits)]
        for i in range(S, S + steps):
            logits, caches = model.decode_step(params, caches, tokens[:, i : i + 1],
                                               jnp.int32(i))
            seq.append(np.asarray(logits))
        outs[name] = np.concatenate(seq, axis=1)
    np.testing.assert_allclose(outs["ring"], outs["full"], rtol=2e-4, atol=2e-4)


def test_ring_cache_is_smaller():
    cfg = get_smoke("gemma2_9b").replace(decode_window_slicing=True,
                                         attn_window_override=8)
    model = build(cfg)
    ring = model.cache_metas(1, 64)
    full = build(get_smoke("gemma2_9b")).cache_metas(1, 64)
    assert ring["b0"]["k"].shape[2] == 16  # local window (smoke window=16)
    assert ring["b1"]["k"].shape[2] == 8  # override window
    assert full["b1"]["k"].shape[2] == 64
