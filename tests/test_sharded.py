"""Sharded execution: fused grant lifecycles on a real jax device list.

`core.batched.train_phases_sharded` must reproduce the modeled path
exactly — the all-None (default-device) dispatch is the refactored fused
code itself and must be BYTE-identical to per-group `train_phases_fused`;
a forced multi-device host mesh (subprocess — the flag must be set before
jax initializes) must keep wire masks byte-identical and fp16 delta
values within 1 ULP. Plus the plumbing the sharded path rides on:
`launch.host_mesh` flag handling, `scripts/env.sh`, and
`GPUPool(device_backend=...)` bindings.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a fallback when absent

from repro.core import batched
from repro.core.batched import train_phases_fused, train_phases_sharded
from repro.launch import host_mesh
from repro.serving.resources import GPUPool

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _seg_sessions(n, k_iters=2, seed0=300, size=16):
    from repro.core.server import AMSConfig, AMSSession, Task
    from repro.data.video import VideoConfig
    from repro.models.seg.student import SegConfig, make_student
    from repro.sim.seg_world import SegWorld, phi_pixel_loss

    seg = SegConfig(n_classes=5)
    ams = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=k_iters,
                    batch_size=2, gamma=0.05, lr=2e-3, phi_target=0.15)
    pre = make_student(seg, jax.random.PRNGKey(0))
    out = []
    for i in range(n):
        world = SegWorld.make(
            VideoConfig(seed=seed0 + i, height=size, width=size, fps=2.0,
                        duration=20.0), seg)
        task = Task(loss_and_grad=world.loss_and_grad, teacher=None,
                    phi_loss=phi_pixel_loss)
        s = AMSSession(task, ams, jax.tree.map(lambda x: x, pre), seed=i)
        frames = np.stack([world.video.frame(j)[0] for j in range(6)])
        labels = np.stack([world.teacher.label(j) for j in range(6)])
        s.receive_labeled(frames, labels, 5.0)
        out.append(s)
    return out


def _groups(fleet, n_groups, group_b):
    return [fleet[g * group_b:(g + 1) * group_b] for g in range(n_groups)]


def _f16_ulp(a, b) -> int:
    def lex(x):
        u = (np.asarray(x, np.float16).reshape(-1).view(np.uint16)
             .astype(np.int32))
        return np.where(u >= 0x8000, 0x8000 - u, u)

    la, lb = lex(a), lex(b)
    return int(np.max(np.abs(la - lb))) if la.size else 0


# ---------------- host_mesh: flag plumbing ----------------


def test_forced_host_device_count_parses_xla_flags():
    f = host_mesh.forced_host_device_count
    assert f("") is None
    assert f("--xla_cpu_multi_thread_eigen=false") is None
    assert f(host_mesh.host_device_count_flag(4)) == 4
    # appended flags: the LAST occurrence wins (shell-append semantics)
    both = (host_mesh.host_device_count_flag(2) + " --other=1 "
            + host_mesh.host_device_count_flag(8))
    assert f(both) == 8


def test_host_device_count_flag_shape():
    assert host_mesh.host_device_count_flag(4) == \
        "--xla_force_host_platform_device_count=4"
    with pytest.raises(ValueError):
        host_mesh.host_device_count_flag(0)


def test_host_devices_raises_with_pointer_at_env_sh():
    want = len(jax.devices()) + 1
    with pytest.raises(RuntimeError, match="env.sh"):
        host_mesh.host_devices(want)
    # and the happy path returns concrete devices
    devs = host_mesh.host_devices(1)
    assert len(devs) == 1 and devs[0] is jax.devices()[0]


def test_session_mesh_and_shardings():
    from repro.launch.mesh import make_session_mesh

    mesh = make_session_mesh(1)
    assert mesh.axis_names == ("session",)
    assert mesh.devices.size == 1
    with pytest.raises(ValueError):
        make_session_mesh(len(jax.devices()) + 1)
    hm = host_mesh.make_host_mesh(1)
    assert hm.axis_names == ("session",)
    sh = host_mesh.session_sharding(hm)
    assert sh.spec == jax.sharding.PartitionSpec("session")
    rep = host_mesh.replicated_sharding(hm)
    assert rep.spec == jax.sharding.PartitionSpec()


def test_env_sh_forces_host_devices_and_strips_stale_flag():
    script = '. scripts/env.sh && printf "%s|%s" "$XLA_FLAGS" ' \
             '"$TF_CPP_MIN_LOG_LEVEL"'
    env = {k: v for k, v in os.environ.items()
           if k not in ("XLA_FLAGS", "TF_CPP_MIN_LOG_LEVEL",
                        "REPRO_HOST_DEVICES", "LD_PRELOAD")}
    out = subprocess.run(
        ["bash", "-c", script], cwd=REPO, capture_output=True, text=True,
        env={**env, "REPRO_HOST_DEVICES": "4",
             "XLA_FLAGS": host_mesh.host_device_count_flag(2) + " --keep=1"},
        check=True).stdout
    flags, tf_level = out.split("|")
    # stale count dropped, caller's other flags kept, new count appended
    assert flags.count("--xla_force_host_platform_device_count") == 1
    assert host_mesh.host_device_count_flag(4) in flags
    assert "--keep=1" in flags
    assert tf_level == "4"
    # without REPRO_HOST_DEVICES the caller's XLA_FLAGS pass through, and
    # an exported TF_CPP_MIN_LOG_LEVEL is respected
    out = subprocess.run(
        ["bash", "-c", script], cwd=REPO, capture_output=True, text=True,
        env={**env, "XLA_FLAGS": "--keep=1", "TF_CPP_MIN_LOG_LEVEL": "2"},
        check=True).stdout
    assert out == "--keep=1|2"


# ---------------- GPUPool device bindings ----------------


def test_gpupool_device_backend_validates_and_binds():
    with pytest.raises(ValueError, match="device_backend"):
        GPUPool(n_gpus=2, device_backend="cuda")
    modeled = GPUPool(n_gpus=2)
    assert modeled.device_backend == "modeled"
    assert all(d.jax_device is None for d in modeled.devices)
    assert modeled.distinct_jax_devices == 0
    bound = GPUPool(n_gpus=3, device_backend="jax")
    assert [d.gid for d in bound.devices] == [0, 1, 2]
    live = jax.devices()
    assert bound.jax_devices() == [live[g % len(live)] for g in range(3)]
    assert bound.distinct_jax_devices == min(3, len(live))


# ---------------- sharded == fused on the default device ----------------


def test_sharded_all_none_is_byte_identical_to_fused():
    """devices=[None]*D is the refactored fused launch/commit code on the
    default device: masks AND wire bytes must be byte-identical to
    per-group `train_phases_fused`, phase after phase (first phase uses
    random masks, the second defers gradient-guided selection)."""
    a = _seg_sessions(4)
    b = _seg_sessions(4)
    batched.sharded_reset()
    for t in (6.0, 14.0):
        ref = [d for g in _groups(a, 2, 2)
               for d in train_phases_fused(g, t, force_stack=True)]
        got = [d for grp in train_phases_sharded(
            _groups(b, 2, 2), t, devices=[None, None]) for d in grp]
        assert len(ref) == len(got) == 4
        for r, g in zip(ref, got):
            assert r.packed_mask == g.packed_mask
            assert np.array_equal(np.asarray(r.values),
                                  np.asarray(g.values))
    info = batched.sharded_info()
    assert info["batches"] == 2 and info["groups"] == 4
    assert info["sessions"] == 8 and info["dispatch_launches"] == 4
    assert info["spmd_launches"] == 0
    assert info["distinct_devices"] == 1  # all-None: nothing placed
    # the sessions themselves advanced identically
    for sa, sb in zip(a, b):
        assert sa.phase == sb.phase == 2


def test_sharded_handles_nothing_to_train_slots():
    """A session whose phase prep yields nothing (no ingested frames) gets
    None in its slot, same contract as `train_phases_fused`."""
    fleet = _seg_sessions(2)
    from repro.core.server import AMSConfig, AMSSession, Task
    from repro.sim.seg_world import phi_pixel_loss

    idle = AMSSession(
        Task(loss_and_grad=fleet[0].task.loss_and_grad, teacher=None,
             phi_loss=phi_pixel_loss),
        AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=2, batch_size=2,
                  gamma=0.05, lr=2e-3, phi_target=0.15),
        jax.tree.map(lambda x: x, fleet[0].params), seed=9)
    out = train_phases_sharded([[idle, fleet[0]], [fleet[1]]], 6.0,
                               devices=[None, None])
    assert out[0][0] is None  # nothing ingested -> no phase
    assert out[0][1] is not None and out[1][0] is not None


def test_sharded_validates_inputs():
    fleet = _seg_sessions(2)
    with pytest.raises(ValueError, match="device bindings"):
        train_phases_sharded([[fleet[0]], [fleet[1]]], 6.0, devices=[None])
    mixed = _seg_sessions(1) + _seg_sessions(1, k_iters=3, seed0=400)
    with pytest.raises(ValueError, match="ONE compile key"):
        train_phases_sharded([mixed], 6.0, devices=[None])
    with pytest.raises(ValueError, match="concrete jax.Device"):
        train_phases_sharded([[fleet[0]], [fleet[1]]], 6.0,
                             devices=[None, None], spmd=True)


@settings(max_examples=4, deadline=None)
@given(layout=st.sampled_from(((1, 2), (2, 1), (2, 2), (3, 1))))
def test_sharded_grouping_property(layout):
    """Over (pool size D, group width B): flattened sharded results align
    slot-for-slot with per-group fused results, byte-identically, and the
    counters account for every session."""
    d, b = layout
    a = _seg_sessions(d * b, seed0=600)
    bb = _seg_sessions(d * b, seed0=600)
    ref = [x for g in _groups(a, d, b)
           for x in train_phases_fused(g, 6.0, force_stack=True)]
    batched.sharded_reset()
    got = train_phases_sharded(_groups(bb, d, b), 6.0, devices=[None] * d)
    assert [len(g) for g in got] == [b] * d
    flat = [x for grp in got for x in grp]
    for r, g in zip(ref, flat):
        assert r.packed_mask == g.packed_mask
        assert np.array_equal(np.asarray(r.values), np.asarray(g.values))
    info = batched.sharded_info()
    assert info["groups"] == d and info["sessions"] == d * b


# ---------------- forced 4-device mesh (subprocess) ----------------

_CHILD = r"""
import json, sys
import jax
import numpy as np

n_dev = len(jax.devices())
assert n_dev == 4, f"forced host mesh gave {n_dev} devices"
sys.path.insert(0, "tests")
from test_sharded import _f16_ulp, _groups, _seg_sessions

from repro.core import batched
from repro.core.batched import train_phases_fused, train_phases_sharded

# pin both auto races: the differential question is placement, not mode
batched.set_exec_mode("loop")
batched.set_kernel_mode("xla")
a = _seg_sessions(4, seed0=700, size=12)
b = _seg_sessions(4, seed0=700, size=12)
batched.sharded_reset()
masks_ok, max_ulp, n_ident = True, 0, 0
for t in (6.0, 14.0):
    ref = [d for g in _groups(a, 4, 1)
           for d in train_phases_fused(g, t, force_stack=True)]
    got = [d for grp in train_phases_sharded(
        _groups(b, 4, 1), t, devices=jax.devices()) for d in grp]
    for r, g in zip(ref, got):
        masks_ok &= r.packed_mask == g.packed_mask
        max_ulp = max(max_ulp, _f16_ulp(r.values, g.values))
        n_ident += np.array_equal(np.asarray(r.values),
                                  np.asarray(g.values))
info = batched.sharded_info()
print(json.dumps({"masks_ok": masks_ok, "max_ulp": max_ulp,
                  "n_identical": n_ident,
                  "distinct_devices": info["distinct_devices"],
                  "dispatch_launches": info["dispatch_launches"]}))
"""


def test_four_device_mesh_matches_single_device():
    """The ISSUE differential gate: the same fleet trained on a forced
    4-device host mesh vs the single-device modeled path — wire masks
    byte-identical, fp16 delta values within 1 ULP. Runs in a subprocess
    because the device-count flag must be set before jax initializes (this
    process's backend is already up)."""
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["XLA_FLAGS"] = host_mesh.host_device_count_flag(4)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    proc = subprocess.run([sys.executable, "-c", _CHILD], cwd=REPO,
                          capture_output=True, text=True, timeout=540,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["masks_ok"], "4-device mesh changed a streamed wire mask"
    assert out["max_ulp"] <= 1, (
        f"4-device wire deltas drifted {out['max_ulp']} f16 ULP (>1)")
    assert out["distinct_devices"] == 4
    assert out["dispatch_launches"] == 8  # 4 groups x 2 phases
