"""MoE dispatch + Mamba2/RWKV6 chunked-vs-recurrence oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.common import init_params
from repro.models.moe import moe_apply, moe_metas, moe_ref
from repro.models.ssm.mamba2 import (
    mamba2_apply,
    mamba2_decode,
    mamba2_init_cache,
    mamba2_metas,
    mamba2_scan_ref,
)
from repro.models.ssm.rwkv6 import (
    rwkv6_decode,
    rwkv6_init_cache,
    rwkv6_metas,
    rwkv6_time_mix,
    rwkv6_time_mix_ref,
)


# ---------------- MoE ----------------


def _moe_cfg(cap):
    return get_smoke("moonshot-v1-16b-a3b").replace(capacity_factor=cap)


def test_moe_matches_dense_ref_at_high_capacity(rng):
    cfg = _moe_cfg(cap=float(4))  # cf >= E/k guarantees dropless
    p = init_params(moe_metas(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    out, aux = moe_apply(cfg, p, x)
    ref, aux_ref = moe_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)


def test_moe_capacity_drops_bounded(rng):
    cfg = _moe_cfg(cap=1.0)
    p = init_params(moe_metas(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)), jnp.float32)
    out, aux = moe_apply(cfg, p, x)
    assert jnp.isfinite(out).all()
    assert float(aux) > 0.5  # load-balance loss ~1 for near-uniform routing


# ---------------- Mamba2 ----------------


def test_mamba2_chunked_matches_recurrence(rng):
    cfg = get_smoke("zamba2-7b")
    p = init_params(mamba2_metas(cfg), jax.random.PRNGKey(1), jnp.float32)
    x = jnp.asarray(0.3 * rng.normal(size=(2, 24, cfg.d_model)), jnp.float32)
    out_c = mamba2_apply(cfg, p, x, chunk=8)
    out_r = mamba2_scan_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r), rtol=2e-4, atol=2e-4)


def test_mamba2_chunk_invariance(rng):
    cfg = get_smoke("zamba2-7b")
    p = init_params(mamba2_metas(cfg), jax.random.PRNGKey(1), jnp.float32)
    x = jnp.asarray(0.3 * rng.normal(size=(1, 24, cfg.d_model)), jnp.float32)
    a = mamba2_apply(cfg, p, x, chunk=4)
    b = mamba2_apply(cfg, p, x, chunk=12)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_mamba2_decode_matches_full(rng):
    cfg = get_smoke("zamba2-7b")
    p = init_params(mamba2_metas(cfg), jax.random.PRNGKey(1), jnp.float32)
    S = 12
    x = jnp.asarray(0.3 * rng.normal(size=(2, S, cfg.d_model)), jnp.float32)
    full = mamba2_apply(cfg, p, x, chunk=4)
    cache = mamba2_init_cache(cfg, batch=2)
    outs = []
    for t in range(S):
        o, cache = mamba2_decode(cfg, p, x[:, t : t + 1], cache)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), rtol=2e-4, atol=2e-4)


# ---------------- RWKV6 ----------------


def test_rwkv6_chunked_matches_recurrence(rng):
    cfg = get_smoke("rwkv6-3b")
    p = init_params(rwkv6_metas(cfg), jax.random.PRNGKey(2), jnp.float32)
    x = jnp.asarray(0.3 * rng.normal(size=(2, 24, cfg.d_model)), jnp.float32)
    out_c = rwkv6_time_mix(cfg, p["tm"], x, chunk=8)
    out_r = rwkv6_time_mix_ref(cfg, p["tm"], x)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r), rtol=2e-4, atol=2e-4)


def test_rwkv6_decode_matches_full(rng):
    cfg = get_smoke("rwkv6-3b")
    p = init_params(rwkv6_metas(cfg), jax.random.PRNGKey(2), jnp.float32)
    S = 10
    x = jnp.asarray(0.3 * rng.normal(size=(1, S, cfg.d_model)), jnp.float32)
    full = rwkv6_time_mix(cfg, p["tm"], x, chunk=4)
    cache = rwkv6_init_cache(cfg, batch=1)
    outs = []
    for t in range(S):
        o, cache = rwkv6_decode(cfg, p, x[:, t : t + 1], cache)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), rtol=2e-4, atol=2e-4)
