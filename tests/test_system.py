"""End-to-end behaviour tests for the AMS system (paper Algorithm 1 loop)."""
import jax
import numpy as np
import pytest

from repro.core.delta import apply_delta
from repro.core.server import AMSConfig, AMSSession, Task
from repro.data.video import OracleTeacher, SyntheticVideo, VideoConfig
from repro.models.seg.student import SegConfig, make_student, seg_loss
from repro.sim.seg_world import SegWorld, phi_pixel_loss


@pytest.fixture(scope="module")
def world():
    vcfg = VideoConfig(height=32, width=32, fps=4.0, duration=40.0, seed=3)
    return SegWorld.make(vcfg)


def test_ams_session_trains_and_streams(world):
    params = make_student(world.seg_cfg, jax.random.PRNGKey(0))
    cfg = AMSConfig(t_update=5.0, t_horizon=20.0, k_iters=4, batch_size=4, gamma=0.05)
    task = Task(loss_and_grad=world.loss_and_grad, teacher=None, phi_loss=phi_pixel_loss)
    sess = AMSSession(task, cfg, params, seed=0)

    # feed 8 labeled frames, run two phases
    frames = [world.video.frame(i)[0] for i in range(8)]
    labels = [world.teacher.label(i) for i in range(8)]
    sess.receive_labeled(np.stack(frames[:4]), np.stack(labels[:4]), t_now=4.0)
    d1 = sess.train_phase(5.0)
    sess.receive_labeled(np.stack(frames[4:]), np.stack(labels[4:]), t_now=9.0)
    d2 = sess.train_phase(10.0)

    assert d1 is not None and d2 is not None
    assert sess.phase == 2
    # sparse update: ~gamma of params at fp16 + gzip'd bitmask
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    assert d1.values.size == pytest.approx(cfg.gamma * n, rel=0.15)
    assert d1.value_bytes == d1.values.size * 2
    assert 0 < d1.mask_bytes < n / 8  # gzip'd bit-vector beats raw bits

    # client applies deltas and converges toward server params (fp16 rounding)
    client = apply_delta(apply_delta(params, d1), d2)
    sp = np.concatenate([np.ravel(l) for l in jax.tree.leaves(sess.params)])
    cp = np.concatenate([np.ravel(l) for l in jax.tree.leaves(client)])
    np.testing.assert_allclose(cp, sp, atol=2e-3)

    # loss on the buffered window decreased vs the initial model
    fr, lb = np.stack(frames), np.stack(labels)
    l0, _ = world.loss_and_grad(params, fr, lb)
    l1, _ = world.loss_and_grad(sess.params, fr, lb)
    assert float(l1) < float(l0)


def test_masked_update_touches_only_masked_coords(world):
    """Coordinates outside I_n must not move (Algorithm 2 line 13)."""
    params = make_student(world.seg_cfg, jax.random.PRNGKey(1))
    cfg = AMSConfig(t_update=5.0, t_horizon=20.0, k_iters=3, batch_size=2, gamma=0.05,
                    strategy="random")
    task = Task(loss_and_grad=world.loss_and_grad, teacher=None, phi_loss=phi_pixel_loss)
    sess = AMSSession(task, cfg, params, seed=0)
    frames = np.stack([world.video.frame(i)[0] for i in range(4)])
    labels = np.stack([world.teacher.label(i) for i in range(4)])
    sess.receive_labeled(frames, labels, t_now=1.0)
    mask = sess._select_mask()
    # run the phase manually with the captured mask
    from repro.core.masked_adam import masked_adam_update

    p, opt = params, sess.opt_state
    for _ in range(3):
        b = sess.buffer.sample(sess.rng, 2, 2.0)
        _, g = world.loss_and_grad(p, *b)
        p, opt, _ = masked_adam_update(p, g, opt, mask, lr=1e-3)
    for leaf0, leaf1, m in zip(jax.tree.leaves(params), jax.tree.leaves(p),
                               jax.tree.leaves(mask)):
        unmasked = ~np.asarray(m)
        np.testing.assert_array_equal(np.asarray(leaf0)[unmasked],
                                      np.asarray(leaf1)[unmasked])
