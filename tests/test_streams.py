"""Dual-stream device model: StreamModel math, per-stream charge/truncate
invariants, bit-identical serialized defaults vs the PR-3 engine, preemption
conservation, cost-aware coalesce, and heterogeneous-pool placement."""
import pytest
from _hyp import given, settings, st  # hypothesis, or a fallback when absent

from repro.core.scheduler import GPUCostModel
from repro.serving import (
    Assignment,
    ClientNetwork,
    GPUPool,
    GPURequest,
    LinkSpec,
    MigrationModel,
    ServingConfig,
    ServingEngine,
    StreamModel,
    StubSession,
    make_policy,
)

# ---------------- StreamModel ----------------


def test_stream_model_validation():
    assert StreamModel().legacy  # the PR-3 single clock is the default
    assert not StreamModel(preempt=True).legacy
    assert not StreamModel(mode="overlap").legacy
    assert StreamModel(mode="overlap").overlapped
    with pytest.raises(ValueError):
        StreamModel(mode="concurrent")
    with pytest.raises(ValueError):
        StreamModel(slowdown=0.5)
    with pytest.raises(ValueError):
        StreamModel(preempt_cost_s=-1.0)


def test_finish_time_piecewise():
    # serialized / uncontended: plain addition
    assert StreamModel().finish_time(2.0, 3.0, 10.0) == pytest.approx(5.0)
    m = StreamModel(mode="overlap", slowdown=2.0)
    # other stream idle: full rate
    assert m.finish_time(5.0, 3.0, 4.0) == pytest.approx(8.0)
    # fully contended: 1 s of work takes slowdown seconds
    assert m.finish_time(0.0, 1.0, 100.0) == pytest.approx(2.0)
    # partially contended: 2 s at half rate (1 s of work), rest at full
    assert m.finish_time(0.0, 3.0, 2.0) == pytest.approx(4.0)
    # full overlap: no stretch at slowdown=1
    assert StreamModel(mode="overlap").finish_time(0.0, 3.0, 100.0) == 3.0


def test_stream_demand_interpolates():
    ser = StreamModel()
    assert ser.stream_demand_s(1.3, 1.0) == pytest.approx(2.3)
    full = StreamModel(mode="overlap", slowdown=1.0)
    assert full.stream_demand_s(1.3, 1.0) == pytest.approx(1.3)
    mid = StreamModel(mode="overlap", slowdown=2.0)
    assert 1.3 < mid.stream_demand_s(1.3, 1.0) < 2.3
    # slowdown -> inf approaches the serialized sum
    assert StreamModel(mode="overlap", slowdown=1e9).stream_demand_s(
        1.3, 1.0) == pytest.approx(2.3, rel=1e-6)


# ---------------- pool stream clocks ----------------


def test_charge_serialized_mutually_excludes():
    pool = GPUPool(1, streams=StreamModel(preempt=True))
    a = pool.charge(0, "label", 0.0, 2.0)
    b = pool.charge(0, "train", 0.0, 1.0)
    c = pool.charge(0, "label", 0.5, 1.0)
    assert a == (0.0, 2.0)
    assert b == (2.0, 3.0)  # serialized: waits for the label stream
    assert c == (3.0, 4.0)  # and the next label launch waits for the train
    assert pool.device(0).overlap_s() == 0.0


def test_charge_overlap_runs_concurrently_with_slowdown():
    pool = GPUPool(1, streams=StreamModel(mode="overlap", slowdown=2.0))
    a = pool.charge(0, "label", 0.0, 4.0)
    b = pool.charge(0, "train", 0.0, 1.0)
    assert a == (0.0, 4.0)
    # starts immediately; 1 s of work at half rate inside the label window
    assert b == (0.0, 2.0)
    assert pool.device(0).overlap_s() == pytest.approx(2.0)
    # per-stream accounting is wall-clock occupancy
    assert pool.device(0).stream_busy_s("label", 100.0) == pytest.approx(4.0)
    assert pool.device(0).stream_busy_s("train", 100.0) == pytest.approx(2.0)
    assert pool.device(0).union_busy_s(100.0) == pytest.approx(4.0)


def test_label_bounds_and_truncate():
    pool = GPUPool(1, streams=StreamModel(preempt=True, preempt_cost_s=0.5))
    start, bounds = pool.label_bounds(0, 0.0, [1.0, 2.0, 4.0])
    assert start == 0.0 and bounds == [1.0, 2.0, 4.0]
    assert pool.stream_free_at(0, "label") == 4.0
    free = pool.truncate_label(0, 2.0, preempted_frames=7)
    assert free == pytest.approx(2.5)  # cut + preemption cost
    assert pool.preemptions == 1 and pool.preempted_frames == 7
    assert pool.preempt_s_total == pytest.approx(0.5)
    # a cancelled (never-started) launch is removed outright, free of charge
    start, bounds = pool.label_bounds(0, 10.0, [1.0])
    assert start == 10.0
    free = pool.truncate_label(0, start, preempted_frames=0, cancel=True)
    assert free == pytest.approx(2.5)
    assert pool.preemptions == 1  # cancels are not preemptions


def test_train_ready_wait_respects_stream_model():
    ser = GPUPool(1, streams=StreamModel(preempt=True))
    ser.charge(0, "label", 0.0, 3.0)
    assert ser.train_ready_wait_s(0, 1.0) == pytest.approx(2.0)
    ovl = GPUPool(1, streams=StreamModel(mode="overlap"))
    ovl.charge(0, "label", 0.0, 3.0)
    assert ovl.train_ready_wait_s(0, 1.0) == 0.0  # label stream irrelevant
    ovl.charge(0, "train", 0.0, 2.0)
    assert ovl.train_ready_wait_s(0, 1.0) == pytest.approx(1.0)


# ---------------- engine fleets ----------------


def _fleet(n, link=None, **kw):
    link = link or LinkSpec(up_kbps=500.0, down_kbps=1000.0)
    return [StubSession(i, rate=0.15 if i < 2 else 1.0,
                        dynamics=0.0005 if i < 2 else 0.004,
                        net=ClientNetwork(link), **kw)
            for i in range(n)]


# ---------------- serialized default == PR-3, bit for bit ----------------

# Captured from the tree at the PR-3 commit (cacaae0), before the stream
# refactor: a fused single-GPU fair run and an unfused 2-GPU gain run (the
# multi-GPU *fused* configs are deliberately not pinned — the cost-aware
# coalesce satellite changes rider admission there by design).
_PR3_GOLD = {
    "fused_g1_fair": dict(
        cfg=dict(duration=180.0, max_queue=8, fuse_train=4), policy="fair",
        want={"mean_miou": 0.8843761416388888,
              "gpu_utilization": 0.9123439111111111,
              "phases_served": 102, "phases_deferred": 92,
              "dropped_requests": 0,
              "mean_up_kbps": 38.14897777777778,
              "mean_down_kbps": 15.111111111111112,
              "delta_latency_mean_s": 0.20999999999999938,
              "labels_total": 732, "label_batches": 34,
              "max_backlog": 5, "events_processed": 1846,
              "fused_launches": 24, "fused_sessions": 82,
              "rider_grants": 58, "migrations": 0,
              "migration_s_total": 0.0}),
    "unfused_g2_gain": dict(
        cfg=dict(duration=180.0, max_queue=8, n_gpus=2), policy="gain",
        want={"mean_miou": 0.8853762615666668,
              "gpu_utilization": 0.5445833333333331,
              "phases_served": 102, "phases_deferred": 68,
              "dropped_requests": 0,
              "mean_up_kbps": 38.14897777777778,
              "mean_down_kbps": 15.111111111111112,
              "delta_latency_mean_s": 0.2099999999999989,
              "labels_total": 732, "label_batches": 51,
              "max_backlog": 4, "events_processed": 1904,
              "migrations": 0, "migration_s_total": 0.0}),
}


def test_default_streams_bit_identical_to_pr3():
    """The default (serialized, no-preemption) stream model must reproduce
    the PR-3 single-busy-clock engine bit-for-bit — golden numbers captured
    before the refactor, and an *explicit* serialized StreamModel must be
    indistinguishable from the default."""
    for name, spec in _PR3_GOLD.items():
        r = ServingEngine(_fleet(6), policy=spec["policy"],
                          cfg=ServingConfig(**spec["cfg"])).run()
        for k, v in spec["want"].items():
            assert r[k] == v, (name, k, r[k], v)
        assert r["preemptions"] == 0 and r["overlap_s"] == 0.0
        explicit = ServingEngine(
            _fleet(6), policy=spec["policy"],
            cfg=ServingConfig(**spec["cfg"],
                              streams=StreamModel("serialized"))).run()
        drop = ("wall_s", "events_per_sec", "events_per_sec_steady")
        assert ({k: v for k, v in r.items() if k not in drop}
                == {k: v for k, v in explicit.items() if k not in drop})


# ---------------- dual-stream engine invariants ----------------


def _stream_intervals(eng):
    return {(d.gid, s): [(c.start, c.end) for c in d.charges[s]]
            for d in eng.pool.devices for s in ("label", "train")}


def _assert_stream_invariants(eng, horizon):
    for (gid, stream), ivals in _stream_intervals(eng).items():
        for a, b in ivals:
            assert b >= a - 1e-9, (gid, stream, "negative-length charge")
            assert a >= -1e-9, (gid, stream, "work before t=0")
        for (_, e0), (s1, _) in zip(ivals, ivals[1:]):
            # no negative idle: a stream never runs two launches at once
            assert s1 >= e0 - 1e-9, (gid, stream, "stream self-overlap")
    for d in eng.pool.devices:
        assert d.union_busy_s(horizon) <= horizon + 1e-9
        for s in ("label", "train"):
            assert d.stream_busy_s(s, horizon) <= horizon + 1e-9


def test_overlap_engine_overlaps_and_stays_bounded():
    eng = ServingEngine(
        _fleet(8), policy="gain",
        cfg=ServingConfig(duration=180.0, max_queue=32, fuse_train=4,
                          streams=StreamModel("overlap", slowdown=1.1)))
    r = eng.run()
    _assert_stream_invariants(eng, 180.0)
    for s in eng.sessions:  # every phase record names its stream
        assert len(s.phase_streams) == s.phases
        assert all(st == "train" for st in s.phase_streams)
    assert r["overlap_s"] > 0.0  # the two streams really ran concurrently
    su = r["per_gpu_stream_utilization"]
    assert su["label"][0] > 0.0 and su["train"][0] > 0.0
    # concurrency means the union is smaller than the per-stream sum
    assert r["gpu_utilization"] < su["label"][0] + su["train"][0]
    # and buys throughput over the serialized clock on the same fleet
    ser = ServingEngine(
        _fleet(8), policy="gain",
        cfg=ServingConfig(duration=180.0, max_queue=32, fuse_train=4)).run()
    assert r["phases_served"] >= ser["phases_served"]


class _RecordingStub(StubSession):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.ingested_idxs = []
        self.uploaded_idxs = []

    def take_outbox(self):
        out = super().take_outbox()
        self.uploaded_idxs.extend(out)
        return out

    def label_and_ingest(self, idxs, t):
        super().label_and_ingest(idxs, t)
        self.ingested_idxs.extend(idxs)


def test_preemption_conserves_labeled_frames():
    """Preempted labeling launches requeue their remainder: across a run
    with real preemptions no frame is labeled twice and every uploaded
    frame is labeled, still queued, or on a still-cut segment — none
    vanish."""
    fleet = [_RecordingStub(i, rate=1.0, dynamics=0.004,
                            net=ClientNetwork(LinkSpec(up_kbps=500.0,
                                                       down_kbps=1000.0)))
             for i in range(8)]
    eng = ServingEngine(
        fleet, policy="fair",
        cfg=ServingConfig(duration=180.0, max_queue=64,
                          streams=StreamModel("overlap", slowdown=1.1,
                                              preempt=True,
                                              preempt_cost_s=0.02)))
    r = eng.run()
    assert r["preemptions"] > 0 and r["preempted_frames"] > 0
    assert r["dropped_requests"] == 0  # queue sized so nothing is sacrificed
    leftover = {b.req.client: list(b.idxs) for b in eng._queue}
    pending = {}
    for launches in eng._label_sched.values():
        for launch in launches:
            for seg in launch.segs:
                if not seg.done:
                    pending.setdefault(seg.client, []).extend(seg.idxs)
    for s in fleet:
        assert len(s.ingested_idxs) == len(set(s.ingested_idxs)), (
            f"client {s.idx} had frames labeled twice")
        accounted = (len(s.ingested_idxs) + len(leftover.get(s.idx, []))
                     + len(pending.get(s.idx, [])))
        assert accounted == len(s.uploaded_idxs), (
            f"client {s.idx}: {len(s.uploaded_idxs)} uploaded, "
            f"{accounted} accounted for")
    assert r["labels_total"] == sum(len(s.ingested_idxs) for s in fleet)
    _assert_stream_invariants(eng, 180.0)


def test_preemption_splits_inflight_launch_and_speeds_train():
    """A grant whose labels would queue behind a long in-flight labeling
    launch cuts it at the next frame-batch boundary: the phase completes
    strictly earlier than without preemption, the remainder requeues."""
    def run(preempt):
        fleet = _fleet(2)
        eng = ServingEngine(
            fleet, policy="fair",
            cfg=ServingConfig(duration=60.0,
                              streams=StreamModel("serialized",
                                                  preempt=preempt,
                                                  preempt_cost_s=0.05)))
        from repro.serving.engine import _Backlog, _Segment

        # a fat foreign labeling launch is mid-flight on device 0...
        eng._charge_label_launch(
            0, 0.0, [_Segment(client=1, idxs=list(range(40 + 10 * i)))
                     for i in range(3)])
        # ...when client 0's request with fresh frames is granted at t=1
        backlog = _Backlog(req=GPURequest(
            client=0, t_request=1.0, n_frames=4, k_iters=20, deadline=11.0,
            phi=1.0, t_update=10.0), idxs=[0, 1, 2, 3])
        eng._start_service_streams(1.0, backlog, 0, [])
        done = [e for _, _, e in eng.q._heap if e.kind == "gpu_done"]
        return eng, done[0].time

    eng_p, t_preempt = run(True)
    eng_n, t_wait = run(False)
    assert eng_p.pool.preemptions == 1
    assert eng_p.pool.preempted_frames > 0
    assert eng_n.pool.preemptions == 0
    assert t_preempt < t_wait  # the split really unblocked the train phase
    # the requeued remainder is rescheduled, not lost
    live = [seg for l in eng_p._label_sched[0] for seg in l.segs
            if not seg.done]
    assert sum(len(s.idxs) for s in live if s.client == 1) > 0


@settings(max_examples=8, deadline=None)
@given(n=st.integers(2, 8), n_gpus=st.integers(1, 3),
       fuse=st.integers(1, 4), overlapped=st.booleans(),
       preempt=st.booleans(),
       slowdown=st.sampled_from([1.0, 1.2, 2.0]))
def test_stream_engine_property_invariants(n, n_gpus, fuse, overlapped,
                                           preempt, slowdown):
    """Any fleet/pool/stream model: no stream self-overlap, busy clocks
    bounded by the horizon, phases add up, rider accounting holds."""
    sm = StreamModel(mode="overlap" if overlapped else "serialized",
                     slowdown=slowdown if overlapped else 1.0,
                     preempt=preempt, preempt_cost_s=0.02)
    eng = ServingEngine(
        _fleet(n), policy="gain",
        cfg=ServingConfig(duration=90.0, n_gpus=n_gpus, fuse_train=fuse,
                          streams=sm))
    r = eng.run()
    _assert_stream_invariants(eng, 90.0)
    assert sum(r["phases_per_client"]) == r["phases_served"]
    assert r["fused_sessions"] - r["fused_launches"] == r["rider_grants"]
    assert r["preempted_frames"] >= 0 and r["preempt_s_total"] >= 0.0
    if not sm.overlapped:
        assert r["overlap_s"] == 0.0


# ---------------- cost-aware coalesce ----------------


def _req(client, t_request=0.0, k_iters=20, state_bytes=0, phi=1.0):
    return GPURequest(client=client, t_request=t_request, n_frames=4,
                      k_iters=k_iters, deadline=10.0, phi=phi, t_update=10.0,
                      state_bytes=state_bytes)


def test_coalesce_accepts_rider_when_discount_beats_migration():
    """ROADMAP follow-on: a rider with *nonzero* staging cost joins the
    stack when the fused discount exceeds its migration time; an expensive
    one still cannot."""
    pool = GPUPool(2, migration=MigrationModel(gbps=1.0, setup_s=0.1))
    pool.grant(0, client=0, t=0.0, dur_s=0.1, horizon_s=100.0)
    pool.release(0)
    pool.grant(1, client=1, t=0.0, dur_s=0.1, horizon_s=100.0)
    pool.release(1)
    pool.grant(1, client=2, t=0.0, dur_s=0.1, horizon_s=100.0)
    pool.release(1)
    p = make_policy("fair")
    granted = Assignment(req=_req(0), gpu=0)
    cost = GPUCostModel()
    # client 1 resident on device 1 with a cheap state: migration 0.1 s +
    # a few ms of bytes < the ~0.5 s solo-vs-marginal fused saving
    cheap, dear = _req(1, state_bytes=10**6), _req(2, state_bytes=10**9)
    saving = (20 * cost.train_iter_s
              - (cost.train_batch_s(2, 20) - cost.train_batch_s(1, 20)))
    assert pool.migration_s(1, 0, cheap.state_bytes) < saving
    assert pool.migration_s(2, 0, dear.state_bytes) > saving
    riders = p.coalesce(1.0, granted, [cheap, dear], pool, max_fuse=4)
    assert [r.client for r in riders] == [1]
    # zero-cost riders are always taken, exactly the PR-3 rule
    resident = _req(1, state_bytes=10**9)
    pool2 = GPUPool(1)
    assert p.coalesce(1.0, granted, [resident], pool2, 4) == [resident]


def test_engine_charges_rider_migration():
    """A cost-aware rider's staging is real: the grant runs longer by the
    rider's migration time and the move lands in the pool telemetry."""
    from repro.serving.engine import _Backlog

    def serve(foreign):
        eng = ServingEngine(
            _fleet(4), policy="fair",
            cfg=ServingConfig(duration=120.0, n_gpus=2, fuse_train=2))
        if foreign:
            # client 1's state lives on device 1; riding client 0's grant
            # on device 0 must stage it across
            eng.pool.grant(1, client=1, t=0.0, dur_s=0.1, horizon_s=120.0)
            eng.pool.release(1)
        primary = _Backlog(req=_req(0), idxs=[0, 1])
        rider = _Backlog(req=_req(1, state_bytes=1_000_000), idxs=[2, 3])
        eng._start_service(5.0, primary, 0, [rider])
        done = [e for _, _, e in eng.q._heap if e.kind == "gpu_done"]
        return eng, done[0].time

    eng_free, t_free = serve(False)  # first-touch rider: stages for free
    assert eng_free.pool.migrations == 0
    eng_paid, t_paid = serve(True)
    assert eng_paid.pool.migrations == 1
    assert eng_paid.pool.migration_s_total > 0.0
    # the staging time is on the granting device's clock: gpu_done shifts
    assert t_paid == pytest.approx(
        t_free + eng_free.pool.migration.transfer_s(1_000_000))


# ---------------- heterogeneous pools: cost-aware placement ----------------


def test_affinity_prefers_cheaper_device_on_heterogeneous_pool():
    fast = GPUCostModel()
    slow = GPUCostModel(teacher_infer_s=0.5, train_iter_s=0.15)
    pool = GPUPool(costs=[slow, fast])
    p = make_policy("affinity")
    got = p.assign(0.0, [_req(0)], [0, 1], pool)
    assert got[0].gpu == 1  # affinity-blind would take device 0
    # a session resident on the slow device with a big state stays put...
    pool2 = GPUPool(costs=[slow, fast],
                    migration=MigrationModel(gbps=1.0, setup_s=0.5))
    pool2.grant(0, client=0, t=0.0, dur_s=0.1, horizon_s=100.0)
    pool2.release(0)
    heavy = _req(0, state_bytes=10**9)  # 8.5 s move >> phase-time gap
    assert p.assign(5.0, [heavy], [0, 1], pool2)[0].gpu == 0
    # ...but migrates to the fast device once the move is cheap enough
    light = _req(0, state_bytes=10**6)
    assert p.assign(5.0, [light], [0, 1], pool2)[0].gpu == 1


def test_affinity_stream_backlog_steers_placement():
    """Dual-stream path: a device whose streams defer training is taxed in
    the joint (request, device) score."""
    pool = GPUPool(2, streams=StreamModel(preempt=True))
    pool.charge(0, "label", 0.0, 5.0)  # device 0's clock is claimed
    p = make_policy("affinity")
    assert p.assign(0.0, [_req(0)], [0, 1], pool)[0].gpu == 1
    # legacy pools never report stream backlog: placement unchanged
    legacy = GPUPool(2)
    assert legacy.train_ready_wait_s(0, 0.0) == 0.0


# ---------------- run_multiclient shim ----------------


def test_run_multiclient_streams_kwarg():
    import jax
    import numpy as np

    from repro.core.server import AMSConfig
    from repro.models.seg.student import SegConfig, make_student
    from repro.sim.multiclient import run_multiclient

    seg = SegConfig(n_classes=5)
    pre = make_student(seg, jax.random.PRNGKey(0))
    ams = AMSConfig(t_update=8.0, t_horizon=30.0, k_iters=2, batch_size=2,
                    gamma=0.05, lr=2e-3, phi_target=0.15)
    r = run_multiclient(3, pre, seg, ams, duration=25.0,
                        video_kw=dict(height=24, width=24, fps=2.0),
                        fuse_train=2,
                        streams=StreamModel("overlap", slowdown=1.1,
                                            preempt=True,
                                            preempt_cost_s=0.02))
    assert r["stream_mode"] == "overlap"
    assert np.isfinite(r["mean_miou"])
    assert r["phases_served"] > 0


# ---------------- preemptability-aware train_ready_wait_s ----------------


def test_train_ready_wait_models_preemptability():
    """A preemptible label launch no longer taxes placement with its full
    tail: the modeled wait is bounded by the next frame-batch boundary plus
    the preemption charge, while a no-preempt pool still reports the
    serialized upper bound."""
    def pool_with_launch(preempt):
        pool = GPUPool(1, streams=StreamModel(preempt=preempt,
                                              preempt_cost_s=0.1))
        # one launch, frame batches completing at 1s/2s/6s of solo work
        pool.label_bounds(0, 0.0, [1.0, 2.0, 6.0])
        return pool

    hard = pool_with_launch(False)
    soft = pool_with_launch(True)
    assert hard.train_ready_wait_s(0, 0.5) == pytest.approx(5.5)
    # preemptible: cut at the 1.0 boundary, pay 0.1 -> ready at 1.1
    assert soft.train_ready_wait_s(0, 0.5) == pytest.approx(0.6)
    # between boundaries the next one gates (t=1.5 -> cut lands at 2.1)
    assert soft.train_ready_wait_s(0, 1.5) == pytest.approx(0.6)
    # past the last boundary there is nothing left to reclaim
    assert soft.train_ready_wait_s(0, 6.5) == 0.0
    # a raw charge (no recorded boundaries) keeps the upper bound
    raw = GPUPool(1, streams=StreamModel(preempt=True))
    raw.charge(0, "label", 0.0, 3.0)
    assert raw.train_ready_wait_s(0, 1.0) == pytest.approx(2.0)
    # truncation drops the boundaries the cut removed
    cut = pool_with_launch(True)
    cut.truncate_label(0, 2.0, preempted_frames=3)
    assert all(b <= 2.0 for b in cut.devices[0].label_cuts)


def test_affinity_prefers_preemptible_device():
    """The stream-backlog tax now reflects preemptability: AffinityAware
    steers toward a device whose labeling launch it could cut into (an
    early frame-batch boundary bounds the wait) over one whose raw label
    charge must be waited out — and without preemption the same layout
    falls back to the tie-break (lowest device id)."""
    def pool_with(preempt):
        pool = GPUPool(2, streams=StreamModel(preempt=preempt,
                                              preempt_cost_s=0.05))
        pool.charge(0, "label", 0.0, 4.0)  # device 0: uncuttable charge
        pool.label_bounds(1, 0.0, [0.5, 4.0])  # device 1: boundary at 0.5
        return pool

    p = make_policy("affinity")
    # preemptible: device 1's wait is ~0.55, device 0's is 4.0 -> steer to 1
    assert p.assign(0.0, [_req(0)], [0, 1], pool_with(True))[0].gpu == 1
    # no preemption: both waits are 4.0; the tie-break picks device 0
    assert p.assign(0.0, [_req(0)], [0, 1], pool_with(False))[0].gpu == 0


# ---------------- priority aging on requeued segments ----------------


def test_stream_model_max_seg_preempts_validation():
    with pytest.raises(ValueError):
        StreamModel(max_seg_preempts=0)
    assert StreamModel().max_seg_preempts == 2


def _preempt_scenario(ages):
    """A fat foreign labeling launch mid-flight when a fresh grant lands;
    ``ages`` presets the victim segments' requeue counts."""
    from repro.serving.engine import _Backlog, _Segment
    from repro.serving.policies import GPURequest as Req

    link = LinkSpec(up_kbps=500.0, down_kbps=1000.0)
    fleet = [StubSession(i, rate=1.0, net=ClientNetwork(link))
             for i in range(2)]
    eng = ServingEngine(
        fleet, policy="fair",
        cfg=ServingConfig(duration=60.0,
                          streams=StreamModel("serialized", preempt=True,
                                              preempt_cost_s=0.05)))
    segs = [_Segment(client=1, idxs=list(range(40 + 10 * i)), preempts=age)
            for i, age in enumerate(ages)]
    eng._charge_label_launch(0, 0.0, segs)
    backlog = _Backlog(req=Req(client=0, t_request=1.0, n_frames=4,
                               k_iters=20, deadline=11.0, phi=1.0,
                               t_update=10.0), idxs=[0, 1, 2, 3])
    eng._start_service_streams(1.0, backlog, 0, [])
    return eng, segs


def test_fresh_segments_still_preempt_but_aged_do_not():
    fresh_eng, _ = _preempt_scenario([0, 0, 0])
    assert fresh_eng.pool.preemptions == 1
    aged_eng, segs = _preempt_scenario([0, 2, 2])
    # the tail that a cut would requeue contains twice-preempted batches:
    # they are uncuttable, so the grant waits instead of splitting
    assert aged_eng.pool.preemptions == 0
    assert all(s.preempts == a for s, a in zip(segs, [0, 2, 2]))


def test_requeued_segments_age():
    eng, segs = _preempt_scenario([0, 0, 0])
    requeued = [s for s in segs if s.preempts > 0]
    assert requeued, "the cut tail should have aged"
    assert all(s.preempts == 1 for s in requeued)
