"""Per-architecture smoke tests (assignment requirement): reduced variant of
each family — forward + one train step on CPU, shape + finiteness asserts —
plus decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.core.masked_adam import MaskedAdamState, init_state, masked_adam_update
from repro.models.registry import build

B, S = 2, 16


def _inputs(cfg, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    memory = None
    if cfg.num_xattn_tokens:
        memory = 0.3 * jax.random.normal(rng, (B, cfg.num_xattn_tokens, cfg.d_model))
    return tokens, memory


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    model = build(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    tokens, memory = _inputs(cfg, rng)
    logits, aux = model.forward(params, tokens, memory)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch

    # one masked-Adam train step: loss finite, masked coords move
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if memory is not None:
        batch["memory"] = memory
    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
    assert bool(jnp.isfinite(loss)), arch
    mask = jax.tree.map(lambda p: jnp.ones(p.shape, bool), params)
    p2, opt, u = masked_adam_update(params, grads, init_state(params), mask, lr=1e-3)
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert moved > 0
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(p2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_consistency(arch):
    """prefill + decode_step must reproduce the parallel forward logits.
    MoE archs use dropless capacity here: capacity drops legitimately differ
    between a (B*S)-token dispatch and a B-token decode dispatch."""
    cfg = get_smoke(arch)
    if cfg.num_experts:
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts))
    model = build(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    tokens, memory = _inputs(cfg, rng)

    full_logits, _ = model.forward(params, tokens, memory)
    cache_len = S + 4
    pre_logits, caches = model.prefill(params, tokens[:, : S - 2], cache_len, memory)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0]),
        np.asarray(model.forward(params, tokens[:, : S - 2], memory)[0][:, -1]),
        rtol=2e-3, atol=2e-3,
    )
    # decode the last two tokens and compare against the parallel forward
    logits = None
    for i in range(S - 2, S):
        logits, caches = model.decode_step(params, caches, tokens[:, i : i + 1],
                                           jnp.int32(i))
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, -1]), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact published dimensions."""
    expected = {
        "gemma2_9b": dict(num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
                          d_ff=14336, vocab_size=256000),
        "zamba2_7b": dict(num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
                          d_ff=14336, vocab_size=32000, ssm_state=64),
        "llama32_vision_90b": dict(num_layers=100, d_model=8192, num_heads=64,
                                   num_kv_heads=8, d_ff=28672, vocab_size=128256),
        "whisper_large_v3": dict(num_layers=32, d_model=1280, num_heads=20,
                                 num_kv_heads=20, d_ff=5120, vocab_size=51866),
        "gemma_2b": dict(num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
                         d_ff=16384, vocab_size=256000, head_dim=256),
        "moonshot_v1_16b_a3b": dict(num_layers=48, d_model=2048, num_heads=16,
                                    num_kv_heads=16, vocab_size=163840,
                                    num_experts=64, experts_per_token=6),
        "rwkv6_3b": dict(num_layers=32, d_model=2560, d_ff=8960, vocab_size=65536),
        "mixtral_8x22b": dict(num_layers=56, d_model=6144, num_heads=48,
                              num_kv_heads=8, vocab_size=32768, num_experts=8,
                              experts_per_token=2, expert_d_ff=16384),
        "llama3_405b": dict(num_layers=126, d_model=16384, num_heads=128,
                            num_kv_heads=8, d_ff=53248, vocab_size=128256),
        "llama4_maverick_400b_a17b": dict(num_layers=48, d_model=5120, num_heads=40,
                                          num_kv_heads=8, vocab_size=202048,
                                          num_experts=128, experts_per_token=1),
    }[arch]
    cfg = get_config(arch)
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert cfg.source  # every config cites its source
