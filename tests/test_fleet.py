"""Fleet control plane (PR 9): the struct-of-arrays `FleetState` path must
reproduce the per-object engine bit-for-bit — same results dict (minus
wall-clock fields), byte-identical traces — across policies, pool sizes,
admission parking, stream models and the chaos fault injector; cohort
events must preserve the queue's (time, seq) semantics; vectorized policy
``rank`` must order exactly like repeated per-object ``pick``; and the
O(1)-memory ``moments`` telemetry must agree with full telemetry to float
tolerance."""
import dataclasses

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a fallback when absent

from repro.serving import (
    ClientNetwork,
    CrashWindow,
    EventQueue,
    FaultPlan,
    FleetState,
    GPURequest,
    LinkSpec,
    ServingConfig,
    ServingEngine,
    StreamModel,
    StubSession,
    Tracer,
    make_policy,
)

# results fields that legitimately differ run-to-run (wall clock) or by
# representation (observability carries measured stage timings)
DROP = ("wall_s", "events_per_sec", "events_per_sec_steady", "observability")


def _core(r: dict) -> dict:
    return {k: v for k, v in r.items() if k not in DROP}


def _stub_fleet(n: int, telemetry: str = "full") -> list[StubSession]:
    link = LinkSpec(up_kbps=500.0, down_kbps=2000.0)
    out = []
    for i in range(n):
        static = i < n // 3
        out.append(StubSession(
            i,
            rate=0.15 if static else 1.0,
            dynamics=0.0005 if static else 0.004,
            net=ClientNetwork(link),
            telemetry=telemetry,
        ))
    return out


def _fleet_state(n: int, telemetry: str = "full") -> FleetState:
    static = np.arange(n) < n // 3
    return FleetState(
        n,
        rate=np.where(static, 0.15, 1.0),
        dynamics=np.where(static, 0.0005, 0.004),
        up_kbps=500.0, down_kbps=2000.0,
        telemetry=telemetry,
    )


def _pair(n: int, policy: str = "fair", duration: float = 40.0,
          telemetry: str = "full", tracers: bool = False, **cfg_kw):
    cfg = ServingConfig(duration=duration, max_queue=16, **cfg_kw)
    t1 = Tracer() if tracers else None
    t2 = Tracer() if tracers else None
    r_obj = ServingEngine(_stub_fleet(n), policy=policy, cfg=cfg,
                          tracer=t1).run()
    r_fl = ServingEngine(_fleet_state(n, telemetry=telemetry), policy=policy,
                         cfg=cfg, tracer=t2).run()
    return r_obj, r_fl, t1, t2


# ---------------- bit-identical equivalence ----------------


@pytest.mark.parametrize("policy", ["fair", "edf", "gain"])
@pytest.mark.parametrize("n_gpus", [1, 3])
def test_fleet_matches_per_object(policy, n_gpus):
    r_obj, r_fl, _, _ = _pair(12, policy=policy, n_gpus=n_gpus)
    assert _core(r_obj) == _core(r_fl)


def test_fleet_matches_under_admission_parking():
    # cap low enough that the gain-aware parking actually rejects sessions
    r_obj, r_fl, _, _ = _pair(12, policy="gain", n_gpus=2,
                              admission_util_cap=0.5)
    assert r_obj["admitted_clients"] < 12  # the cap must actually bind
    assert _core(r_obj) == _core(r_fl)


def test_fleet_matches_with_fused_training():
    r_obj, r_fl, _, _ = _pair(12, policy="gain", n_gpus=2, fuse_train=4,
                              admission_util_cap=0.8)
    assert _core(r_obj) == _core(r_fl)


def test_fleet_matches_with_stream_overlap():
    streams = StreamModel(mode="overlap", slowdown=1.3, preempt=True)
    r_obj, r_fl, _, _ = _pair(10, policy="gain", n_gpus=2, streams=streams)
    assert _core(r_obj) == _core(r_fl)


def test_fleet_matches_with_rate_ctrl_messages():
    r_obj, r_fl, _, _ = _pair(10, policy="fair", asr_ctrl_bytes=64)
    assert _core(r_obj) == _core(r_fl)


def test_fleet_matches_under_lossy_links():
    plan = FaultPlan(seed=7, up_loss=0.1, down_loss=0.05, max_retries=2)
    r_obj, r_fl, _, _ = _pair(10, policy="gain", n_gpus=2, faults=plan)
    assert r_obj["chaos"]["upload_retries"] > 0  # the plan must actually bite
    assert _core(r_obj) == _core(r_fl)


def test_fleet_matches_through_device_crash():
    plan = dataclasses.replace(
        FaultPlan(seed=3, up_loss=0.05),
        crashes=(CrashWindow(gid=1, start=15.0, end=30.0),))
    r_obj, r_fl, _, _ = _pair(10, policy="gain", n_gpus=2, faults=plan,
                              duration=60.0)
    assert _core(r_obj) == _core(r_fl)


def test_fleet_trace_bytes_identical():
    # the flight recorder forces the scalar lane per cohort; the bytes it
    # writes must be indistinguishable from a per-object run
    r_obj, r_fl, t1, t2 = _pair(8, policy="gain", n_gpus=2, tracers=True,
                                faults=FaultPlan.none())
    assert _core(r_obj) == _core(r_fl)
    assert t1.to_json() == t2.to_json()


# ---------------- cohort event queue ----------------


def test_push_many_pops_like_repeated_push():
    items = [(3.0, "a", 1, None), (1.0, "b", 2, None), (1.0, "c", 3, None),
             (2.0, "d", 4, None), (1.0, "e", 5, None)]
    q1, q2 = EventQueue(), EventQueue()
    for t, k, c, p in items:
        q1.push(t, k, c, p)
    q2.push_many(items)
    got1 = [(e.time, e.seq, e.kind) for e in (q1.pop() for _ in range(5))]
    got2 = [(e.time, e.seq, e.kind) for e in (q2.pop() for _ in range(5))]
    assert got1 == got2


def test_push_many_after_existing_heap():
    # exercise both branches of the heapify-vs-push heuristic
    q = EventQueue()
    for i in range(64):
        q.push(float(i), "seed")
    q.push_many([(0.5, "small", None, None)])  # small batch: sift-up path
    q.push_many([(float(i) + 0.25, "bulk", None, None)
                 for i in range(64)])  # large batch: heapify path
    times = []
    while q:
        times.append(q.pop().time)
    assert times == sorted(times)


def test_pop_batch_drains_min_timestamp_in_seq_order():
    q = EventQueue()
    q.push(2.0, "later")
    q.push(1.0, "a")
    q.push(1.0, "b")
    q.push(1.0, "c")
    batch = q.pop_batch()
    assert [e.kind for e in batch] == ["a", "b", "c"]
    assert q.peek_time() == 2.0


def test_cohort_events_count_logical_multiplicity():
    q = EventQueue()
    cohort = np.arange(5, dtype=np.int64)
    ev = q.push(1.0, "sample", cohort)
    assert ev.n == 5
    assert q.pushed == 5
    q.push(1.0, "eval", 3)  # scalar rides the same timestamp
    batch = q.pop_batch()
    assert len(batch) == 2  # two heap entries...
    assert q.popped == 6  # ...but six logical events


# ---------------- vectorized rank vs per-object pick ----------------


def _requests(rng, n):
    return [GPURequest(client=i, t_request=float(rng.uniform(0, 10)),
                       n_frames=1, k_iters=20,
                       deadline=float(rng.uniform(10, 30)),
                       phi=float(rng.uniform(0.1, 1.5)),
                       t_update=float(rng.choice([5.0, 10.0, 20.0])))
            for i in range(n)]


@pytest.mark.parametrize("policy", ["fair", "edf", "gain"])
@pytest.mark.parametrize("limit", [1, 3, 8])
def test_rank_orders_exactly_like_repeated_pick(policy, limit):
    rng = np.random.default_rng(hash(policy) % 2**32)
    reqs = _requests(rng, 8)
    t_now = 12.0

    p_pick = make_policy(policy)
    ready = list(reqs)
    picked = []
    for _ in range(min(limit, len(ready))):
        r = p_pick.pick(t_now, ready)
        ready.remove(r)
        picked.append(r.client)

    p_rank = make_policy(policy)
    order = p_rank.rank(
        t_now,
        clients=np.array([r.client for r in reqs], dtype=np.int64),
        t_request=np.array([r.t_request for r in reqs]),
        deadline=np.array([r.deadline for r in reqs]),
        phi=np.array([r.phi for r in reqs]),
        t_update=np.array([r.t_update for r in reqs]),
        limit=limit)
    assert [reqs[int(j)].client for j in order] == picked
    if policy == "fair":  # the ring pointer must advance identically
        assert p_rank.turn == p_pick.turn


def test_fair_rank_round_robin_across_calls():
    # the turn pointer carries between batches exactly as with pick
    p = make_policy("fair")
    clients = np.array([0, 1, 2, 3], dtype=np.int64)
    zeros = np.zeros(4)
    first = p.rank(0.0, clients=clients, t_request=zeros, deadline=zeros,
                   phi=zeros, t_update=zeros, limit=2)
    assert [int(clients[j]) for j in first] == [0, 1]
    second = p.rank(0.0, clients=clients, t_request=zeros, deadline=zeros,
                    phi=zeros, t_update=zeros, limit=2)
    assert [int(clients[j]) for j in second] == [2, 3]


# ---------------- telemetry modes ----------------


def test_stub_moments_telemetry_matches_full_to_tolerance():
    cfg = ServingConfig(duration=40.0, max_queue=16)
    r_full = ServingEngine(_stub_fleet(8, "full"), cfg=cfg).run()
    r_mom = ServingEngine(_stub_fleet(8, "moments"), cfg=cfg).run()
    assert r_mom["mean_miou"] == pytest.approx(r_full["mean_miou"], abs=1e-12)
    assert r_mom["delta_latency_mean_s"] == pytest.approx(
        r_full["delta_latency_mean_s"], abs=1e-12)
    assert r_mom["delta_latency_max_s"] == r_full["delta_latency_max_s"]
    assert r_mom["events_processed"] == r_full["events_processed"]


def test_fleet_moments_telemetry_matches_full_to_tolerance():
    cfg = ServingConfig(duration=40.0, max_queue=16)
    r_full = ServingEngine(_fleet_state(8, "full"), cfg=cfg).run()
    r_mom = ServingEngine(_fleet_state(8, "moments"), cfg=cfg).run()
    assert r_mom["mean_miou"] == pytest.approx(r_full["mean_miou"], abs=1e-12)
    assert r_mom["delta_latency_mean_s"] == pytest.approx(
        r_full["delta_latency_mean_s"], abs=1e-12)
    assert r_mom["events_processed"] == r_full["events_processed"]


def test_moments_session_reports_no_per_sample_values():
    s = StubSession(0, telemetry="moments")
    s.evaluate(5.0)
    s.apply_delta(None, 1.0, 2.0)
    assert s.latency_values() is None
    assert s.latency_summary() == (1, 1.0, 1.0)
    assert s.miou_mean() == pytest.approx(0.9 - 0.01 * 5.0)


def test_bad_telemetry_mode_rejected():
    with pytest.raises(ValueError, match="telemetry"):
        StubSession(0, telemetry="verbose")
    with pytest.raises(ValueError, match="telemetry"):
        FleetState(4, telemetry="verbose")


# ---------------- tracer fleet-size guard ----------------


def test_tracer_refuses_huge_fleets():
    with pytest.raises(ValueError, match="refusing to trace"):
        ServingEngine(_fleet_state(5), tracer=Tracer(max_clients=4),
                      cfg=ServingConfig(duration=1.0))
    # opting in raises the cap
    ServingEngine(_fleet_state(5), tracer=Tracer(max_clients=8),
                  cfg=ServingConfig(duration=1.0))


# ---------------- property: equivalence over random configs ----------------


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=2, max_value=14),
       n_gpus=st.integers(min_value=1, max_value=3),
       policy=st.sampled_from(["fair", "edf", "gain"]),
       capped=st.booleans())
def test_fleet_equivalence_property(n, n_gpus, policy, capped):
    cap = 0.6 if capped else None
    r_obj, r_fl, _, _ = _pair(n, policy=policy, duration=25.0,
                              n_gpus=n_gpus, admission_util_cap=cap)
    assert _core(r_obj) == _core(r_fl)
