"""Roofline machinery: HLO collective parser + analytic flop validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.masked_adam import MaskedAdamState, init_state
from repro.launch.steps import make_train_step
from repro.models.registry import build
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_terms
from repro.roofline.analytic import ShapeSpec, analytic_cost


def test_collective_parser_flat():
    hlo = """
HloModule test

ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16] parameter(0)
  %ag = f32[16,16] all-gather(%p), replica_groups={}
  %ar = f32[8,16]{1,0} all-reduce(%p), to_apply=%add
  ROOT %out = f32[8,16] add(%ar, %ar)
}
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["totals"]["all-gather"] == 16 * 16 * 4
    assert got["totals"]["all-reduce"] == 8 * 16 * 4
    assert got["counts"]["all-gather"] == 1


def test_collective_parser_scan_aware():
    """A collective inside a while body counts trip-count times."""
    hlo = """
HloModule test

%body.1 (arg: (s32[], f32[4])) -> (s32[], f32[4]) {
  %arg = (s32[], f32[4]) parameter(0)
  %ag = f32[8] all-gather(%gte), replica_groups={}
  ROOT %t = (s32[], f32[4]) tuple(%i, %gte)
}

%cond.1 (arg: (s32[], f32[4])) -> pred[] {
  %arg = (s32[], f32[4]) parameter(0)
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4] parameter(0)
  %w = (s32[], f32[4]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[4] get-tuple-element(%w), index=1
}
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["totals"]["all-gather"] == 7 * 8 * 4
    assert got["counts"]["all-gather"] == 7


def test_analytic_matches_hlo_on_unrolled_smoke():
    """On a small, fully-unrolled, unchunked config the analytic FLOP model
    must track XLA's own count within modeling tolerance."""
    B, S = 2, 64
    cfg = get_smoke("gemma-2b").replace(
        scan_unroll=True, attn_q_chunk=S, attn_kv_chunk=S, remat=False
    )
    model = build(cfg)
    params = model.abstract()
    opt = MaskedAdamState(
        m=params,
        v=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
        count=jax.ShapeDtypeStruct((), jnp.int32),
    )
    mask = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bool_), params)
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    step = make_train_step(model)
    compiled = jax.jit(step).lower(params, opt, mask, batch).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo_flops = float(cost["flops"])
    ana = analytic_cost(cfg, ShapeSpec(kind="train", seq_len=S, global_batch=B))
    assert ana["flops"] == pytest.approx(hlo_flops, rel=0.35)


def test_roofline_terms_bottleneck():
    t = roofline_terms(flops=1e18, hbm_bytes=1e12, collective_bytes=1e12, chips=256)
    assert t["bottleneck"] == "compute"
    assert t["t_compute_s"] > t["t_memory_s"]
    t2 = roofline_terms(flops=1e12, hbm_bytes=1e13, collective_bytes=1e9, chips=256)
    assert t2["bottleneck"] == "memory"
